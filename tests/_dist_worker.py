"""Subprocess worker: distributed HDB on N host devices must match the
single-device reference exactly. Invoked by test_distributed.py with
XLA_FLAGS=--xla_force_host_platform_device_count=8 set in the child env.
"""
import os
import sys

assert "--xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import blocks, hdb, distributed, pairs
from repro.data import synthetic


def key_set(r):
    return set(zip(r.rids.tolist(), r.key_hi.tolist(), r.key_lo.tolist()))


def assert_pairsets_equal(got, want, label):
    assert got.exact == want.exact, label
    assert got.total_slots == want.total_slots, label
    np.testing.assert_array_equal(got.a, want.a, err_msg=label)
    np.testing.assert_array_equal(got.b, want.b, err_msg=label)
    np.testing.assert_array_equal(got.src_size, want.src_size, err_msg=label)


def check_routed_pair_dedupe(mesh_kind, mesh, axes, ref):
    """Routed distributed dedupe must be bit-identical to the numpy oracle
    on this mesh — exact path, budget-exceeded sampled path, and an
    empty-shard layout where whole shards receive no pairs."""
    blk = pairs.build_blocks(ref)
    want = pairs.dedupe_pairs(blk, backend="numpy")
    got = distributed.dedupe_pairs_distributed(blk, mesh, axes,
                                               chunk_per_shard=4096)
    assert_pairsets_equal(got, want, f"routed-exact {mesh_kind}")
    assert len(want.a) > 100, "blocking produced too few pairs to be a real test"

    # the radix shard-local dedupe sort must be bit-identical on the
    # emulated mesh (forces the device path — "auto" is the numpy u64
    # sort on this CPU backend)
    got_r = distributed.dedupe_pairs_distributed(
        blk, mesh, axes, chunk_per_shard=4096, sort_backend="radix")
    assert_pairsets_equal(got_r, want, f"routed-radix {mesh_kind}")

    budget = blk.num_pair_slots // 3
    want_s = pairs.dedupe_pairs(blk, budget=budget, backend="numpy",
                                sample_seed=13)
    got_s = distributed.dedupe_pairs_distributed(
        blk, mesh, axes, budget=budget, chunk_per_shard=1024, sample_seed=13)
    assert not got_s.exact
    assert_pairsets_equal(got_s, want_s, f"routed-sampled {mesh_kind}")

    # empty-shard edge: 1 tiny block => single pair, 7 of 8 shards idle
    one = pairs.Blocks(np.zeros(1, np.uint32), np.zeros(1, np.uint32),
                       np.zeros(1, np.int64), np.array([2], np.int64),
                       np.array([3, 9], np.int64))
    want_e = pairs.dedupe_pairs(one, backend="numpy")
    got_e = distributed.dedupe_pairs_distributed(one, mesh, axes,
                                                 chunk_per_shard=256)
    assert_pairsets_equal(got_e, want_e, f"routed-empty-shard {mesh_kind}")
    print("OK-PAIRS", mesh_kind)


def main(mesh_kind: str):
    corpus = synthetic.generate(synthetic.SyntheticSpec(num_entities=900, seed=11))
    keys, valid = blocks.build_keys(corpus.columns, corpus.blocking)
    # pad N to a multiple of 8 shards
    n = valid.shape[0]
    import jax.numpy as jnp
    pad = (-n) % 8
    if pad:
        keys = jnp.concatenate(
            [keys, jnp.full((pad,) + keys.shape[1:], 0xFFFFFFFF, jnp.uint32)])
        valid = jnp.concatenate([valid, jnp.zeros((pad, valid.shape[1]), bool)])
    cfg = hdb.HDBConfig(max_block_size=40, max_iterations=5)
    ref = hdb.hashed_dynamic_blocking(keys, valid, cfg)

    if mesh_kind == "flat":
        mesh = jax.make_mesh((8,), ("data",))
        axes = ("data",)
    elif mesh_kind == "pod":
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        axes = ("pod", "data")
    else:  # production-style 3-axis
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        axes = ("pod", "data", "model")
    got = distributed.distributed_hashed_dynamic_blocking(
        keys, valid, cfg, mesh, axes)

    ks_ref, ks_got = key_set(ref), key_set(got)
    missing = ks_ref - ks_got
    extra = ks_got - ks_ref
    print(f"ref={len(ks_ref)} got={len(ks_got)} missing={len(missing)} extra={len(extra)}")
    assert len(ks_ref) > 1000, "reference produced too few assignments to be a real test"
    assert not extra, f"distributed produced spurious assignments: {list(extra)[:5]}"
    # bloom false positives may drop assignments; with FPR ~1e-8 expect zero
    assert len(missing) <= 2, f"too many missing: {list(missing)[:5]}"
    for st_r, st_g in zip(ref.stats, got.stats):
        assert st_r.n_surviving_oversized == st_g.n_surviving_oversized, (st_r, st_g)
        assert st_r.n_right_cms == st_g.n_right_cms, (st_r, st_g)
    check_routed_pair_dedupe(mesh_kind, mesh, axes, ref)
    print("OK", mesh_kind)


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "pod")
