"""Subprocess worker: distributed HDB on N host devices must match the
single-device reference exactly. Invoked by test_distributed.py with
XLA_FLAGS=--xla_force_host_platform_device_count=8 set in the child env.
"""
import os
import sys

assert "--xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import blocks, hdb, distributed
from repro.data import synthetic


def key_set(r):
    return set(zip(r.rids.tolist(), r.key_hi.tolist(), r.key_lo.tolist()))


def main(mesh_kind: str):
    corpus = synthetic.generate(synthetic.SyntheticSpec(num_entities=900, seed=11))
    keys, valid = blocks.build_keys(corpus.columns, corpus.blocking)
    # pad N to a multiple of 8 shards
    n = valid.shape[0]
    import jax.numpy as jnp
    pad = (-n) % 8
    if pad:
        keys = jnp.concatenate(
            [keys, jnp.full((pad,) + keys.shape[1:], 0xFFFFFFFF, jnp.uint32)])
        valid = jnp.concatenate([valid, jnp.zeros((pad, valid.shape[1]), bool)])
    cfg = hdb.HDBConfig(max_block_size=40, max_iterations=5)
    ref = hdb.hashed_dynamic_blocking(keys, valid, cfg)

    if mesh_kind == "flat":
        mesh = jax.make_mesh((8,), ("data",))
        axes = ("data",)
    elif mesh_kind == "pod":
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        axes = ("pod", "data")
    else:  # production-style 3-axis
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        axes = ("pod", "data", "model")
    got = distributed.distributed_hashed_dynamic_blocking(
        keys, valid, cfg, mesh, axes)

    ks_ref, ks_got = key_set(ref), key_set(got)
    missing = ks_ref - ks_got
    extra = ks_got - ks_ref
    print(f"ref={len(ks_ref)} got={len(ks_got)} missing={len(missing)} extra={len(extra)}")
    assert len(ks_ref) > 1000, "reference produced too few assignments to be a real test"
    assert not extra, f"distributed produced spurious assignments: {list(extra)[:5]}"
    # bloom false positives may drop assignments; with FPR ~1e-8 expect zero
    assert len(missing) <= 2, f"too many missing: {list(missing)[:5]}"
    for st_r, st_g in zip(ref.stats, got.stats):
        assert st_r.n_surviving_oversized == st_g.n_surviving_oversized, (st_r, st_g)
        assert st_r.n_right_cms == st_g.n_right_cms, (st_r, st_g)
    print("OK", mesh_kind)


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "pod")
