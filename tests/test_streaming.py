"""Streaming incremental blocking: exact batch/stream parity + service API.

The acceptance property: ingesting a corpus in K micro-batches through
``DeltaBlocker`` leaves the BlockStore's candidate-pair ledger EXACTLY
equal (sorted canonical pairs, including largest-block-wins provenance)
to one batch ``hashed_dynamic_blocking`` + ``dedupe_pairs`` run on the
union — for randomized K, key layouts and ``max_block_size``.
"""
import jax
import jax.numpy as jnp
import numpy as np

from _propcheck import given, settings, st

from repro.core import blocks as blocks_mod
from repro.core import hashing, hdb, pairs, sketches
from repro.data import matcher, pipeline, synthetic
from repro.streaming import (BlockStore, DeltaBlocker, RecordBatch,
                             StreamingEngine)

# one config family (static jit arg) reused across examples to bound compiles
_CFGS = {
    3: hdb.HDBConfig(max_block_size=3, max_iterations=5, max_oversize_keys=6,
                     cms_width=1 << 10),
    8: hdb.HDBConfig(max_block_size=8, max_iterations=5, max_oversize_keys=6,
                     cms_width=1 << 10),
    20: hdb.HDBConfig(max_block_size=20, max_iterations=5, max_oversize_keys=6,
                      cms_width=1 << 10),
}


def _random_keys(rng, n, k, card, pvalid=0.85):
    """Random low-cardinality key matrix: shared blocks, over-sized blocks,
    duplicate blocks and intersections all occur."""
    k64 = (rng.integers(0, card, (n, k)).astype(np.uint64)
           * np.uint64(0x9E3779B97F4A7C15))
    valid = rng.random((n, k)) < pvalid
    keys = np.stack([(k64 >> np.uint64(32)).astype(np.uint32),
                     (k64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)], -1)
    keys[~valid] = 0xFFFFFFFF
    h, l, v = blocks_mod.dedupe_row_keys(
        jnp.asarray(keys[..., 0]), jnp.asarray(keys[..., 1]),
        jnp.asarray(valid))
    return np.stack([np.asarray(h), np.asarray(l)], -1), np.asarray(v)


def _batch_reference(keys, valid, cfg):
    res = hdb.hashed_dynamic_blocking(jnp.asarray(keys), jnp.asarray(valid),
                                      cfg)
    blk = pairs.build_blocks(res)
    return (pairs.dedupe_pairs(blk, budget=blk.num_pair_slots + 1),
            pairs.build_blocks(res, min_size=1))


def _ingest_in_parts(keys, valid, cfg, k_parts, rng):
    n = len(keys)
    store = BlockStore(cfg)
    blocker = DeltaBlocker(store)
    if k_parts > 1:
        cuts = np.sort(rng.choice(np.arange(1, n), min(k_parts - 1, n - 1),
                                  replace=False))
        parts = np.split(np.arange(n), cuts)
    else:
        parts = [np.arange(n)]
    reports = []
    for part in parts:
        if len(part):
            reports.append(blocker.ingest_keys(keys[part], valid[part]))
    return store, reports


def _assert_store_matches_batch(store, keys, valid, cfg, tag):
    want, want_blk = _batch_reference(keys, valid, cfg)
    got = store.candidate_pairs()
    np.testing.assert_array_equal(got.a, want.a, err_msg=tag)
    np.testing.assert_array_equal(got.b, want.b, err_msg=tag)
    np.testing.assert_array_equal(got.src_size, want.src_size, err_msg=tag)
    gb = store.accepted_blocks(min_size=1)
    np.testing.assert_array_equal(gb.key_hi, want_blk.key_hi, err_msg=tag)
    np.testing.assert_array_equal(gb.key_lo, want_blk.key_lo, err_msg=tag)
    np.testing.assert_array_equal(gb.size, want_blk.size, err_msg=tag)
    np.testing.assert_array_equal(gb.members, want_blk.members, err_msg=tag)
    return len(want.a)


# ---------------------------------------------------------------------------
# the acceptance property
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000),
       k_parts=st.sampled_from([1, 2, 3, 6]),
       max_block=st.sampled_from(sorted(_CFGS)),
       card=st.sampled_from([12, 30, 60]))
def test_micro_batch_ingest_equals_batch_hdb(seed, k_parts, max_block, card):
    rng = np.random.default_rng(seed)
    cfg = _CFGS[max_block]
    keys, valid = _random_keys(rng, n=160, k=6, card=card)
    store, _ = _ingest_in_parts(keys, valid, cfg, k_parts, rng)
    n_pairs = _assert_store_matches_batch(
        store, keys, valid, cfg,
        f"seed={seed} K={k_parts} mbs={max_block} card={card}")
    assert n_pairs > 0  # layouts must actually exercise the engine


def test_ingest_pair_deltas_reconstruct_ledger():
    """Applying each ingest's (added, retracted) pair deltas in order must
    reproduce the final ledger — the deltas ARE the service's output."""
    rng = np.random.default_rng(77)
    cfg = _CFGS[8]
    keys, valid = _random_keys(rng, n=200, k=6, card=15)
    store, reports = _ingest_in_parts(keys, valid, cfg, 5, rng)
    led = {}
    for rep in reports:
        ra, rb = rep.pairs_retracted
        for x, y in zip(ra, rb):
            del led[(int(x), int(y))]
        aa, ab, asrc = rep.pairs_added
        for x, y, s in zip(aa, ab, asrc):
            assert (int(x), int(y)) not in led
            led[(int(x), int(y))] = int(s)
    got = store.candidate_pairs()
    want = {(int(x), int(y)): int(s)
            for x, y, s in zip(got.a, got.b, got.src_size)}
    # src provenance of surviving pairs may have been updated in-place by a
    # later ingest; compare pair sets exactly and provenance via the store
    assert set(led) == set(want)


def test_query_returns_block_mates():
    rng = np.random.default_rng(3)
    cfg = _CFGS[8]
    keys, valid = _random_keys(rng, n=150, k=6, card=20)
    store, _ = _ingest_in_parts(keys, valid, cfg, 2, rng)
    blocker = DeltaBlocker(store)
    # probe with record 0's own keys: candidates must cover every rid
    # sharing an accepted block with record 0 (including itself)
    res = blocker.query_keys(keys[:1], valid[:1])[0]
    gb = store.accepted_blocks(min_size=1)
    mates = set()
    for bi in range(gb.num_blocks):
        mem = gb.members[gb.start[bi]:gb.start[bi] + gb.size[bi]]
        if 0 in mem:
            mates.update(int(m) for m in mem)
    assert mates <= set(res.candidates.tolist())
    assert res.n_blocks_hit > 0 and len(mates) > 0
    # queries are read-only
    before = store.memory_stats()
    blocker.query_keys(keys[:4], valid[:4])
    assert store.memory_stats() == before


def _probe_oracle(store, rid):
    """Post-ingest truth for one probe record: (co-member set, sizes of
    its accepted blocks that contain at least one other record)."""
    gb = store.accepted_blocks(min_size=1)
    mates, sizes = set(), []
    for bi in range(gb.num_blocks):
        mem = gb.members[gb.start[bi]:gb.start[bi] + gb.size[bi]]
        if rid in mem and len(mem) > 1:
            mates.update(int(m) for m in mem if m != rid)
            sizes.append(len(mem))
    return mates, sorted(sizes)


def _assignment_set(store, drop_rid=None):
    gb = store.accepted_blocks(min_size=1)
    out = set()
    for bi in range(gb.num_blocks):
        for m in gb.members[gb.start[bi]:gb.start[bi] + gb.size[bi]]:
            if m != drop_rid:
                out.add((int(gb.key_hi[bi]), int(gb.key_lo[bi]), int(m)))
    return out


def test_query_include_probe_matches_ingest_oracle():
    """include_probe=True must replay the walk AS IF the probe had been
    ingested: candidates == the probe's post-ingest co-members, and
    block_sizes == its accepted blocks' post-ingest sizes (probe
    counted). Exact whenever ingesting the probe would not re-block any
    OTHER record (the documented cascade caveat — tipping a shared block
    across max_block_size, or a CMS collision flipping a borderline
    estimate); cascading layouts are detected via the oracle store and
    skipped, and at least one clean layout must be verified."""
    cfg = hdb.HDBConfig(max_block_size=8, max_iterations=5,
                        max_oversize_keys=6, cms_width=1 << 16)
    checked = 0
    for seed in range(10):
        rng = np.random.default_rng(seed)
        keys, valid = _random_keys(rng, n=121, k=6, card=18)
        base_k, base_v = keys[:-1], valid[:-1]
        store, _ = _ingest_in_parts(base_k, base_v, cfg, 2, rng)
        # oracle: really ingest the probe into an identical second store
        store2, _ = _ingest_in_parts(base_k, base_v, cfg, 1, rng)
        DeltaBlocker(store2).ingest_keys(keys[-1:], valid[-1:])
        rid = len(base_k)
        if _assignment_set(store) != _assignment_set(store2, drop_rid=rid):
            continue  # probe cascaded into other records: caveat applies
        blocker = DeltaBlocker(store)
        res = blocker.query_keys(keys[-1:], valid[-1:],
                                 include_probe=True)[0]
        res_plain = blocker.query_keys(keys[-1:], valid[-1:])[0]
        mates, sizes = _probe_oracle(store2, rid=rid)
        assert set(res.candidates.tolist()) == mates, seed
        assert list(res.block_sizes) == sizes, seed
        assert len(sizes) > 0, seed  # must actually produce matches
        # the flag's whole point: sizes now count the probe itself
        if res_plain.n_blocks_hit == res.n_blocks_hit:
            np.testing.assert_array_equal(res_plain.block_sizes + 1,
                                          res.block_sizes)
        checked += 1
        if checked >= 3:
            break
    assert checked >= 1, "every layout cascaded; test exercised nothing"


# ---------------------------------------------------------------------------
# record-level service front-end
# ---------------------------------------------------------------------------


def test_streaming_engine_corpus_parity_and_scoring():
    corpus = synthetic.generate(synthetic.SyntheticSpec(num_entities=80,
                                                        seed=11))
    n = corpus.num_records
    cfg = hdb.HDBConfig(max_block_size=25, max_iterations=5,
                        cms_width=1 << 12)
    keys, valid = blocks_mod.build_keys(corpus.columns, corpus.blocking)
    want, _ = _batch_reference(np.asarray(keys), np.asarray(valid), cfg)

    eng = StreamingEngine(corpus.blocking, cfg, ingest_slots=64,
                          matcher_cfg=matcher.MatcherConfig())
    rng = np.random.default_rng(0)
    cuts = np.sort(rng.choice(np.arange(1, n), 3, replace=False))
    for part in np.split(np.arange(n), cuts):
        eng.submit_ingest(RecordBatch.from_corpus(corpus, part))
    eng.submit_query(RecordBatch.from_corpus(corpus, np.array([0])))
    ingests, probes = eng.run()
    got = eng.store.candidate_pairs()
    np.testing.assert_array_equal(got.a, want.a)
    np.testing.assert_array_equal(got.b, want.b)
    # every ingest scored its new pairs straight from the pair buffer
    for ir in ingests:
        if ir.report.num_pairs_added:
            assert ir.match_scores is not None
            assert len(ir.match_scores) == ir.report.num_pairs_added
            # (scores can exceed 1 on duplicate-token records; just sane)
            assert np.all(np.isfinite(ir.match_scores))
            assert np.all(ir.match_scores >= 0)
    assert len(probes) == 1 and probes[0].result.n_blocks_hit > 0


def test_dedup_pipeline_extend_matches_batch():
    corpus = synthetic.generate(synthetic.SyntheticSpec(num_entities=100,
                                                        seed=21))
    n = corpus.num_records
    cfg = hdb.HDBConfig(max_block_size=30, max_iterations=5,
                        cms_width=1 << 12)
    batch = pipeline.dedup_corpus(corpus, cfg, pair_budget=50_000_000)
    pipe = pipeline.DedupPipeline(cfg)
    rng = np.random.default_rng(5)
    cuts = np.sort(rng.choice(np.arange(1, n), 2, replace=False))
    for part in np.split(np.arange(n), cuts):
        rep = pipe.extend(synthetic.corpus_slice(corpus, part))
    assert rep.num_candidate_pairs == batch.num_candidate_pairs
    assert rep.num_matched_pairs == batch.num_matched_pairs
    np.testing.assert_array_equal(rep.component_of, batch.component_of)


# ---------------------------------------------------------------------------
# matcher device-buffer path
# ---------------------------------------------------------------------------


def test_matcher_accepts_device_pair_buffers():
    corpus = synthetic.generate(synthetic.SyntheticSpec(num_entities=40,
                                                        seed=2))
    a = np.array([0, 3, 7, 11, 20], np.int64)
    b = np.array([1, 5, 8, 13, 31], np.int64)
    host = matcher.score_pairs(corpus.columns, a, b)
    dev = matcher.score_pairs(corpus.columns, jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(host, dev)
    # PairSet.pair_buffers: device dedupe path keeps device arrays
    rng = np.random.default_rng(0)
    sizes = rng.integers(2, 9, 40).astype(np.int64)
    start = np.concatenate([[0], np.cumsum(sizes)])[:-1]
    members = np.concatenate(
        [np.sort(rng.choice(300, s, replace=False)) for s in sizes]
    ).astype(np.int64)
    zu = np.zeros(40, np.uint32)
    blk = pairs.Blocks(zu, zu, start, sizes, members)
    big = pairs.Blocks(zu, zu, start, sizes,
                       members + (1 << 24))  # beyond the pack-rid bound
    ps = pairs.dedupe_pairs(big, backend="jax")
    assert ps.device_a is not None
    da, db = ps.pair_buffers()
    np.testing.assert_array_equal(np.asarray(da).astype(np.int64) ,ps.a)
    ps_np = pairs.dedupe_pairs(blk, backend="numpy")
    ha, hb = ps_np.pair_buffers()  # host fallback still yields buffers
    np.testing.assert_array_equal(np.asarray(ha), ps_np.a)


# ---------------------------------------------------------------------------
# numpy mirrors + CMS fold algebra
# ---------------------------------------------------------------------------


def test_np_mirrors_are_bit_exact():
    rng = np.random.default_rng(0)
    k64 = rng.integers(0, 1 << 63, 500, dtype=np.uint64)
    cfg = sketches.CMSConfig(4, 1 << 12)
    hi = (k64 >> np.uint64(32)).astype(np.uint32)
    lo = (k64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    # cms_indices / fingerprint_rid are jit-free by contract (they trace
    # inside jitted callers); eager calls would upload their hash
    # constants implicitly, so call them through jit like callers do
    jidx = np.asarray(jax.jit(sketches.cms_indices, static_argnums=0)(
        cfg, (jnp.asarray(hi), jnp.asarray(lo))))
    np.testing.assert_array_equal(jidx, sketches.np_cms_indices(cfg, k64))
    rid = rng.integers(0, 1 << 31, 500).astype(np.int32)
    fh, fl = jax.jit(hashing.fingerprint_rid)(jnp.asarray(rid))
    want = ((np.asarray(fh).astype(np.uint64) << np.uint64(32))
            | np.asarray(fl))
    np.testing.assert_array_equal(want, hashing.np_fingerprint_rid(rid))


def test_cms_fold_and_subtract_are_exact():
    cfg = sketches.CMSConfig(2, 1 << 8)
    rng = np.random.default_rng(1)
    k64 = rng.integers(0, 50, 300, dtype=np.uint64)
    idx = sketches.np_cms_indices(cfg, k64)
    full = np.zeros((cfg.depth, cfg.width), np.int32)
    for j in range(cfg.depth):
        np.add.at(full[j], idx[j], 1)
    part_a = np.zeros_like(full)
    part_b = np.zeros_like(full)
    for j in range(cfg.depth):
        np.add.at(part_a[j], idx[j][:100], 1)
        np.add.at(part_b[j], idx[j][100:], 1)
    np.testing.assert_array_equal(sketches.cms_fold(part_a, part_b), full)
    np.testing.assert_array_equal(sketches.cms_subtract(full, part_b), part_a)
    assert np.all(sketches.cms_decay(full, 1) == full >> 1)
