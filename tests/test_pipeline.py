"""End-to-end 4-stage dedup pipeline tests + loader determinism."""
import numpy as np
import pytest

from repro.data import components, loader, matcher, pipeline, synthetic
from repro.core import hdb, pairs


@pytest.fixture(scope="module")
def corpus():
    return synthetic.generate(synthetic.SyntheticSpec(num_entities=1200, seed=5))


def test_connected_components_basic():
    lab = components.connected_components(6, np.array([0, 1, 4]), np.array([1, 2, 5]))
    assert lab[0] == lab[1] == lab[2]
    assert lab[4] == lab[5]
    assert lab[3] == 3
    assert lab[0] != lab[4]


def test_connected_components_chain():
    n = 500
    a = np.arange(n - 1)
    b = a + 1
    lab = components.connected_components(n, a, b)
    assert (lab == 0).all()


def test_matcher_scores_duplicates_higher(corpus):
    la, lb = corpus.labeled_pairs(max_pairs=500)
    rng = np.random.default_rng(0)
    ra = rng.integers(0, corpus.num_records, 500)
    rb = rng.integers(0, corpus.num_records, 500)
    nontrivial = corpus.entity_id[ra] != corpus.entity_id[rb]
    pos = matcher.score_pairs(corpus.columns, la, lb)
    neg = matcher.score_pairs(corpus.columns, ra[nontrivial], rb[nontrivial])
    assert pos.mean() > 0.5
    assert neg.mean() < 0.2
    assert pos.mean() - neg.mean() > 0.4


def test_dedup_pipeline_end_to_end(corpus):
    rep = pipeline.dedup_corpus(corpus, hdb.HDBConfig(max_block_size=80))
    q = pipeline.dedup_quality(rep, corpus)
    # planted duplicates should be mostly merged, few false merges
    assert q["pair_recall"] > 0.85
    assert q["pair_precision"] > 0.9
    assert rep.num_survivors < corpus.num_records
    # survivors are one-per-component
    assert rep.num_survivors == len(np.unique(rep.component_of))


def test_loader_deterministic_and_resumable(corpus):
    cfg = loader.LoaderConfig(batch_size=8, seq_len=64, vocab_size=1000)
    ld1 = loader.TokenStreamLoader(corpus, cfg)
    ld2 = loader.TokenStreamLoader(corpus, cfg)
    a1, t1 = ld1.batch(7)
    a2, t2 = ld2.batch(7)  # fresh loader, same step -> identical batch
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(t1, t2)
    # targets are inputs shifted by one
    np.testing.assert_array_equal(a1[:, 1:], t1[:, :-1])


def test_loader_dp_sharding_partitions_batch(corpus):
    cfg = loader.LoaderConfig(batch_size=8, seq_len=32, vocab_size=1000)
    ld = loader.TokenStreamLoader(corpus, cfg)
    full, _ = ld.batch(3)
    shards = [ld.batch(3, dp_rank=r, dp_size=4)[0] for r in range(4)]
    np.testing.assert_array_equal(np.concatenate(shards), full)


def test_pair_bitmap_roundtrip():
    n = 23
    rng = np.random.default_rng(1)
    ii, jj = np.triu_indices(n, 1)
    keep = rng.random(len(ii)) < 0.3
    bm = pairs.build_pair_bitmap(n, ii[keep], jj[keep])
    gi, gj = pairs.read_pair_bitmap(n, bm)
    np.testing.assert_array_equal(gi, ii[keep])
    np.testing.assert_array_equal(gj, jj[keep])


def test_pair_bit_index_is_dense_triangular():
    n = 17
    ii, jj = np.triu_indices(n, 1)
    idx = pairs.pair_bit_index(ii, jj, n)
    assert idx.min() == 0 and idx.max() == n * (n - 1) // 2 - 1
    assert len(np.unique(idx)) == len(idx)
