"""Dryrun-path integration test on an 8-device emulated mesh (subprocess:
device count locks at first jax init in the main test process)."""
import os
import subprocess
import sys

import pytest

WORKER = os.path.join(os.path.dirname(__file__), "_dryrun_worker.py")


@pytest.mark.slow
def test_dryrun_cells_on_small_mesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.pop("REPRO_SAVE_HLO", None)
    proc = subprocess.run([sys.executable, WORKER], capture_output=True,
                          text=True, timeout=1200, env=env)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}"
    assert "ALL-OK" in proc.stdout
