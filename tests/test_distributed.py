"""Distributed HDB == single-device HDB, on 8 emulated host devices.

Runs in a subprocess because device count is locked at first jax init
(the main test process must keep seeing exactly 1 device).
"""
import os
import subprocess
import sys

import pytest

WORKER = os.path.join(os.path.dirname(__file__), "_dist_worker.py")
SHARD_WORKER = os.path.join(os.path.dirname(__file__), "_shard_worker.py")


def _run(mesh_kind, worker=WORKER):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.pop("JAX_PLATFORMS", None)
    proc = subprocess.run(
        [sys.executable, worker, mesh_kind],
        capture_output=True, text=True, timeout=900, env=env)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert f"OK {mesh_kind}" in proc.stdout


@pytest.mark.slow
def test_distributed_matches_reference_flat_mesh():
    _run("flat")


@pytest.mark.slow
def test_distributed_matches_reference_pod_mesh():
    _run("pod")


@pytest.mark.slow
def test_distributed_matches_reference_3axis_mesh():
    _run("3axis")


# ---------------------------------------------------------------------------
# sharded streaming store on the same emulated meshes (tests/_shard_worker.py):
# mesh-routed key-table exchange + distributed ledger sync must be
# bit-identical to the single-host DeltaBlocker and to batch HDB
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_store_matches_reference_flat_mesh():
    _run("flat", worker=SHARD_WORKER)


@pytest.mark.slow
def test_sharded_store_matches_reference_pod_mesh():
    _run("pod", worker=SHARD_WORKER)


@pytest.mark.slow
def test_sharded_store_matches_reference_3axis_mesh():
    _run("3axis", worker=SHARD_WORKER)


@pytest.mark.slow
def test_sharded_store_overflow_fallback_is_loud_and_lossless():
    _run("overflow", worker=SHARD_WORKER)
