"""Sharded streaming store: bit-identity with the single-host path.

The shard contract (docs/STREAMING.md): a ``ShardedBlockStore`` with any
``n_shards`` produces EXACTLY the single-host ``BlockStore``'s ledger,
accepted blocks, and query results after any ingest sequence — and
therefore (by the existing streaming property) exactly one batch HDB run
on the union. These tests drive the host-routing mirror (bit-identical
to the mesh path by construction; the emulated-mesh parity itself runs
in test_distributed.py's slow lane via tests/_shard_worker.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _propcheck import given, settings, st
from test_streaming import (_CFGS, _assert_store_matches_batch,
                            _random_keys)

from repro.core import routing
from repro.serving.service import DedupeService, ServiceConfig
from repro.streaming import BlockStore, DeltaBlocker, ShardedBlockStore
from repro.streaming.shard import ShardRouter


def _ingest_both(keys, valid, cfg, k_parts, rng, n_shards):
    """Same micro-batch schedule into a single-host and a sharded store."""
    n = len(keys)
    ref = BlockStore(cfg)
    st = ShardedBlockStore(cfg, n_shards=n_shards)
    rb, sb = DeltaBlocker(ref), DeltaBlocker(st)
    if k_parts > 1:
        cuts = np.sort(rng.choice(np.arange(1, n), min(k_parts - 1, n - 1),
                                  replace=False))
        parts = np.split(np.arange(n), cuts)
    else:
        parts = [np.arange(n)]
    for part in parts:
        if len(part):
            rb.ingest_keys(keys[part], valid[part])
            sb.ingest_keys(keys[part], valid[part])
    return ref, st, rb, sb


def _assert_stores_identical(ref: BlockStore, st: ShardedBlockStore, tag):
    np.testing.assert_array_equal(st.led_pack, ref.led_pack, err_msg=tag)
    np.testing.assert_array_equal(st.led_src, ref.led_src, err_msg=tag)
    ga, gb = ref.accepted_blocks(1), st.accepted_blocks(1)
    np.testing.assert_array_equal(ga.key_hi, gb.key_hi, err_msg=tag)
    np.testing.assert_array_equal(ga.key_lo, gb.key_lo, err_msg=tag)
    np.testing.assert_array_equal(ga.size, gb.size, err_msg=tag)
    np.testing.assert_array_equal(ga.members, gb.members, err_msg=tag)


# ---------------------------------------------------------------------------
# the sharded acceptance property
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000),
       k_parts=st.sampled_from([1, 3, 6]),
       n_shards=st.sampled_from([1, 4, 8]),
       card=st.sampled_from([12, 30]))
def test_sharded_ingest_equals_single_host_and_batch(seed, k_parts,
                                                     n_shards, card):
    rng = np.random.default_rng(seed)
    cfg = _CFGS[8]
    keys, valid = _random_keys(rng, n=140, k=6, card=card)
    ref, st, _, _ = _ingest_both(keys, valid, cfg, k_parts, rng, n_shards)
    tag = f"seed={seed} K={k_parts} shards={n_shards} card={card}"
    _assert_stores_identical(ref, st, tag)
    n_pairs = _assert_store_matches_batch(st, keys, valid, cfg, tag)
    assert n_pairs > 0


def test_single_shard_degenerates_to_blockstore():
    """n_shards=1 must match today's store down to the per-level tables,
    sketches, and reports — the degeneracy guarantee."""
    rng = np.random.default_rng(5)
    cfg = _CFGS[3]
    keys, valid = _random_keys(rng, n=120, k=5, card=15)
    ref = BlockStore(cfg)
    st = ShardedBlockStore(cfg, n_shards=1)
    rb, sb = DeltaBlocker(ref), DeltaBlocker(st)
    for a, b in ((0, 40), (40, 80), (80, 120)):
        rrep = rb.ingest_keys(keys[a:b], valid[a:b])
        srep = sb.ingest_keys(keys[a:b], valid[a:b])
        np.testing.assert_array_equal(rrep.pairs_added[0],
                                      srep.pairs_added[0])
        np.testing.assert_array_equal(rrep.pairs_added[2],
                                      srep.pairs_added[2])
        np.testing.assert_array_equal(rrep.pairs_retracted[0],
                                      srep.pairs_retracted[0])
        for lr, ls in zip(rrep.levels, srep.levels):
            assert (lr.n_reclassified, lr.n_changed_keys, lr.n_dirty_rows) \
                == (ls.n_reclassified, ls.n_changed_keys, ls.n_dirty_rows)
    _assert_stores_identical(ref, st, "degenerate")
    for i, (rs, ss) in enumerate(zip(ref.levels, st.levels)):
        if rs is None or ss is None:
            assert rs is ss
            continue
        sl = ss.keyspace.slices[0]
        np.testing.assert_array_equal(rs.keyspace.tab_key, sl.tab_key)
        np.testing.assert_array_equal(rs.keyspace.tab_cnt, sl.tab_cnt)
        np.testing.assert_array_equal(rs.keyspace.tab_fp, sl.tab_fp)
        np.testing.assert_array_equal(rs.keyspace.tab_surv, sl.tab_surv)
        np.testing.assert_array_equal(rs.keyspace.cms, sl.cms)
        np.testing.assert_array_equal(rs.keyspace.cms, ss.keyspace.cms)


@pytest.mark.parametrize("include_probe", [False, True])
def test_sharded_query_parity(include_probe):
    rng = np.random.default_rng(11)
    cfg = _CFGS[8]
    keys, valid = _random_keys(rng, n=150, k=6, card=20)
    ref, st, rb, sb = _ingest_both(keys, valid, cfg, 3, rng, n_shards=4)
    qk, qv = _random_keys(rng, 16, 6, 20)
    for r1, r2 in zip(rb.query_keys(qk, qv, include_probe=include_probe),
                      sb.query_keys(qk, qv, include_probe=include_probe)):
        np.testing.assert_array_equal(r1.candidates, r2.candidates)
        assert r1.n_blocks_hit == r2.n_blocks_hit
        assert r1.levels_walked == r2.levels_walked
        np.testing.assert_array_equal(r1.block_sizes, r2.block_sizes)
    # queries are read-only on the sharded store too
    before = st.memory_stats()
    sb.query_keys(qk, qv, include_probe=include_probe)
    assert st.memory_stats() == before


def test_empty_shard_edge():
    """card=1 sends every key to ONE owner: 7 of 8 shards stay empty and
    every merged view must still be exact."""
    rng = np.random.default_rng(2)
    cfg = _CFGS[3]
    k64 = np.full((40, 3), np.uint64(0x9E3779B97F4A7C15))
    keys = np.stack([(k64 >> np.uint64(32)).astype(np.uint32),
                     (k64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)], -1)
    keys[:, 1:] = 0xFFFFFFFF
    valid = np.zeros((40, 3), bool)
    valid[:, 0] = True
    ref, st, _, _ = _ingest_both(keys, valid, cfg, 3, rng, n_shards=8)
    _assert_stores_identical(ref, st, "empty-shard")
    occupied = sum(sh.num_keys > 0 for sh in st.shards)
    assert occupied == 1
    assert st.memory_stats()["shard_skew"] > 1.0


# ---------------------------------------------------------------------------
# routing + router units
# ---------------------------------------------------------------------------


def test_route_buckets_general_rank_path_matches_onehot(monkeypatch):
    """The >64-shard argsort rank path must bucket identically (as
    per-destination multisets; ranks within a bucket may permute) to the
    one-hot path, and count the same overflow."""
    rng = np.random.default_rng(9)
    n, n_shards, cap = 512, 96, 8   # 96 > _ONEHOT_RANK_MAX_SHARDS
    khi = rng.integers(0, 1 << 30, n).astype(np.uint32)
    klo = rng.integers(0, 1 << 30, n).astype(np.uint32)
    owner = rng.integers(0, n_shards + 1, n).astype(np.int32)

    def run():
        bhi, blo, (bpl,), ovf = jax.jit(
            routing.route_buckets, static_argnums=(4, 5))(
                jnp.asarray(khi), jnp.asarray(klo), [jnp.asarray(klo)],
                jnp.asarray(owner), n_shards, cap)
        return (np.asarray(bhi), np.asarray(blo), np.asarray(bpl),
                int(ovf))

    g_hi, g_lo, g_pl, g_ovf = run()   # n_shards > 64: general path
    # force the general path off via the elems cap to get a second,
    # independently-ranked result for a <=64-shard layout; fresh jit
    # wrappers per call so the monkeypatched threshold is re-traced
    owner = np.minimum(owner, 64).astype(np.int32)

    def run64():
        return jax.jit(routing.route_buckets, static_argnums=(4, 5))(
            jnp.asarray(khi), jnp.asarray(klo), [jnp.asarray(klo)],
            jnp.asarray(owner), 64, cap)

    small = run64()
    monkeypatch.setattr(routing, "_ONEHOT_RANK_MAX_ELEMS", 0)
    forced = run64()
    for a, b in zip(small[:2] + tuple(small[2]), forced[:2] + tuple(forced[2])):
        for d in range(64):
            assert (sorted(np.asarray(a)[d].tolist())
                    == sorted(np.asarray(b)[d].tolist())), d
    assert int(small[3]) == int(forced[3])
    # the wide layout filled real buckets too
    assert g_ovf >= 0 and (g_hi != 0xFFFFFFFF).any()


def test_router_validation_and_owner_rule():
    with pytest.raises(ValueError):
        ShardRouter(0)
    with pytest.raises(ValueError):
        routing.np_owner_u64(np.zeros(1, np.uint64), 0)
    r = ShardRouter(8)
    x = np.arange(1000, dtype=np.uint64)
    ko, po = r.key_owner(x), r.pair_owner(x)
    assert ko.min() >= 0 and ko.max() < 8
    # the two seeds partition independently
    assert (ko != po).any()
    np.testing.assert_array_equal(
        ko, routing.np_owner_u64(x, 8, seed=routing.KEY_OWNER_SEED))


def test_merged_cms_equals_sum_of_shard_slices():
    rng = np.random.default_rng(21)
    cfg = _CFGS[8]
    keys, valid = _random_keys(rng, n=100, k=5, card=18)
    _, st, _, _ = _ingest_both(keys, valid, cfg, 2, rng, n_shards=4)
    for ss in st.levels:
        if ss is None:
            continue
        total = np.zeros_like(ss.keyspace.cms)
        for sl in ss.keyspace.slices:
            total += sl.cms
        np.testing.assert_array_equal(total, ss.keyspace.cms)


def test_memory_stats_per_shard_gauges():
    rng = np.random.default_rng(33)
    cfg = _CFGS[8]
    keys, valid = _random_keys(rng, n=120, k=5, card=20)
    ref, st, _, _ = _ingest_both(keys, valid, cfg, 2, rng, n_shards=4)
    ms = st.memory_stats()
    assert ms["n_shards"] == 4
    assert ms["shard_skew"] >= 1.0
    for s in range(4):
        assert ms[f"shard{s}_keytab_bytes"] >= 0
        assert ms[f"shard{s}_csr_bytes"] >= 0
        assert ms[f"shard{s}_ledger_bytes"] >= 0
    assert sum(ms[f"shard{s}_ledger_bytes"] for s in range(4)) \
        == ms["ledger_bytes"]
    rms = ref.memory_stats()
    for k in ("ledger_pairs", "accepted_blocks", "accepted_assignments",
              "num_records"):
        assert ms[k] == rms[k], k
    # the single-host stats carry the same byte-count key family
    for k in ("keytab_bytes", "cms_bytes", "csr_bytes", "ledger_bytes"):
        assert k in rms and rms[k] > 0, k


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------


def test_service_sharded_tenants_parity_and_gauges():
    rng = np.random.default_rng(4)
    cfg = _CFGS[8]
    keys, valid = _random_keys(rng, n=90, k=5, card=16)
    flat = DedupeService(cfg, ServiceConfig())
    shard = DedupeService(cfg, ServiceConfig(n_shards=4))
    for svc in (flat, shard):
        svc.submit_ingest("t", keys[:50], valid[:50])
        svc.submit_ingest("t", keys[50:], valid[50:])
        svc.submit_probe("t", keys[:8], valid[:8])
        svc.run()
    assert shard.tenant("t").store.n_shards == 4
    np.testing.assert_array_equal(flat.tenant("t").store.led_pack,
                                  shard.tenant("t").store.led_pack)
    for rf, rs in zip(flat.probe_responses, shard.probe_responses):
        assert rf.status == rs.status == "ok"
        for a, b in zip(rf.results, rs.results):
            np.testing.assert_array_equal(a.candidates, b.candidates)
    g = shard.snapshot()["gauges"]
    assert g["store_shards"] == 4
    assert g["store_shard_skew_max"] >= 1.0
    assert g["ledger_routed_fallback_total"] == 0
    assert g["store_exchange_fallback_total"] == 0
    assert flat.snapshot()["gauges"]["store_shards"] == 1
