"""Property tests for sort/segment reductions vs a numpy oracle."""
import jax.numpy as jnp
import numpy as np
from _propcheck import given, settings, st

from repro.core import segments, hashing


def _to_u64(xs):
    arr = hashing.np_to_u64_arrays(np.asarray(xs, np.uint64))
    packed = jnp.asarray(arr)
    return packed[..., 0], packed[..., 1]


small_keys = st.lists(st.integers(min_value=0, max_value=12), min_size=1, max_size=64)


@settings(max_examples=40, deadline=None)
@given(small_keys)
def test_segment_counts_match_numpy(xs):
    xs = sorted(xs)
    key = _to_u64(xs)
    got = np.asarray(segments.segment_counts(key))
    vals, counts = np.unique(np.asarray(xs), return_counts=True)
    true = dict(zip(vals.tolist(), counts.tolist()))
    for x, g in zip(xs, got):
        assert g == true[x]


@settings(max_examples=40, deadline=None)
@given(small_keys)
def test_segment_xor_matches_numpy(xs):
    xs = sorted(xs)
    key = _to_u64(xs)
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 1 << 62, len(xs)).astype(np.uint64)
    v = _to_u64(vals)
    xh, xl = segments.segment_xor(key, v)
    got = (np.asarray(xh).astype(np.uint64) << np.uint64(32)) | np.asarray(xl)
    true = {}
    for x, val in zip(xs, vals):
        true[x] = true.get(x, np.uint64(0)) ^ val
    for x, g in zip(xs, got):
        assert g == true[x]


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=1 << 60), min_size=1, max_size=50),
       st.lists(st.integers(min_value=0, max_value=1 << 60), min_size=1, max_size=50))
def test_lookup_u64(table_vals, queries):
    table_vals = sorted(set(table_vals))
    tkey = _to_u64(table_vals)
    vals = jnp.arange(len(table_vals), dtype=jnp.int32) + 100
    qkey = _to_u64(queries)
    hit, got = segments.lookup_u64(tkey, vals, qkey, default=-1)
    hit, got = np.asarray(hit), np.asarray(got)
    index = {v: i + 100 for i, v in enumerate(table_vals)}
    for q, h, g in zip(queries, hit, got):
        if q in index:
            assert h and g == index[q]
        else:
            assert not h and g == -1


def test_sort_by_key_is_lexicographic():
    rng = np.random.default_rng(3)
    xs = rng.integers(0, 1 << 63, 1000).astype(np.uint64)
    key = _to_u64(xs)
    (shi, slo), _ = segments.sort_by_key(key, [jnp.arange(1000, dtype=jnp.int32)])
    got = (np.asarray(shi).astype(np.uint64) << np.uint64(32)) | np.asarray(slo)
    np.testing.assert_array_equal(got, np.sort(xs))


def test_compact_moves_valid_to_prefix():
    key = _to_u64([5, 6, 7, 8])
    mask = jnp.asarray([False, True, False, True])
    (khi, klo), [p], n = segments.compact(mask, key, [jnp.asarray([10, 20, 30, 40])])
    assert int(n) == 2
    got = (np.asarray(khi).astype(np.uint64) << np.uint64(32)) | np.asarray(klo)
    assert got[:2].tolist() == [6, 8] and p[:2].tolist() == [20, 40]
