"""Meta-blocking baseline (paper §4.2) behaviour tests."""
import numpy as np
import pytest

from repro.core import blocks, metablocking
from repro.data import metrics, synthetic


@pytest.fixture(scope="module")
def built():
    corpus = synthetic.generate(synthetic.SyntheticSpec(num_entities=1500, seed=9))
    keys, valid = blocks.build_keys(corpus.columns, corpus.blocking)
    return corpus, keys, valid


def test_meta_blocking_produces_reasonable_recall(built):
    corpus, keys, valid = built
    res = metablocking.meta_blocking_result(keys, valid)
    m = metrics.evaluate(res, corpus)
    assert m.pc > 0.5
    assert m.pq > 0.0


def test_meta_blocking_prunes_edges(built):
    corpus, keys, valid = built
    a, b = metablocking.meta_blocking(keys, valid)
    # WEP must prune: fewer pairs than the unpruned candidate set
    a2, b2 = metablocking.meta_blocking(
        keys, valid, metablocking.MetaBlockingConfig(filter_ratio=1.0))
    assert len(a) > 0
    # pairs are unique and ordered
    key = a.astype(np.int64) * (1 << 32) + b
    assert len(np.unique(key)) == len(key)
    assert (a < b).all()


def test_meta_blocking_budget_error():
    """Exceeding the edge budget raises — the paper's linear-in-comparisons
    criticism made concrete (PMB OOMs on the paper's 50M+ datasets)."""
    corpus = synthetic.generate(synthetic.SyntheticSpec(num_entities=800, seed=4))
    keys, valid = blocks.build_keys(corpus.columns, corpus.blocking)
    with pytest.raises(metablocking.MetaBlockingBudgetError):
        metablocking.meta_blocking(
            keys, valid, metablocking.MetaBlockingConfig(edge_budget=10))
