"""Parity: chunked WKV (§Perf optimization) == per-step scan recurrence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import rwkv
from repro.models.model import build_model


@pytest.mark.parametrize("seq,chunk", [(64, 16), (128, 32), (96, 96)])
def test_chunked_wkv_matches_scan(seq, chunk):
    cfg = dataclasses.replace(reduced_config("rwkv6-1.6b"),
                              rwkv_impl="chunked", rwkv_chunk=chunk)
    params = rwkv.rwkv_init(jax.random.PRNGKey(0), cfg)["rwkv"]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, seq, cfg.d_model),
                          jnp.float32)
    y_chunk, _ = rwkv.rwkv_apply(params, x, cfg)
    y_scan, _ = rwkv.rwkv_apply(params, x,
                                dataclasses.replace(cfg, rwkv_impl="scan"))
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_scan),
                               rtol=2e-4, atol=2e-4)


def test_chunked_full_model_loss_matches():
    cfg_s = reduced_config("rwkv6-1.6b")
    cfg_c = dataclasses.replace(cfg_s, rwkv_impl="chunked", rwkv_chunk=16)
    m_s, m_c = build_model(cfg_s), build_model(cfg_c)
    params = m_s.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 256, (2, 64)), jnp.int32),
             "targets": jnp.asarray(rng.integers(0, 256, (2, 64)), jnp.int32)}
    l_s, _ = m_s.loss(params, batch)
    l_c, _ = m_c.loss(params, batch)
    assert float(l_s) == pytest.approx(float(l_c), rel=1e-4)


def test_chunked_gradients_match():
    cfg_s = reduced_config("rwkv6-1.6b")
    cfg_c = dataclasses.replace(cfg_s, rwkv_impl="chunked", rwkv_chunk=32)
    m_s, m_c = build_model(cfg_s), build_model(cfg_c)
    params = m_s.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, 256, (1, 64)), jnp.int32),
             "targets": jnp.asarray(rng.integers(0, 256, (1, 64)), jnp.int32)}
    g_s = jax.grad(lambda p: m_s.loss(p, batch)[0])(params)
    g_c = jax.grad(lambda p: m_c.loss(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g_s), jax.tree.leaves(g_c)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-4)
