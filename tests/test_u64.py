"""Property tests: u64 limb arithmetic must match python int semantics."""
import jax.numpy as jnp
import numpy as np
from _propcheck import given, settings, st

from repro.core import u64, hashing

MASK = (1 << 64) - 1
u64s = st.integers(min_value=0, max_value=MASK)


def _mk(x):
    return u64.from_int(x)


@settings(max_examples=60, deadline=None)
@given(u64s, u64s)
def test_add(a, b):
    assert u64.to_int(u64.add(_mk(a), _mk(b))) == (a + b) & MASK


@settings(max_examples=60, deadline=None)
@given(u64s, u64s)
def test_mul(a, b):
    assert u64.to_int(u64.mul(_mk(a), _mk(b))) == (a * b) & MASK


@settings(max_examples=40, deadline=None)
@given(u64s, st.integers(min_value=0, max_value=63))
def test_shifts(a, n):
    assert u64.to_int(u64.shr(_mk(a), n)) == (a >> n) & MASK
    assert u64.to_int(u64.shl(_mk(a), n)) == (a << n) & MASK


@settings(max_examples=40, deadline=None)
@given(u64s, st.integers(min_value=0, max_value=63))
def test_rotl(a, n):
    expect = ((a << n) | (a >> (64 - n))) & MASK if n else a
    assert u64.to_int(u64.rotl(_mk(a), n)) == expect


@settings(max_examples=60, deadline=None)
@given(u64s, u64s)
def test_compare(a, b):
    assert bool(u64.lt(_mk(a), _mk(b))) == (a < b)
    assert bool(u64.le(_mk(a), _mk(b))) == (a <= b)
    assert bool(u64.eq(_mk(a), _mk(b))) == (a == b)


@settings(max_examples=40, deadline=None)
@given(u64s)
def test_mix64_matches_numpy_mirror(a):
    assert u64.to_int(hashing.mix64(_mk(a))) == hashing.np_mix64(a)


@settings(max_examples=30, deadline=None)
@given(u64s, st.integers(min_value=0, max_value=2**31))
def test_hash_u64_matches_numpy_mirror(a, seed):
    got = u64.to_int(hashing.hash_u64(_mk(a), seed))
    assert got == hashing.np_hash_u64(a, seed)


def test_mix64_bijective_on_sample():
    xs = np.random.default_rng(0).integers(0, MASK, size=4096, dtype=np.uint64)
    arr = hashing.np_to_u64_arrays(xs)
    hi, lo = hashing.mix64(u64.unpack(jnp.asarray(arr)))
    packed = (np.asarray(hi).astype(np.uint64) << np.uint64(32)) | np.asarray(lo)
    assert len(np.unique(packed)) == len(np.unique(xs))


def test_pack_unpack_roundtrip():
    xs = [0, 1, MASK, 0xDEADBEEFCAFEBABE]
    for x in xs:
        assert u64.to_int(u64.unpack(u64.pack(_mk(x)))) == x


def test_sentinel_ordering():
    s = u64.sentinel(())
    assert bool(u64.is_sentinel(s))
    assert bool(u64.lt(_mk(12345), s))


def test_vectorized_shapes():
    hi = jnp.arange(12, dtype=jnp.uint32).reshape(3, 4)
    lo = hi + 7
    out = hashing.mix64((hi, lo))
    assert out[0].shape == (3, 4) and out[0].dtype == jnp.uint32


def test_combine_is_order_sensitive_and_mixes():
    a, b = _mk(1), _mk(2)
    ab = u64.to_int(hashing.combine(a, b))
    ba = u64.to_int(hashing.combine(b, a))
    assert ab != ba
    # avalanche sanity: flipping one input bit changes ~half the output bits
    c = u64.to_int(hashing.combine(_mk(1 ^ (1 << 17)), b))
    assert 10 < bin(ab ^ c).count("1") < 54


def test_hash_distribution_uniformity():
    """Chi-square-ish sanity: low nibble of hashes should be near uniform."""
    x = jnp.arange(1 << 14, dtype=jnp.uint32)
    _, lo = hashing.hash_u32(x, seed=7)
    counts = np.bincount(np.asarray(lo) & 15, minlength=16)
    expected = (1 << 14) / 16
    assert np.all(np.abs(counts - expected) < 6 * np.sqrt(expected))
