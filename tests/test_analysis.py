"""repro.analysis rule pack: each rule must fire on a known-bad fixture
and stay quiet on the fixed version of the same code, suppressions must
downgrade findings without hiding them, and the repo's own hot-path
packages must be finding-free (the self-hosting gate that keeps the CI
lint lane meaningful)."""
import os

import pytest

from repro.analysis import all_rules, analyze_paths, analyze_source, run_cli

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src", "repro")


def _live(src, select=None):
    return [f for f in analyze_source(src, select=select) if not f.suppressed]


def _rules_of(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# R001 host transfer inside jit
# ---------------------------------------------------------------------------

BAD_R001 = """
import jax
import jax.numpy as jnp
import numpy as np

@jax.jit
def step(x):
    y = np.asarray(x)
    return jnp.sum(jnp.asarray(y))
"""

BAD_R001_TRANSITIVE = """
import functools
import jax
import jax.numpy as jnp

def helper(x):
    return float(x[0]) + 1.0

@functools.partial(jax.jit, static_argnames=("n",))
def step(x, n):
    return helper(x) * n
"""

GOOD_R001 = """
import jax
import jax.numpy as jnp
import numpy as np

def host_prep(a):
    return np.asarray(a, np.int32)  # outside any jit root: fine

@jax.jit
def step(x):
    return jnp.sum(x * 2)
"""


def test_r001_fires_on_numpy_call_in_jit():
    findings = _live(BAD_R001, select=["R001"])
    assert _rules_of(findings) == {"R001"}
    assert any("np.asarray" in f.message for f in findings)


def test_r001_fires_through_the_call_graph():
    findings = _live(BAD_R001_TRANSITIVE, select=["R001"])
    assert _rules_of(findings) == {"R001"}
    assert all(f.line for f in findings)


def test_r001_quiet_on_host_side_numpy():
    assert _live(GOOD_R001, select=["R001"]) == []


# ---------------------------------------------------------------------------
# R002 dtype-contract drift
# ---------------------------------------------------------------------------

BAD_R002_LITERAL = """
import numpy as np

def pack(w):
    w = np.uint64(w)
    return w + 3
"""

BAD_R002_NARROW = """
import numpy as np

def truncate(words):
    w = np.uint64(words)
    return w.astype(np.int32)
"""

BAD_R002_JNP64 = """
import jax.numpy as jnp

def keys(x):
    return x.astype(jnp.uint64)
"""

GOOD_R002 = """
import numpy as np

def pack(w):
    w = np.uint64(w)
    return w + np.uint64(3)

def low_bits(words):
    w = np.uint64(words)
    return (w & np.uint64(0xFFFF)).astype(np.int32)
"""


def test_r002_fires_on_u64_literal_mix():
    assert _rules_of(_live(BAD_R002_LITERAL, select=["R002"])) == {"R002"}


def test_r002_fires_on_narrowing_cast():
    assert _rules_of(_live(BAD_R002_NARROW, select=["R002"])) == {"R002"}


def test_r002_fires_on_jnp_64bit_dtype():
    # with x64 disabled jnp.uint64 silently produces 32-bit values
    assert _rules_of(_live(BAD_R002_JNP64, select=["R002"])) == {"R002"}


def test_r002_quiet_on_typed_constants_and_masked_narrowing():
    assert _live(GOOD_R002, select=["R002"]) == []


# ---------------------------------------------------------------------------
# R003 python control flow on traced values
# ---------------------------------------------------------------------------

BAD_R003 = """
import jax
import jax.numpy as jnp

@jax.jit
def relu_or_neg(x):
    if x.sum() > 0:
        return x
    return -x
"""

GOOD_R003 = """
import jax
import jax.numpy as jnp

@jax.jit
def relu_or_neg(x, *, flip: bool = False):
    if flip:  # static kwarg: fine
        x = -x
    return jnp.where(x > 0, x, -x)
"""


def test_r003_fires_on_traced_branch():
    assert _rules_of(_live(BAD_R003, select=["R003"])) == {"R003"}


def test_r003_quiet_on_static_branch_and_where():
    assert _live(GOOD_R003, select=["R003"]) == []


# ---------------------------------------------------------------------------
# R004 unsynced benchmark timing
# ---------------------------------------------------------------------------

BAD_R004 = """
import time
import jax

def bench(fn, x):
    t0 = time.perf_counter()
    out = fn(x)
    dt = time.perf_counter() - t0
    return out, dt
"""

GOOD_R004 = """
import time
import jax

def bench(fn, x):
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(x))
    dt = time.perf_counter() - t0
    return out, dt
"""


def test_r004_fires_on_unsynced_window():
    assert _rules_of(_live(BAD_R004, select=["R004"])) == {"R004"}


def test_r004_quiet_when_blocked_until_ready():
    assert _live(GOOD_R004, select=["R004"]) == []


# ---------------------------------------------------------------------------
# R005 jit-cache hazards
# ---------------------------------------------------------------------------

BAD_R005_LOOP = """
import jax

def run(xs):
    outs = []
    for x in xs:
        f = jax.jit(lambda v: v * 2)
        outs.append(f(x))
    return outs
"""

BAD_R005_FACTORY = """
import jax

def make_step(scale):
    return jax.jit(lambda v: v * scale)
"""

GOOD_R005 = """
import functools
import jax

@functools.lru_cache(maxsize=8)
def make_step(scale):
    return jax.jit(lambda v: v * scale)

step = jax.jit(lambda v: v * 2)  # module-level: compiled once
"""


def test_r005_fires_on_jit_in_loop():
    assert _rules_of(_live(BAD_R005_LOOP, select=["R005"])) == {"R005"}


def test_r005_fires_on_uncached_factory():
    assert _rules_of(_live(BAD_R005_FACTORY, select=["R005"])) == {"R005"}


def test_r005_quiet_on_cached_factory_and_module_jit():
    assert _live(GOOD_R005, select=["R005"]) == []


# ---------------------------------------------------------------------------
# engine mechanics: suppressions, syntax errors, CLI exit codes
# ---------------------------------------------------------------------------

SUPPRESSED = """
import jax
import numpy as np

@jax.jit
def step(x):
    return np.asarray(x)  # repro: noqa[R001] parity check reads back on host
"""

SUPPRESSED_OTHER_RULE = """
import jax
import numpy as np

@jax.jit
def step(x):
    return np.asarray(x)  # repro: noqa[R004]
"""


def test_noqa_downgrades_but_keeps_the_finding():
    findings = analyze_source(SUPPRESSED, select=["R001"])
    assert len(findings) == 1
    assert findings[0].suppressed


def test_noqa_for_a_different_rule_does_not_apply():
    findings = analyze_source(SUPPRESSED_OTHER_RULE, select=["R001"])
    assert [f.suppressed for f in findings] == [False]


def test_bare_noqa_suppresses_every_rule():
    src = SUPPRESSED.replace("noqa[R001] parity check reads back on host",
                             "noqa")
    assert all(f.suppressed for f in analyze_source(src))


def test_syntax_error_becomes_e999():
    findings = analyze_source("def f(:\n    pass\n")
    assert [f.rule for f in findings] == ["E999"]


def test_rule_pack_is_complete():
    assert set(all_rules()) == {
        "R001", "R002", "R003", "R004", "R005",
        "R006", "R007", "R008", "R009",
    }


def test_cli_exit_codes(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(BAD_R001)
    good = tmp_path / "good.py"
    good.write_text(GOOD_R001)
    assert run_cli([str(good), "--no-cache"]) == 0
    assert run_cli([str(bad), "--no-cache"]) == 1
    out = capsys.readouterr()
    assert "R001" in out.out
    assert run_cli([str(bad), "--no-cache", "--select", "R004"]) == 0


# ---------------------------------------------------------------------------
# R006 collective contracts (mesh-axis universe + all_to_all divisibility)
# ---------------------------------------------------------------------------

BAD_R006_AXIS = """
import jax

mesh = jax.make_mesh((4,), ("data",))

def local_sum(x):
    return jax.lax.psum(x, "model")
"""

BAD_R006_SPLIT = """
import jax

mesh = jax.make_mesh((4,), ("data",))

def exchange(x):
    y = x.reshape(6, 128)
    return jax.lax.all_to_all(y, "data", 0, 0)
"""

GOOD_R006 = """
import jax
from jax import lax

mesh = jax.make_mesh((4,), ("data",))

def local_sum(x, axis_name="data"):
    return lax.psum(x, axis_name)

def exchange(x):
    y = x.reshape(8, 128)
    return lax.all_to_all(y, "data", 0, 0)

def shards(m):
    return m.shape["data"]
"""


def test_r006_fires_on_undeclared_axis():
    findings = _live(BAD_R006_AXIS, select=["R006"])
    assert _rules_of(findings) == {"R006"}
    assert any("model" in f.message for f in findings)


def test_r006_fires_on_indivisible_all_to_all_split():
    findings = _live(BAD_R006_SPLIT, select=["R006"])
    assert _rules_of(findings) == {"R006"}
    assert any("divisible" in f.message for f in findings)


def test_r006_fires_on_undeclared_mesh_shape_key():
    src = GOOD_R006.replace('m.shape["data"]', 'm.shape["expert"]')
    findings = _live(src, select=["R006"])
    assert _rules_of(findings) == {"R006"}


def test_r006_quiet_on_declared_axes_and_dividing_split():
    assert _live(GOOD_R006, select=["R006"]) == []


def test_r006_quiet_without_any_mesh_declaration():
    # no universe to check against: stay silent rather than guess
    src = "import jax\n\ndef f(x):\n    return jax.lax.psum(x, 'model')\n"
    assert _live(src, select=["R006"]) == []


def test_r006_resolves_conditional_mesh_construction():
    # axes bound through a local name with branch-dependent literals
    # (the launch/mesh.py idiom) still populate the universe
    src = """
import jax

def make(multi: bool = False):
    shape = (2, 4) if multi else (4,)
    axes = ("pod", "data") if multi else ("data",)
    return jax.make_mesh(shape, axes)

def f(x):
    return jax.lax.psum(x, "pod")

def g(x):
    return jax.lax.psum(x, "model")
"""
    findings = _live(src, select=["R006"])
    assert len(findings) == 1 and "model" in findings[0].message


# ---------------------------------------------------------------------------
# R007 padding / sentinel contracts
# ---------------------------------------------------------------------------

BAD_R007_PAD = """
import numpy as np

def mean_rows(x, n_real: int):
    padded = np.pad(x, ((0, 8), (0, 0)))
    return np.mean(padded)
"""

BAD_R007_SENTINEL = """
import numpy as np

def decode(keys):
    words = np.full((4, 16), np.uint32(0xFFFFFFFF))
    words[: len(keys)] = keys
    return unpack_words_host(words)
"""

GOOD_R007 = """
import numpy as np

def mean_rows(x, n_real: int):
    padded = np.pad(x, ((0, 8), (0, 0)))
    return np.mean(padded[:n_real])

def decode(keys, words):
    live = words[words != np.uint32(0xFFFFFFFF)]
    return unpack_words_host(live)
"""


def test_r007_fires_on_reduction_over_padded():
    findings = _live(BAD_R007_PAD, select=["R007"])
    assert _rules_of(findings) == {"R007"}
    assert any("mean" in f.message for f in findings)


def test_r007_fires_on_unfiltered_sentinel_unpack():
    findings = _live(BAD_R007_SENTINEL, select=["R007"])
    assert _rules_of(findings) == {"R007"}
    assert any("sentinel" in f.message for f in findings)


def test_r007_quiet_on_sliced_and_filtered_uses():
    assert _live(GOOD_R007, select=["R007"]) == []


def test_r007_taint_does_not_cross_arbitrary_calls():
    # a callee may consume the padding internally (kernel launches whose
    # outputs are per-lane ranks): its results are not padded values
    src = """
import numpy as np

def histogram(x):
    padded = np.pad(x, (0, 8))
    counts = launch_kernel(padded)
    return np.cumsum(counts)
"""
    assert _live(src, select=["R007"]) == []


# ---------------------------------------------------------------------------
# R008 serving concurrency
# ---------------------------------------------------------------------------

BAD_R008_BLOCKING = """
import time

class Lane:
    def drain(self):
        with self._lock:
            time.sleep(0.01)
            self.flushed += 1
"""

BAD_R008_UNGUARDED = """
class Metrics:
    def __init__(self):
        self.served = 0

    def record(self):
        with self._lock:
            self.served += 1

    def record_fast(self):
        self.served += 1
"""

GOOD_R008 = """
import time

class Lane:
    def __init__(self):
        self.flushed = 0

    def drain(self):
        batch = self.q.get()
        with self._lock:
            self.flushed += 1
        time.sleep(0.01)

    def report(self):
        with self._lock:
            self.flushed += 1
"""


def test_r008_fires_on_blocking_call_under_lock():
    findings = _live(BAD_R008_BLOCKING, select=["R008"])
    assert _rules_of(findings) == {"R008"}
    assert any("blocking" in f.message for f in findings)


def test_r008_fires_on_inconsistently_guarded_attribute():
    findings = _live(BAD_R008_UNGUARDED, select=["R008"])
    assert _rules_of(findings) == {"R008"}
    assert any("record_fast" in f.message for f in findings)


def test_r008_quiet_on_consistent_locking():
    # __init__ writes and lock-free single-lane classes are fine
    assert _live(GOOD_R008, select=["R008"]) == []


# ---------------------------------------------------------------------------
# R009 pallas kernel shapes
# ---------------------------------------------------------------------------

BAD_R009_GRID = """
from jax.experimental import pallas as pl

def kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]

def launch(x):
    return pl.pallas_call(kernel, grid=(x.shape[0] // 8,))(x)
"""

BAD_R009_OOB = """
from jax.experimental import pallas as pl

def kernel(x_ref, o_ref):
    o_ref[0, 0] = x_ref[2, 0]

def launch(x):
    return pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec((1, 128), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((1, 128), lambda r: (r, 0)),
    )(x)
"""

GOOD_R009 = """
from jax.experimental import pallas as pl

def kernel(x_ref, o_ref):
    o_ref[0, 127] = x_ref[0, 0]

def launch(x):
    rows = x.shape[0]
    assert rows % 8 == 0
    spec = pl.BlockSpec((1, 128), lambda r: (r, 0))
    return pl.pallas_call(
        kernel, grid=(rows // 8,), in_specs=[spec], out_specs=spec,
    )(x)
"""


def test_r009_fires_on_unguarded_grid_floordiv():
    findings = _live(BAD_R009_GRID, select=["R009"])
    assert _rules_of(findings) == {"R009"}
    assert any("divisibility" in f.message for f in findings)


def test_r009_fires_on_out_of_bounds_static_ref_index():
    findings = _live(BAD_R009_OOB, select=["R009"])
    assert _rules_of(findings) == {"R009"}
    assert any("exceeds" in f.message for f in findings)


def test_r009_quiet_on_guarded_grid_and_in_bounds_indices():
    # the divisibility assert covers the grid; index 127 < block 128,
    # and the spec resolves through its local name binding
    assert _live(GOOD_R009, select=["R009"]) == []


# ---------------------------------------------------------------------------
# noqa spans: first-line suppression of multi-line statements
# ---------------------------------------------------------------------------


def test_noqa_on_first_line_covers_the_whole_statement():
    src = """
import jax
import numpy as np

@jax.jit
def step(x):
    y = (  # repro: noqa[R001]
        np.asarray(x))
    return y
"""
    findings = analyze_source(src, select=["R001"])
    assert len(findings) == 1
    assert findings[0].suppressed


def test_noqa_on_compound_header_does_not_blanket_the_body():
    src = """
import jax
import numpy as np

@jax.jit
def step(  # repro: noqa[R001]
    x,
):
    return np.asarray(x)
"""
    findings = analyze_source(src, select=["R001"])
    assert [f.suppressed for f in findings] == [False]


# ---------------------------------------------------------------------------
# cross-module reachability (phase-1 index)
# ---------------------------------------------------------------------------


def _write_pkg(tmp_path, a_src, b_src):
    pkg = tmp_path / "pkg"
    pkg.mkdir(exist_ok=True)
    (pkg / "__init__.py").write_text("")
    (pkg / "a.py").write_text(a_src)
    (pkg / "b.py").write_text(b_src)
    return pkg


XMOD_HELPER = """
import numpy as np

def helper(x):
    return np.asarray(x)
"""

XMOD_JIT_CALLER = """
import jax
from .b import helper

@jax.jit
def step(x):
    return helper(x)
"""

XMOD_HOST_CALLER = """
from .b import helper

def prep(x):
    return helper(x)
"""


def test_cross_module_jit_reachability_flags_the_helper(tmp_path):
    pkg = _write_pkg(tmp_path, XMOD_JIT_CALLER, XMOD_HELPER)
    findings = [f for f in analyze_paths([str(pkg)], select=["R001"])
                if not f.suppressed]
    assert _rules_of(findings) == {"R001"}
    assert all(f.path.endswith("b.py") for f in findings)


def test_cross_module_reachability_quiet_for_host_only_callers(tmp_path):
    pkg = _write_pkg(tmp_path, XMOD_HOST_CALLER, XMOD_HELPER)
    findings = [f for f in analyze_paths([str(pkg)], select=["R001"])
                if not f.suppressed]
    assert findings == []


def test_cross_module_reachability_through_package_reexport(tmp_path):
    pkg = _write_pkg(tmp_path, XMOD_JIT_CALLER.replace(
        "from .b import helper", "from . import helper"), XMOD_HELPER)
    (pkg / "__init__.py").write_text("from .b import helper\n")
    findings = [f for f in analyze_paths([str(pkg)], select=["R001"])
                if not f.suppressed]
    assert _rules_of(findings) == {"R001"}


# ---------------------------------------------------------------------------
# on-disk findings cache
# ---------------------------------------------------------------------------


def test_cache_round_trip_returns_identical_findings(tmp_path):
    mod = tmp_path / "mod.py"
    mod.write_text(BAD_R001)
    cache = tmp_path / "cache.json"
    first = analyze_paths([str(mod)], cache_path=str(cache))
    assert cache.exists()
    second = analyze_paths([str(mod)], cache_path=str(cache))
    assert second == first
    assert _rules_of(second) == {"R001"}


def test_cache_hits_skip_the_rule_run(tmp_path):
    import json

    mod = tmp_path / "mod.py"
    mod.write_text(BAD_R001)
    cache = tmp_path / "cache.json"
    analyze_paths([str(mod)], cache_path=str(cache))
    # poison the cached findings in place (same digest/mtime/size): a
    # true cache hit must surface the poisoned copy, not re-run rules
    raw = json.loads(cache.read_text())
    (entry,) = raw["files"].values()
    entry["findings"][0]["message"] = "poisoned-cache-entry"
    cache.write_text(json.dumps(raw))
    got = analyze_paths([str(mod)], cache_path=str(cache))
    assert [f.message for f in got] == ["poisoned-cache-entry"]


def test_cache_invalidates_on_file_edit(tmp_path):
    mod = tmp_path / "mod.py"
    mod.write_text(BAD_R001)
    cache = tmp_path / "cache.json"
    assert _rules_of(analyze_paths([str(mod)], cache_path=str(cache))) \
        == {"R001"}
    mod.write_text(GOOD_R001)
    assert analyze_paths([str(mod)], cache_path=str(cache)) == []


def test_cache_invalidates_when_a_dependency_changes_reachability(tmp_path):
    # b.py never changes; editing ONLY a.py makes b.helper jit-reachable,
    # so the cache must re-check b.py (the digest carries injected
    # cross-module facts, not just the file's own mtime/size)
    pkg = _write_pkg(tmp_path, XMOD_HOST_CALLER, XMOD_HELPER)
    cache = tmp_path / "cache.json"
    quiet = [f for f in analyze_paths([str(pkg)], select=["R001"],
                                      cache_path=str(cache))
             if not f.suppressed]
    assert quiet == []
    (pkg / "a.py").write_text(XMOD_JIT_CALLER)
    loud = [f for f in analyze_paths([str(pkg)], select=["R001"],
                                     cache_path=str(cache))
            if not f.suppressed]
    assert _rules_of(loud) == {"R001"}
    assert all(f.path.endswith("b.py") for f in loud)


# ---------------------------------------------------------------------------
# CLI output formats
# ---------------------------------------------------------------------------


def test_cli_github_format_emits_annotations(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(BAD_R001)
    assert run_cli([str(bad), "--no-cache", "--format", "github"]) == 1
    out = capsys.readouterr().out
    assert "::error file=" in out
    assert "R001" in out


def test_cli_warn_only_reports_but_exits_zero(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(BAD_R001)
    assert run_cli([str(bad), "--no-cache", "--warn-only",
                    "--format", "github"]) == 0
    out = capsys.readouterr().out
    assert "::warning file=" in out


def test_cli_writes_json_report(tmp_path):
    import json

    bad = tmp_path / "bad.py"
    bad.write_text(BAD_R001)
    report = tmp_path / "report.json"
    assert run_cli([str(bad), "--no-cache", "--report", str(report)]) == 1
    data = json.loads(report.read_text())
    assert any(f["rule"] == "R001" for f in data)


# ---------------------------------------------------------------------------
# self-hosting gate: the repo's own hot-path packages stay finding-free
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pkg", ["core", "kernels", "streaming", "serving"])
def test_self_hosting_hot_paths_are_clean(pkg):
    findings = analyze_paths([os.path.join(SRC, pkg)])
    live = [f.format() for f in findings if not f.suppressed]
    assert live == [], "\n".join(live)
