"""repro.analysis rule pack: each rule must fire on a known-bad fixture
and stay quiet on the fixed version of the same code, suppressions must
downgrade findings without hiding them, and the repo's own hot-path
packages must be finding-free (the self-hosting gate that keeps the CI
lint lane meaningful)."""
import os

import pytest

from repro.analysis import all_rules, analyze_paths, analyze_source, run_cli

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src", "repro")


def _live(src, select=None):
    return [f for f in analyze_source(src, select=select) if not f.suppressed]


def _rules_of(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# R001 host transfer inside jit
# ---------------------------------------------------------------------------

BAD_R001 = """
import jax
import jax.numpy as jnp
import numpy as np

@jax.jit
def step(x):
    y = np.asarray(x)
    return jnp.sum(jnp.asarray(y))
"""

BAD_R001_TRANSITIVE = """
import functools
import jax
import jax.numpy as jnp

def helper(x):
    return float(x[0]) + 1.0

@functools.partial(jax.jit, static_argnames=("n",))
def step(x, n):
    return helper(x) * n
"""

GOOD_R001 = """
import jax
import jax.numpy as jnp
import numpy as np

def host_prep(a):
    return np.asarray(a, np.int32)  # outside any jit root: fine

@jax.jit
def step(x):
    return jnp.sum(x * 2)
"""


def test_r001_fires_on_numpy_call_in_jit():
    findings = _live(BAD_R001, select=["R001"])
    assert _rules_of(findings) == {"R001"}
    assert any("np.asarray" in f.message for f in findings)


def test_r001_fires_through_the_call_graph():
    findings = _live(BAD_R001_TRANSITIVE, select=["R001"])
    assert _rules_of(findings) == {"R001"}
    assert all(f.line for f in findings)


def test_r001_quiet_on_host_side_numpy():
    assert _live(GOOD_R001, select=["R001"]) == []


# ---------------------------------------------------------------------------
# R002 dtype-contract drift
# ---------------------------------------------------------------------------

BAD_R002_LITERAL = """
import numpy as np

def pack(w):
    w = np.uint64(w)
    return w + 3
"""

BAD_R002_NARROW = """
import numpy as np

def truncate(words):
    w = np.uint64(words)
    return w.astype(np.int32)
"""

BAD_R002_JNP64 = """
import jax.numpy as jnp

def keys(x):
    return x.astype(jnp.uint64)
"""

GOOD_R002 = """
import numpy as np

def pack(w):
    w = np.uint64(w)
    return w + np.uint64(3)

def low_bits(words):
    w = np.uint64(words)
    return (w & np.uint64(0xFFFF)).astype(np.int32)
"""


def test_r002_fires_on_u64_literal_mix():
    assert _rules_of(_live(BAD_R002_LITERAL, select=["R002"])) == {"R002"}


def test_r002_fires_on_narrowing_cast():
    assert _rules_of(_live(BAD_R002_NARROW, select=["R002"])) == {"R002"}


def test_r002_fires_on_jnp_64bit_dtype():
    # with x64 disabled jnp.uint64 silently produces 32-bit values
    assert _rules_of(_live(BAD_R002_JNP64, select=["R002"])) == {"R002"}


def test_r002_quiet_on_typed_constants_and_masked_narrowing():
    assert _live(GOOD_R002, select=["R002"]) == []


# ---------------------------------------------------------------------------
# R003 python control flow on traced values
# ---------------------------------------------------------------------------

BAD_R003 = """
import jax
import jax.numpy as jnp

@jax.jit
def relu_or_neg(x):
    if x.sum() > 0:
        return x
    return -x
"""

GOOD_R003 = """
import jax
import jax.numpy as jnp

@jax.jit
def relu_or_neg(x, *, flip: bool = False):
    if flip:  # static kwarg: fine
        x = -x
    return jnp.where(x > 0, x, -x)
"""


def test_r003_fires_on_traced_branch():
    assert _rules_of(_live(BAD_R003, select=["R003"])) == {"R003"}


def test_r003_quiet_on_static_branch_and_where():
    assert _live(GOOD_R003, select=["R003"]) == []


# ---------------------------------------------------------------------------
# R004 unsynced benchmark timing
# ---------------------------------------------------------------------------

BAD_R004 = """
import time
import jax

def bench(fn, x):
    t0 = time.perf_counter()
    out = fn(x)
    dt = time.perf_counter() - t0
    return out, dt
"""

GOOD_R004 = """
import time
import jax

def bench(fn, x):
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(x))
    dt = time.perf_counter() - t0
    return out, dt
"""


def test_r004_fires_on_unsynced_window():
    assert _rules_of(_live(BAD_R004, select=["R004"])) == {"R004"}


def test_r004_quiet_when_blocked_until_ready():
    assert _live(GOOD_R004, select=["R004"]) == []


# ---------------------------------------------------------------------------
# R005 jit-cache hazards
# ---------------------------------------------------------------------------

BAD_R005_LOOP = """
import jax

def run(xs):
    outs = []
    for x in xs:
        f = jax.jit(lambda v: v * 2)
        outs.append(f(x))
    return outs
"""

BAD_R005_FACTORY = """
import jax

def make_step(scale):
    return jax.jit(lambda v: v * scale)
"""

GOOD_R005 = """
import functools
import jax

@functools.lru_cache(maxsize=8)
def make_step(scale):
    return jax.jit(lambda v: v * scale)

step = jax.jit(lambda v: v * 2)  # module-level: compiled once
"""


def test_r005_fires_on_jit_in_loop():
    assert _rules_of(_live(BAD_R005_LOOP, select=["R005"])) == {"R005"}


def test_r005_fires_on_uncached_factory():
    assert _rules_of(_live(BAD_R005_FACTORY, select=["R005"])) == {"R005"}


def test_r005_quiet_on_cached_factory_and_module_jit():
    assert _live(GOOD_R005, select=["R005"]) == []


# ---------------------------------------------------------------------------
# engine mechanics: suppressions, syntax errors, CLI exit codes
# ---------------------------------------------------------------------------

SUPPRESSED = """
import jax
import numpy as np

@jax.jit
def step(x):
    return np.asarray(x)  # repro: noqa[R001] parity check reads back on host
"""

SUPPRESSED_OTHER_RULE = """
import jax
import numpy as np

@jax.jit
def step(x):
    return np.asarray(x)  # repro: noqa[R004]
"""


def test_noqa_downgrades_but_keeps_the_finding():
    findings = analyze_source(SUPPRESSED, select=["R001"])
    assert len(findings) == 1
    assert findings[0].suppressed


def test_noqa_for_a_different_rule_does_not_apply():
    findings = analyze_source(SUPPRESSED_OTHER_RULE, select=["R001"])
    assert [f.suppressed for f in findings] == [False]


def test_bare_noqa_suppresses_every_rule():
    src = SUPPRESSED.replace("noqa[R001] parity check reads back on host",
                             "noqa")
    assert all(f.suppressed for f in analyze_source(src))


def test_syntax_error_becomes_e999():
    findings = analyze_source("def f(:\n    pass\n")
    assert [f.rule for f in findings] == ["E999"]


def test_rule_pack_is_complete():
    assert set(all_rules()) == {"R001", "R002", "R003", "R004", "R005"}


def test_cli_exit_codes(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(BAD_R001)
    good = tmp_path / "good.py"
    good.write_text(GOOD_R001)
    assert run_cli([str(good)]) == 0
    assert run_cli([str(bad)]) == 1
    out = capsys.readouterr()
    assert "R001" in out.out
    assert run_cli([str(bad), "--select", "R004"]) == 0


# ---------------------------------------------------------------------------
# self-hosting gate: the repo's own hot-path packages stay finding-free
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pkg", ["core", "kernels", "streaming"])
def test_self_hosting_hot_paths_are_clean(pkg):
    findings = analyze_paths([os.path.join(SRC, pkg)])
    live = [f.format() for f in findings if not f.suppressed]
    assert live == [], "\n".join(live)
