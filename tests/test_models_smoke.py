"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and no NaNs (assignment requirement).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.launch import specs
from repro.models.model import build_model
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import TrainConfig, init_train_state, make_train_step

SEQ, BATCH = 32, 2


def _smoke(arch_id):
    cfg = reduced_config(arch_id)
    model = build_model(cfg)
    rng = np.random.default_rng(1)
    batch = specs.train_batch(cfg, SEQ, BATCH, concrete=True, rng=rng)
    tcfg = TrainConfig(opt=OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=10))
    state = init_train_state(model, jax.random.PRNGKey(0), tcfg)

    logits, _ = jax.jit(model.apply)(state["params"], batch)
    expect_len = batch["tokens"].shape[1]
    assert logits.shape == (BATCH, expect_len, cfg.vocab_size), logits.shape
    assert not np.isnan(np.asarray(logits, np.float32)).any()

    step = jax.jit(make_train_step(model, tcfg))
    state2, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), metrics
    # params actually changed
    delta = jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                    - b.astype(jnp.float32)).max()),
                         state["params"], state2["params"])
    assert max(jax.tree.leaves(delta)) > 0
    return model, state2, batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_train_step(arch_id):
    _smoke(arch_id)


@pytest.mark.parametrize("arch_id", ["tinyllama-1.1b", "rwkv6-1.6b",
                                     "olmoe-1b-7b", "whisper-medium",
                                     "jamba-1.5-large-398b",
                                     "deepseek-v3-671b"])
def test_smoke_decode_step(arch_id):
    cfg = reduced_config(arch_id)
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0),
                             TrainConfig(opt=OptimizerConfig()))
    token, caches, extras = specs.decode_inputs(model, 16, BATCH, concrete=True)
    logits, new_caches = jax.jit(model.decode_step)(
        state["params"], token, caches, extras if extras else None)
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned dimensions."""
    spec = {
        "deepseek-v3-671b": (61, 7168, 128, 128, 129280),
        "olmoe-1b-7b": (16, 2048, 16, 16, 50304),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 65536),
        "internlm2-20b": (48, 6144, 48, 8, 92544),
        "tinyllama-1.1b": (22, 2048, 32, 4, 32000),
        "mistral-nemo-12b": (40, 5120, 32, 8, 131072),
        "stablelm-3b": (32, 2560, 32, 32, 50304),
        "rwkv6-1.6b": (24, 2048, 32, 32, 65536),
        "internvl2-76b": (80, 8192, 64, 8, 128256),
    }
    for arch, (nl, dm, h, kv, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.num_layers == nl and cfg.d_model == dm, arch
        assert cfg.num_heads == h and cfg.num_kv_heads == kv, arch
        assert cfg.vocab_size == v, arch
    w = get_config("whisper-medium")
    assert w.encoder_layers == w.decoder_layers == 24
    assert w.d_model == 1024 and w.vocab_size == 51865


def test_param_counts_in_expected_range():
    """Total param estimates should land near the nameplate sizes."""
    expect = {
        "deepseek-v3-671b": (550e9, 800e9),
        "olmoe-1b-7b": (5e9, 9e9),
        "jamba-1.5-large-398b": (300e9, 480e9),
        "internlm2-20b": (15e9, 26e9),
        "tinyllama-1.1b": (0.8e9, 1.5e9),
        "mistral-nemo-12b": (10e9, 15e9),
        "stablelm-3b": (2e9, 4.5e9),
        "rwkv6-1.6b": (1e9, 2.5e9),
        "internvl2-76b": (60e9, 90e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).total_params()
        assert lo <= n <= hi, (arch, f"{n:.3g}")


def test_moe_activates_fewer_params_than_total():
    cfg = get_config("deepseek-v3-671b")
    assert cfg.active_params() < 0.12 * cfg.total_params()
