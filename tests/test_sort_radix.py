"""Radix-sort dedupe backend: kernel parity + sort_backend threading.

The ``kernels/sort`` LSB radix engine must order u64 sort words (uint32
limb pairs) bit-identically to ``np.sort`` and ``lax.sort`` — a sorted
multiset is unique — on every edge the pair engine can feed it:
sentinel-only buffers, heavy duplicate runs, empty inputs, and
full-capacity field values of the 62-bit pack. The Pallas
histogram/rank kernel (interpret mode here) must match the fused-jnp
mirror bit-for-bit, and the ``sort_backend`` knob must leave every
dedupe result unchanged across comparator/radix on all drivers.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pairs
from repro.core.distributed import dedupe_pairs_distributed
from repro.kernels import sort as ksort
from repro.kernels.pairs import (PACK_RID_BITS, dedupe_device,
                                 dedupe_packed_device, pack_sort_words,
                                 radix_passes_for, unpack_words_host)
from repro.kernels.pairs import ref as pairs_ref

SENTINEL = np.uint64(0xFFFFFFFFFFFFFFFF)


def _limbs(w):
    w = np.asarray(w, np.uint64)
    return (jnp.asarray((w >> np.uint64(32)).astype(np.uint32)),
            jnp.asarray((w & np.uint64(0xFFFFFFFF)).astype(np.uint32)))


def _join(hi, lo):
    return ((np.asarray(hi).astype(np.uint64) << np.uint64(32))
            | np.asarray(lo).astype(np.uint64))


def _radix(w, use_kernel=False, n_passes=ksort.MAX_PASSES):
    hi, lo = _limbs(w)
    shi, slo = ksort.radix_sort_words(hi, lo, n_passes=n_passes,
                                      use_kernel=use_kernel, interpret=True)
    return _join(shi, slo)


# ---------------------------------------------------------------------------
# sort parity on edge inputs (satellite: sentinel-only / duplicates /
# empty / full-capacity limb pairs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("use_kernel", [False, True])
def test_radix_matches_npsort_random(use_kernel):
    rng = np.random.default_rng(0)
    w = rng.integers(0, 1 << 62, 2048, dtype=np.uint64)
    w[rng.random(2048) < 0.1] = SENTINEL
    np.testing.assert_array_equal(_radix(w, use_kernel), np.sort(w))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_radix_sentinel_only(use_kernel):
    w = np.full(1000, SENTINEL, np.uint64)
    np.testing.assert_array_equal(_radix(w, use_kernel), w)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_radix_duplicate_words(use_kernel):
    rng = np.random.default_rng(1)
    base = rng.integers(0, 1 << 62, 7, dtype=np.uint64)
    w = rng.choice(base, 2048).astype(np.uint64)
    np.testing.assert_array_equal(_radix(w, use_kernel), np.sort(w))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_radix_empty(use_kernel):
    w = np.zeros((0,), np.uint64)
    assert len(_radix(w, use_kernel)) == 0


@pytest.mark.parametrize("use_kernel", [False, True])
def test_radix_full_capacity_limb_pairs(use_kernel):
    """Max field values of the 62-bit pack: a = b = 2**23 - 1 and the
    extreme size codes, mixed with sentinels — every digit boundary of
    the limb pair is exercised, at an exact tile multiple (no padding)
    and off-multiple (padding lanes)."""
    rid_max = (1 << PACK_RID_BITS) - 1
    a = np.asarray([rid_max, rid_max, 0, 0, rid_max - 1], np.int32)
    b = np.asarray([rid_max, rid_max, 1, rid_max, rid_max], np.int32)
    s = np.asarray([2, 65535, 65535, 2, 3], np.int32)
    hi, lo = pack_sort_words(jnp.asarray(a), jnp.asarray(b), jnp.asarray(s),
                             jnp.asarray(np.ones(5, bool)))
    base = _join(hi, lo)
    rng = np.random.default_rng(2)
    for n in (1024, 1000):  # tile-exact and padded
        w = rng.choice(np.concatenate([base, [SENTINEL]]), n).astype(np.uint64)
        np.testing.assert_array_equal(_radix(w, use_kernel), np.sort(w))


@pytest.mark.parametrize("use_kernel", [False, True])
def test_radix_truncated_passes_keep_sentinels_last(use_kernel):
    """With n_passes bounding only the valid words' significant bits the
    all-ones sentinel must still sort strictly last (its untouched high
    digits are ignored; its low 16 bits beat any valid size code)."""
    rng = np.random.default_rng(3)
    w = rng.integers(0, 1 << 40, 2048, dtype=np.uint64)
    w[:17] = SENTINEL
    np.testing.assert_array_equal(_radix(w, use_kernel, n_passes=10),
                                  np.sort(w))


def test_radix_matches_laxsort():
    rng = np.random.default_rng(4)
    w = rng.integers(0, 1 << 62, 2048, dtype=np.uint64)
    hi, lo = _limbs(w)
    chi, clo = jax.lax.sort((hi, lo), num_keys=2)
    np.testing.assert_array_equal(_radix(w), _join(chi, clo))


def test_numpy_oracle_matches_npsort():
    rng = np.random.default_rng(5)
    w = rng.integers(0, 1 << 62, 3000, dtype=np.uint64)
    w[:5] = SENTINEL
    np.testing.assert_array_equal(ksort.np_radix_sort_words(w), np.sort(w))


@pytest.mark.parametrize("n", [128 * 8, 129])
def test_pallas_kernel_bit_identical_to_jnp_mirror(n):
    rng = np.random.default_rng(6)
    w = rng.integers(0, 1 << 62, n, dtype=np.uint64)
    w[rng.random(n) < 0.05] = SENTINEL
    np.testing.assert_array_equal(_radix(w, use_kernel=True),
                                  _radix(w, use_kernel=False))


def test_radix_pass_histogram_and_rank():
    """One kernel pass: the per-tile histogram must count every digit and
    the in-tile ranks must be a stable enumeration of each digit class."""
    rng = np.random.default_rng(7)
    n = 2048  # two tiles
    w = rng.integers(0, 1 << 62, n, dtype=np.uint64)
    hi = (w >> np.uint64(32)).astype(np.uint32).reshape(-1, 128)
    lo = (w & np.uint64(0xFFFFFFFF)).astype(np.uint32).reshape(-1, 128)
    rank, hist = ksort.radix_pass_pallas(jnp.asarray(hi), jnp.asarray(lo),
                                         p=3, interpret=True)
    rank = np.asarray(rank).reshape(-1)
    hist = np.asarray(hist)[:, :ksort.RADIX]
    d = ((w >> np.uint64(3 * ksort.RADIX_BITS))
         & np.uint64(ksort.RADIX - 1)).astype(np.int64)
    tile = np.arange(n) // 1024
    for t in range(2):
        np.testing.assert_array_equal(
            hist[t], np.bincount(d[tile == t], minlength=ksort.RADIX))
        for k in range(ksort.RADIX):
            sel = (tile == t) & (d == k)
            np.testing.assert_array_equal(np.sort(rank[sel]),
                                          np.arange(sel.sum()))


# ---------------------------------------------------------------------------
# sort_backend threading through the dedupe stack
# ---------------------------------------------------------------------------


def _random_blocks(seed, n_blocks, max_size, universe):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(2, max_size + 1, n_blocks).astype(np.int64)
    start = np.concatenate([[0], np.cumsum(sizes)])[:-1]
    members = np.concatenate(
        [np.sort(rng.choice(universe, n, replace=False)) for n in sizes]
    ).astype(np.int64)
    zu = np.zeros(n_blocks, np.uint32)
    return pairs.Blocks(zu, zu, start, sizes, members)


def _assert_pairsets_equal(got, want, label):
    assert got.exact == want.exact, label
    assert got.total_slots == want.total_slots, label
    np.testing.assert_array_equal(got.a, want.a, err_msg=label)
    np.testing.assert_array_equal(got.b, want.b, err_msg=label)
    np.testing.assert_array_equal(got.src_size, want.src_size, err_msg=label)


def test_dedupe_packed_device_radix_matches_comparator():
    rng = np.random.default_rng(8)
    a = rng.integers(0, 500, 2048).astype(np.int32)
    b = (a + rng.integers(1, 100, 2048)).astype(np.int32)
    s = rng.integers(2, 600, 2048).astype(np.int32)
    valid = rng.random(2048) < 0.8
    hi, lo = pack_sort_words(jnp.asarray(a), jnp.asarray(b), jnp.asarray(s),
                             jnp.asarray(valid))
    outs = {}
    for sb in ("comparator", "radix"):
        # dedupe_packed_device is jit-free by contract ("for use INSIDE
        # shard_map"); call it through jit, as its real callers do
        fn = jax.jit(functools.partial(
            dedupe_packed_device, sort_backend=sb,
            n_passes=radix_passes_for(600)))
        shi, slo, win = fn(hi, lo)
        outs[sb] = _join(shi, slo)[np.asarray(win)]
    np.testing.assert_array_equal(outs["radix"], outs["comparator"])
    ga, gb, gs = unpack_words_host(np.sort(outs["radix"]))
    wa, wb, ws = pairs_ref.dedupe_ref(a[valid], b[valid], s[valid])
    np.testing.assert_array_equal(ga, wa)
    np.testing.assert_array_equal(gb, wb)
    np.testing.assert_array_equal(gs, ws)


def test_dedupe_device_radix_matches_comparator():
    rng = np.random.default_rng(9)
    a = rng.integers(0, 1000, 4096).astype(np.int32)
    b = (a + rng.integers(1, 50, 4096)).astype(np.int32)
    s = rng.integers(2, 65535, 4096).astype(np.int32)
    valid = rng.random(4096) < 0.9
    args = (jnp.asarray(a), jnp.asarray(b), jnp.asarray(s), jnp.asarray(valid))
    ca, cb, cs, cw = dedupe_device(*args, sort_backend="comparator")
    ra, rb, rs, rw = dedupe_device(*args, sort_backend="radix",
                                   n_passes=radix_passes_for(1050))
    cw, rw = np.asarray(cw), np.asarray(rw)
    np.testing.assert_array_equal(np.asarray(ra)[rw], np.asarray(ca)[cw])
    np.testing.assert_array_equal(np.asarray(rb)[rw], np.asarray(cb)[cw])
    np.testing.assert_array_equal(np.asarray(rs)[rw], np.asarray(cs)[cw])


@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("sort_backend", ["comparator", "radix"])
def test_dedupe_pairs_sort_backends_bit_identical(backend, sort_backend):
    blk = _random_blocks(10, 40, 30, universe=400)
    want = pairs.dedupe_pairs(blk, backend="numpy")
    got = pairs.dedupe_pairs(blk, backend=backend, sort_backend=sort_backend)
    _assert_pairsets_equal(got, want, f"{backend}/{sort_backend}")
    # budget-exceeded sampled path shares the seeded global sample
    budget = blk.num_pair_slots // 3
    want_s = pairs.dedupe_pairs(blk, budget=budget, backend="numpy",
                                sample_seed=11)
    got_s = pairs.dedupe_pairs(blk, budget=budget, backend=backend,
                               sample_seed=11, sort_backend=sort_backend)
    _assert_pairsets_equal(got_s, want_s, f"sampled {backend}/{sort_backend}")


@pytest.mark.parametrize("sort_backend", ["auto", "comparator", "radix"])
def test_routed_dedupe_sort_backends_one_device_mesh(sort_backend):
    """The routed distributed dedupe must be sort_backend-invariant (the
    emulated 8-host parity runs in the slow-lane _dist_worker)."""
    blk = _random_blocks(12, 30, 25, universe=300)
    mesh = jax.make_mesh((1,), ("data",))
    want = pairs.dedupe_pairs(blk, backend="numpy")
    got = dedupe_pairs_distributed(blk, mesh, ("data",), chunk_per_shard=1024,
                                   sort_backend=sort_backend)
    _assert_pairsets_equal(got, want, f"routed/{sort_backend}")


def test_radix_beyond_pack_bound_degrades_with_warning():
    blk = _random_blocks(13, 12, 10, universe=200)
    big = pairs.Blocks(blk.key_hi, blk.key_lo, blk.start, blk.size,
                       blk.members + (1 << PACK_RID_BITS))
    want = pairs.dedupe_pairs(big, backend="numpy")
    with pytest.warns(RuntimeWarning, match="62-bit sort"):
        got = pairs.dedupe_pairs(big, backend="jax", sort_backend="radix")
    _assert_pairsets_equal(got, want, "radix-degrade")


def test_invalid_sort_backend_rejected():
    blk = _random_blocks(14, 3, 5, universe=40)
    with pytest.raises(ValueError, match="sort_backend"):
        pairs.dedupe_pairs(blk, backend="jax", sort_backend="bogus")
    # eager validation: the numpy shortcut (sub-crossover workloads with
    # backend="auto") must reject the typo too, not silently ignore it
    assert blk.num_pair_slots < pairs._AUTO_NUMPY_CROSSOVER
    with pytest.raises(ValueError, match="sort_backend"):
        pairs.dedupe_pairs(blk, backend="auto", sort_backend="bogus")


def test_radix_passes_for_bounds():
    # 16 size bits + 23 b bits + bitlength(max a) digits, clamped
    assert radix_passes_for(0) == -(-(16 + 23 + 1) // ksort.RADIX_BITS)
    assert radix_passes_for((1 << PACK_RID_BITS) - 1) == ksort.MAX_PASSES
    assert radix_passes_for(1 << 40) == ksort.MAX_PASSES  # clamped
