"""System tests for Hashed Dynamic Blocking (Algorithms 1-4)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blocks, hdb, pairs, baselines
from repro.core.blocks import ColumnBlocking, TokenColumn
from repro.data import synthetic, metrics


@pytest.fixture(scope="module")
def corpus():
    return synthetic.generate(synthetic.SyntheticSpec(num_entities=2000, seed=3))


@pytest.fixture(scope="module")
def built(corpus):
    return blocks.build_keys(corpus.columns, corpus.blocking)


def _block_sizes(result):
    b = pairs.build_blocks(result, min_size=1)
    return b.size


def test_every_accepted_block_is_right_sized(built):
    keys, valid = built
    cfg = hdb.HDBConfig(max_block_size=50, max_iterations=6)
    res = hdb.hashed_dynamic_blocking(keys, valid, cfg)
    sizes = _block_sizes(res)
    assert len(sizes) > 0
    assert sizes.max() <= 50


def test_no_duplicate_assignments(built):
    keys, valid = built
    res = hdb.hashed_dynamic_blocking(keys, valid, hdb.HDBConfig(max_block_size=50))
    key64 = (res.key_hi.astype(np.uint64) << np.uint64(32)) | res.key_lo
    assign = np.stack([key64, res.rids.astype(np.uint64)], 1)
    assert len(np.unique(assign, axis=0)) == len(assign)


def test_hdb_recall_superset_of_threshold(built, corpus):
    """HDB accepts every block THR accepts (iteration 1 == THR) plus
    intersections of the over-sized remainder => PC(HDB) >= PC(THR)."""
    keys, valid = built
    labeled = corpus.labeled_pairs()
    thr = baselines.threshold_blocking(keys, valid, max_block_size=50)
    res = hdb.hashed_dynamic_blocking(keys, valid, hdb.HDBConfig(max_block_size=50))
    m_thr = metrics.evaluate(thr, corpus, labeled)
    m_hdb = metrics.evaluate(res, corpus, labeled)
    assert m_hdb.pc >= m_thr.pc - 1e-9
    assert m_hdb.pc > 0.5  # sanity: blocking actually finds duplicates


def test_hdb_finds_intersections_threshold_misses():
    """Two over-sized blocks whose intersection is a right-sized block:
    THR drops everything; HDB must find the intersection (the paper's
    'Jones' x 'Tim' example)."""
    n = 400
    # column A: everyone shares value a0 => one giant block
    col_a = TokenColumn(jnp.full((n, 1), 7, jnp.uint32), jnp.ones((n, 1), bool))
    # column B: first 30 records share b0, rest unique
    b = np.arange(n, dtype=np.uint32) + 1000
    b[:30] = 999
    col_b = TokenColumn(jnp.asarray(b[:, None]), jnp.ones((n, 1), bool))
    keys, valid = blocks.build_keys(
        {"a": col_a, "b": col_b},
        {"a": ColumnBlocking.identity(), "b": ColumnBlocking.identity()})
    cfg = hdb.HDBConfig(max_block_size=100, max_iterations=4)
    thr = baselines.threshold_blocking(keys, valid, max_block_size=100)
    res = hdb.hashed_dynamic_blocking(keys, valid, cfg)
    thr_blocks = pairs.build_blocks(thr)
    hdb_blocks = pairs.build_blocks(res)
    # THR: block A over-sized (400) dropped; block b0 (30) kept.
    assert thr_blocks.num_blocks == 1
    # HDB additionally intersects A with b0 -> same 30 records (duplicate
    # membership -> deduped), so pairs must cover the 30-clique.
    pset = pairs.dedupe_pairs(hdb_blocks)
    clique = set()
    for x, y in zip(pset.a, pset.b):
        if x < 30 and y < 30:
            clique.add((int(x), int(y)))
    assert len(clique) == 30 * 29 // 2


def test_duplicate_blocks_are_deduped():
    """Two columns with identical partitions produce identical over-sized
    blocks; after intersection they'd explode quadratically unless deduped
    (paper Alg. 4). Verify the iteration reports duplicates."""
    n = 300
    v = np.repeat(np.arange(2, dtype=np.uint32), n // 2)
    cols = {
        "a": TokenColumn(jnp.asarray(v[:, None]), jnp.ones((n, 1), bool)),
        "b": TokenColumn(jnp.asarray((v + 10)[:, None]), jnp.ones((n, 1), bool)),
    }
    spec = {k: ColumnBlocking.identity() for k in cols}
    keys, valid = blocks.build_keys(cols, spec)
    cfg = hdb.HDBConfig(max_block_size=50, max_iterations=3)
    res = hdb.hashed_dynamic_blocking(keys, valid, cfg)
    # iteration 1: intersecting the deduped pair of over-sized blocks can
    # only produce blocks identical to their parents -> progress heuristic
    # kills them; nothing right-sized ever appears.
    assert sum(s.n_duplicate_blocks for s in res.stats) >= 2
    assert len(res.rids) == 0


def test_rep_capacity_overflow_is_warned_and_counted():
    """A tiny ``rep_capacity`` drops over-sized block representatives —
    a silent divergence from the capless streaming store unless surfaced:
    the run must emit RepCapacityWarning AND report the dropped count in
    ``BlockingResult.rep_overflow_total``."""
    n, m = 160, 16      # two 16-way partitions: 32 over-sized 10-blocks
    va = (np.arange(n, dtype=np.uint32) % m)
    vb = (np.arange(n, dtype=np.uint32) // (n // m))
    cols = {
        "a": TokenColumn(jnp.asarray(va[:, None]), jnp.ones((n, 1), bool)),
        "b": TokenColumn(jnp.asarray(vb[:, None]), jnp.ones((n, 1), bool)),
    }
    spec = {k: ColumnBlocking.identity() for k in cols}
    keys, valid = blocks.build_keys(cols, spec)
    cfg_small = hdb.HDBConfig(max_block_size=5, max_iterations=2,
                              rep_capacity=4)
    with pytest.warns(hdb.RepCapacityWarning):
        res = hdb.hashed_dynamic_blocking(keys, valid, cfg_small)
    # iteration 0 found 2*m over-sized representatives, capacity 4
    assert res.stats[0].rep_overflow == 2 * m - 4
    assert res.rep_overflow_total >= 2 * m - 4
    # a capacious run keeps every representative and reports zero
    cfg_big = hdb.HDBConfig(max_block_size=5, max_iterations=2,
                            rep_capacity=1 << 10)
    res_big = hdb.hashed_dynamic_blocking(keys, valid, cfg_big)
    assert res_big.rep_overflow_total == 0
    # the count quantifies the divergence: dropped reps' blocks vanish
    # from the survivor set instead of surviving to intersection
    assert res.stats[0].n_surviving_oversized == 4
    assert res_big.stats[0].n_surviving_oversized == 2 * m


def test_progress_heuristic_terminates():
    """Blocks too similar to parents are discarded (MAX_SIMILARITY)."""
    n = 500
    v = np.zeros(n, np.uint32)
    cols = {
        "a": TokenColumn(jnp.asarray(v[:, None]), jnp.ones((n, 1), bool)),
        "b": TokenColumn(jnp.asarray(v[:, None] + 5), jnp.ones((n, 1), bool)),
        "c": TokenColumn(jnp.asarray(v[:, None] + 9), jnp.ones((n, 1), bool)),
    }
    spec = {k: ColumnBlocking.identity() for k in cols}
    keys, valid = blocks.build_keys(cols, spec)
    res = hdb.hashed_dynamic_blocking(
        keys, valid, hdb.HDBConfig(max_block_size=100, max_iterations=6))
    assert len(res.rids) == 0
    assert len(res.stats) < 6  # converged before the cap, didn't spin


def test_max_keys_guard():
    """Records with more than MAX_KEYS over-sized keys are dropped from
    intersection (Alg. 2 line 2). Six *distinct* binary partitions (bit i of
    rid) give every record 6 over-sized keys with distinct memberships."""
    n = 256
    rid = np.arange(n, dtype=np.uint32)
    cols = {
        f"c{i}": TokenColumn(jnp.asarray(((rid >> i) & 1)[:, None] + 10 * i),
                             jnp.ones((n, 1), bool))
        for i in range(6)
    }
    spec = {k: ColumnBlocking.identity() for k in cols}
    keys, valid = blocks.build_keys(cols, spec)
    cfg = hdb.HDBConfig(max_block_size=50, max_keys=4, max_iterations=2)
    res = hdb.hashed_dynamic_blocking(keys, valid, cfg)
    assert res.stats[0].n_dropped_max_keys == n
    assert len(res.rids) == 0
    # with a permissive max_keys the same corpus DOES produce intersections
    res2 = hdb.hashed_dynamic_blocking(
        keys, valid, hdb.HDBConfig(max_block_size=50, max_keys=80,
                                   max_iterations=4))
    assert len(res2.rids) > 0


def test_cms_overcount_recovery(built):
    """With a tiny CMS, many right-sized blocks get over-counted; the exact
    stage must recover them (identical final accepted set modulo none lost)."""
    keys, valid = built
    big = hdb.hashed_dynamic_blocking(
        keys, valid, hdb.HDBConfig(max_block_size=50, cms_width=1 << 20))
    small = hdb.hashed_dynamic_blocking(
        keys, valid, hdb.HDBConfig(max_block_size=50, cms_width=1 << 10))
    def key_set(r):
        return set(zip(r.rids.tolist(), r.key_hi.tolist(), r.key_lo.tolist()))
    assert key_set(big) == key_set(small)
    assert sum(s.n_right_exact for s in small.stats) > 0
