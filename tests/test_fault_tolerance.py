"""Fault tolerance: checkpoint/restart (training AND the HDB pipeline),
corruption detection, elastic resharding, straggler detection, preemption."""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core import blocks, hdb
from repro.data import synthetic
from repro.launch import specs
from repro.models.model import build_model
from repro.training import checkpoint
from repro.training.optimizer import OptimizerConfig
from repro.training.stragglers import PreemptionHandler, StragglerConfig, StragglerMonitor
from repro.training.train_loop import TrainConfig, init_train_state, make_train_step


def _tree_equal(a, b):
    eq = jax.tree.map(
        lambda x, y: bool(jnp.all(x.astype(jnp.float32) == y.astype(jnp.float32))),
        a, b)
    return all(jax.tree.leaves(eq))


def test_checkpoint_roundtrip_mixed_dtypes(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.int32).reshape(2, 3),
        "b": jnp.ones((4,), jnp.bfloat16) * 1.5,
        "c": {"d": jnp.asarray([True, False]),
              "e": jnp.asarray(3.25, jnp.float32)},
        "f": jnp.asarray([1, 2], jnp.uint32),
    }
    checkpoint.save(str(tmp_path), 7, tree)
    assert checkpoint.latest_step(str(tmp_path)) == 7
    got = checkpoint.restore(str(tmp_path), tree)
    assert _tree_equal(tree, got)
    assert got["b"].dtype == jnp.bfloat16


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"w": jnp.ones((8, 8))}
    path = checkpoint.save(str(tmp_path), 1, tree)
    npz = os.path.join(path, "arrays.npz")
    data = dict(np.load(npz))
    data["leaf_0"] = data["leaf_0"] + 1  # corrupt
    np.savez(npz, **data)
    with pytest.raises(IOError, match="corruption"):
        checkpoint.restore(str(tmp_path), tree)


def test_checkpoint_gc_keeps_latest(tmp_path):
    tree = {"w": jnp.zeros(2)}
    for step in range(6):
        checkpoint.save(str(tmp_path), step, tree, keep=2)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(dirs) == 2
    assert checkpoint.latest_step(str(tmp_path)) == 5


def test_train_resume_bitwise_identical(tmp_path):
    """kill-after-step-N resume == uninterrupted run (same batches)."""
    cfg = reduced_config("tinyllama-1.1b")
    model = build_model(cfg)
    tcfg = TrainConfig(opt=OptimizerConfig(lr=1e-3, warmup_steps=0,
                                           total_steps=50))
    batches = [specs.train_batch(cfg, 16, 2, concrete=True,
                                 rng=np.random.default_rng(i))
               for i in range(6)]
    step = jax.jit(make_train_step(model, tcfg))

    # uninterrupted
    s = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    for b in batches:
        s, _ = step(s, b)
    # interrupted at step 3 + resume
    s2 = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    for b in batches[:3]:
        s2, _ = step(s2, b)
    checkpoint.save(str(tmp_path), 3, s2)
    resumed = checkpoint.restore(str(tmp_path),
                                 jax.eval_shape(lambda: s2))
    for b in batches[3:]:
        resumed, _ = step(resumed, b)
    assert _tree_equal(s["params"], resumed["params"])
    assert int(resumed["step"]) == 6


def test_hdb_pipeline_checkpoint_resume(tmp_path):
    """Blocking restarted from iteration-1 state matches the full run."""
    corpus = synthetic.generate(synthetic.SyntheticSpec(num_entities=600, seed=2))
    keys, valid = blocks.build_keys(corpus.columns, corpus.blocking)
    cfg = hdb.HDBConfig(max_block_size=40, max_iterations=5)

    full = hdb.hashed_dynamic_blocking(keys, valid, cfg)

    # run iteration 0 manually, checkpoint the state, resume manually
    psize = jnp.full(valid.shape, hdb.INT32_MAX, jnp.int32)
    accepted, (k1, v1, p1), stats = hdb.hdb_iteration(cfg, keys, valid, psize)
    state = {"keys": k1, "valid": v1, "psize": p1}
    checkpoint.save(str(tmp_path), 0, state)
    restored = checkpoint.restore(str(tmp_path), jax.eval_shape(lambda: state))

    acc_list = [np.asarray(accepted)]
    k, v, p = restored["keys"], restored["valid"], restored["psize"]
    for _ in range(1, cfg.max_iterations):
        acc, (k, v, p), st = hdb.hdb_iteration(cfg, k, v, p)
        acc_list.append(np.asarray(acc))
        if int(st["n_surviving_entries"]) == 0:
            break
    resumed_total = sum(a.sum() for a in acc_list)
    full_total = len(full.rids)
    assert resumed_total == full_total


def test_elastic_restore_reshards(tmp_path):
    """Restore with an explicit (single-device) sharding spec works — the
    elastic path device_puts every leaf into the target sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    checkpoint.save(str(tmp_path), 0, tree)
    shard = {"w": NamedSharding(mesh, P("data", None))}
    got = checkpoint.restore(str(tmp_path), tree, sharding=shard)
    assert got["w"].sharding == shard["w"]
    assert _tree_equal(tree, got)


def test_straggler_monitor_flags_persistent_slowness():
    mon = StragglerMonitor(StragglerConfig(outlier_factor=2.0, trip_threshold=3))
    flags = []
    for step in range(20):
        dur = 1.0 if step < 10 else 5.0  # becomes 5x slower at step 10
        flags.append(mon.end_step(step, duration=dur))
    assert not any(flags[:10])
    assert any(flags[10:])


def test_straggler_monitor_tolerates_single_blip():
    mon = StragglerMonitor(StragglerConfig(outlier_factor=2.0, trip_threshold=3))
    flags = [mon.end_step(0, duration=1.0)]
    flags.append(mon.end_step(1, duration=9.0))  # one GC pause
    for step in range(2, 10):
        flags.append(mon.end_step(step, duration=1.0))
    assert not any(flags)


def test_preemption_handler_requests_checkpoint():
    h = PreemptionHandler().install()
    try:
        os.kill(os.getpid(), signal.SIGTERM)
        assert h.requested
    finally:
        h.uninstall()


def test_heartbeat_written(tmp_path):
    hb = str(tmp_path / "hb")
    mon = StragglerMonitor(StragglerConfig(heartbeat_path=hb, heartbeat_every=2))
    mon.end_step(0, duration=1.0)
    mon.end_step(1, duration=1.0)
    assert os.path.exists(hb)
