"""Training substrate tests: optimizer convergence, grad accumulation
equivalence, gradient compression parity, serving engine determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.launch import specs
from repro.models.model import build_model
from repro.serving.engine import Request, ServingEngine
from repro.training.compression import dequantize_int8, quantize_int8
from repro.training.optimizer import OptimizerConfig, adamw_update, init_opt_state, schedule
from repro.training.train_loop import TrainConfig, init_train_state, make_train_step


def test_adamw_converges_quadratic():
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=200,
                          weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(cfg, params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_schedule_warmup_and_decay():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5, rel=0.01)
    assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0, rel=0.01)
    assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1, rel=0.05)


def test_grad_clip_bounds_update():
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, grad_clip=1.0,
                          weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(cfg, params)
    _, _, metrics = adamw_update(cfg, params, {"w": jnp.full(4, 1e6)}, state)
    assert metrics["grad_norm"] > 1e6  # reported pre-clip


def test_quantize_roundtrip_error_small():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 256)), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    amax = np.abs(np.asarray(x)).max(axis=1, keepdims=True)
    assert (err <= amax / 127 * 0.51 + 1e-7).all()


def _tiny_model_and_batch():
    cfg = reduced_config("tinyllama-1.1b")
    model = build_model(cfg)
    batch = specs.train_batch(cfg, 32, 4, concrete=True,
                              rng=np.random.default_rng(7))
    return model, batch


def test_grad_accum_matches_full_batch():
    model, batch = _tiny_model_and_batch()
    opt = OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=100)
    s1 = init_train_state(model, jax.random.PRNGKey(0), TrainConfig(opt=opt))
    s2 = init_train_state(model, jax.random.PRNGKey(0),
                          TrainConfig(opt=opt, grad_accum=2))
    step1 = jax.jit(make_train_step(model, TrainConfig(opt=opt)))
    step2 = jax.jit(make_train_step(model, TrainConfig(opt=opt, grad_accum=2)))
    s1b, m1 = step1(s1, batch)
    s2b, m2 = step2(s2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-3)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        s1b["params"], s2b["params"])
    assert max(jax.tree.leaves(d)) < 5e-3


def test_compressed_training_tracks_uncompressed():
    """int8+EF training must stay close to exact training on a small LM."""
    model, batch = _tiny_model_and_batch()
    opt = OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=100)
    plain_state = init_train_state(model, jax.random.PRNGKey(0),
                                   TrainConfig(opt=opt))
    comp_state = init_train_state(model, jax.random.PRNGKey(0),
                                  TrainConfig(opt=opt, compress_grads=True))
    plain = jax.jit(make_train_step(model, TrainConfig(opt=opt)))
    comp = jax.jit(make_train_step(model,
                                   TrainConfig(opt=opt, compress_grads=True)))
    for _ in range(10):
        plain_state, mp = plain(plain_state, batch)
        comp_state, mc = comp(comp_state, batch)
    # both must have reduced loss, and end within a few percent
    assert float(mc["loss"]) < float(mp["loss"]) * 1.1 + 0.1


def test_serving_engine_generates():
    cfg = reduced_config("tinyllama-1.1b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, batch_slots=2, max_len=64)
    eng.submit(Request(uid=1, prompt=np.asarray([5, 6, 7], np.int32),
                       max_new_tokens=5, eos_id=-1))
    eng.submit(Request(uid=2, prompt=np.asarray([9, 3], np.int32),
                       max_new_tokens=4, eos_id=-1))
    results = eng.run()
    assert sorted(r.uid for r in results) == [1, 2]
    lens = {r.uid: len(r.tokens) for r in results}
    assert lens[1] == 5 and lens[2] == 4
    for r in results:
        assert all(0 <= t < cfg.vocab_size for t in r.tokens)
