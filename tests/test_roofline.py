"""HLO cost-model validation against analytically-known programs.

These pin the two facts the roofline report depends on:
  1. XLA's cost_analysis counts while bodies once (so we must not use it),
  2. our HloCostModel recovers exact dot FLOPs and loop trip counts.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import HloCostModel, analyze


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_dot_flops_exact():
    m, k, n = 128, 256, 512
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((m, k), jnp.float32),
                 jax.ShapeDtypeStruct((k, n), jnp.float32))
    cost = HloCostModel(c.as_text()).entry_cost()
    assert cost.flops == 2 * m * k * n


def test_scan_multiplies_by_trip_count():
    d, trips = 128, 12

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=trips)
        return y

    c = _compile(f, jax.ShapeDtypeStruct((d, d), jnp.float32),
                 jax.ShapeDtypeStruct((d, d), jnp.float32))
    cost = HloCostModel(c.as_text()).entry_cost()
    dot_flops = 2 * d * d * d * trips
    assert cost.flops >= dot_flops, (cost.flops, dot_flops)
    assert cost.flops < dot_flops * 1.5  # elementwise overhead is small
    # sanity: XLA's own analysis under-counts (bodies once); newer jaxlibs
    # return a per-device list of cost dicts, older ones a bare dict
    ca = c.cost_analysis()
    xla_flops = (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]
    assert xla_flops < dot_flops / 2


def test_nested_scan_trip_counts_compose():
    d, outer, inner = 64, 5, 7

    def f(x, w):
        def inner_body(c, _):
            return c @ w, None

        def outer_body(c, _):
            y, _ = jax.lax.scan(inner_body, c, None, length=inner)
            return y, None

        y, _ = jax.lax.scan(outer_body, x, None, length=outer)
        return y

    c = _compile(f, jax.ShapeDtypeStruct((d, d), jnp.float32),
                 jax.ShapeDtypeStruct((d, d), jnp.float32))
    cost = HloCostModel(c.as_text()).entry_cost()
    expect = 2 * d ** 3 * outer * inner
    assert expect <= cost.flops <= expect * 1.3


def test_grad_flops_about_3x_forward():
    d = 128

    def loss(w, x):
        return jnp.sum((x @ w) ** 2)

    x = jax.ShapeDtypeStruct((d, d), jnp.float32)
    fwd = HloCostModel(_compile(loss, x, x).as_text()).entry_cost()
    bwd = HloCostModel(
        _compile(jax.grad(loss, argnums=(0, 1)), x, x).as_text()).entry_cost()
    assert 2.2 <= bwd.flops / fwd.flops <= 3.8


def test_bytes_track_memory_traffic():
    n = 1 << 20

    def f(a, b):
        return a * 2.0 + b

    c = _compile(f, jax.ShapeDtypeStruct((n,), jnp.float32),
                 jax.ShapeDtypeStruct((n,), jnp.float32))
    cost = HloCostModel(c.as_text()).entry_cost()
    # two reads + one write of 4MB each
    assert 2.5 * 4 * n <= cost.bytes <= 4 * 4 * n


def test_analyze_smoke_model_flops_ratio():
    """Whole-model check: HLO flops within 2x of the 6ND estimate."""
    from repro.configs import reduced_config
    from repro.models.model import build_model
    from repro.launch import specs

    cfg = reduced_config("tinyllama-1.1b")
    model = build_model(cfg)
    batch = specs.train_batch(cfg, 64, 4)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    def loss_fn(p, b):
        return model.loss(p, b)[0]

    c = jax.jit(jax.grad(loss_fn)).lower(params, batch).compile()
    roof, cost = analyze(c.as_text(), chips=1)
    # 6 N D with N = non-embedding params approx
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    d_tokens = 4 * 64
    model_flops = 6 * n_params * d_tokens
    ratio = cost.flops / model_flops
    assert 0.5 < ratio < 4.0, ratio
