"""Fused match->filter->cluster: bit-identity, oracle, and bound tests.

The contract under test (docs/PIPELINE.md): the fused device path
(kernels/match + components.cluster_pairs_device) produces the SAME
matched-pair set, component labels, and survivors as the host baseline —
bit-identical, not approximately — and connected components agree with a
numpy union-find oracle on arbitrary graphs. This module runs under
``--transfer-guard`` (conftest.TRANSFER_GUARDED_MODULES): the whole
match->cluster hot path must hold the no-implicit-transfer contract.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from _propcheck import given, settings, st
from repro.core import hdb
from repro.data import components, matcher, pipeline, synthetic
from repro.kernels.match import ops as match_ops
from repro.kernels.match import ref as match_ref
from repro.kernels.match import packed_host

DEVICE_BACKENDS = ("jnp", "pallas")


@pytest.fixture(scope="module")
def corpus():
    return synthetic.generate(synthetic.SyntheticSpec(num_entities=150,
                                                      seed=7))


@pytest.fixture(scope="module")
def hdb_cfg():
    return hdb.HDBConfig(max_block_size=30, max_iterations=5,
                         cms_width=1 << 12)


def _random_pairs(corpus, seed, n_pairs=3000):
    """Candidate mix: random pairs + true duplicate pairs (so a healthy
    fraction actually clears the match threshold)."""
    rng = np.random.default_rng(seed)
    n = corpus.num_records
    a = rng.integers(0, n, n_pairs // 2)
    b = rng.integers(0, n, n_pairs // 2)
    la, lb = corpus.labeled_pairs()
    take = rng.integers(0, len(la), n_pairs - len(a))
    a = np.concatenate([a, la[take]]).astype(np.int64)
    b = np.concatenate([b, lb[take]]).astype(np.int64)
    return a, b


# ---------------------------------------------------------------------------
# connected components: union-find oracle + bounds
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_cc_matches_oracle_random_graphs(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 200))
    m = int(rng.integers(0, 400))
    a = rng.integers(0, n, m)      # self-pairs occur naturally
    b = rng.integers(0, n, m)
    got = components.connected_components(n, a, b)
    want = components.connected_components_oracle(n, a, b)
    np.testing.assert_array_equal(got, want)


def test_cc_empty_edges():
    got = components.connected_components(17, np.zeros(0), np.zeros(0))
    np.testing.assert_array_equal(got, np.arange(17))


def test_cc_self_pairs_only():
    idx = np.arange(9)
    got = components.connected_components(9, idx, idx)
    np.testing.assert_array_equal(got, np.arange(9))


def test_cc_single_giant_component():
    # a shuffled chain linking every node: one component labeled 0
    n = 300
    rng = np.random.default_rng(0)
    order = rng.permutation(n)
    a, b = order[:-1], order[1:]
    got = components.connected_components(n, a, b)
    np.testing.assert_array_equal(got, np.zeros(n, np.int64))
    np.testing.assert_array_equal(
        got, components.connected_components_oracle(n, a, b))


def test_cc_max_rounds_is_enforced():
    # a long path graph needs ~log2(n) doubling rounds; max_rounds=1
    # cannot converge and must warn instead of silently truncating
    n = 128
    a, b = np.arange(n - 1), np.arange(1, n)
    with pytest.warns(RuntimeWarning, match="max_rounds"):
        components.connected_components(n, a, b, max_rounds=1)
    # ...and the default bound converges silently on the same graph
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        got = components.connected_components(n, a, b)
    np.testing.assert_array_equal(got, np.zeros(n, np.int64))


def test_cluster_edges_matches_oracle_and_pads():
    rng = np.random.default_rng(3)
    n, m = 1500, 900         # edge count not a pow-2: exercises padding
    a = rng.integers(0, n, m)
    b = rng.integers(0, n, m)
    res = components.cluster_edges(n, a, b)
    want = components.connected_components_oracle(n, a, b)
    np.testing.assert_array_equal(res.label, want)
    np.testing.assert_array_equal(res.survivors, np.unique(want))
    assert res.converged and res.rounds > 0
    assert len(res.label) == n       # capacity padding cropped


def test_cluster_edges_empty():
    res = components.cluster_edges(11, np.zeros(0), np.zeros(0))
    np.testing.assert_array_equal(res.label, np.arange(11))
    np.testing.assert_array_equal(res.survivors, np.arange(11))
    assert res.converged and res.rounds == 0


def test_cluster_edges_truncation_warns_and_flags():
    n = 256
    a, b = np.arange(n - 1), np.arange(1, n)
    with pytest.warns(RuntimeWarning, match="max_rounds"):
        res = components.cluster_edges(n, a, b, max_rounds=1)
    assert not res.converged


# ---------------------------------------------------------------------------
# fused match: kernel/mirror/oracle/host agreement
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_match_compact_matches_host_baseline(corpus, backend):
    a, b = _random_pairs(corpus, seed=11)
    host = matcher.match_pairs(corpus.columns, a, b)
    ca, cb, cnt = matcher.match_compact(corpus.columns, a, b,
                                        backend=backend)
    cnt = int(np.asarray(cnt))
    assert cnt == int(host.sum())
    # compaction is order-preserving: matched pairs in candidate order
    np.testing.assert_array_equal(np.asarray(ca)[:cnt], a[host])
    np.testing.assert_array_equal(np.asarray(cb)[:cnt], b[host])
    # tail is (0, 0) padding
    assert not np.asarray(ca)[cnt:].any()
    assert not np.asarray(cb)[cnt:].any()


@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_match_compact_matches_numpy_oracle(corpus, backend):
    a, b = _random_pairs(corpus, seed=12, n_pairs=1100)
    tokens, masks, weights = matcher._schema(corpus.columns,
                                             matcher.MatcherConfig())
    ca, cb, cnt = matcher.match_compact(corpus.columns, a, b,
                                        backend=backend)
    ra, rb, rcount = match_ref.np_match_compact(
        [np.asarray(t) for t in tokens], [np.asarray(m) for m in masks],
        weights, a, b, threshold=matcher.MatcherConfig().threshold,
        out_len=len(np.asarray(ca)))
    assert int(np.asarray(cnt)) == rcount
    np.testing.assert_array_equal(np.asarray(ca), ra)
    np.testing.assert_array_equal(np.asarray(cb), rb)


def test_match_compact_multi_chunk(corpus):
    # chunk smaller than the pair list: exercises the cross-chunk base
    # cumsum in compact_matched and the tail-validity mask
    a, b = _random_pairs(corpus, seed=13, n_pairs=3000)
    host = matcher.match_pairs(corpus.columns, a, b)
    ca, cb, cnt = matcher.match_compact(corpus.columns, a, b,
                                        backend="jnp", chunk=1024)
    cnt = int(np.asarray(cnt))
    assert cnt == int(host.sum())
    np.testing.assert_array_equal(np.asarray(ca)[:cnt], a[host])
    np.testing.assert_array_equal(np.asarray(cb)[:cnt], b[host])


def test_match_compact_empty(corpus):
    ca, cb, cnt = matcher.match_compact(
        corpus.columns, np.zeros(0, np.int64), np.zeros(0, np.int64))
    assert int(np.asarray(cnt)) == 0
    assert np.asarray(ca).shape == (0,)


def test_match_compact_accepts_device_buffers(corpus):
    a, b = _random_pairs(corpus, seed=14, n_pairs=800)
    da = jnp.asarray(np.asarray(a, np.int32))
    db = jnp.asarray(np.asarray(b, np.int32))
    ca, cb, cnt = matcher.match_compact(corpus.columns, da, db)
    host = matcher.match_pairs(corpus.columns, a, b)
    cnt = int(np.asarray(cnt))
    np.testing.assert_array_equal(np.asarray(ca)[:cnt], a[host])
    words = packed_host(ca, cb, cnt)
    assert words.dtype == np.uint64
    np.testing.assert_array_equal(
        words, (np.asarray(a[host], np.uint64) << np.uint64(32))
        | np.asarray(b[host], np.uint64))


def test_match_compact_rejects_host_backend(corpus):
    with pytest.raises(ValueError, match="host"):
        matcher.match_compact(corpus.columns, np.zeros(1, np.int64),
                              np.zeros(1, np.int64), backend="host")
    with pytest.raises(ValueError, match="match_backend"):
        matcher.match_compact(corpus.columns, np.zeros(1, np.int64),
                              np.zeros(1, np.int64), backend="bogus")


def test_oracle_scores_bit_identical_to_host(corpus):
    # the ref.py f32 op sequence must reproduce device scores exactly
    a, b = _random_pairs(corpus, seed=15, n_pairs=900)
    tokens, masks, weights = matcher._schema(corpus.columns,
                                             matcher.MatcherConfig())
    got = match_ref.np_score_pairs(
        [np.asarray(t) for t in tokens], [np.asarray(m) for m in masks],
        weights, a, b)
    want = matcher.score_pairs(corpus.columns, a, b)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# end-to-end bit-identity: dedup_corpus and DedupPipeline.extend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_dedup_corpus_fused_matches_host(corpus, hdb_cfg, backend):
    host = pipeline.dedup_corpus(corpus, hdb_cfg, match_backend="host")
    fused = pipeline.dedup_corpus(corpus, hdb_cfg, match_backend=backend)
    assert fused.num_candidate_pairs == host.num_candidate_pairs
    assert fused.num_matched_pairs == host.num_matched_pairs
    assert fused.num_components == host.num_components
    np.testing.assert_array_equal(fused.component_of, host.component_of)
    np.testing.assert_array_equal(fused.survivors, host.survivors)
    # labels agree with the union-find oracle on the matched graph
    dev_label = components.connected_components_oracle(
        corpus.num_records, *_matched_edges(corpus, hdb_cfg))
    np.testing.assert_array_equal(fused.component_of, dev_label)


def _matched_edges(corpus, cfg):
    from repro.core import blocks as blocks_mod
    from repro.core import pairs as pairs_mod
    keys, valid = blocks_mod.build_keys(corpus.columns, corpus.blocking)
    result = hdb.hashed_dynamic_blocking(keys, valid, cfg)
    pset = pairs_mod.dedupe_pairs(pairs_mod.build_blocks(result))
    matched = matcher.match_pairs(corpus.columns, *pset.pair_buffers())
    return pset.a[matched], pset.b[matched]


@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_pipeline_extend_fused_matches_host(corpus, hdb_cfg, backend):
    n = corpus.num_records
    rng = np.random.default_rng(21)
    cuts = np.sort(rng.choice(np.arange(1, n), 2, replace=False))
    pipe_h = pipeline.DedupPipeline(hdb_cfg, match_backend="host")
    pipe_f = pipeline.DedupPipeline(hdb_cfg, match_backend=backend)
    for part in np.split(np.arange(n), cuts):
        delta = synthetic.corpus_slice(corpus, part)
        rh = pipe_h.extend(delta)
        rf = pipe_f.extend(delta)
        assert rf.num_matched_pairs == rh.num_matched_pairs
        np.testing.assert_array_equal(rf.component_of, rh.component_of)
        np.testing.assert_array_equal(rf.survivors, rh.survivors)
        # the packed matched-pair ledgers agree word for word
        np.testing.assert_array_equal(pipe_f._matched, pipe_h._matched)
    # ...and the final streaming state matches the batch run
    batch = pipeline.dedup_corpus(corpus, hdb_cfg, match_backend=backend)
    assert rf.num_matched_pairs == batch.num_matched_pairs
    np.testing.assert_array_equal(rf.component_of, batch.component_of)


def test_dedup_corpus_rejects_bad_backend(corpus, hdb_cfg):
    with pytest.raises(ValueError, match="match_backend"):
        pipeline.dedup_corpus(corpus, hdb_cfg, match_backend="nope")


# ---------------------------------------------------------------------------
# compaction combiner unit: jnp path == kernel tile semantics
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_compact_matched_prefix_scatter(seed):
    rng = np.random.default_rng(seed)
    n = 4 * 128
    aa = rng.integers(0, 1000, n).astype(np.int32)
    bb = rng.integers(0, 1000, n).astype(np.int32)
    matched = rng.random(n) < rng.random()    # varying density
    m2 = matched.reshape(-1, 128).astype(np.int32)
    rank = (np.cumsum(m2, axis=1) - m2).reshape(-1)
    counts = m2.sum(axis=1)
    ca, cb, cnt = match_ops.compact_matched(
        jnp.asarray(aa), jnp.asarray(bb), jnp.asarray(matched),
        jnp.asarray(rank.astype(np.int32)),
        jnp.asarray(counts.astype(np.int32)))
    cnt = int(np.asarray(cnt))
    assert cnt == int(matched.sum())
    np.testing.assert_array_equal(np.asarray(ca)[:cnt], aa[matched])
    np.testing.assert_array_equal(np.asarray(cb)[:cnt], bb[matched])
    assert not np.asarray(ca)[cnt:].any()
