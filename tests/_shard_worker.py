"""Subprocess worker: mesh-routed sharded streaming store parity.

On 8 emulated host devices: a ``ShardedBlockStore`` with a live mesh
(key-table deltas exchanged with ``route_buckets`` + one ``all_to_all``
per level, pair-ledger syncs through ``dedupe_pairs_distributed``) must
stay bit-identical to the single-host ``DeltaBlocker`` AND to one batch
HDB run on the union, on flat/pod/3axis meshes. The ``overflow`` mode
forces the key-exchange bucket overflow and asserts the fallback is loud
(``RepCapacityWarning`` + counter) and lossless.

Invoked by test_distributed.py with
XLA_FLAGS=--xla_force_host_platform_device_count=8 in the child env.
"""
import os
import sys
import warnings

assert "--xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import blocks as blocks_mod
from repro.core import hdb as hdb_mod
from repro.core import pairs as pairs_mod
from repro.core.hdb import RepCapacityWarning
from repro.streaming.delta import DeltaBlocker
from repro.streaming.shard import ShardedBlockStore
from repro.streaming.store import BlockStore, pack_pair

CFG = hdb_mod.HDBConfig(max_block_size=8, max_iterations=5,
                        max_oversize_keys=6, cms_width=1 << 10)


def random_keys(rng, n, k, card, pvalid=0.85):
    """Mirror of test_streaming._random_keys (low-cardinality layout)."""
    k64 = (rng.integers(0, card, (n, k)).astype(np.uint64)
           * np.uint64(0x9E3779B97F4A7C15))
    valid = rng.random((n, k)) < pvalid
    keys = np.stack([(k64 >> np.uint64(32)).astype(np.uint32),
                     (k64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)], -1)
    keys[~valid] = 0xFFFFFFFF
    h, lo, v = blocks_mod.dedupe_row_keys(
        jnp.asarray(keys[..., 0]), jnp.asarray(keys[..., 1]),
        jnp.asarray(valid))
    return np.stack([np.asarray(h), np.asarray(lo)], -1), np.asarray(v)


def batch_ledger(keys, valid):
    res = hdb_mod.hashed_dynamic_blocking(jnp.asarray(keys),
                                          jnp.asarray(valid), CFG)
    blk = pairs_mod.build_blocks(res)
    ps = pairs_mod.dedupe_pairs(blk, budget=blk.num_pair_slots + 1)
    pack = pack_pair(ps.a, ps.b)
    order = np.argsort(pack)
    return pack[order], ps.src_size[order]


def run_parity(tag, mesh, axes, n_shards, route_slack, expect_fallback,
               n=120, card=20, min_pairs=50):
    rng = np.random.default_rng(17)
    keys, valid = random_keys(rng, n, 5, card)
    ref = BlockStore(CFG)
    rblk = DeltaBlocker(ref)
    st = ShardedBlockStore(CFG, n_shards=n_shards, mesh=mesh,
                           axis_names=axes, route_slack=route_slack)
    sblk = DeltaBlocker(st)
    assert sblk.mesh is mesh  # the store's mesh drives the ledger sync
    cuts = [0, n // 4 + 1, n // 2, 3 * n // 4 + 1, n]
    caught_fallback = 0
    for a, b in zip(cuts[:-1], cuts[1:]):
        rblk.ingest_keys(keys[a:b], valid[a:b])
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            sblk.ingest_keys(keys[a:b], valid[a:b])
        caught_fallback += sum(
            issubclass(x.category, RepCapacityWarning) for x in w)

    np.testing.assert_array_equal(st.led_pack, ref.led_pack, err_msg=tag)
    np.testing.assert_array_equal(st.led_src, ref.led_src, err_msg=tag)
    want_pack, want_src = batch_ledger(keys, valid)
    np.testing.assert_array_equal(st.led_pack, want_pack, err_msg=tag)
    np.testing.assert_array_equal(st.led_src, want_src, err_msg=tag)
    assert len(want_pack) > min_pairs, "layout too small to be a real test"
    ga, gb = ref.accepted_blocks(1), st.accepted_blocks(1)
    np.testing.assert_array_equal(ga.key_hi, gb.key_hi, err_msg=tag)
    np.testing.assert_array_equal(ga.members, gb.members, err_msg=tag)

    assert st.router.exchange_total > 0, tag
    if expect_fallback:
        assert st.router.exchange_fallback_total > 0, \
            f"{tag}: tiny route_slack did not trip the exchange fallback"
        assert caught_fallback > 0, f"{tag}: fallback was silent"
    else:
        assert st.router.exchange_fallback_total == 0, \
            f"{tag}: unexpected routed-exchange fallback"

    # read path parity, both probe modes (host-side, mesh-independent)
    qk, qv = random_keys(rng, 12, 5, 20)
    for ip in (False, True):
        for r1, r2 in zip(rblk.query_keys(qk, qv, include_probe=ip),
                          sblk.query_keys(qk, qv, include_probe=ip)):
            np.testing.assert_array_equal(r1.candidates, r2.candidates)
            np.testing.assert_array_equal(r1.block_sizes, r2.block_sizes)
    print("OK-SHARD", tag)


def main(mesh_kind: str):
    if mesh_kind == "flat":
        mesh = jax.make_mesh((8,), ("data",))
        axes = ("data",)
        run_parity("flat", mesh, axes, 8, 2.0, expect_fallback=False)
        # 4-shard submesh: shard count decoupled from the full device set
        from jax.sharding import Mesh
        sub = Mesh(np.asarray(jax.devices()[:4]), ("data",))
        run_parity("flat-sub4", sub, ("data",), 4, 2.0,
                   expect_fallback=False)
    elif mesh_kind == "pod":
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        run_parity("pod", mesh, ("pod", "data"), 8, 2.0,
                   expect_fallback=False)
    elif mesh_kind == "3axis":
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        run_parity("3axis", mesh, ("pod", "data", "model"), 8, 2.0,
                   expect_fallback=False)
    elif mesh_kind == "overflow":
        # enough distinct keys per exchange (~card per level) that the
        # cap-floor bucket (8 lanes/dest) must overflow under tiny slack
        mesh = jax.make_mesh((8,), ("data",))
        run_parity("overflow", mesh, ("data",), 8, 0.01,
                   expect_fallback=True, n=240, card=120, min_pairs=20)
    else:
        raise SystemExit(f"unknown mesh kind {mesh_kind!r}")
    print("OK", mesh_kind)


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "flat")
