"""MinHash / LSH tests incl. the statistical Jaccard property (paper Fig 1a)."""
import jax.numpy as jnp
import numpy as np

from repro.core import minhash
from repro.data import synthetic


def test_minhash_shape_and_padding():
    tokens = jnp.asarray(np.arange(12, dtype=np.uint32).reshape(3, 4))
    mask = jnp.asarray([[True] * 4, [True, False, False, False], [False] * 4])
    mh = minhash.minhash_tokens(tokens, mask, num_hashes=8)
    assert mh.shape == (3, 8)
    assert (np.asarray(mh[2]) == 0xFFFFFFFF).all()  # empty set -> sentinel


def test_minhash_set_semantics():
    """MinHash depends on the token SET: order and duplicates don't matter."""
    a = jnp.asarray([[5, 9, 2, 2]], dtype=jnp.uint32)
    b = jnp.asarray([[2, 5, 9, 9]], dtype=jnp.uint32)
    m = jnp.ones((1, 4), bool)
    np.testing.assert_array_equal(
        np.asarray(minhash.minhash_tokens(a, m, 16)),
        np.asarray(minhash.minhash_tokens(b, m, 16)))


def test_minhash_collision_rate_tracks_jaccard():
    """P[minhash_i(A) == minhash_i(B)] ~= J(A,B)."""
    for j_target in (0.3, 0.7):
        a, b, true_j = synthetic.jaccard_pair_corpus(400, j_target, set_size=50)
        m = jnp.ones(a.shape, bool)
        mh_a = np.asarray(minhash.minhash_tokens(jnp.asarray(a), m, 24))
        mh_b = np.asarray(minhash.minhash_tokens(jnp.asarray(b), m, 24))
        rate = (mh_a == mh_b).mean()
        assert abs(rate - true_j) < 0.05, (rate, true_j)


def test_lsh_probability_curve_matches_empirical():
    """Empirical band-collision rate vs analytic 1-(1-j^w)^b (Fig 1a)."""
    bands, w = 6, 4
    for j_target in (0.4, 0.6, 0.8):
        a, b, true_j = synthetic.jaccard_pair_corpus(500, j_target, set_size=60,
                                                     seed=7)
        m = jnp.ones(a.shape, bool)
        ka, va = minhash.lsh_keys(jnp.asarray(a), m, bands, w)
        kb, vb = minhash.lsh_keys(jnp.asarray(b), m, bands, w)
        share = ((np.asarray(ka[0]) == np.asarray(kb[0]))
                 & (np.asarray(ka[1]) == np.asarray(kb[1]))).any(axis=1)
        analytic = float(minhash.lsh_probability(bands, w, true_j))
        assert abs(share.mean() - analytic) < 0.08, (share.mean(), analytic, true_j)


def test_band_keys_distinct_across_bands_and_columns():
    mh = jnp.asarray(np.zeros((4, 8), np.uint32))
    k_c0 = minhash.band_keys(mh, 2, 4, column_seed=0)
    k_c1 = minhash.band_keys(mh, 2, 4, column_seed=1)
    # same minhashes: band 0 key != band 1 key; column 0 != column 1
    assert int(k_c0[0][0, 0]) != int(k_c0[0][0, 1]) or int(k_c0[1][0, 0]) != int(k_c0[1][0, 1])
    assert int(k_c0[0][0, 0]) != int(k_c1[0][0, 0]) or int(k_c0[1][0, 0]) != int(k_c1[1][0, 0])


def test_lsh_empty_rows_emit_no_keys():
    tokens = jnp.zeros((2, 4), jnp.uint32)
    mask = jnp.asarray([[True, True, False, False], [False] * 4])
    _, valid = minhash.lsh_keys(tokens, mask, 3, 2)
    assert np.asarray(valid)[0].all()
    assert not np.asarray(valid)[1].any()
