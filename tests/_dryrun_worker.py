"""Subprocess worker: the dryrun cell path on an 8-device (2,2,2) mesh with
reduced configs — covers sharding rules, cache sharding, lowering, compile
and roofline analysis for every family without the 512-device cost."""
import os
import sys

assert "--xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax

from repro.configs import reduced_config
from repro.configs.shapes import ShapeSpec
from repro.launch.dryrun import CellOptions, run_cell


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cells = [
        ("tinyllama-1.1b", ShapeSpec("train", 64, 8, "train")),
        ("olmoe-1b-7b", ShapeSpec("train", 64, 8, "train")),
        ("deepseek-v3-671b", ShapeSpec("decode", 128, 8, "decode")),
        ("jamba-1.5-large-398b", ShapeSpec("decode", 128, 1, "decode")),
        ("rwkv6-1.6b", ShapeSpec("prefill", 128, 8, "prefill")),
        ("whisper-medium", ShapeSpec("train", 64, 8, "train")),
        ("internvl2-76b", ShapeSpec("train", 64, 8, "train")),
    ]
    for arch, shape in cells:
        cfg = reduced_config(arch)
        cfg = dataclasses.replace(cfg, mamba_chunk=16)
        res = run_cell(arch, shape.name, True, CellOptions(grad_accum=2),
                       mesh=mesh, cfg=cfg, shape=shape)
        assert res["ok"], res
        roof = res["roofline"]
        assert roof["flops_per_device"] > 0
        assert roof["dominant"] in ("compute", "memory", "collective")
        print(f"OK {arch} {shape.kind} {roof['dominant']}")
    print("ALL-OK")


if __name__ == "__main__":
    main()
