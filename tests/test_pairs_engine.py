"""Oracle tests for the device-side pair materialization engine.

The numpy (shift-method reference), JAX, and Pallas (interpret) backends
must emit BIT-IDENTICAL deduped PairSets — including the budget-exceeded
uniform-sampling fallback and the largest-block-wins provenance — on
randomized block layouts. The triangular decode kernel is additionally
checked against the float64 closed-form oracle at the int32 contract
boundary (n = MAX_BLOCK_N).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _propcheck import given, settings, st

from repro.core import blocks as blocks_mod, hdb, pairs
from repro.core.distributed import (dedupe_pairs_distributed,
                                    materialize_pairs_distributed)
from repro.kernels.pairs import (MAX_BLOCK_N, decode_chunk, dedupe_device,
                                 dedupe_packed_device, pack_sort_words,
                                 pair_route_owner, tri_decode_jnp,
                                 tri_decode_pallas, unpack_words_host)
from repro.kernels.pairs import ref as pairs_ref
from repro.data import synthetic

BACKENDS = ("numpy", "jax", "pallas")


def _random_blocks(seed, n_blocks, max_size, universe):
    """Random CSR Blocks with heavy membership overlap (cross-block dupes)."""
    rng = np.random.default_rng(seed)
    sizes = rng.integers(2, max_size + 1, n_blocks).astype(np.int64)
    start = np.concatenate([[0], np.cumsum(sizes)])[:-1]
    members = np.concatenate(
        [np.sort(rng.choice(universe, n, replace=False)) for n in sizes]
    ).astype(np.int64)
    zu = np.zeros(n_blocks, np.uint32)
    return pairs.Blocks(zu, zu, start, sizes, members)


def _assert_pairsets_equal(got, want, label):
    assert got.exact == want.exact, label
    assert got.total_slots == want.total_slots, label
    np.testing.assert_array_equal(got.a, want.a, err_msg=label)
    np.testing.assert_array_equal(got.b, want.b, err_msg=label)
    np.testing.assert_array_equal(got.src_size, want.src_size, err_msg=label)


# ---------------------------------------------------------------------------
# backend parity on randomized layouts
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000),
       n_blocks=st.sampled_from([1, 7, 40]),
       max_size=st.sampled_from([3, 16, 48]))
def test_backends_agree_exact(seed, n_blocks, max_size):
    blk = _random_blocks(seed, n_blocks, max_size, universe=400)
    want = pairs.dedupe_pairs(blk, backend="numpy")
    assert want.exact
    # exact results are the distinct-pair set: cross-check count bounds
    assert 0 < len(want.a) <= blk.num_pair_slots
    for be in ("jax", "pallas"):
        got = pairs.dedupe_pairs(blk, backend=be)
        _assert_pairsets_equal(got, want, f"backend={be} seed={seed}")


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_backends_agree_sampling_fallback(seed):
    blk = _random_blocks(seed, 30, 40, universe=300)
    budget = blk.num_pair_slots // 3
    want = pairs.dedupe_pairs(blk, budget=budget, backend="numpy",
                              sample_seed=seed)
    assert not want.exact
    assert want.total_slots == blk.num_pair_slots  # counting stays exact
    assert len(want.a) <= budget
    for be in ("jax", "pallas"):
        got = pairs.dedupe_pairs(blk, budget=budget, backend=be,
                                 sample_seed=seed)
        _assert_pairsets_equal(got, want, f"backend={be} seed={seed}")


def test_sample_slots_budget_bounded_allocation_and_determinism():
    """_sample_slots must draw exactly min(budget, total) distinct slots
    in O(budget) memory — the old permutation branch materialized and
    shuffled slot spaces up to 2**24 (~128 MiB) for any budget."""
    import tracemalloc

    total, budget = 1 << 24, 1024
    tracemalloc.start()
    s1 = pairs._sample_slots(total, budget, seed=42)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    # old code: >= 128 MiB int64 permutation; new bound is O(budget)
    assert peak < 4 << 20, f"peak allocation {peak} bytes is not O(budget)"
    assert len(s1) == budget
    assert np.all(np.diff(s1) > 0) and 0 <= s1[0] and s1[-1] < total
    # deterministic per seed, sensitive to it
    np.testing.assert_array_equal(s1, pairs._sample_slots(total, budget, 42))
    assert not np.array_equal(s1, pairs._sample_slots(total, budget, 43))
    # dense draws still return exactly budget distinct slots
    s2 = pairs._sample_slots(100, 90, seed=0)
    assert len(s2) == 90 and len(np.unique(s2)) == 90
    assert len(pairs._sample_slots(100, 200, seed=0)) == 100
    assert len(pairs._sample_slots(100, 0, seed=0)) == 0


def test_sampling_is_deterministic_and_seed_sensitive():
    blk = _random_blocks(0, 30, 40, universe=300)
    budget = blk.num_pair_slots // 4
    p1 = pairs.dedupe_pairs(blk, budget=budget, backend="jax", sample_seed=7)
    p2 = pairs.dedupe_pairs(blk, budget=budget, backend="jax", sample_seed=7)
    p3 = pairs.dedupe_pairs(blk, budget=budget, backend="jax", sample_seed=8)
    np.testing.assert_array_equal(p1.a, p2.a)
    np.testing.assert_array_equal(p1.b, p2.b)
    assert len(p1.a) != len(p3.a) or not np.array_equal(p1.a, p3.a)


# ---------------------------------------------------------------------------
# largest-block-wins provenance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_largest_block_wins_provenance(backend):
    # pair (0, 1) appears in a 5-block, a 9-block, and a 3-block
    groups = [np.arange(5), np.arange(9), np.array([0, 1, 50])]
    sizes = np.array([len(g) for g in groups], np.int64)
    start = np.concatenate([[0], np.cumsum(sizes)])[:-1]
    blk = pairs.Blocks(np.zeros(3, np.uint32), np.zeros(3, np.uint32),
                       start, sizes,
                       np.concatenate(groups).astype(np.int64))
    p = pairs.dedupe_pairs(blk, backend=backend)
    by_pair = {(a, b): s for a, b, s in zip(p.a, p.b, p.src_size)}
    assert by_pair[(0, 1)] == 9          # largest source block wins
    assert by_pair[(0, 50)] == 3         # only source
    assert by_pair[(5, 8)] == 9
    # distinct set: the 5-block is a subset of the 9-block
    assert len(p.a) == 9 * 8 // 2 + 2    # C(9,2) + (0,50) + (1,50)


# ---------------------------------------------------------------------------
# triangular decode kernel at the contract boundary
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 3, 17, 1000, MAX_BLOCK_N])
def test_tri_decode_matches_oracle_at_boundaries(n):
    last = n * (n - 1) // 2 - 1
    t = np.unique(np.clip(
        np.array([0, 1, n - 2, n - 1, last // 2, last - 1, last]), 0, last))
    n_arr = np.full(len(t), n, np.int64)
    ri, rj = pairs_ref.tri_decode_ref(t, n_arr)
    # ref must satisfy the bitmap identity b(i,j,n) == t
    np.testing.assert_array_equal(pairs.pair_bit_index(ri, rj, n), t)
    # tri_decode_jnp is a jit-free mirror meant to trace inside
    # decode_chunk; call it the way its callers do
    gi, gj = jax.jit(tri_decode_jnp, static_argnames=("steps",))(
        jnp.asarray(t.astype(np.int32)), jnp.asarray(n_arr.astype(np.int32)))
    np.testing.assert_array_equal(np.asarray(gi), ri)
    np.testing.assert_array_equal(np.asarray(gj), rj)


def test_tri_decode_pallas_matches_jnp_dense():
    rng = np.random.default_rng(0)
    n = rng.integers(2, 300, 4096).astype(np.int64)
    t = (rng.random(4096) * (n * (n - 1) // 2)).astype(np.int64)
    t32, n32 = t.astype(np.int32), n.astype(np.int32)
    ji, jj = jax.jit(tri_decode_jnp, static_argnames=("steps",))(
        jnp.asarray(t32), jnp.asarray(n32))
    pi, pj = tri_decode_pallas(jnp.asarray(t32.reshape(-1, 128)),
                               jnp.asarray(n32.reshape(-1, 128)),
                               interpret=True)
    np.testing.assert_array_equal(np.asarray(pi).reshape(-1), np.asarray(ji))
    np.testing.assert_array_equal(np.asarray(pj).reshape(-1), np.asarray(jj))


def test_decode_chunk_validity_immune_to_int32_wrap():
    """Padding lanes past total near 2**31 must stay invalid even though
    base + offset wraps int32 (regression: wrapped-negative slots used to
    pass the `slots < total` check)."""
    total = 2**31 - 100
    # a single synthetic block table; only validity counting matters here
    cum = jnp.asarray(np.array([0, total], np.int32))
    start = jnp.asarray(np.zeros(1, np.int32))
    size = jnp.asarray(np.array([3], np.int32))
    members = jnp.asarray(np.array([0, 1, 2], np.int32))
    base = total - 512
    _, _, _, v = decode_chunk(cum, start, size, members,
                              jax.device_put(np.int32(base)),
                              jax.device_put(np.int32(total)), chunk=1024)
    v = np.asarray(v)
    assert v.sum() == 512 and v[:512].all() and not v[512:].any()


def test_decode_chunk_masks_out_of_range_slots():
    blk = _random_blocks(1, 4, 6, universe=50)
    total = blk.num_pair_slots
    cum = jnp.asarray(pairs_ref.cum_pair_counts(blk.size).astype(np.int32))
    a, b, s, v = decode_chunk(
        cum, jnp.asarray(blk.start.astype(np.int32)),
        jnp.asarray(blk.size.astype(np.int32)),
        jnp.asarray(blk.members.astype(np.int32)),
        jax.device_put(np.int32(0)), jax.device_put(np.int32(total)),
        chunk=1024)
    v = np.asarray(v)
    assert v.sum() == total and not v[total:].any()


def test_dedupe_device_pushes_invalid_to_tail():
    a = jnp.asarray(np.array([5, 3, 3, 9], np.int32))
    b = jnp.asarray(np.array([6, 4, 4, 11], np.int32))
    s = jnp.asarray(np.array([2, 7, 3, 2], np.int32))
    valid = jnp.asarray(np.array([True, True, True, False]))
    sa, sb, ss, w = dedupe_device(a, b, s, valid)
    w = np.asarray(w)
    assert w.sum() == 2
    np.testing.assert_array_equal(np.asarray(sa)[w], [3, 5])
    np.testing.assert_array_equal(np.asarray(ss)[w], [7, 2])  # largest wins


# ---------------------------------------------------------------------------
# integration: HDB result -> blocks -> engine; distributed decode
# ---------------------------------------------------------------------------


def test_engine_on_real_hdb_blocks():
    corpus = synthetic.generate(synthetic.SyntheticSpec(num_entities=150, seed=2))
    keys, valid = blocks_mod.build_keys(corpus.columns, corpus.blocking)
    res = hdb.hashed_dynamic_blocking(keys, valid,
                                      hdb.HDBConfig(max_block_size=25))
    blk = pairs.build_blocks(res)
    want = pairs.dedupe_pairs(blk, backend="numpy")
    for be in ("jax", "pallas"):
        _assert_pairsets_equal(pairs.dedupe_pairs(blk, backend=be), want, be)


def test_distributed_materialization_matches_single_device():
    blk = _random_blocks(4, 50, 30, universe=600)
    mesh = jax.make_mesh((1,), ("data",))
    for dedupe in ("routed", "global"):
        got = materialize_pairs_distributed(blk, mesh, ("data",),
                                            chunk_per_shard=2048,
                                            dedupe=dedupe)
        want = pairs.dedupe_pairs(blk, backend="numpy")
        _assert_pairsets_equal(got, want, f"distributed-{dedupe}")


# ---------------------------------------------------------------------------
# fingerprint-routed dedupe: oracle layout + shard-local ops
# (multi-device parity for all three mesh kinds runs in _dist_worker.py —
# the main test process is locked to 1 device)
# ---------------------------------------------------------------------------


def _raw_pairs(blk):
    chunks = [(np.minimum(a, b), np.maximum(a, b), s)
              for a, b, s in pairs.iter_block_pairs(blk)]
    return (np.concatenate([c[0] for c in chunks]),
            np.concatenate([c[1] for c in chunks]),
            np.concatenate([c[2] for c in chunks]))


@pytest.mark.parametrize("n_shards", [1, 3, 8])
def test_routed_oracle_equals_global_dedupe(n_shards):
    """Per-shard dedupe over the fingerprint partition, merged, must equal
    the global dedupe — the identity the routed distributed path rests on."""
    blk = _random_blocks(11, 40, 30, universe=400)
    ra, rb, rs = _raw_pairs(blk)
    oa, ob, os_ = pairs_ref.dedupe_routed_ref(ra, rb, rs, n_shards)
    wa, wb, ws = pairs_ref.dedupe_ref(ra, rb, rs)
    np.testing.assert_array_equal(oa, wa)
    np.testing.assert_array_equal(ob, wb)
    np.testing.assert_array_equal(os_, ws)


def test_pair_route_owner_matches_numpy_mirror():
    rng = np.random.default_rng(3)
    a = rng.integers(0, 1 << 23, 4096).astype(np.int32)
    b = rng.integers(0, 1 << 23, 4096).astype(np.int32)
    valid = rng.random(4096) < 0.9
    # pair_route_owner is jit-free by contract (traces inside shard_map);
    # call it through jit like its callers do
    route = jax.jit(functools.partial(pair_route_owner, n_shards=8))
    got = np.asarray(route(jnp.asarray(a), jnp.asarray(b), jnp.asarray(valid)))
    want = np.where(valid, pairs_ref.np_pair_route_owner(a, b, 8), 8)
    np.testing.assert_array_equal(got, want)
    # owners must be well spread (splitmix64 avalanche)
    counts = np.bincount(got[valid], minlength=8)
    assert counts.min() > 0.5 * counts.mean()


def test_dedupe_packed_device_matches_host():
    rng = np.random.default_rng(7)
    a = rng.integers(0, 500, 2048).astype(np.int32)
    b = (a + rng.integers(1, 100, 2048)).astype(np.int32)
    s = rng.integers(2, 600, 2048).astype(np.int32)
    valid = rng.random(2048) < 0.8
    hi, lo = pack_sort_words(jnp.asarray(a), jnp.asarray(b), jnp.asarray(s),
                             jnp.asarray(valid))
    # dedupe_packed_device is jit-free by contract; jit it like callers do
    shi, slo, winner = jax.jit(dedupe_packed_device)(hi, lo)
    w = np.asarray(winner)
    words = ((np.asarray(shi).astype(np.uint64) << np.uint64(32))
             | np.asarray(slo).astype(np.uint64))[w]
    ga, gb, gs = unpack_words_host(np.sort(words))
    wa, wb, ws = pairs_ref.dedupe_ref(a[valid], b[valid], s[valid])
    np.testing.assert_array_equal(ga, wa)
    np.testing.assert_array_equal(gb, wb)
    np.testing.assert_array_equal(gs, ws)


def test_routed_dedupe_single_device_mesh_all_paths():
    """1-device mesh exercises the full routed machinery (pack, route,
    all_to_all, shard-local dedupe) without subprocess devices."""
    blk = _random_blocks(21, 30, 25, universe=300)
    mesh = jax.make_mesh((1,), ("data",))
    want = pairs.dedupe_pairs(blk, backend="numpy")
    got = dedupe_pairs_distributed(blk, mesh, ("data",), chunk_per_shard=1024)
    _assert_pairsets_equal(got, want, "routed-1dev-exact")
    # budget-exceeded sampling path (global seeded sample)
    budget = blk.num_pair_slots // 4
    want_s = pairs.dedupe_pairs(blk, budget=budget, backend="numpy",
                                sample_seed=3)
    got_s = dedupe_pairs_distributed(blk, mesh, ("data",), budget=budget,
                                     chunk_per_shard=512, sample_seed=3)
    _assert_pairsets_equal(got_s, want_s, "routed-1dev-sampled")
    # backend dispatch through the core driver
    got_d = pairs.dedupe_pairs(blk, backend="distributed", chunk_pairs=1024)
    _assert_pairsets_equal(got_d, want, "backend-distributed")


def test_routed_dedupe_zero_budget_returns_empty_inexact():
    blk = _random_blocks(2, 5, 6, universe=60)
    mesh = jax.make_mesh((1,), ("data",))
    p = dedupe_pairs_distributed(blk, mesh, ("data",), budget=0)
    assert not p.exact and len(p.a) == 0
    assert p.total_slots == blk.num_pair_slots  # counting stays exact


def test_enumerate_pairs_rejects_distributed_backend():
    blk = _random_blocks(2, 5, 6, universe=60)
    with pytest.raises(ValueError, match="no.*distributed backend"):
        next(pairs.enumerate_pairs(blk, backend="distributed"))


def test_routed_dedupe_empty_and_tiny():
    mesh = jax.make_mesh((1,), ("data",))
    z64 = np.zeros((0,), np.int64)
    zu = np.zeros((0,), np.uint32)
    empty = pairs.Blocks(zu, zu, z64, z64, z64)
    p = dedupe_pairs_distributed(empty, mesh, ("data",))
    assert p.exact and len(p.a) == 0 and p.total_slots == 0
    one = pairs.Blocks(np.zeros(1, np.uint32), np.zeros(1, np.uint32),
                       np.zeros(1, np.int64), np.array([2], np.int64),
                       np.array([7, 42], np.int64))
    p1 = dedupe_pairs_distributed(one, mesh, ("data",), chunk_per_shard=256)
    assert p1.exact and list(p1.a) == [7] and list(p1.b) == [42]


def test_routed_dedupe_falls_back_beyond_pack_bound():
    """rids >= 2**PACK_RID_BITS can't take the packed routed path; the
    driver must fall back to the single-device engine, not mis-pack."""
    from repro.kernels.pairs import PACK_RID_BITS
    blk = _random_blocks(9, 12, 10, universe=200)
    big = pairs.Blocks(blk.key_hi, blk.key_lo, blk.start, blk.size,
                       blk.members + (1 << PACK_RID_BITS))
    mesh = jax.make_mesh((1,), ("data",))
    want = pairs.dedupe_pairs(big, backend="numpy")
    with pytest.warns(RuntimeWarning, match="62-bit sort-word pack"):
        got = dedupe_pairs_distributed(big, mesh, ("data",))
    _assert_pairsets_equal(got, want, "routed-pack-fallback")


def test_routed_int32_guard_at_slot_edge(monkeypatch):
    """Per-shard slot offsets near 2**31: the routed driver must refuse
    layouts where base + per_round wraps int32 (the single-device guards
    in core/pairs.py never see per-shard offsets) and fall back."""
    n = MAX_BLOCK_N  # C(65535, 2) = 2_147_418_113, just under 2**31
    blk = pairs.Blocks(np.zeros(1, np.uint32), np.zeros(1, np.uint32),
                       np.zeros(1, np.int64), np.array([n], np.int64),
                       np.arange(n, dtype=np.int64))
    total = blk.num_pair_slots
    assert total + (1 << 18) > 2**31 - 1 > total  # sits exactly at the edge
    sentinel = object()
    monkeypatch.setattr(pairs, "dedupe_pairs", lambda *a, **k: sentinel)
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.warns(RuntimeWarning, match="overflows int32"):
        got = dedupe_pairs_distributed(blk, mesh, ("data",),
                                       budget=2**31 - 2)
    assert got is sentinel  # fell back without decoding 2B slots


def test_routed_decode_validity_at_int32_slot_edge_per_shard_bases():
    """Routed-boundary companion of
    test_decode_chunk_validity_immune_to_int32_wrap: at the largest total
    the routed guard admits (total + n_shards*chunk <= 2**31 - 1), the
    final round's per-shard bases overshoot r0 by shard*chunk — the
    straddling shard must mask its tail and fully-past-the-end shards
    must decode nothing, with no int32 wrap corrupting validity."""
    n_shards, chunk = 8, 1024
    per_round = n_shards * chunk
    total = 2**31 - 1 - per_round  # guard-admitted maximum
    cum = jnp.asarray(np.array([0, total], np.int32))
    start = jnp.asarray(np.zeros(1, np.int32))
    size = jnp.asarray(np.array([3], np.int32))
    members = jnp.asarray(np.array([0, 1, 2], np.int32))
    r0 = (total // per_round) * per_round
    for shard in range(n_shards):
        base = r0 + shard * chunk
        assert base + chunk <= 2**31 - 1  # the invariant the guard enforces
        live = max(0, min(chunk, total - base))
        _, _, _, v = decode_chunk(cum, start, size, members,
                                  jax.device_put(np.int32(base)),
                                  jax.device_put(np.int32(total)),
                                  chunk=chunk)
        v = np.asarray(v)
        assert v.sum() == live and v[:live].all() and not v[live:].any(), shard


def test_enumerate_pairs_streams_all_slots():
    blk = _random_blocks(5, 20, 20, universe=200)
    for be in BACKENDS:
        tot = 0
        for a, b, s in pairs.enumerate_pairs(blk, backend=be,
                                             chunk_pairs=2048):
            assert np.all(a < b)
            tot += len(a)
        assert tot == blk.num_pair_slots, be


def test_oversize_blocks_fall_back_to_numpy():
    # a block larger than MAX_BLOCK_N breaks the int32 contract
    n = MAX_BLOCK_N + 1
    blk = pairs.Blocks(np.zeros(1, np.uint32), np.zeros(1, np.uint32),
                       np.zeros(1, np.int64), np.array([n], np.int64),
                       np.arange(n, dtype=np.int64))
    with pytest.warns(RuntimeWarning, match="MAX_BLOCK_N"):
        p = pairs.dedupe_pairs(blk, budget=1000, backend="jax")
    assert not p.exact and len(p.a) <= 1000


def test_backends_agree_beyond_pack_rid_bound():
    """rids >= 2**PACK_RID_BITS force the general lax.sort dedupe path,
    which must still match the numpy reference exactly."""
    from repro.kernels.pairs import PACK_RID_BITS
    blk = _random_blocks(9, 12, 10, universe=200)
    big = pairs.Blocks(blk.key_hi, blk.key_lo, blk.start, blk.size,
                       blk.members + (1 << PACK_RID_BITS))
    want = pairs.dedupe_pairs(big, backend="numpy")
    got = pairs.dedupe_pairs(big, backend="jax")
    _assert_pairsets_equal(got, want, "big-rid general dedupe")


def test_empty_blocks():
    z64 = np.zeros((0,), np.int64)
    zu = np.zeros((0,), np.uint32)
    blk = pairs.Blocks(zu, zu, z64, z64, z64)
    for be in BACKENDS:
        p = pairs.dedupe_pairs(blk, backend=be)
        assert p.exact and len(p.a) == 0 and p.total_slots == 0
