"""Subprocess worker: a2a MoE dispatch must match the psum-partial path
(same routing decisions; only the communication pattern differs)."""
import os
import sys

assert "--xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.distributed.sharding import production_rules, use_rules
from repro.models import moe
from repro.models.model import build_model


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    rules = production_rules(mesh)
    cfg = reduced_config("olmoe-1b-7b")
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops => exact
    params = moe.moe_init(jax.random.PRNGKey(0), cfg)["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model),
                          jnp.float32)

    with use_rules(rules):
        out_psum, aux_p, drop_p = jax.jit(
            lambda p, x: moe.moe_apply(p, x, cfg))(params, x)
        cfg_a2a = dataclasses.replace(cfg, moe_impl="a2a")
        out_a2a, aux_a, drop_a = jax.jit(
            lambda p, x: moe.moe_apply(p, x, cfg_a2a))(params, x)

    np.testing.assert_allclose(np.asarray(out_psum), np.asarray(out_a2a),
                               rtol=2e-5, atol=2e-5)
    assert int(drop_p) == 0 and int(drop_a) == 0, (int(drop_p), int(drop_a))
    # aux is a per-chunk load-balance ESTIMATOR in the a2a path (computed on
    # each shard's token slice, then averaged) — statistically equivalent,
    # not bitwise equal
    np.testing.assert_allclose(float(aux_p), float(aux_a), rtol=0.1)

    # end-to-end through the model: losses match
    m_p = build_model(cfg)
    m_a = build_model(cfg_a2a)
    mp = m_p.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, 256, (4, 8)), jnp.int32),
             "targets": jnp.asarray(rng.integers(0, 256, (4, 8)), jnp.int32)}
    with use_rules(rules):
        l_p = float(jax.jit(lambda p, b: m_p.loss(p, b)[0])(mp, batch))
        l_a = float(jax.jit(lambda p, b: m_a.loss(p, b)[0])(mp, batch))
    # the two dispatch paths reduce expert outputs in different orders
    # (psum-partial vs all_to_all regather), so the f32 losses agree only
    # to accumulated rounding — observed ~1.4e-4 relative on 8 emulated
    # devices, bounded at 5e-4
    assert abs(l_p - l_a) < 5e-4 * max(abs(l_p), 1.0), (l_p, l_a)
    print("MOE-A2A-OK")


if __name__ == "__main__":
    main()
