"""Property tests for the CMS and Bloom sketches."""
import jax.numpy as jnp
import numpy as np
from _propcheck import given, settings, st

from repro.core import sketches, hashing


def _keys_from_ints(xs):
    arr = hashing.np_to_u64_arrays(np.asarray(xs, np.uint64))
    packed = jnp.asarray(arr)
    return packed[..., 0], packed[..., 1]


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=1 << 48), min_size=1, max_size=300))
def test_cms_never_undercounts(xs):
    cfg = sketches.CMSConfig(depth=4, width=1 << 8)  # deliberately tiny
    key = _keys_from_ints(xs)
    mask = jnp.ones(len(xs), bool)
    cms = sketches.cms_build(cfg, key, mask)
    est = np.asarray(sketches.cms_query(cfg, cms, key))
    vals, counts = np.unique(np.asarray(xs, np.uint64), return_counts=True)
    true = dict(zip(vals.tolist(), counts.tolist()))
    for x, e in zip(xs, est):
        assert e >= true[x]


def test_cms_exact_when_wide():
    rng = np.random.default_rng(0)
    xs = rng.integers(0, 1 << 40, size=2000)
    xs = np.repeat(xs, rng.integers(1, 5, size=len(xs)))
    cfg = sketches.CMSConfig(depth=4, width=1 << 18)
    key = _keys_from_ints(xs)
    cms = sketches.cms_build(cfg, key, jnp.ones(len(xs), bool))
    est = np.asarray(sketches.cms_query(cfg, cms, key))
    vals, counts = np.unique(xs, return_counts=True)
    true = dict(zip(vals.tolist(), counts.tolist()))
    exact = sum(int(e) == true[x] for x, e in zip(xs.tolist(), est))
    assert exact / len(xs) > 0.999


def test_cms_mask_excludes_entries():
    cfg = sketches.CMSConfig(depth=2, width=1 << 10)
    xs = [7, 7, 7, 7]
    key = _keys_from_ints(xs)
    mask = jnp.asarray([True, True, False, False])
    cms = sketches.cms_build(cfg, key, mask)
    assert int(sketches.cms_query(cfg, cms, key)[0]) == 2


def test_cms_merge_is_linear():
    cfg = sketches.CMSConfig(depth=4, width=1 << 10)
    rng = np.random.default_rng(1)
    xs = rng.integers(0, 1000, 500)
    ka = _keys_from_ints(xs[:250])
    kb = _keys_from_ints(xs[250:])
    kall = _keys_from_ints(xs)
    ones = lambda n: jnp.ones(n, bool)
    merged = sketches.cms_merge(sketches.cms_build(cfg, ka, ones(250)),
                                sketches.cms_build(cfg, kb, ones(250)))
    direct = sketches.cms_build(cfg, kall, ones(500))
    np.testing.assert_array_equal(np.asarray(merged), np.asarray(direct))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=1 << 48), min_size=1, max_size=200),
       st.lists(st.integers(min_value=0, max_value=1 << 48), min_size=1, max_size=200))
def test_bloom_no_false_negatives(members, probes):
    cfg = sketches.BloomConfig.for_capacity(len(members), fpr=1e-6)
    mkey = _keys_from_ints(members)
    bits = sketches.bloom_build(cfg, mkey, jnp.ones(len(members), bool))
    hits = np.asarray(sketches.bloom_query(cfg, bits, mkey))
    assert hits.all()
    # false-positive sanity on non-members
    non = [p for p in probes if p not in set(members)]
    if non:
        nkey = _keys_from_ints(non)
        fp = np.asarray(sketches.bloom_query(cfg, bits, nkey)).mean()
        assert fp <= 0.05


def test_bloom_fpr_near_target():
    rng = np.random.default_rng(2)
    members = rng.integers(0, 1 << 60, 5000)
    cfg = sketches.BloomConfig.for_capacity(5000, fpr=1e-3)
    bits = sketches.bloom_build(cfg, _keys_from_ints(members),
                                jnp.ones(len(members), bool))
    probes = rng.integers(1 << 61, 1 << 62, 20000)
    fp = np.asarray(sketches.bloom_query(cfg, bits, _keys_from_ints(probes))).mean()
    assert fp < 5e-3


def test_bloom_merge_is_union():
    cfg = sketches.BloomConfig(num_slots=1 << 12, num_hashes=4)
    a = sketches.bloom_build(cfg, _keys_from_ints([1, 2, 3]), jnp.ones(3, bool))
    b = sketches.bloom_build(cfg, _keys_from_ints([4, 5]), jnp.ones(2, bool))
    m = sketches.bloom_merge(a, b)
    hits = np.asarray(sketches.bloom_query(cfg, m, _keys_from_ints([1, 2, 3, 4, 5])))
    assert hits.all()
