"""Property-testing compat shim: real hypothesis when importable, else a
deterministic seeded-example fallback.

The tier-1 suite must collect and pass in a clean environment that has no
``hypothesis`` wheel (the container bakes in only jax/numpy/pytest). Test
modules import ``given``/``settings``/``st`` from here instead of from
``hypothesis``; when the real library is present we simply re-export it,
so installing hypothesis transparently upgrades the suite to real
shrinking/fuzzing. The fallback draws ``max_examples`` pseudo-random
examples from a fixed per-test seed (derived from the test name via
crc32, NOT ``hash()``, so runs are reproducible across interpreters).

Only the strategy surface used by this repo is implemented:
``st.integers``, ``st.lists``, ``st.sampled_from``. Extend as needed.
"""
from __future__ import annotations

import functools
import random
import zlib

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _DEFAULT_MAX_EXAMPLES = 25

    class _Strategy:
        """A draw rule: ``example(rng) -> value``."""

        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _StNamespace:
        """Fallback mirror of ``hypothesis.strategies``."""

        @staticmethod
        def integers(min_value=0, max_value=None) -> _Strategy:
            if max_value is None:
                max_value = min_value + (1 << 32)
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def lists(elements: _Strategy, min_size=0, max_size=None) -> _Strategy:
            if max_size is None:
                max_size = min_size + 20

            def draw(rng):
                size = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(size)]

            return _Strategy(draw)

        @staticmethod
        def sampled_from(seq) -> _Strategy:
            seq = list(seq)
            return _Strategy(lambda rng: rng.choice(seq))

    st = _StNamespace()

    def given(*arg_strategies, **kw_strategies):
        """Fallback ``@given``: run the test body on N seeded examples."""

        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_pc_max_examples", _DEFAULT_MAX_EXAMPLES)
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    drawn = [s.example(rng) for s in arg_strategies]
                    drawn_kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)

            # pytest introspects __wrapped__ for fixture names; the drawn
            # arguments are not fixtures, so hide the original signature.
            del wrapper.__wrapped__
            wrapper._pc_max_examples = _DEFAULT_MAX_EXAMPLES
            wrapper._pc_is_given = True
            return wrapper

        return decorate

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
        """Fallback ``@settings``: only ``max_examples`` has an effect."""
        del deadline

        def decorate(fn):
            if getattr(fn, "_pc_is_given", False):
                fn._pc_max_examples = max_examples
            return fn

        return decorate
