"""End-to-end property test: the fixed-shape JAX HDB must produce EXACTLY
the accepted (rid, key) set of an independent pure-python reference
implementation of Algorithms 1-4 (core/oracle.py), across randomized
corpora and hyper-parameters.

The CMS is kept wide so approximate counting is exact at these sizes; the
JAX path's CMS/exact/dedupe/intersect machinery is otherwise fully
exercised (multiple iterations, duplicate blocks, the similarity and
max-keys guards, the oversize-key cap).
"""
import jax.numpy as jnp
import numpy as np
from _propcheck import given, settings, st

from repro.core import blocks, hdb, oracle
from repro.core.blocks import ColumnBlocking, TokenColumn
from repro.data import synthetic


def _to_python_keys(keys, valid):
    keys_np = np.asarray(keys)
    valid_np = np.asarray(valid)
    out = []
    for r in range(valid_np.shape[0]):
        ks = set()
        for c in np.flatnonzero(valid_np[r]):
            ks.add((int(keys_np[r, c, 0]) << 32) | int(keys_np[r, c, 1]))
        out.append(ks)
    return out


def _jax_accepted(res):
    return set((int(r), (int(h) << 32) | int(l))
               for r, h, l in zip(res.rids, res.key_hi, res.key_lo))


def _compare(keys, valid, cfg):
    res = hdb.hashed_dynamic_blocking(keys, valid, cfg)
    want = oracle.oracle_hdb(_to_python_keys(keys, valid), cfg)
    got = _jax_accepted(res)
    missing = want - got
    extra = got - want
    assert not missing and not extra, (
        f"missing={list(missing)[:4]} extra={list(extra)[:4]} "
        f"|want|={len(want)} |got|={len(got)}")
    return len(want)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000),
       max_block=st.sampled_from([10, 25, 60]),
       max_over=st.sampled_from([4, 8, 16]))
def test_jax_matches_oracle_on_synthetic(seed, max_block, max_over):
    corpus = synthetic.generate(synthetic.SyntheticSpec(
        num_entities=120, dup_rate=0.5, seed=seed))
    keys, valid = blocks.build_keys(corpus.columns, corpus.blocking)
    cfg = hdb.HDBConfig(max_block_size=max_block, max_iterations=6,
                        max_oversize_keys=max_over)
    n = _compare(keys, valid, cfg)
    assert n > 0


def test_jax_matches_oracle_adversarial_overlaps():
    """Heavily overlapping identity columns: many duplicate blocks, several
    intersection iterations, similarity drops."""
    n = 240
    rng = np.random.default_rng(0)
    cols, spec = {}, {}
    for i, card in enumerate([2, 2, 3, 4, 50]):
        v = rng.integers(0, card, n).astype(np.uint32) + 100 * i
        cols[f"c{i}"] = TokenColumn(jnp.asarray(v[:, None]),
                                    jnp.ones((n, 1), bool))
        spec[f"c{i}"] = ColumnBlocking.identity()
    keys, valid = blocks.build_keys(cols, spec)
    cfg = hdb.HDBConfig(max_block_size=20, max_iterations=8)
    _compare(keys, valid, cfg)


def test_jax_matches_oracle_with_max_keys_guard():
    n = 128
    cols, spec = {}, {}
    for i in range(7):  # 7 over-sized binary partitions -> guard fires at 6
        v = ((np.arange(n, dtype=np.uint32) >> i) & 1) + 10 * i
        cols[f"c{i}"] = TokenColumn(jnp.asarray(v[:, None]),
                                    jnp.ones((n, 1), bool))
        spec[f"c{i}"] = ColumnBlocking.identity()
    keys, valid = blocks.build_keys(cols, spec)
    for mk in (4, 6, 80):
        cfg = hdb.HDBConfig(max_block_size=30, max_keys=mk, max_iterations=5)
        _compare(keys, valid, cfg)
