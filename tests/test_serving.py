"""DedupeService: batching invariance, lanes, backpressure, fair share,
metrics contract, and the shared slot-scheduler collation."""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from _propcheck import given, settings, st
from test_streaming import _random_keys

from repro.core import hdb
from repro.data import synthetic
from repro.serving import BackpressureError, DedupeService, ServiceConfig
from repro.serving.buckets import BucketLadder, pad_probe_rows
from repro.serving.metrics import Histogram, Metrics
from repro.serving.scheduler import collate_fifo
from repro.streaming import RecordBatch, StreamingEngine
from repro.streaming.delta import probe_jit_cache_sizes

_CFG = hdb.HDBConfig(max_block_size=8, max_iterations=5, max_oversize_keys=6,
                     cms_width=1 << 10)


def _assert_result_equal(got, want):
    np.testing.assert_array_equal(got.candidates, want.candidates)
    np.testing.assert_array_equal(got.block_sizes, want.block_sizes)
    assert got.n_blocks_hit == want.n_blocks_hit
    assert got.levels_walked == want.levels_walked


# ---------------------------------------------------------------------------
# batching invariance (the tentpole correctness property)
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000),
       batch=st.sampled_from([1, 2, 5, 7, 16]),
       include_probe=st.sampled_from([False, True]))
def test_micro_batched_probes_match_one_at_a_time(seed, batch, include_probe):
    """Service responses (collated across requests, padded to bucket rungs)
    are bit-identical to solo DeltaBlocker.query_keys calls — candidates,
    block sizes, hit and level counts — in both include_probe modes.
    probe_slots=16 with min_bucket=4 makes the collated batches cross
    several ladder rungs (4, 8, 16) across draws."""
    rng = np.random.default_rng(seed)
    keys, valid = _random_keys(rng, n=160, k=6, card=18)
    base_k, base_v = keys[:120], valid[:120]
    probe_k, probe_v = keys[120:], valid[120:]
    svc = DedupeService(_CFG, ServiceConfig(probe_slots=16, min_bucket=4))
    tenant = svc.add_tenant("t")
    svc.submit_ingest("t", base_k, base_v)
    svc.run()
    uids = []
    for off in range(0, len(probe_k), batch):
        uids.append(svc.submit_probe(
            "t", probe_k[off:off + batch], probe_v[off:off + batch],
            include_probe=include_probe))
    svc.run()
    got = {r.uid: r for r in svc.probe_responses}
    row = 0
    some_candidates = False
    for uid in uids:
        resp = got[uid]
        assert resp.status == "ok"
        for qr in resp.results:
            want = tenant.blocker.query_keys(
                probe_k[row:row + 1], probe_v[row:row + 1],
                include_probe=include_probe)[0]
            _assert_result_equal(qr, want)
            some_candidates |= len(qr.candidates) > 0
            row += 1
    assert row == len(probe_k)       # every probe row answered exactly once
    assert some_candidates           # the draw actually exercised the walk


def test_pad_probe_rows_and_ladder():
    ladder = BucketLadder(min_bucket=8)
    assert [ladder.bucket(n) for n in (0, 1, 8, 9, 64, 65)] == [
        8, 8, 8, 16, 64, 128]
    assert ladder.rungs(64) == [8, 16, 32, 64]
    rng = np.random.default_rng(0)
    keys, valid = _random_keys(rng, n=5, k=4, card=9)
    pk, pv = pad_probe_rows(keys, valid, 8)
    assert pk.shape == (8, 4, 2) and pv.shape == (8, 4)
    np.testing.assert_array_equal(pk[:5], keys)
    np.testing.assert_array_equal(pv[:5], valid)
    assert not pv[5:].any()
    assert (pk[5:] == np.uint32(0xFFFFFFFF)).all()
    with pytest.raises(ValueError):
        pad_probe_rows(keys, valid, 4)


# ---------------------------------------------------------------------------
# lanes, backpressure, deadlines, fair share
# ---------------------------------------------------------------------------


def test_probes_never_stall_behind_ingest_queue():
    rng = np.random.default_rng(3)
    keys, valid = _random_keys(rng, n=200, k=6, card=20)
    svc = DedupeService(_CFG, ServiceConfig(probe_slots=8, ingest_slots=32))
    svc.add_tenant("t")
    svc.submit_ingest("t", keys[:64], valid[:64])
    svc.run()
    for off in range(64, 192, 32):   # 4 queued ledger syncs
        svc.submit_ingest("t", keys[off:off + 32], valid[off:off + 32])
    uid = svc.submit_probe("t", keys[:4], valid[:4])
    svc.step()   # read lane served in the same step, not after the backlog
    assert any(r.uid == uid for r in svc.probe_responses)
    assert svc.queue_depths()["write"] > 0


def test_backpressure_rejects_full_lanes():
    rng = np.random.default_rng(1)
    keys, valid = _random_keys(rng, n=40, k=6, card=12)
    svc = DedupeService(_CFG, ServiceConfig(max_read_queue=2,
                                            max_write_queue=1))
    svc.add_tenant("t")
    svc.submit_ingest("t", keys[:20], valid[:20])
    with pytest.raises(BackpressureError):
        svc.submit_ingest("t", keys[20:30], valid[20:30])
    svc.run()
    svc.submit_probe("t", keys[:1], valid[:1])
    svc.submit_probe("t", keys[1:2], valid[1:2])
    with pytest.raises(BackpressureError):
        svc.submit_probe("t", keys[2:3], valid[2:3])
    assert svc.snapshot()["counters"]["rejected_total"] == 2
    svc.run()
    assert all(r.status == "ok" for r in svc.probe_responses)


def test_expired_probe_is_shed_with_explicit_response():
    rng = np.random.default_rng(2)
    keys, valid = _random_keys(rng, n=30, k=6, card=10)
    svc = DedupeService(_CFG, ServiceConfig())
    svc.add_tenant("t")
    svc.submit_ingest("t", keys[:20], valid[:20])
    svc.run()
    expired = svc.submit_probe("t", keys[20:22], valid[20:22],
                               deadline_s=-1.0)   # already past its deadline
    live = svc.submit_probe("t", keys[22:24], valid[22:24])
    svc.run()
    by_uid = {r.uid: r for r in svc.probe_responses}
    assert by_uid[expired].status == "expired"
    assert by_uid[expired].results == []
    assert by_uid[live].status == "ok" and len(by_uid[live].results) == 2
    counters = svc.snapshot()["counters"]
    assert counters["shed_total"] == 1
    assert counters["probe_requests_total"] == 1   # shed rows never walked


def test_tenant_isolation_and_fair_share():
    rng = np.random.default_rng(5)
    keys, valid = _random_keys(rng, n=120, k=6, card=15)
    svc = DedupeService(_CFG, ServiceConfig(probe_slots=4))
    svc.add_tenant("a")
    svc.add_tenant("b")
    svc.submit_ingest("a", keys[:50], valid[:50])
    svc.submit_ingest("b", keys[50:100], valid[50:100])
    svc.run()
    assert svc.tenant("a").store.num_records == 50
    assert svc.tenant("b").store.num_records == 50
    ua = svc.submit_probe("a", keys[:2], valid[:2])
    ub = svc.submit_probe("b", keys[:2], valid[:2])
    for _ in range(6):   # flood a's read lane behind ua
        svc.submit_probe("a", keys[:4], valid[:4])
    svc.step()
    svc.step()   # round-robin: b is served on the second step, not last
    done = {r.uid for r in svc.probe_responses}
    assert ua in done and ub in done
    # identical probe, isolated stores: answers come from each tenant's own
    # rows and match that tenant's solo blocker exactly
    by_uid = {r.uid: r for r in svc.probe_responses}
    for name, uid in (("a", ua), ("b", ub)):
        want = svc.tenant(name).blocker.query_keys(keys[:2], valid[:2])
        for qr, w in zip(by_uid[uid].results, want):
            _assert_result_equal(qr, w)


def test_mixed_include_probe_modes_keep_fifo_and_split_batches():
    rng = np.random.default_rng(8)
    keys, valid = _random_keys(rng, n=60, k=6, card=12)
    svc = DedupeService(_CFG, ServiceConfig(probe_slots=16))
    tenant = svc.add_tenant("t")
    svc.submit_ingest("t", keys[:40], valid[:40])
    svc.run()
    u1 = svc.submit_probe("t", keys[40:42], valid[40:42], include_probe=False)
    u2 = svc.submit_probe("t", keys[42:44], valid[42:44], include_probe=True)
    u3 = svc.submit_probe("t", keys[44:46], valid[44:46], include_probe=False)
    svc.run()
    by_uid = {r.uid: r for r in svc.probe_responses}
    for uid, off, mode in ((u1, 40, False), (u2, 42, True), (u3, 44, False)):
        want = tenant.blocker.query_keys(keys[off:off + 2], valid[off:off + 2],
                                         include_probe=mode)
        for qr, w in zip(by_uid[uid].results, want):
            _assert_result_equal(qr, w)


# ---------------------------------------------------------------------------
# metrics contract
# ---------------------------------------------------------------------------


def test_metrics_contract_and_bucket_ladder_stability():
    rng = np.random.default_rng(9)
    keys, valid = _random_keys(rng, n=100, k=6, card=15)
    svc = DedupeService(_CFG, ServiceConfig(probe_slots=8, min_bucket=4))
    svc.add_tenant("t")
    svc.submit_ingest("t", keys[:60], valid[:60])
    svc.run()
    for rep in range(5):
        svc.submit_probe("t", keys[60 + 4 * rep:64 + 4 * rep],
                         valid[60 + 4 * rep:64 + 4 * rep])
        svc.run()
    snap = svc.snapshot()
    counters = snap["counters"]
    assert counters["probe_requests_total"] == 5
    assert counters["probe_rows_total"] == 20
    assert counters["probe_batches_total"] == 5
    assert counters["ingest_rows_total"] == 60
    # one ladder rung (4 rows -> bucket 4), compiled exactly once
    assert counters["bucket_compiles_total"] == 1
    lat = snap["histograms"]["probe_latency_s"]
    assert lat["count"] == 5
    assert 0 <= lat["min"] <= lat["p50"] <= lat["p90"] <= lat["p99"] <= lat["max"]
    occ = snap["histograms"]["batch_occupancy"]
    assert occ["count"] == 5 and occ["max"] == 1.0   # 4 rows in bucket 4
    gauges = snap["gauges"]
    assert gauges["read_queue_depth"] == 0
    assert gauges["write_queue_depth"] == 0
    assert gauges["tenants"] == 1
    # jit cache: repeating warmed shapes adds no compiled variants
    cache_after_warm = probe_jit_cache_sizes()
    for rep in range(3):
        svc.submit_probe("t", keys[80 + 4 * rep:84 + 4 * rep],
                         valid[80 + 4 * rep:84 + 4 * rep])
        svc.run()
    assert probe_jit_cache_sizes() == cache_after_warm
    assert svc.snapshot()["counters"]["bucket_compiles_total"] == 1


def test_histogram_percentiles_and_reset():
    h = Histogram.log(1e-6, 100.0, per_decade=5)
    for v in (0.001, 0.001, 0.001, 0.001, 0.5):
        h.record(v)
    snap = h.snapshot()
    assert snap["count"] == 5
    assert snap["min"] == 0.001 and snap["max"] == 0.5
    assert 0.0005 <= snap["p50"] <= 0.002    # within the 0.001 bin
    assert snap["p99"] <= 0.5                # clamped to observed max
    h.reset()
    assert h.snapshot()["count"] == 0
    m = Metrics()
    m.counter("x").inc(3)
    m.histogram("y", kind="unit").record(0.5)
    m.reset()
    snap = m.snapshot(g=1)
    assert snap["counters"]["x"] == 0
    assert snap["histograms"]["y"]["count"] == 0
    assert snap["gauges"]["g"] == 1


# ---------------------------------------------------------------------------
# shared collation + StreamingEngine satellites
# ---------------------------------------------------------------------------


def test_collate_fifo_skip_scan_fixes_head_of_line():
    queue = [("a", 40), ("b", 100), ("c", 10)]
    taken = collate_fifo(queue, 64, size_fn=lambda e: e[1],
                         group_fn=lambda e: e[0])
    assert [u for u, _ in taken] == ["a", "c"]   # c no longer waits on b
    assert [u for u, _ in queue] == ["b"]
    taken = collate_fifo(queue, 64, size_fn=lambda e: e[1],
                         group_fn=lambda e: e[0])
    assert [u for u, _ in taken] == ["b"]        # oversized head passes alone
    assert queue == []


def test_collate_fifo_preserves_per_group_order():
    queue = [("g", 60), ("g", 10), ("g", 2)]
    taken = collate_fifo(queue, 64, size_fn=lambda e: e[1],
                         group_fn=lambda e: e[0])
    # the 2 must not jump the skipped 10 from the same group
    assert taken == [("g", 60)]
    assert queue == [("g", 10), ("g", 2)]


@dataclasses.dataclass
class _FakeBatch:
    num_records: int


def test_streaming_engine_pad_batch_skip_scan():
    eng = StreamingEngine({}, _CFG, ingest_slots=64)
    u1 = eng.submit_ingest(_FakeBatch(40))
    u2 = eng.submit_ingest(_FakeBatch(100))
    u3 = eng.submit_ingest(_FakeBatch(10))
    taken = eng._pad_batch(eng._ingest_queue, eng.ingest_slots)
    assert [u for u, _ in taken] == [u1, u3]
    taken = eng._pad_batch(eng._ingest_queue, eng.ingest_slots)
    assert [u for u, _ in taken] == [u2]
    assert eng.queue_depth == 0


def test_streaming_engine_run_warns_on_truncated_drain():
    corpus = synthetic.generate(synthetic.SyntheticSpec(num_entities=30,
                                                        seed=3))
    cfg = hdb.HDBConfig(max_block_size=20, max_iterations=4,
                        cms_width=1 << 10)
    eng = StreamingEngine(corpus.blocking, cfg, ingest_slots=8)
    n = min(corpus.num_records, 24)
    for part in np.array_split(np.arange(n), 3):
        eng.submit_ingest(RecordBatch.from_corpus(corpus, part))
    with pytest.warns(RuntimeWarning, match="still queued"):
        eng.run(max_steps=1)
    assert eng.busy and eng.queue_depth == 2
    ingests, _ = eng.run()   # finishing drain: no warning, queue empty
    assert eng.queue_depth == 0 and not eng.busy
    assert sum(len(r.uids) for r in ingests) == 3
    assert eng.store.num_records == n
