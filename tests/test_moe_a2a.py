"""a2a MoE dispatch == psum-partial dispatch (8 emulated devices)."""
import os
import subprocess
import sys

import pytest

WORKER = os.path.join(os.path.dirname(__file__), "_moe_worker.py")


@pytest.mark.slow
def test_a2a_matches_psum_dispatch():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run([sys.executable, WORKER], capture_output=True,
                          text=True, timeout=900, env=env)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}"
    assert "MOE-A2A-OK" in proc.stdout
