"""Pallas kernel parity tests: interpret-mode kernel vs pure-jnp oracle,
swept across shapes/dtypes as required for every kernel."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.minhash import minhash
from repro.kernels.minhash.ref import minhash_ref
from repro.kernels.hash64 import combine64, mix64_bulk
from repro.kernels.hash64.ref import combine64_ref
from repro.kernels.cms import cms_update
from repro.kernels.cms.ref import cms_update_ref
from repro.core import sketches, hashing


# ---------------------------------------------------------------------------
# minhash
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,t,m", [
    (8, 16, 8),       # tiny, heavy padding
    (64, 128, 24),    # exact tile fit
    (100, 70, 16),    # ragged both axes
    (257, 129, 32),   # off-by-one over tiles
])
def test_minhash_kernel_matches_ref(r, t, m):
    rng = np.random.default_rng(r * 1000 + t)
    tokens = jnp.asarray(rng.integers(0, 1 << 32, (r, t), dtype=np.uint64)
                         .astype(np.uint32))
    mask = jnp.asarray(rng.random((r, t)) < 0.8)
    got = minhash(tokens, mask, m, use_kernel=True, interpret=True)
    want = minhash_ref(tokens, mask, m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("mask_kind", ["all", "none", "empty_rows"])
def test_minhash_kernel_mask_edge_cases(mask_kind):
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, 1 << 31, (32, 16), dtype=np.int64)
                         .astype(np.uint32))
    if mask_kind == "all":
        mask = jnp.ones((32, 16), bool)
    elif mask_kind == "none":
        mask = jnp.zeros((32, 16), bool)
    else:
        mask = jnp.asarray(np.repeat([[True], [False]], [16, 16], axis=0)
                           .reshape(32, 1) * np.ones((1, 16), bool))
    got = minhash(tokens, mask, 8, use_kernel=True, interpret=True)
    want = minhash_ref(tokens, mask, 8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# hash64
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(16,), (1000,), (64, 80), (3, 5, 7)])
def test_combine64_kernel_matches_ref(shape):
    rng = np.random.default_rng(int(np.prod(shape)))
    mk = lambda: jnp.asarray(rng.integers(0, 1 << 32, shape, dtype=np.uint64)
                             .astype(np.uint32))
    ahi, alo, bhi, blo = mk(), mk(), mk(), mk()
    ghi, glo = combine64(ahi, alo, bhi, blo, use_kernel=True, interpret=True)
    whi, wlo = combine64_ref(ahi, alo, bhi, blo)
    np.testing.assert_array_equal(np.asarray(ghi), np.asarray(whi))
    np.testing.assert_array_equal(np.asarray(glo), np.asarray(wlo))


def test_combine64_is_symmetric_under_swap():
    """Canonical ordering => combine(a,b) == combine(b,a)."""
    rng = np.random.default_rng(5)
    mk = lambda: jnp.asarray(rng.integers(0, 1 << 32, (512,), dtype=np.uint64)
                             .astype(np.uint32))
    ahi, alo, bhi, blo = mk(), mk(), mk(), mk()
    h1 = combine64(ahi, alo, bhi, blo, use_kernel=True, interpret=True)
    h2 = combine64(bhi, blo, ahi, alo, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(h1[0]), np.asarray(h2[0]))
    np.testing.assert_array_equal(np.asarray(h1[1]), np.asarray(h2[1]))


@pytest.mark.parametrize("n", [1, 512, 5000])
def test_mix64_bulk_matches_ref_and_python(n):
    rng = np.random.default_rng(n)
    vals = rng.integers(0, (1 << 64) - 1, n, dtype=np.uint64)
    packed = jnp.asarray(hashing.np_to_u64_arrays(vals))
    ghi, glo = mix64_bulk(packed[..., 0], packed[..., 1], use_kernel=True,
                          interpret=True)
    got = (np.asarray(ghi).astype(np.uint64) << np.uint64(32)) | np.asarray(glo)
    want = np.asarray([hashing.np_mix64(int(v)) for v in vals], np.uint64)
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# cms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth,n,width", [
    (1, 256, 2048),
    (4, 1024, 4096),
    (4, 3000, 2048),   # ragged key axis
    (6, 128, 8192),    # wider than block_width
])
def test_cms_kernel_matches_ref(depth, n, width):
    rng = np.random.default_rng(depth * n)
    idx = jnp.asarray(rng.integers(0, width, (depth, n)), jnp.int32)
    mask = jnp.asarray(rng.random(n) < 0.7)
    got = cms_update(idx, mask, width, use_kernel=True, interpret=True,
                     block_keys=256, block_width=1024)
    want = cms_update_ref(idx, mask, width)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_cms_kernel_plugs_into_sketch_queries():
    """Kernel-built sketch must answer queries identically to cms_build."""
    cfg = sketches.CMSConfig(depth=4, width=1 << 12)
    rng = np.random.default_rng(9)
    vals = rng.integers(0, 500, 4096, dtype=np.uint64)
    packed = jnp.asarray(hashing.np_to_u64_arrays(vals))
    key = (packed[..., 0], packed[..., 1])
    mask = jnp.ones(len(vals), bool)
    idx = sketches.cms_indices(cfg, key)
    sk_kernel = cms_update(idx, mask, cfg.width, use_kernel=True,
                           interpret=True, block_keys=512, block_width=1024)
    sk_ref = sketches.cms_build(cfg, key, mask)
    np.testing.assert_array_equal(np.asarray(sk_kernel), np.asarray(sk_ref))
