"""Re-run the HLO cost model over saved .hlo.gz artifacts and refresh the
roofline fields of the matching results/dryrun/*.json (no recompilation).

Usage: PYTHONPATH=src python scripts/reanalyze_hlo.py [hlo_dir] [json_dir]
"""
import glob
import gzip
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.hlo_analysis import analyze

hlo_dir = sys.argv[1] if len(sys.argv) > 1 else "results/hlo"
json_dir = sys.argv[2] if len(sys.argv) > 2 else "results/dryrun"

for path in sorted(glob.glob(os.path.join(hlo_dir, "*.hlo.gz"))):
    stem = os.path.basename(path)[: -len(".hlo.gz")]
    jpath = os.path.join(json_dir, stem + ".json")
    if not os.path.exists(jpath):
        print(f"[skip] no json for {stem}")
        continue
    with open(jpath) as f:
        rec = json.load(f)
    if not rec.get("ok"):
        continue
    with gzip.open(path, "rt") as f:
        text = f.read()
    roof, cost = analyze(text, rec["chips"])
    rec["roofline"] = roof.as_dict()
    rec["collectives"] = {"bytes": cost.coll_by_kind, "count": cost.coll_count}
    with open(jpath, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[ok] {stem}: {roof.dominant} "
          f"{roof.compute_seconds:.3g}/{roof.memory_seconds:.3g}/"
          f"{roof.collective_seconds:.3g}s")
