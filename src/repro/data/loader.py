"""Deterministic, shardable batch loader feeding LM training.

Fault-tolerance by construction: batch(step) is a pure function of
(corpus fingerprint, step, data-parallel rank), so restarts resume
mid-stream with no loader state in the checkpoint beyond the step counter,
and elastic re-sharding (different DP size) just changes the rank slicing.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .synthetic import Corpus


@dataclasses.dataclass(frozen=True)
class LoaderConfig:
    batch_size: int      # GLOBAL batch
    seq_len: int
    vocab_size: int
    eos_id: int = 0
    seed: int = 1234


class TokenStreamLoader:
    """Packs (deduplicated) records into LM batches.

    Record token hashes map into the model vocab by modulo; records are
    shuffled once (seeded) and concatenated with EOS separators into a
    ring buffer token stream.
    """

    def __init__(self, corpus: Corpus, cfg: LoaderConfig,
                 survivors: Optional[np.ndarray] = None):
        self.cfg = cfg
        keep = survivors if survivors is not None else np.arange(corpus.num_records)
        rng = np.random.default_rng(cfg.seed)
        order = rng.permutation(keep)
        chunks = []
        for name in sorted(corpus.columns):
            col = corpus.columns[name]
            toks = np.asarray(col.tokens)[order]
            mask = np.asarray(col.mask)[order]
            ids = (toks.astype(np.int64) % (cfg.vocab_size - 2)) + 2
            ids = np.where(mask, ids, -1)
            chunks.append(ids)
        flat = np.concatenate([c.reshape(len(order), -1) for c in chunks], axis=1)
        docs = []
        for row in flat:
            t = row[row >= 0]
            docs.append(np.concatenate([t, [cfg.eos_id]]))
        self.stream = np.concatenate(docs).astype(np.int32)
        if len(self.stream) < cfg.seq_len + 1:
            reps = int(np.ceil((cfg.seq_len + 1) / len(self.stream)))
            self.stream = np.tile(self.stream, reps + 1)

    @property
    def tokens_per_batch(self) -> int:
        return self.cfg.batch_size * self.cfg.seq_len

    def batch(self, step: int, dp_rank: int = 0, dp_size: int = 1):
        """(inputs, targets) for `step`, restricted to this DP rank's rows."""
        cfg = self.cfg
        assert cfg.batch_size % dp_size == 0
        rows_per_rank = cfg.batch_size // dp_size
        n = len(self.stream)
        out_in = np.empty((rows_per_rank, cfg.seq_len), np.int32)
        out_tg = np.empty((rows_per_rank, cfg.seq_len), np.int32)
        for r in range(rows_per_rank):
            row = dp_rank * rows_per_rank + r
            start = (step * self.tokens_per_batch + row * cfg.seq_len) % (n - cfg.seq_len - 1)
            seg = self.stream[start : start + cfg.seq_len + 1]
            out_in[r] = seg[:-1]
            out_tg[r] = seg[1:]
        return out_in, out_tg
