"""Graph partitioning — stage 4 of the dedup pipeline (paper §1, [14,25]).

Connected components over matched pairs via pointer-jumping label
propagation: each node adopts the min label among its neighbors; labels
then path-compress. Converges in O(log N) rounds; both phases are
fixed-shape JAX ops so the whole thing jits and shards.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("num_nodes",))
def _cc_device(a: jnp.ndarray, b: jnp.ndarray, *, num_nodes: int) -> jnp.ndarray:
    def round_fn(state):
        label, _ = state
        la, lb = label[a], label[b]
        new = jnp.minimum(la, lb)
        label2 = label.at[a].min(new)
        label2 = label2.at[b].min(new)
        # pointer jumping: label <- label[label] twice
        label2 = label2[label2]
        label2 = label2[label2]
        changed = jnp.any(label2 != label)
        return label2, changed

    def cond_fn(state):
        return state[1]

    init = (jnp.arange(num_nodes, dtype=jnp.int32), jnp.asarray(True))
    label, _ = jax.lax.while_loop(cond_fn, round_fn, init)
    return label


def connected_components(num_nodes: int, a: np.ndarray, b: np.ndarray,
                         max_rounds: int = 64) -> np.ndarray:
    """Component label per node (min node id in the component).

    Jitted (via ``_cc_device``): the eager label-propagation loop built
    its init labels and edge uploads as implicit transfers every call
    (repro.analysis R001); now edges are pre-cast host-side and the whole
    fixpoint runs as one compiled while_loop.
    """
    if len(a) == 0:
        return np.arange(num_nodes, dtype=np.int64)
    a = jnp.asarray(np.asarray(a, np.int32))
    b = jnp.asarray(np.asarray(b, np.int32))
    label = _cc_device(a, b, num_nodes=num_nodes)
    return np.asarray(label).astype(np.int64)
