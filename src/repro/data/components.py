"""Graph partitioning — stage 4 of the dedup pipeline (paper §1, [14,25]).

Connected components over matched pairs via frontier-masked min-label
hooking + full path compression (the Shiloach-Vishkin shape): each
round, only edges whose endpoints still disagree (the frontier) scatter
their min label — converged edges contribute an INT32_MAX no-op to the
``.min`` scatter, so masking costs no control flow — then labels
pointer-jump to fixpoint. Hook + full compression converges in O(log N)
rounds even on chain graphs (the seed's fixed-two-jumps variant was
O(diameter) and hid it behind an unbounded loop); the whole fixpoint is
one compiled ``while_loop`` with a hard ``max_rounds`` bound and an
early-exit changed flag, and the survivor
set (one canonical record per component = the min record id, which is
the label itself) is extracted on device by a root-mask prefix-sum
scatter. The fused pipeline feeds this straight from the match kernel's
compacted pair buffer — zero-padded tails are (0, 0) self-edges, which
the frontier mask drops for free.

``connected_components_oracle`` is the host union-find ground truth the
device labels are property-tested against.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

_INT32_MAX = np.iinfo(np.int32).max
# pow-2 floor for node/edge capacities: bounds the jit-cache footprint of
# long-running callers (streaming extend, serving refresh) to one compile
# per doubling instead of one per call
_MIN_CAP = 1024


@functools.partial(jax.jit, static_argnames=("num_nodes", "max_rounds"))
def _cc_device(a: jnp.ndarray, b: jnp.ndarray, *, num_nodes: int,
               max_rounds: int):
    """Bounded label-propagation fixpoint.

    Returns ``(label, converged, rounds)`` — all device values. The loop
    exits when no label moved (converged) OR at ``max_rounds``; callers
    surface truncation loudly (the engines' convention) instead of
    silently shipping stale labels.
    """
    def compress(label):
        # full path compression: pointer-jump to fixpoint. Labels only
        # ever decrease and point downward (label[v] <= v), so the label
        # forest is acyclic and each jump doubles the compressed depth —
        # this inner loop is O(log depth) and does not count as rounds.
        return jax.lax.while_loop(
            lambda lab: jnp.any(lab != lab[lab]),
            lambda lab: lab[lab], label)

    def round_fn(state):
        label, _, rounds = state
        la, lb = label[a], label[b]
        # frontier mask: settled edges (la == lb) push INT32_MAX, a no-op
        # for the .min scatter — self-edge padding (0, 0) lands here too
        new = jnp.where(la != lb, jnp.minimum(la, lb), _INT32_MAX)
        # hook the ROOTS (la/lb), not the endpoints: after compression
        # every member points at its root, so lowering the root's label
        # merges whole components at once — scattering onto a/b (the
        # seed behavior) moves one node per round, O(diameter) on chains
        label2 = label.at[la].min(new)
        label2 = label2.at[lb].min(new)
        # hook + full compression converges in O(log N) hooking rounds
        # (each round at least halves the roots along any edge path);
        # the seed's two-fixed-jumps variant was O(diameter) on chain
        # graphs and only looked convergent because its loop had no bound
        label2 = compress(label2)
        changed = jnp.any(label2 != label)
        return label2, changed, rounds + 1

    def cond_fn(state):
        return state[1] & (state[2] < max_rounds)

    init = (jnp.arange(num_nodes, dtype=jnp.int32), jnp.asarray(True),
            jnp.asarray(0, jnp.int32))
    label, changed, rounds = jax.lax.while_loop(cond_fn, round_fn, init)
    # `changed` False means the last round was a fixpoint check that
    # found nothing to do — i.e. converged within the bound
    return label, jnp.logical_not(changed), rounds


@functools.partial(jax.jit, static_argnames=("num_nodes",))
def _survivors_device(label: jnp.ndarray, n_real: jnp.ndarray, *,
                      num_nodes: int):
    """Device survivor extraction: the sorted component roots < n_real.

    A root is a node that labels itself; the exclusive prefix sum over
    the root mask is each root's output slot, and one dump-slot scatter
    compacts them in ascending id order (== ``np.unique(label)``).
    Capacity-padding nodes at index >= ``n_real`` are self-labeled
    isolates and are masked out of the root set.
    """
    idx = jnp.arange(num_nodes, dtype=jnp.int32)
    root = (label == idx) & (idx < n_real)
    ri = root.astype(jnp.int32)
    rank = jnp.cumsum(ri) - ri
    pos = jnp.where(root, rank, num_nodes)
    surv = jnp.zeros((num_nodes + 1,), jnp.int32).at[pos].set(idx)[:num_nodes]
    return surv, jnp.sum(ri)


def _pow2_cap(n: int) -> int:
    cap = _MIN_CAP
    while cap < n:
        cap *= 2
    return cap


def cluster_pairs_device(num_nodes: int, a: jnp.ndarray, b: jnp.ndarray, *,
                         max_rounds: int = 64):
    """Cluster a device-resident (possibly zero-padded) pair buffer.

    The fused pipeline's device hot path: ``a``/``b`` come straight from
    the match kernel's compacted output — the tail beyond the matched
    count is (0, 0) pairs, which the frontier mask treats as no-ops, so
    no host-side crop (and no transfer) is needed between match and
    cluster. Node capacity is pow-2 padded; returns device values
    ``(label, survivors, n_survivors, converged, rounds)`` where
    ``label``/``survivors`` are capacity-length (crop host-side with
    ``num_nodes`` / ``n_survivors``).
    """
    cap = _pow2_cap(num_nodes)
    label, converged, rounds = _cc_device(a, b, num_nodes=cap,
                                          max_rounds=max_rounds)
    surv, n_surv = _survivors_device(
        label, jax.device_put(np.int32(num_nodes)), num_nodes=cap)
    return label, surv, n_surv, converged, rounds


@dataclasses.dataclass
class ClusterResult:
    """Host-side clustering outcome (the only values that cross over)."""
    label: np.ndarray        # (N,) int64 component label = min member id
    survivors: np.ndarray    # (S,) int64 sorted canonical record ids
    converged: bool          # False iff truncated at max_rounds
    rounds: int              # propagation rounds actually run


def _warn_truncated(max_rounds: int) -> None:
    warnings.warn(
        f"connected_components stopped at max_rounds={max_rounds} before "
        "convergence; labels may merge further — raise max_rounds",
        RuntimeWarning, stacklevel=3)


def cluster_edges(num_nodes: int, a: np.ndarray, b: np.ndarray, *,
                  max_rounds: int = 64) -> ClusterResult:
    """Host edge list -> ClusterResult via the device CC path.

    Edge count and node capacity are pow-2 bucketed (zero padding =
    frontier no-ops), so streaming callers that grow by deltas compile
    one kernel per doubling, not one per ingest.
    """
    m = int(len(a))
    if m == 0:
        label = np.arange(num_nodes, dtype=np.int64)
        return ClusterResult(label=label, survivors=label.copy(),
                             converged=True, rounds=0)
    cap_e = _pow2_cap(m)
    ae = np.zeros(cap_e, np.int32)
    be = np.zeros(cap_e, np.int32)
    ae[:m] = np.asarray(a, np.int32)
    be[:m] = np.asarray(b, np.int32)
    label, surv, n_surv, converged, rounds = cluster_pairs_device(
        num_nodes, jnp.asarray(ae), jnp.asarray(be), max_rounds=max_rounds)
    conv = bool(np.asarray(converged))
    if not conv:
        _warn_truncated(max_rounds)
    ns = int(np.asarray(n_surv))
    return ClusterResult(
        label=np.asarray(label)[:num_nodes].astype(np.int64),
        survivors=np.asarray(surv)[:ns].astype(np.int64),
        converged=conv,
        rounds=int(np.asarray(rounds)),
    )


def connected_components(num_nodes: int, a: np.ndarray, b: np.ndarray,
                         max_rounds: int = 64) -> np.ndarray:
    """Component label per node (min node id in the component).

    Jitted (via ``_cc_device``): the eager label-propagation loop built
    its init labels and edge uploads as implicit transfers every call
    (repro.analysis R001); now edges are pre-cast host-side and the whole
    fixpoint runs as one compiled while_loop. ``max_rounds`` is a hard
    bound — truncation warns (RuntimeWarning) instead of being ignored.
    """
    if len(a) == 0:
        return np.arange(num_nodes, dtype=np.int64)
    a = jnp.asarray(np.asarray(a, np.int32))
    b = jnp.asarray(np.asarray(b, np.int32))
    label, converged, _ = _cc_device(a, b, num_nodes=num_nodes,
                                     max_rounds=max_rounds)
    if not bool(np.asarray(converged)):
        _warn_truncated(max_rounds)
    return np.asarray(label).astype(np.int64)


def connected_components_oracle(num_nodes: int, a: np.ndarray,
                                b: np.ndarray) -> np.ndarray:
    """Union-find ground truth: same contract as ``connected_components``.

    Path-halving find + union that always attaches the larger root under
    the smaller, so every root IS the min member id and labels match the
    device propagation exactly (not just up to relabeling).
    """
    parent = np.arange(num_nodes, dtype=np.int64)

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]   # path halving
            x = parent[x]
        return x

    for x, y in zip(np.asarray(a, np.int64), np.asarray(b, np.int64)):
        rx, ry = find(int(x)), find(int(y))
        if rx != ry:
            if rx < ry:
                parent[ry] = rx
            else:
                parent[rx] = ry
    return np.array([find(i) for i in range(num_nodes)], dtype=np.int64)
