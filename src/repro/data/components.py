"""Graph partitioning — stage 4 of the dedup pipeline (paper §1, [14,25]).

Connected components over matched pairs via pointer-jumping label
propagation: each node adopts the min label among its neighbors; labels
then path-compress. Converges in O(log N) rounds; both phases are
fixed-shape JAX ops so the whole thing jits and shards.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def connected_components(num_nodes: int, a: np.ndarray, b: np.ndarray,
                         max_rounds: int = 64) -> np.ndarray:
    """Component label per node (min node id in the component)."""
    if len(a) == 0:
        return np.arange(num_nodes, dtype=np.int64)
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)

    def round_fn(state):
        label, _ = state
        la, lb = label[a], label[b]
        new = jnp.minimum(la, lb)
        label2 = label.at[a].min(new)
        label2 = label2.at[b].min(new)
        # pointer jumping: label <- label[label] twice
        label2 = label2[label2]
        label2 = label2[label2]
        changed = jnp.any(label2 != label)
        return label2, changed

    def cond_fn(state):
        return state[1]

    init = (jnp.arange(num_nodes, dtype=jnp.int32), jnp.asarray(True))
    label, _ = jax.lax.while_loop(cond_fn, round_fn, init)
    return np.asarray(label).astype(np.int64)
