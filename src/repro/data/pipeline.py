"""End-to-end dedup pipeline: the paper's 4 stages feeding LM training.

    normalize -> BLOCK (HDB, the paper's contribution) -> pairwise match
    -> graph partition -> canonical records -> token stream -> batches

``dedup_corpus`` runs stages 2-4 batch-mode and returns one surviving
record per entity-component. ``DedupPipeline`` is the streaming-consistent
form: it holds a persistent ``streaming.BlockStore`` so ``extend(delta)``
absorbs new records incrementally — blocking work proportional to the
delta, matching only the new candidate pairs (scored from the device pair
buffer), retraction-aware — and exposes the current survivors for the
training-batch stream (see loader.py).

Both run the back half (match -> filter -> cluster) behind a
``match_backend`` knob: "host" is the original score-on-host parity
baseline; "jnp"/"pallas" (and "auto") route through the fused
``kernels/match`` + ``cluster_pairs_device`` path, where the pair list
never crosses to the host — only final labels/survivors do. The two
paths are bit-identical (docs/PIPELINE.md).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import blocks as blocks_mod
from ..core import hdb as hdb_mod
from ..core import pairs as pairs_mod
from . import components, matcher
from .synthetic import Corpus


def _sync(*vals) -> None:
    """Block on device work so ``perf_counter`` windows attribute stage
    time to the stage that did the work (the repro.analysis R004 hazard:
    async dispatch bleeds matching time into partition time)."""
    for v in vals:
        for leaf in jax.tree_util.tree_leaves(v):
            if isinstance(leaf, jax.Array):
                leaf.block_until_ready()


@dataclasses.dataclass
class DedupReport:
    num_records: int
    num_candidate_pairs: int
    num_matched_pairs: int
    num_components: int
    num_survivors: int
    blocking_seconds: float
    matching_seconds: float
    partition_seconds: float
    survivors: np.ndarray       # (S,) record ids, one per component
    component_of: np.ndarray    # (N,) component label per record


def dedup_corpus(corpus: Corpus,
                 cfg: hdb_mod.HDBConfig = hdb_mod.HDBConfig(max_block_size=100),
                 match_cfg: matcher.MatcherConfig = matcher.MatcherConfig(),
                 pair_budget: int = 20_000_000,
                 blocker: str = "hdb",
                 verbose: bool = False,
                 match_backend: str = "auto",
                 cc_max_rounds: int = 64) -> DedupReport:
    n = corpus.num_records
    backend = ("host" if match_backend == "host"
               else matcher.resolve_match_backend(match_backend))
    t0 = time.perf_counter()
    keys, valid = blocks_mod.build_keys(corpus.columns, corpus.blocking)
    if blocker == "hdb":
        result = hdb_mod.hashed_dynamic_blocking(keys, valid, cfg, verbose=verbose)
    elif blocker == "threshold":
        from ..core.baselines import threshold_blocking
        result = threshold_blocking(keys, valid, cfg.max_block_size)
    else:
        raise ValueError(blocker)
    blk = pairs_mod.build_blocks(result)
    pset = pairs_mod.dedupe_pairs(blk, budget=pair_budget)
    # feed the matcher the device pair buffer directly (no host round trip
    # of the pair list when the device dedupe path produced it)
    dev_a, dev_b = pset.pair_buffers()
    _sync(dev_a, dev_b)
    t1 = time.perf_counter()
    if backend == "host":
        # parity baseline: scores + matched mask land host-side, the
        # matched pair list is gathered in numpy and re-uploaded for CC
        matched = matcher.match_pairs(corpus.columns, dev_a, dev_b, match_cfg)
        ma, mb = pset.a[matched], pset.b[matched]
        num_matched = int(matched.sum())
        t2 = time.perf_counter()
        label = components.connected_components(n, ma, mb,
                                                max_rounds=cc_max_rounds)
        # canonical survivor = min record id per component == the label
        survivors = np.unique(label)
    else:
        # fused path: matched pairs stay device-resident end to end —
        # the compacted (0,0)-padded buffer flows straight into CC and
        # only labels/survivors/counters ever cross to the host
        ca, cb, cnt = matcher.match_compact(corpus.columns, dev_a, dev_b,
                                            match_cfg, backend=backend)
        _sync(ca, cb, cnt)
        t2 = time.perf_counter()
        label_d, surv_d, n_surv, converged, _ = components.cluster_pairs_device(
            n, ca, cb, max_rounds=cc_max_rounds)
        _sync(label_d, surv_d)
        if not bool(np.asarray(converged)):
            components._warn_truncated(cc_max_rounds)
        num_matched = int(np.asarray(cnt))
        label = np.asarray(label_d)[:n].astype(np.int64)
        survivors = np.asarray(surv_d)[:int(np.asarray(n_surv))].astype(np.int64)
    t3 = time.perf_counter()
    return DedupReport(
        num_records=n,
        num_candidate_pairs=len(pset.a),
        num_matched_pairs=num_matched,
        num_components=len(survivors),
        num_survivors=len(survivors),
        blocking_seconds=t1 - t0,
        matching_seconds=t2 - t1,
        partition_seconds=t3 - t2,
        survivors=survivors,
        component_of=label,
    )


class DedupPipeline:
    """Incremental dedup: persistent blocking state + delta matching.

    ``extend(corpus_delta)`` ingests a record delta through the streaming
    blocker (exact-incremental HDB over the union), scores ONLY the new
    candidate pairs with the matcher — reading the pair buffer directly —
    drops matches whose candidate pair was retracted, and re-partitions.
    The returned ``DedupReport`` always describes the full union.
    """

    def __init__(self, cfg: hdb_mod.HDBConfig = hdb_mod.HDBConfig(max_block_size=100),
                 match_cfg: matcher.MatcherConfig = matcher.MatcherConfig(),
                 match_backend: str = "auto",
                 cc_max_rounds: int = 64):
        from ..streaming import BlockStore, DeltaBlocker  # local: optional dep cycle
        from ..streaming.engine import ColumnCache
        self.cfg = cfg
        self.match_cfg = match_cfg
        self.match_backend = ("host" if match_backend == "host"
                              else matcher.resolve_match_backend(match_backend))
        self.cc_max_rounds = cc_max_rounds
        self.store = BlockStore(cfg)
        self.blocker = DeltaBlocker(self.store)
        self.blocking: Optional[Dict[str, blocks_mod.ColumnBlocking]] = None
        self._columns = ColumnCache()
        # matched pairs as packed a<<32|b, sorted
        self._matched = np.zeros((0,), np.uint64)

    def extend(self, corpus_delta: Corpus) -> DedupReport:
        from ..kernels.match import packed_host
        from ..streaming.store import pack_pair, searchsorted_mask, unpack_pair
        t0 = time.perf_counter()
        if self.blocking is None:
            self.blocking = corpus_delta.blocking
        self._columns.append({name: (np.asarray(col.tokens),
                                     np.asarray(col.mask))
                              for name, col in corpus_delta.columns.items()})
        keys, valid = blocks_mod.build_keys(corpus_delta.columns, self.blocking)
        report = self.blocker.ingest_keys(np.asarray(keys), np.asarray(valid))
        # ingest returns host arrays, so device work is already drained
        # here; the explicit barrier keeps the stage windows honest if
        # that ever changes (repro.analysis R004)
        _sync(report)
        t1 = time.perf_counter()
        a, b, _ = report.pairs_added
        ra, rb = report.pairs_retracted
        if len(ra):
            # retraction against the packed ledger: blocks dissolved by
            # this delta withdraw their pairs before the union re-forms
            pos, hit = searchsorted_mask(self._matched, pack_pair(ra, rb))
            keep = np.ones(len(self._matched), bool)
            keep[pos[hit]] = False
            self._matched = self._matched[keep]
        if len(a):
            cols = self._columns.columns()
            if self.match_backend == "host":
                # pre-cast host-side then upload explicitly: dtype-coercing
                # jnp.asarray is an implicit transfer (repro.analysis R001)
                matched = matcher.match_pairs(
                    cols, jnp.asarray(np.asarray(a, np.int32)),
                    jnp.asarray(np.asarray(b, np.int32)), self.match_cfg)
                new = pack_pair(a[matched], b[matched])
            else:
                # fused delta match: score+threshold+compact on device,
                # pull only the packed matched words for the ledger
                ca, cb, cnt = matcher.match_compact(
                    cols, a, b, self.match_cfg, backend=self.match_backend)
                _sync(ca, cb, cnt)
                new = packed_host(ca, cb, int(np.asarray(cnt)))
            self._matched = np.union1d(self._matched, new)
        t2 = time.perf_counter()
        n = self.store.num_records
        ma, mb = unpack_pair(self._matched)
        if self.match_backend == "host":
            label = components.connected_components(
                n, ma, mb, max_rounds=self.cc_max_rounds)
            survivors = np.unique(label)
        else:
            # pow-2 bucketed device CC: bounded compiles as the union grows
            cres = components.cluster_edges(
                n, ma, mb, max_rounds=self.cc_max_rounds)
            label, survivors = cres.label, cres.survivors
        t3 = time.perf_counter()
        return DedupReport(
            num_records=n,
            num_candidate_pairs=len(self.store.led_pack),
            num_matched_pairs=len(self._matched),
            num_components=len(survivors),
            num_survivors=len(survivors),
            blocking_seconds=t1 - t0,
            matching_seconds=t2 - t1,
            partition_seconds=t3 - t2,
            survivors=survivors,
            component_of=label,
        )


def dedup_quality(report: DedupReport, corpus: Corpus) -> dict:
    """Cluster-level quality vs ground truth entity ids."""
    # pairwise precision/recall of the final components on the labeled pairs
    la, lb = corpus.labeled_pairs()
    same_comp = report.component_of[la] == report.component_of[lb]
    recall = float(same_comp.mean()) if len(la) else 0.0
    # sampled precision: pairs within components
    rng = np.random.default_rng(0)
    order = np.argsort(report.component_of, kind="stable")
    lab = report.component_of[order]
    starts = np.flatnonzero(np.concatenate([[True], lab[1:] != lab[:-1]]))
    sizes = np.diff(np.concatenate([starts, [len(lab)]]))
    multi = np.flatnonzero(sizes >= 2)
    correct = total = 0
    for ci in multi[:20000]:
        s, m = starts[ci], sizes[ci]
        mem = order[s : s + m]
        if m > 12:
            mem = rng.choice(mem, 12, replace=False)
        ii, jj = np.triu_indices(len(mem), 1)
        correct += int((corpus.entity_id[mem[ii]] == corpus.entity_id[mem[jj]]).sum())
        total += len(ii)
    precision = correct / total if total else 1.0
    return {"pair_recall": recall, "pair_precision": precision,
            "dedup_ratio": report.num_survivors / report.num_records}
