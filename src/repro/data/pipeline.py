"""End-to-end dedup pipeline: the paper's 4 stages feeding LM training.

    normalize -> BLOCK (HDB, the paper's contribution) -> pairwise match
    -> graph partition -> canonical records -> token stream -> batches

``dedup_corpus`` runs stages 2-4 and returns one surviving record per
entity-component. ``DedupPipeline`` additionally exposes the result as a
deterministic, shardable training-batch stream (see loader.py) so any
model in the zoo trains on deduplicated data (`--dedup`).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from ..core import blocks as blocks_mod
from ..core import hdb as hdb_mod
from ..core import pairs as pairs_mod
from . import components, matcher
from .synthetic import Corpus


@dataclasses.dataclass
class DedupReport:
    num_records: int
    num_candidate_pairs: int
    num_matched_pairs: int
    num_components: int
    num_survivors: int
    blocking_seconds: float
    matching_seconds: float
    partition_seconds: float
    survivors: np.ndarray       # (S,) record ids, one per component
    component_of: np.ndarray    # (N,) component label per record


def dedup_corpus(corpus: Corpus,
                 cfg: hdb_mod.HDBConfig = hdb_mod.HDBConfig(max_block_size=100),
                 match_cfg: matcher.MatcherConfig = matcher.MatcherConfig(),
                 pair_budget: int = 20_000_000,
                 blocker: str = "hdb",
                 verbose: bool = False) -> DedupReport:
    n = corpus.num_records
    t0 = time.perf_counter()
    keys, valid = blocks_mod.build_keys(corpus.columns, corpus.blocking)
    if blocker == "hdb":
        result = hdb_mod.hashed_dynamic_blocking(keys, valid, cfg, verbose=verbose)
    elif blocker == "threshold":
        from ..core.baselines import threshold_blocking
        result = threshold_blocking(keys, valid, cfg.max_block_size)
    else:
        raise ValueError(blocker)
    blk = pairs_mod.build_blocks(result)
    pset = pairs_mod.dedupe_pairs(blk, budget=pair_budget)
    t1 = time.perf_counter()
    matched = matcher.match_pairs(corpus.columns, pset.a, pset.b, match_cfg)
    ma, mb = pset.a[matched], pset.b[matched]
    t2 = time.perf_counter()
    label = components.connected_components(n, ma, mb)
    # canonical survivor = min record id per component == the label itself
    survivors = np.unique(label)
    t3 = time.perf_counter()
    return DedupReport(
        num_records=n,
        num_candidate_pairs=len(pset.a),
        num_matched_pairs=int(matched.sum()),
        num_components=len(survivors),
        num_survivors=len(survivors),
        blocking_seconds=t1 - t0,
        matching_seconds=t2 - t1,
        partition_seconds=t3 - t2,
        survivors=survivors,
        component_of=label,
    )


def dedup_quality(report: DedupReport, corpus: Corpus) -> dict:
    """Cluster-level quality vs ground truth entity ids."""
    # pairwise precision/recall of the final components on the labeled pairs
    la, lb = corpus.labeled_pairs()
    same_comp = report.component_of[la] == report.component_of[lb]
    recall = float(same_comp.mean()) if len(la) else 0.0
    # sampled precision: pairs within components
    rng = np.random.default_rng(0)
    order = np.argsort(report.component_of, kind="stable")
    lab = report.component_of[order]
    starts = np.flatnonzero(np.concatenate([[True], lab[1:] != lab[:-1]]))
    sizes = np.diff(np.concatenate([starts, [len(lab)]]))
    multi = np.flatnonzero(sizes >= 2)
    correct = total = 0
    for ci in multi[:20000]:
        s, m = starts[ci], sizes[ci]
        mem = order[s : s + m]
        if m > 12:
            mem = rng.choice(mem, 12, replace=False)
        ii, jj = np.triu_indices(len(mem), 1)
        correct += int((corpus.entity_id[mem[ii]] == corpus.entity_id[mem[jj]]).sum())
        total += len(ii)
    precision = correct / total if total else 1.0
    return {"pair_recall": recall, "pair_precision": precision,
            "dedup_ratio": report.num_survivors / report.num_records}
