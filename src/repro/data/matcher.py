"""Pairwise matching — stage 3 of the 4-stage dedup pipeline (paper §1).

The paper treats pairwise matching as downstream of blocking (their
production system uses a trained model [6]; their evaluation uses a
pre-trained "oracle"). Here the oracle is a weighted token-overlap scorer
over the same padded token columns used for blocking: it is vectorized
over candidate pairs in JAX and is deliberately much more expensive per
pair than blocking — preserving the economics that make blocking matter.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..core.blocks import TokenColumn


@dataclasses.dataclass(frozen=True)
class MatcherConfig:
    threshold: float = 0.65
    # per-column weights; text columns dominate, scalar agreement helps
    weights: tuple = (("name", 0.4), ("description", 0.3), ("brand", 0.1),
                      ("category", 0.05), ("model_no", 0.15))


def _pair_jaccard(tok: jnp.ndarray, mask: jnp.ndarray, a: jnp.ndarray,
                  b: jnp.ndarray) -> jnp.ndarray:
    """Jaccard of padded token sets for record index pairs (a, b)."""
    ta, ma = tok[a], mask[a]
    tb, mb = tok[b], mask[b]
    eq = (ta[:, :, None] == tb[:, None, :]) & ma[:, :, None] & mb[:, None, :]
    inter = jnp.sum(jnp.any(eq, axis=2), axis=1)
    na = jnp.sum(ma, axis=1)
    nb = jnp.sum(mb, axis=1)
    union = na + nb - inter
    both = (na > 0) & (nb > 0)
    return jnp.where(both, inter / jnp.maximum(union, 1), 0.0), both


@functools.partial(jax.jit, static_argnames=("bucket",))
def _gather_bucket(x: jnp.ndarray, start: jnp.ndarray, *,
                   bucket: int) -> jnp.ndarray:
    """Device-side bucket slice by clamped gather: one compile per bucket
    size (bounded), any start offset, no implicit transfers."""
    idx = start + jnp.arange(bucket, dtype=jnp.int32)
    idx = jnp.clip(idx, 0, x.shape[0] - 1)
    return x[idx].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("weights",))
def _score_batch(tokens, masks, weights, a, b):
    # weights is a static tuple of python floats: traced scalars would be
    # one implicit host->device upload apiece (repro.analysis R001)
    total = jnp.zeros(a.shape, jnp.float32)
    norm = jnp.zeros(a.shape, jnp.float32)
    for i in range(len(weights)):
        j, present = _pair_jaccard(tokens[i], masks[i], a, b)
        w = weights[i]
        total = total + w * j
        norm = norm + jnp.where(present, w, 0.0)
    return jnp.where(norm > 0, total / jnp.maximum(norm, 1e-6), 0.0)


def score_pairs(columns: Dict[str, TokenColumn], a, b,
                cfg: MatcherConfig = MatcherConfig(),
                batch: int = 65536) -> np.ndarray:
    """Similarity in [0,1] for each candidate pair.

    ``a``/``b`` may be host numpy arrays OR device jax arrays — e.g. the
    pair engine's ``PairSet.pair_buffers()`` or a streaming ingest's new
    pair buffer. Device inputs are sliced device-side (no forced host
    copy of the full pair list); only the scores come back to the host.
    Slices are padded to power-of-two buckets (capped at ``batch``) so a
    long-running service compiles a bounded set of kernels per column
    schema instead of one per pair-count.
    """
    names = [n for n, _ in cfg.weights if n in columns]
    tokens = tuple(columns[n].tokens for n in names)
    masks = tuple(columns[n].mask for n in names)
    weights = tuple(w for n, w in cfg.weights if n in columns)
    n_pairs = int(a.shape[0])
    out = np.empty(n_pairs, np.float32)
    on_device = isinstance(a, jax.Array)
    for off in range(0, n_pairs, batch):
        sl = slice(off, min(off + batch, n_pairs))
        m = sl.stop - sl.start
        bucket = 256
        while bucket < m:
            bucket *= 2
        bucket = min(bucket, batch)
        if on_device:
            # device inputs stay device-side: a jitted clamped gather
            # slices the bucket (eager slicing/padding would be implicit
            # transfers — repro.analysis R001); pad lanes replicate the
            # tail element and are discarded by the [:m] crop below
            start = jax.device_put(np.int32(off))
            aa = _gather_bucket(a, start, bucket=bucket)
            bb = _gather_bucket(b, start, bucket=bucket)
        else:
            pad = (0, bucket - m)
            aa = jnp.asarray(np.pad(np.asarray(a[sl], np.int32), pad))
            bb = jnp.asarray(np.pad(np.asarray(b[sl], np.int32), pad))
        got = _score_batch(tokens, masks, weights, aa, bb)
        out[sl] = np.asarray(got)[:m]
    return out


def match_pairs(columns, a, b, cfg: MatcherConfig = MatcherConfig()) -> np.ndarray:
    """Boolean match decision per candidate pair."""
    return score_pairs(columns, a, b, cfg) >= cfg.threshold
