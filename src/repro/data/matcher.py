"""Pairwise matching — stage 3 of the 4-stage dedup pipeline (paper §1).

The paper treats pairwise matching as downstream of blocking (their
production system uses a trained model [6]; their evaluation uses a
pre-trained "oracle"). Here the oracle is a weighted token-overlap scorer
over the same padded token columns used for blocking: it is vectorized
over candidate pairs in JAX and is deliberately much more expensive per
pair than blocking — preserving the economics that make blocking matter.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.blocks import TokenColumn
from ..kernels.match import ops as match_ops

# fused-match backend knob: "host" is the score-on-host parity baseline,
# "jnp"/"pallas" keep the matched pair set on device (kernels/match);
# "auto" currently resolves to the jnp mirror (interpret-mode Pallas is
# emulation-speed on CPU — the same policy as the pairs/sort kernels)
MATCH_BACKENDS = ("auto", "host", "jnp", "pallas")


def resolve_match_backend(backend: str) -> str:
    if backend not in MATCH_BACKENDS:
        raise ValueError(
            f"match_backend {backend!r} not in {MATCH_BACKENDS}")
    return "jnp" if backend == "auto" else backend


@dataclasses.dataclass(frozen=True)
class MatcherConfig:
    threshold: float = 0.65
    # per-column weights; text columns dominate, scalar agreement helps
    weights: tuple = (("name", 0.4), ("description", 0.3), ("brand", 0.1),
                      ("category", 0.05), ("model_no", 0.15))


def _pair_jaccard(tok: jnp.ndarray, mask: jnp.ndarray, a: jnp.ndarray,
                  b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Jaccard of padded token sets for record index pairs (a, b).

    Returns ``(jaccard, present)``. Single-sourced from the fused match
    kernel package so host scoring and the on-device fused path share
    one float op sequence (the bit-identity contract, docs/PIPELINE.md).
    """
    return match_ops.pair_jaccard_jnp(tok, mask, a, b)


@functools.partial(jax.jit, static_argnames=("bucket",))
def _gather_bucket(x: jnp.ndarray, start: jnp.ndarray, *,
                   bucket: int) -> jnp.ndarray:
    """Device-side bucket slice by clamped gather: one compile per bucket
    size (bounded), any start offset, no implicit transfers."""
    idx = start + jnp.arange(bucket, dtype=jnp.int32)
    idx = jnp.clip(idx, 0, x.shape[0] - 1)
    return x[idx].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("weights",))
def _score_batch(tokens, masks, weights, a, b):
    # weights is a static tuple of python floats: traced scalars would be
    # one implicit host->device upload apiece (repro.analysis R001).
    # Delegates to the kernel package's mirror — one scoring source.
    return match_ops.score_lanes_jnp(tokens, masks, weights, a, b)


def _schema(columns: Dict[str, TokenColumn], cfg: MatcherConfig):
    """Config-ordered (tokens, masks, weights) for the columns present."""
    names = [n for n, _ in cfg.weights if n in columns]
    tokens = tuple(columns[n].tokens for n in names)
    masks = tuple(columns[n].mask for n in names)
    weights = tuple(w for n, w in cfg.weights if n in columns)
    return tokens, masks, weights


def score_pairs(columns: Dict[str, TokenColumn], a, b,
                cfg: MatcherConfig = MatcherConfig(),
                batch: int = 65536) -> np.ndarray:
    """Similarity in [0,1] for each candidate pair.

    ``a``/``b`` may be host numpy arrays OR device jax arrays — e.g. the
    pair engine's ``PairSet.pair_buffers()`` or a streaming ingest's new
    pair buffer. Device inputs are sliced device-side (no forced host
    copy of the full pair list); only the scores come back to the host.
    Slices are padded to power-of-two buckets (capped at ``batch``) so a
    long-running service compiles a bounded set of kernels per column
    schema instead of one per pair-count.
    """
    tokens, masks, weights = _schema(columns, cfg)
    n_pairs = int(a.shape[0])
    out = np.empty(n_pairs, np.float32)
    on_device = isinstance(a, jax.Array)
    for off in range(0, n_pairs, batch):
        sl = slice(off, min(off + batch, n_pairs))
        m = sl.stop - sl.start
        bucket = 256
        while bucket < m:
            bucket *= 2
        bucket = min(bucket, batch)
        if on_device:
            # device inputs stay device-side: a jitted clamped gather
            # slices the bucket (eager slicing/padding would be implicit
            # transfers — repro.analysis R001); pad lanes replicate the
            # tail element and are discarded by the [:m] crop below
            start = jax.device_put(np.int32(off))
            aa = _gather_bucket(a, start, bucket=bucket)
            bb = _gather_bucket(b, start, bucket=bucket)
        else:
            pad = (0, bucket - m)
            aa = jnp.asarray(np.pad(np.asarray(a[sl], np.int32), pad))
            bb = jnp.asarray(np.pad(np.asarray(b[sl], np.int32), pad))
        got = _score_batch(tokens, masks, weights, aa, bb)
        out[sl] = np.asarray(got)[:m]
    return out


def match_pairs(columns, a, b, cfg: MatcherConfig = MatcherConfig()) -> np.ndarray:
    """Boolean match decision per candidate pair (host parity baseline).

    Compares in float32: a bare python-float threshold would promote the
    numpy comparison to f64 and could flip pairs that sit exactly on the
    threshold relative to the device paths (which compare in f32).
    """
    return score_pairs(columns, a, b, cfg) >= np.float32(cfg.threshold)


def match_compact(columns: Dict[str, TokenColumn], a, b,
                  cfg: MatcherConfig = MatcherConfig(), *,
                  backend: str = "auto",
                  chunk: int = match_ops.DEFAULT_CHUNK,
                  interpret: bool = True):
    """Fused on-device match: score + threshold + compaction, no host hop.

    ``a``/``b`` are the candidate pair list — device buffers
    (``PairSet.pair_buffers()``, a streaming ingest's pair buffer) stay
    on device; host numpy is pre-cast and uploaded explicitly once.
    Returns device ``(ca, cb, count)``: the first ``count`` lanes of
    ``ca``/``cb`` are the matched pairs in candidate order — the device
    limb form of the packed ``a<<32|b`` ledger words
    (``kernels.match.packed_host`` reassembles them) — and the tail is
    (0, 0) padding that feeds straight into ``cluster_pairs_device`` as
    frontier no-ops. Backend "pallas" runs the fused Pallas kernel
    (interpret-mode off-TPU), "jnp"/"auto" the XLA mirror; both are
    bit-identical to ``match_pairs``.
    """
    resolved = resolve_match_backend(backend)
    if resolved == "host":
        raise ValueError("match_compact is the device path; use "
                         "match_pairs for the host baseline")
    tokens, masks, weights = _schema(columns, cfg)
    n_real = int(a.shape[0])
    if not isinstance(a, jax.Array):
        # pre-cast host-side then upload explicitly: dtype-coercing
        # jnp.asarray is an implicit transfer (repro.analysis R001)
        a = jnp.asarray(np.asarray(a, np.int32))
        b = jnp.asarray(np.asarray(b, np.int32))
    return match_ops.fused_match_pairs(
        tokens, masks, weights, a, b, threshold=cfg.threshold,
        n_real=n_real, chunk=chunk, use_kernel=(resolved == "pallas"),
        interpret=interpret)
