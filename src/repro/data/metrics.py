"""Blocking quality metrics (paper §5.2): PQ, PC, pair counts."""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core import pairs as pairs_mod
from ..core.hdb import BlockingResult
from .synthetic import Corpus


@dataclasses.dataclass
class BlockingMetrics:
    pq: float                # pair quality (precision analog)
    pc: float                # pair completeness (recall analog)
    distinct_pairs: int      # |P| (exact or budget-truncated)
    pair_slots: int          # sum C(n,2) before cross-block dedupe
    exact_pairs: bool
    num_blocks: int
    largest_block: int

    def row(self, name: str) -> str:
        return (f"{name},{self.pq:.6g},{self.pc:.6g},{self.distinct_pairs},"
                f"{self.pair_slots},{self.num_blocks},{self.largest_block}")


def evaluate(result: BlockingResult, corpus: Corpus,
             labeled: Optional[tuple] = None,
             pair_budget: int = 30_000_000) -> BlockingMetrics:
    """PQ over distinct produced pairs (vs ground truth), PC over labels."""
    blocks = pairs_mod.build_blocks(result)
    pset = pairs_mod.dedupe_pairs(blocks, budget=pair_budget)
    if len(pset.a):
        pq = float(np.mean(corpus.is_duplicate(pset.a, pset.b)))
    else:
        pq = 0.0
    if labeled is None:
        labeled = corpus.labeled_pairs()
    la, lb = labeled
    if len(la):
        covered = pairs_mod.pair_covered(result, la, lb)
        pc = float(np.mean(covered))
    else:
        pc = 0.0
    return BlockingMetrics(
        pq=pq, pc=pc,
        distinct_pairs=len(pset.a),
        pair_slots=pset.total_slots,
        exact_pairs=pset.exact,
        num_blocks=blocks.num_blocks,
        largest_block=int(blocks.size.max()) if blocks.num_blocks else 0,
    )
