"""Synthetic dedup corpora with planted duplicate clusters + ground truth.

The paper's commercial VARxx datasets (1M–530M product records, ~60 sparse
columns) are not available; these generators produce structurally similar
corpora (DESIGN.md §6): each *entity* has a canonical record; duplicates
are corrupted copies (token dropout / substitution / swaps), mimicking the
"same product, different listing" noise the paper targets. Complete ground
truth (entity id per record) lets us compute PQ exactly instead of the
paper's trained oracle.

Columns emitted:
  name        multi-token text (Zipfian vocab)  -> LSH blocking
  description multi-token text, longer, noisier -> LSH blocking
  brand       scalar categorical (skewed)       -> identity blocking
  category    scalar categorical (few values)   -> identity blocking
  model_no    quasi-unique scalar, often absent -> identity blocking

Token "hashes" are uint32 drawn per vocab id via splitmix, so records go
straight into the blocking stack without a string tokenizer.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from ..core.blocks import ColumnBlocking, TokenColumn

import jax.numpy as jnp


@dataclasses.dataclass
class SyntheticSpec:
    num_entities: int = 5_000
    dup_rate: float = 0.35          # fraction of entities with >=1 duplicate
    max_dups: int = 4
    name_len: Tuple[int, int] = (3, 8)
    desc_len: Tuple[int, int] = (8, 24)
    vocab: int = 50_000
    zipf_a: float = 1.3
    brand_card: int = 2_000
    category_card: int = 40
    model_no_present: float = 0.6
    # corruption strength for duplicate copies
    tok_dropout: float = 0.15
    tok_substitute: float = 0.10
    seed: int = 0


@dataclasses.dataclass
class Corpus:
    columns: Dict[str, TokenColumn]
    blocking: Dict[str, ColumnBlocking]
    entity_id: np.ndarray       # (N,) ground-truth cluster per record
    num_records: int

    def labeled_pairs(self, max_pairs: int = 200_000, seed: int = 1
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """All (or sampled) positive pairs from ground truth clusters."""
        order = np.argsort(self.entity_id, kind="stable")
        ent = self.entity_id[order]
        starts = np.flatnonzero(np.concatenate([[True], ent[1:] != ent[:-1]]))
        sizes = np.diff(np.concatenate([starts, [len(ent)]]))
        a_l, b_l = [], []
        for s, n in zip(starts, sizes):
            if n < 2:
                continue
            mem = order[s : s + n]
            ii, jj = np.triu_indices(n, 1)
            a_l.append(mem[ii])
            b_l.append(mem[jj])
        if not a_l:
            z = np.zeros((0,), np.int64)
            return z, z
        a = np.concatenate(a_l)
        b = np.concatenate(b_l)
        if len(a) > max_pairs:
            rng = np.random.default_rng(seed)
            pick = rng.choice(len(a), max_pairs, replace=False)
            a, b = a[pick], b[pick]
        lo, hi = np.minimum(a, b), np.maximum(a, b)
        return lo.astype(np.int64), hi.astype(np.int64)

    def is_duplicate(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        return self.entity_id[a] == self.entity_id[b]


def _token_hash(ids: np.ndarray, namespace: int) -> np.ndarray:
    """Stable uint32 token hash per vocab id."""
    x = ids.astype(np.uint64) + np.uint64((namespace * 0x9E3779B97F4A7C15) & ((1 << 64) - 1))
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x &= np.uint64((1 << 64) - 1)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x &= np.uint64((1 << 64) - 1)
    x ^= x >> np.uint64(31)
    return (x & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def _zipf_ids(rng, n, vocab, a):
    ids = rng.zipf(a, size=n)
    return np.minimum(ids - 1, vocab - 1).astype(np.int64)


def _corrupt(rng, tokens: np.ndarray, mask: np.ndarray, spec: SyntheticSpec,
             namespace: int) -> Tuple[np.ndarray, np.ndarray]:
    """Corrupt one record's token row: dropout + substitution + swap."""
    tokens = tokens.copy()
    mask = mask.copy()
    t = len(tokens)
    drop = (rng.random(t) < spec.tok_dropout) & mask
    # never drop everything
    if drop.sum() >= mask.sum():
        drop[np.flatnonzero(mask)[0]] = False
    mask &= ~drop
    sub = (rng.random(t) < spec.tok_substitute) & mask
    n_sub = int(sub.sum())
    if n_sub:
        tokens[sub] = _token_hash(_zipf_ids(rng, n_sub, spec.vocab, spec.zipf_a), namespace)
    return tokens, mask


def generate(spec: SyntheticSpec) -> Corpus:
    rng = np.random.default_rng(spec.seed)
    # -- canonical entities --
    e = spec.num_entities
    name_w = spec.name_len[1]
    desc_w = spec.desc_len[1]
    name_len = rng.integers(spec.name_len[0], spec.name_len[1] + 1, e)
    desc_len = rng.integers(spec.desc_len[0], spec.desc_len[1] + 1, e)
    name_tok = _token_hash(
        _zipf_ids(rng, e * name_w, spec.vocab, spec.zipf_a), 1).reshape(e, name_w)
    desc_tok = _token_hash(
        _zipf_ids(rng, e * desc_w, spec.vocab, spec.zipf_a), 2).reshape(e, desc_w)
    name_mask = np.arange(name_w)[None, :] < name_len[:, None]
    desc_mask = np.arange(desc_w)[None, :] < desc_len[:, None]
    brand = _token_hash(rng.integers(0, spec.brand_card, e), 3)
    # brands skewed: 20% of records share 5 mega-brands
    mega = rng.random(e) < 0.2
    brand[mega] = _token_hash(rng.integers(0, 5, int(mega.sum())), 4)
    category = _token_hash(rng.integers(0, spec.category_card, e), 5)
    model_no = _token_hash(rng.integers(0, 1 << 30, e), 6)
    model_present = rng.random(e) < spec.model_no_present

    # -- expand to records: canonical + duplicates --
    n_dups = np.where(rng.random(e) < spec.dup_rate,
                      rng.integers(1, spec.max_dups + 1, e), 0)
    copies = 1 + n_dups
    entity_id = np.repeat(np.arange(e), copies)
    n = len(entity_id)
    src = np.repeat(np.arange(e), copies)
    is_dup = np.concatenate([np.arange(c) > 0 for c in copies]).astype(bool)

    name_t = name_tok[src].copy()
    name_m = name_mask[src].copy()
    desc_t = desc_tok[src].copy()
    desc_m = desc_mask[src].copy()
    brand_r = brand[src].copy()
    cat_r = category[src].copy()
    model_r = model_no[src].copy()
    model_m = model_present[src].copy()

    dup_idx = np.flatnonzero(is_dup)
    for i in dup_idx:
        name_t[i], name_m[i] = _corrupt(rng, name_t[i], name_m[i], spec, 1)
        desc_t[i], desc_m[i] = _corrupt(rng, desc_t[i], desc_m[i], spec, 2)
        # duplicates sometimes lose / change scalar fields
        if rng.random() < 0.15:
            brand_r[i] = _token_hash(np.array([rng.integers(0, spec.brand_card)]), 3)[0]
        if rng.random() < 0.5:
            model_m[i] = False

    perm = rng.permutation(n)

    def col(tok, mask):
        return TokenColumn(jnp.asarray(tok[perm]), jnp.asarray(mask[perm]))

    columns = {
        "name": col(name_t, name_m),
        "description": col(desc_t, desc_m),
        "brand": col(brand_r[:, None], np.ones((n, 1), bool)),
        "category": col(cat_r[:, None], np.ones((n, 1), bool)),
        "model_no": col(model_r[:, None], model_m[:, None]),
    }
    blocking = {
        "name": ColumnBlocking.lsh(bands=6, rows_per_band=4),
        "description": ColumnBlocking.lsh(bands=6, rows_per_band=4),
        "brand": ColumnBlocking.identity(),
        "category": ColumnBlocking.identity(),
        "model_no": ColumnBlocking.identity(),
    }
    return Corpus(columns=columns, blocking=blocking,
                  entity_id=entity_id[perm], num_records=n)


def corpus_slice(corpus: Corpus, idx: np.ndarray) -> Corpus:
    """Row-subset view of a corpus (for streaming it in micro-batches)."""
    idx = np.asarray(idx)
    cols = {name: TokenColumn(jnp.asarray(np.asarray(c.tokens)[idx]),
                              jnp.asarray(np.asarray(c.mask)[idx]))
            for name, c in corpus.columns.items()}
    return Corpus(columns=cols, blocking=corpus.blocking,
                  entity_id=corpus.entity_id[idx], num_records=len(idx))


def jaccard_pair_corpus(n_pairs: int, jaccard: float, set_size: int = 40,
                        seed: int = 0):
    """Pairs of token sets with (near-)exact Jaccard j — validates the
    analytic LSH(b,w,j) curve of paper Fig. 1a empirically."""
    rng = np.random.default_rng(seed)
    inter = int(round(2 * set_size * jaccard / (1 + jaccard)))
    only = set_size - inter
    total = inter + 2 * only
    base = rng.integers(0, 1 << 31, size=(n_pairs, total)).astype(np.uint32)
    a = np.concatenate([base[:, :inter], base[:, inter:inter + only]], axis=1)
    b = np.concatenate([base[:, :inter], base[:, inter + only:]], axis=1)
    true_j = inter / (2 * set_size - inter) if (2 * set_size - inter) else 1.0
    return a, b, true_j
