"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel package ships three modules:
  <name>.py  -- pl.pallas_call + BlockSpec VMEM tiling (TPU target)
  ops.py     -- jit'd public wrapper (padding, dispatch, interpret switch)
  ref.py     -- pure-jnp oracle used by the parity tests

This container is CPU-only: kernels are validated with interpret=True
(which executes the kernel body per-grid-step on CPU) against the oracles
across shape/dtype sweeps in tests/test_kernels_*.py.
"""
