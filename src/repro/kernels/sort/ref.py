"""Pure-numpy oracle for the LSB radix sort over u64 sort words.

Deliberately the same *algorithm* (per-digit stable counting passes) but
an independent *implementation* (numpy stable argsort per digit), so the
device backends' histogram/rank/scatter plumbing is tested against both
this oracle and ``np.sort`` — a sorted multiset is unique, so all three
must agree bit-for-bit.
"""
from __future__ import annotations

import numpy as np

from .sort import MAX_PASSES, RADIX_BITS


def np_radix_sort_words(w: np.ndarray, n_passes: int = MAX_PASSES
                        ) -> np.ndarray:
    """LSB radix sort of u64 words, ``RADIX_BITS`` bits per stable pass.

    ``n_passes`` truncation matches the device contract: digits at and
    above ``n_passes * RADIX_BITS`` are never compared, so the result is
    fully sorted only when those bits are constant across valid words
    (the sentinel's high digits are all-ones and still sort last, see
    ``ops`` module docstring).
    """
    w = np.asarray(w, np.uint64)
    mask = np.uint64((1 << RADIX_BITS) - 1)
    for p in range(int(n_passes)):
        digit = (w >> np.uint64(p * RADIX_BITS)) & mask
        w = w[np.argsort(digit, kind="stable")]
    return w
