from .ops import (MIN_PASSES, SORT_BACKENDS, radix_sort_words,  # noqa: F401
                  sort_words)
from .sort import (MAX_PASSES, RADIX, RADIX_BITS, digit_of,  # noqa: F401
                   radix_pass_pallas)
from .ref import np_radix_sort_words  # noqa: F401
