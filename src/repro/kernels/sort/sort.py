"""Pallas TPU kernel for one LSB radix-sort digit pass.

The pair engine's dedupe is ONE sort of 62-bit packed sort words (uint32
limb pairs, see ``kernels/pairs/ops.py``); on real accelerators it was
XLA's comparator ``lax.sort`` — O(n log^2 n) bitonic rounds of full
cross-lane shuffles. Radix-sorting the word in ``RADIX_BITS``-wide digits
replaces that with O(passes) streaming rounds: per pass, each element
needs only its digit's global rank, which splits into

    rank = global_base[digit]                (exclusive digit prefix sum)
         + tile_base[digit, tile]            (exclusive per-tile prefix)
         + in_tile_rank                      (rank within the tile)

This kernel computes the per-tile histogram and the in-tile rank in one
HBM read of the tile — the only cross-lane work is ``RADIX`` in-register
cumulative sums over an (8, 128) tile, pure VPU traffic. The tiny
(digits x tiles) base table and the final position gather/scatter are
memory-bound data movement and stay in XLA (same split as the pairs
tri-decode kernel: compute in Pallas, gathers in XLA).

Digit extraction never straddles a limb because ``RADIX_BITS`` divides
32; the in-tile element order is row-major over the (block_rows, 128)
tile, matching the flattened order the XLA side scatters with.

Grid: (rows / block_rows,) over a (rows, 128) lane layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Digit width. 4 bits => RADIX 16: the kernel statically unrolls RADIX
# per-digit mask/cumsum rounds (16 is cheap; 256 would not be), and the
# jnp mirror's (n, RADIX) one-hot rank transient stays small.
RADIX_BITS = 4
RADIX = 1 << RADIX_BITS
# Full u64 word coverage (sentinel = all-ones sorts last).
MAX_PASSES = 64 // RADIX_BITS


def digit_of(hi: jnp.ndarray, lo: jnp.ndarray, p: int) -> jnp.ndarray:
    """Digit ``p`` (little-endian) of the u64 word ``hi << 32 | lo``.

    ``RADIX_BITS`` divides 32, so a digit never straddles the limbs.
    Shift/mask are python ints (weak-typed): the kernel must not capture
    array constants.
    """
    shift = p * RADIX_BITS
    if shift < 32:
        return (lo >> shift) & (RADIX - 1)
    return (hi >> (shift - 32)) & (RADIX - 1)


def _radix_pass_kernel(hi_ref, lo_ref, rank_ref, hist_ref, *, p: int):
    d = digit_of(hi_ref[...], lo_ref[...], p)       # (BR, 128) uint32
    rank = jnp.zeros(d.shape, jnp.int32)
    hist_ref[...] = jnp.zeros(hist_ref.shape, jnp.int32)
    for k in range(RADIX):                          # static unroll
        m = (d == jnp.uint32(k)).astype(jnp.int32)
        row_tot = jnp.sum(m, axis=1, keepdims=True)           # (BR, 1)
        rows_before = jnp.cumsum(row_tot, axis=0) - row_tot   # exclusive
        within = jnp.cumsum(m, axis=1) - m                    # exclusive
        rank = jnp.where(m > 0, rows_before + within, rank)
        hist_ref[0, k] = jnp.sum(m)
    rank_ref[...] = rank


def radix_pass_pallas(hi: jnp.ndarray, lo: jnp.ndarray, *, p: int,
                      block_rows: int = 8, interpret: bool = False):
    """(R, 128) uint32 limb pair -> (in-tile rank, per-tile histogram).

    Returns ``rank`` of shape (R, 128) int32 — each element's rank among
    same-digit elements earlier (row-major) in its tile — and ``hist`` of
    shape (n_tiles, 128) int32 with the tile's per-digit counts in lanes
    [0, RADIX) and zeros beyond (lane padding keeps the output tile
    shape; callers slice ``hist[:, :RADIX]``).
    """
    rows, lanes = hi.shape
    assert lanes == 128 and rows % block_rows == 0, (rows, lanes)
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, 128), lambda r: (r, 0))
    hist_spec = pl.BlockSpec((1, 128), lambda r: (r, 0))
    return pl.pallas_call(
        functools.partial(_radix_pass_kernel, p=p),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=(spec, hist_spec),
        out_shape=(jax.ShapeDtypeStruct((rows, 128), jnp.int32),
                   jax.ShapeDtypeStruct((grid[0], 128), jnp.int32)),
        interpret=interpret,
    )(hi, lo)
