"""Public radix-sort ops over uint32 limb pairs (u64 sort words).

The pair engine's dedupe sorts 62-bit packed sort words held as uint32
``(hi, lo)`` limb pairs with the all-ones u64 as the invalid-lane
sentinel. ``sort_words`` is the one sort abstraction every dedupe call
site routes through:

- ``backend="comparator"``: XLA's 2-key ``lax.sort`` over the limb pair
  (the legacy path — bitonic comparator network on TPU).
- ``backend="radix"``: LSB radix sort, ``RADIX_BITS`` bits per pass.
  Each pass computes per-element stable positions (digit base + rank
  within digit) and applies ONE scatter; ``use_kernel=True`` runs the
  histogram/rank step in the Pallas kernel (``sort.radix_pass_pallas``,
  interpret mode on CPU), otherwise an equivalent fused-jnp one-hot
  cumsum mirror. Both are bit-identical to the comparator path on any
  input (a sorted multiset is unique), which the parity suite asserts.

The pass count is STATIC: callers bound the significant word bits (e.g.
``kernels.pairs.radix_passes_for`` from the max record id in the 62-bit
layout) and pass ``n_passes = ceil(bits / RADIX_BITS)``. Skipping the
all-zero high digits of small keyspaces is where radix wins most.
Sentinel safety under truncated passes: the sentinel's every digit is
the maximum (0xF), and a valid word can never match it across the low 16
size bits (block size >= 2 keeps ``inv_size < 0xFFFF``), so sentinels
sort strictly last whenever ``n_passes >= 4`` — asserted below.

Functions here are NOT jitted (they inherit the caller's tracing, so the
shard-local distributed dedupe can call them inside ``shard_map``);
``radix_sort_words`` is the jitted convenience wrapper.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .sort import (MAX_PASSES, RADIX, RADIX_BITS, digit_of,  # noqa: F401
                   radix_pass_pallas)

SORT_BACKENDS = ("comparator", "radix")
_LANES = 128
_TILE = 8 * _LANES
# below this, sentinels can interleave with valid words (see module doc)
MIN_PASSES = 16 // RADIX_BITS


def _rank_pass_jnp(d: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stable in-digit rank + per-digit counts via one-hot cumsum.

    The jnp mirror of the Pallas histogram/rank kernel, whole-array (no
    tiling): rank[i] = #(j < i with d[j] == d[i]).
    """
    onehot = (d[:, None]
              == jnp.arange(RADIX, dtype=d.dtype)[None, :]).astype(jnp.int32)
    incl = jnp.cumsum(onehot, axis=0)
    rank = jnp.take_along_axis(incl, d.astype(jnp.int32)[:, None],
                               axis=1)[:, 0] - 1
    return rank, incl[-1]


def _scatter_pass(hi, lo, pos):
    n = hi.shape[0]
    out_hi = jnp.zeros((n,), hi.dtype).at[pos].set(hi)
    out_lo = jnp.zeros((n,), lo.dtype).at[pos].set(lo)
    return out_hi, out_lo


def _radix_sort_jnp(hi, lo, n_passes: int):
    for p in range(n_passes):
        d = digit_of(hi, lo, p)
        rank, counts = _rank_pass_jnp(d)
        base = jnp.cumsum(counts) - counts          # exclusive digit prefix
        hi, lo = _scatter_pass(hi, lo, base[d.astype(jnp.int32)] + rank)
    return hi, lo


def _radix_sort_kernel(hi, lo, n_passes: int, interpret: bool):
    n = hi.shape[0]
    pad = (-n) % _TILE
    sentinel = jnp.uint32(0xFFFFFFFF)
    # pad lanes are sentinels: identical to real invalid-lane words, so
    # the stable sort keeps all sentinels (real + pad) contiguous at the
    # tail and the leading n elements ARE the sorted input
    hi = jnp.pad(hi, (0, pad), constant_values=sentinel)
    lo = jnp.pad(lo, (0, pad), constant_values=sentinel)
    n_tiles = (n + pad) // _TILE
    tile = jnp.arange(n + pad, dtype=jnp.int32) // _TILE
    for p in range(n_passes):
        rank, hist = radix_pass_pallas(hi.reshape(-1, _LANES),
                                       lo.reshape(-1, _LANES),
                                       p=p, interpret=interpret)
        hist = hist[:, :RADIX]                       # (n_tiles, RADIX)
        # base[d, t] = all counts of digits < d + counts of d in tiles < t
        flat = hist.T.reshape(-1)                    # digit-major
        base = (jnp.cumsum(flat) - flat).reshape(RADIX, n_tiles)
        d = digit_of(hi, lo, p).astype(jnp.int32)
        pos = base[d, tile] + rank.reshape(-1)
        hi, lo = _scatter_pass(hi, lo, pos)
    return hi[:n], lo[:n]


def sort_words(hi: jnp.ndarray, lo: jnp.ndarray, *,
               backend: str = "comparator", n_passes: int = MAX_PASSES,
               use_kernel: bool = False, interpret: bool = True):
    """Sort u64 words (uint32 limb pairs) ascending; the one dedupe sort.

    Not jitted — traces into the caller (jit or shard_map). ``n_passes``
    must cover every significant bit of the valid words (sentinels are
    safe from ``MIN_PASSES`` up, see module docstring); ``backend``,
    ``n_passes``, ``use_kernel``, ``interpret`` must be static under the
    caller's jit.
    """
    if backend not in SORT_BACKENDS:
        raise ValueError(
            f"sort backend must be one of {SORT_BACKENDS}, got {backend!r}")
    if backend == "comparator":
        return jax.lax.sort((hi, lo), num_keys=2)
    n_passes = int(n_passes)
    assert MIN_PASSES <= n_passes <= MAX_PASSES, n_passes
    if hi.shape[0] == 0:
        return hi, lo
    if use_kernel:
        return _radix_sort_kernel(hi, lo, n_passes, interpret)
    return _radix_sort_jnp(hi, lo, n_passes)


@functools.partial(jax.jit,
                   static_argnames=("n_passes", "use_kernel", "interpret"))
def radix_sort_words(hi: jnp.ndarray, lo: jnp.ndarray, *,
                     n_passes: int = MAX_PASSES, use_kernel: bool = False,
                     interpret: bool = True):
    """Jitted standalone radix sort (bench / direct test entry point)."""
    return sort_words(hi, lo, backend="radix", n_passes=n_passes,
                      use_kernel=use_kernel, interpret=interpret)
