"""Fused match drivers: jnp mirror, chunked gather, device compaction.

Layout mirrors ``kernels/pairs`` + ``kernels/sort``: the Pallas kernel
(match.py) computes, XLA does the gathers/scatters, ref.py holds the
numpy oracle. Three public layers:

- ``pair_jaccard_jnp`` / ``score_lanes_jnp``: the single-source scoring
  math. ``data/matcher.py``'s host path jits the SAME functions, so host
  scores and fused on-device matches are bit-identical by construction
  (not merely by test).
- ``fused_match_pairs``: chunked driver over a device pair list —
  clamped-gather member rows, score+threshold+in-tile-rank per chunk
  (jnp mirror or the Pallas kernel), then ONE cross-chunk prefix-sum
  scatter (``compact_matched``) into the packed matched-pair buffer.
- The packed buffer is the device form of the streaming ledger's
  ``a<<32|b`` uint64 words: x64 stays off (core/u64.py), so it lives as
  the two int32 limbs ``(hi=a, lo=b)``; ``packed_host`` reassembles the
  numpy uint64 ledger words at the host boundary.

Everything device-side is explicit-transfer only: scalars cross as
``jax.device_put(np.int32(...))``, results cross only when the caller
pulls them (repro.analysis R001 / transfer-guard clean).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .match import SUBLANES, _LANES, match_score_pallas

# chunk granularity: multiple of lanes, amortizes dispatch without
# blowing VMEM on the (C, T, chunk) gathered stacks
_CHUNK_QUANTUM = 1024
DEFAULT_CHUNK = 1 << 16


def pair_jaccard_jnp(tok: jnp.ndarray, mask: jnp.ndarray, a: jnp.ndarray,
                     b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Jaccard of padded token sets for record index pairs (a, b).

    Returns ``(jaccard, present)``: the f32 score and whether both sides
    have at least one valid token (absent columns drop out of the
    weighted norm instead of dragging the score to 0).
    """
    ta, ma = tok[a], mask[a]
    tb, mb = tok[b], mask[b]
    eq = (ta[:, :, None] == tb[:, None, :]) & ma[:, :, None] & mb[:, None, :]
    inter = jnp.sum(jnp.any(eq, axis=2), axis=1)
    na = jnp.sum(ma, axis=1)
    nb = jnp.sum(mb, axis=1)
    union = na + nb - inter
    both = (na > 0) & (nb > 0)
    return jnp.where(both, inter / jnp.maximum(union, 1), 0.0), both


def score_lanes_jnp(tokens, masks, weights, a, b) -> jnp.ndarray:
    """Weighted multi-column score for pair lanes (a, b) — trace-level.

    ``weights`` must be a static tuple of python floats (traced scalars
    would be one implicit upload apiece — repro.analysis R001). The op
    sequence here defines the bit-exact contract shared by the host
    matcher, the jnp mirror, the Pallas kernel, and ref.py.
    """
    total = jnp.zeros(a.shape, jnp.float32)
    norm = jnp.zeros(a.shape, jnp.float32)
    for i in range(len(weights)):
        j, present = pair_jaccard_jnp(tokens[i], masks[i], a, b)
        w = weights[i]
        total = total + w * j
        norm = norm + jnp.where(present, w, 0.0)
    return jnp.where(norm > 0, total / jnp.maximum(norm, 1e-6), 0.0)


def _round_up(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


@functools.partial(jax.jit, static_argnames=(
    "chunk", "weights", "threshold", "use_kernel", "interpret"))
def _match_chunk(tokens, masks, a, b, base, n_real, *, chunk: int,
                 weights: tuple, threshold: float, use_kernel: bool,
                 interpret: bool):
    """Score one ``chunk`` of the pair list and emit compaction inputs.

    ``base``/``n_real`` are device int32 scalars so any offset reuses one
    compile per (chunk, schema). Out-of-range lanes replicate a clamped
    in-range pair (the ``_gather_bucket`` idiom) and are force-unmatched
    via ``valid``. Returns per-lane ``(aa, bb, matched, rank)`` plus the
    per-tile matched ``counts`` (chunk/128,).
    """
    offsets = jnp.arange(chunk, dtype=jnp.int32)
    valid = offsets < (n_real - base)
    idx = jnp.clip(base + offsets, 0, a.shape[0] - 1)
    aa = a[idx]
    bb = b[idx]
    if use_kernel:
        t_pad = _round_up(max(t.shape[1] for t in tokens), SUBLANES)
        # stack columns as (C, T_pad, chunk): pairs ride the lane axis
        def stacked(cols, rows, cast):
            out = []
            for i in range(len(cols)):
                g = cols[i][rows].astype(cast)              # (chunk, T_c)
                pad = ((0, 0), (0, t_pad - cols[i].shape[1]))
                out.append(jnp.pad(g, pad).T)               # (T_pad, chunk)
            return jnp.stack(out)
        ta = stacked(tokens, aa, jnp.uint32)
        tb = stacked(tokens, bb, jnp.uint32)
        # masks ride as int32 0/1 (bool tiles are backend-fragile)
        ma = stacked(masks, aa, jnp.int32)
        mb = stacked(masks, bb, jnp.int32)
        v = valid.astype(jnp.int32).reshape(-1, _LANES)
        m2, r2, c2 = match_score_pallas(ta, ma, tb, mb, v, weights=weights,
                                        threshold=threshold,
                                        interpret=interpret)
        matched = m2.reshape(-1) != 0
        rank = r2.reshape(-1)
        counts = c2[:, 0]
    else:
        score = score_lanes_jnp(tokens, masks, weights, aa, bb)
        matched = valid & (score >= threshold)
        m2 = matched.astype(jnp.int32).reshape(-1, _LANES)
        rank = (jnp.cumsum(m2, axis=1) - m2).reshape(-1)
        counts = jnp.sum(m2, axis=1)
    return aa, bb, matched, rank, counts


@jax.jit
def compact_matched(aa, bb, matched, rank, counts):
    """Prefix-sum scatter of the matched lanes into a packed pair buffer.

    One exclusive cumsum over the per-tile counts gives each tile its
    base offset; ``base[tile] + rank`` is every matched lane's final
    slot. Unmatched lanes aim at the dump slot ``n`` of an (n+1)-long
    zero buffer that is cropped back to ``n`` — so the single scatter is
    total, and the tail beyond ``count`` stays zero, which downstream
    clustering reads as (0, 0) self-edge no-ops.
    """
    n = aa.shape[0]
    base = jnp.cumsum(counts) - counts
    tile = jnp.arange(n, dtype=jnp.int32) // _LANES
    pos = jnp.where(matched, base[tile] + rank, n)
    ca = jnp.zeros((n + 1,), jnp.int32).at[pos].set(aa)[:n]
    cb = jnp.zeros((n + 1,), jnp.int32).at[pos].set(bb)[:n]
    return ca, cb, jnp.sum(counts)


def fused_match_pairs(tokens, masks, weights, a, b, *, threshold: float,
                      n_real: int, chunk: int = DEFAULT_CHUNK,
                      use_kernel: bool = False, interpret: bool = False):
    """Fused match over a device pair list -> compacted device buffers.

    Returns ``(ca, cb, count)``, all device-resident: the first ``count``
    lanes of ``ca``/``cb`` are the matched pairs in candidate order (the
    scatter is order-preserving), the tail is zeros. ``count`` is a
    device int32 scalar — nothing crosses to the host here.
    """
    assert isinstance(a, jax.Array) and isinstance(b, jax.Array)
    n = int(n_real)
    if n == 0:
        # device_put, not eager jnp.zeros: the latter transfers its fill
        # constant implicitly and trips transfer_guard("disallow")
        z = jax.device_put(np.zeros((0,), np.int32))
        return z, z, jax.device_put(np.int32(0))
    chunk = max(_CHUNK_QUANTUM, min(chunk, _round_up(n, _CHUNK_QUANTUM)))
    assert chunk % _LANES == 0
    n_dev = jax.device_put(np.int32(n))
    parts = []
    for off in range(0, n, chunk):
        parts.append(_match_chunk(
            tokens, masks, a, b, jax.device_put(np.int32(off)), n_dev,
            chunk=chunk, weights=weights, threshold=threshold,
            use_kernel=use_kernel, interpret=interpret))
    if len(parts) == 1:
        aa, bb, matched, rank, counts = parts[0]
    else:
        aa, bb, matched, rank, counts = (
            jnp.concatenate([p[i] for p in parts]) for i in range(5))
    return compact_matched(aa, bb, matched, rank, counts)


def packed_host(ca, cb, count: int) -> np.ndarray:
    """Host uint64 ledger words ``a<<32|b`` from compacted device limbs."""
    hi = np.asarray(ca)[:count].astype(np.uint64)
    lo = np.asarray(cb)[:count].astype(np.uint64)
    return (hi << np.uint64(32)) | lo
