"""Pallas TPU kernel for fused pair matching: score + threshold + compaction ranks.

Pairwise matching (paper §1 stage 3) consumes the pair engine's candidate
buffers and must emit only the *matched* subset to graph partitioning.
The host path materializes a full per-pair score vector and a boolean
mask on the host — a device->host->device round trip of the whole pair
list per call. This kernel fuses the three steps so the matched pair set
never leaves the device:

1. **score**: per-column weighted Jaccard over the gathered token rows —
   for each candidate lane, ``T x T`` token-equality rounds per column,
   all in-register VPU compares/selects with no cross-lane traffic,
2. **threshold**: ``score >= threshold`` with the weights and threshold
   baked in as compile-time constants (one compile per MatcherConfig),
3. **compaction ranks**: each lane's exclusive prefix-sum rank among the
   matched lanes of its tile plus the per-tile matched count — the same
   histogram/rank split as the radix-sort kernel (``kernels/sort``), so
   the only XLA-side work left is the tiny cross-tile base cumsum and
   ONE scatter into the packed output buffer (memory-bound data
   movement, which stays in XLA by this repo's kernel convention; see
   ``ops.compact_matched``).

Member gathers (``tokens[a]``) also stay in XLA — the kernel reads each
pair's already-gathered ``(C, T)`` token stack from HBM exactly once.
Token/mask stacks arrive transposed to ``(C, T, lanes)`` so the lane
dimension is the pair axis; ``T`` is padded to a sublane multiple with
``mask == 0`` rows, which contribute nothing to any Jaccard term.

Grid: (pairs / 128,) over (C, T, 128) column blocks per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128
# sublane granularity the token axis is padded to (float32/int32 tiling)
SUBLANES = 8


def _match_kernel(ta_ref, ma_ref, tb_ref, mb_ref, valid_ref,
                  matched_ref, rank_ref, count_ref, *,
                  weights: tuple, threshold: float):
    """One 128-pair tile: weighted-Jaccard score -> matched/rank/count.

    The float sequence (int32 true-divide, ``w * j`` accumulation in
    weight order, ``total / max(norm, 1e-6)``) replicates
    ``ops.score_lanes_jnp`` op for op, so kernel and mirror thresholds
    are bit-identical.
    """
    total = jnp.zeros((1, _LANES), jnp.float32)
    norm = jnp.zeros((1, _LANES), jnp.float32)
    for c in range(len(weights)):
        ta = ta_ref[c]              # (T, 128) uint32 tokens of side a
        ma = ma_ref[c] != 0         # (T, 128) token-validity masks
        tb = tb_ref[c]
        mb = mb_ref[c] != 0
        inter = jnp.zeros((1, _LANES), jnp.int32)
        for i in range(ta.shape[0]):        # static unroll over a-tokens
            hit = (tb == ta[i:i + 1, :]) & mb                 # (T, 128)
            anyhit = jnp.any(hit, axis=0, keepdims=True) & ma[i:i + 1, :]
            inter = inter + anyhit.astype(jnp.int32)
        na = jnp.sum(ma.astype(jnp.int32), axis=0, keepdims=True)
        nb = jnp.sum(mb.astype(jnp.int32), axis=0, keepdims=True)
        union = na + nb - inter
        both = (na > 0) & (nb > 0)
        jac = jnp.where(both, inter / jnp.maximum(union, 1), 0.0)
        w = weights[c]              # python float: weak-typed constant
        total = total + w * jac
        norm = norm + jnp.where(both, w, 0.0)
    score = jnp.where(norm > 0, total / jnp.maximum(norm, 1e-6), 0.0)
    matched = (valid_ref[...] != 0) & (score >= threshold)
    mi = matched.astype(jnp.int32)
    matched_ref[...] = mi
    rank_ref[...] = jnp.cumsum(mi, axis=1) - mi     # exclusive in-tile rank
    count_ref[...] = jnp.zeros((1, _LANES), jnp.int32)
    count_ref[0, 0] = jnp.sum(mi)


def match_score_pallas(ta: jnp.ndarray, ma: jnp.ndarray, tb: jnp.ndarray,
                       mb: jnp.ndarray, valid: jnp.ndarray, *,
                       weights: tuple, threshold: float,
                       interpret: bool = False):
    """(C, T, P) token/mask stacks + (P/128, 128) valid -> fused match.

    ``ta``/``tb`` are uint32 token stacks, ``ma``/``mb``/``valid`` int32
    0/1 masks. P must divide 128 and T must divide ``SUBLANES`` (ops.py
    pads). Returns int32 ``(matched, rank, count)`` each shaped
    (P/128, 128); ``count`` carries the tile's matched total in lane 0
    of each row and zeros beyond (same lane-padding convention as the
    radix kernel's histogram output).
    """
    n_cols, t_pad, n_pairs = ta.shape
    assert n_pairs % _LANES == 0 and t_pad % SUBLANES == 0, ta.shape
    grid = (n_pairs // _LANES,)
    col_spec = pl.BlockSpec((n_cols, t_pad, _LANES), lambda g: (0, 0, g))
    lane_spec = pl.BlockSpec((1, _LANES), lambda g: (g, 0))
    out = jax.ShapeDtypeStruct((grid[0], _LANES), jnp.int32)
    return pl.pallas_call(
        functools.partial(_match_kernel, weights=weights,
                          threshold=threshold),
        grid=grid,
        in_specs=[col_spec, col_spec, col_spec, col_spec, lane_spec],
        out_specs=(lane_spec, lane_spec, lane_spec),
        out_shape=(out, out, out),
        interpret=interpret,
    )(ta, ma, tb, mb, valid)
