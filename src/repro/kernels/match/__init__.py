from .ops import (  # noqa: F401
    compact_matched,
    fused_match_pairs,
    packed_host,
    pair_jaccard_jnp,
    score_lanes_jnp,
)
from .match import match_score_pallas  # noqa: F401
