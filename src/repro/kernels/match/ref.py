"""Numpy oracle for the fused match kernel (host-only, no JAX).

Scoring replays the exact float32 op sequence of ``ops.score_lanes_jnp``
(int32 intersections/unions, f32 true-divide, weight accumulation in
config order) so the oracle threshold decision is bit-identical to the
device paths, not merely close. Compaction is the trivially-correct
form: boolean indexing, which the device prefix-sum scatter must equal.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def np_pair_jaccard(tok: np.ndarray, mask: np.ndarray, a: np.ndarray,
                    b: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(jaccard f32, present bool) per pair — mirror of pair_jaccard_jnp."""
    ta, ma = tok[a], mask[a]
    tb, mb = tok[b], mask[b]
    eq = (ta[:, :, None] == tb[:, None, :]) & ma[:, :, None] & mb[:, None, :]
    inter = np.sum(np.any(eq, axis=2), axis=1).astype(np.int32)
    na = np.sum(ma, axis=1).astype(np.int32)
    nb = np.sum(mb, axis=1).astype(np.int32)
    union = na + nb - inter
    both = (na > 0) & (nb > 0)
    # f32 true-divide, matching jnp's int32/int32 promotion
    jac = inter.astype(np.float32) / np.maximum(union, 1).astype(np.float32)
    return np.where(both, jac, np.float32(0.0)), both


def np_score_pairs(tokens, masks, weights, a, b) -> np.ndarray:
    """Weighted multi-column score, f32-exact vs the device paths."""
    a = np.asarray(a)
    b = np.asarray(b)
    total = np.zeros(a.shape, np.float32)
    norm = np.zeros(a.shape, np.float32)
    for i, w in enumerate(weights):
        j, present = np_pair_jaccard(np.asarray(tokens[i]),
                                     np.asarray(masks[i]), a, b)
        w32 = np.float32(w)
        total = total + w32 * j
        norm = norm + np.where(present, w32, np.float32(0.0))
    return np.where(norm > 0,
                    total / np.maximum(norm, np.float32(1e-6)),
                    np.float32(0.0))


def np_match_compact(tokens, masks, weights, a, b, *, threshold: float,
                     out_len: int | None = None):
    """Oracle for ``ops.fused_match_pairs``: (ca, cb, count) int32.

    The compacted prefix holds matched pairs in candidate order; the
    tail up to ``out_len`` is zeros — the same (0,0) no-op padding the
    device scatter produces.
    """
    a = np.asarray(a, np.int32)
    b = np.asarray(b, np.int32)
    score = np_score_pairs(tokens, masks, weights, a, b)
    matched = score >= np.float32(threshold)
    count = int(matched.sum())
    n = len(a) if out_len is None else int(out_len)
    ca = np.zeros(n, np.int32)
    cb = np.zeros(n, np.int32)
    ca[:count] = a[matched]
    cb[:count] = b[matched]
    return ca, cb, count
