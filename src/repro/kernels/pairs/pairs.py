"""Pallas TPU kernel for triangular pair-slot decoding.

Pair materialization (paper §3.1) turns each CSR block of size ``n`` into
its C(n, 2) strictly-upper-triangular pairs. Once the driver has mapped a
flat chunk of pair slots to (block-local slot ``t``, block size ``n``) —
one cheap vectorized searchsorted — the hot loop is the *triangular
decode* ``t -> (i, j)``: an exact integer binary search for the largest
row ``i`` with ``cum(i) = i*(n-1) - i*(i-1)/2 <= t``.

That search is ~17 rounds of pure VPU integer arithmetic per slot with no
gathers and no cross-lane traffic, so the kernel reads each (t, n) lane
from HBM exactly once, runs the whole search in-register, and writes
(i, j) once — the member gathers that follow are memory-bound and stay in
XLA. Row products are computed in uint32: ``i*(n-1) <= 65533*65534 <
2**32``, which is why the engine caps block sizes at ``MAX_BLOCK_N``
(enforced by the host driver; HDB's max_block_size=500 default is three
orders of magnitude below it).

Grid: (rows / block_rows,) over a (rows, 128) lane layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Largest block size whose row products fit uint32 (see module docstring).
MAX_BLOCK_N = 65535
# ceil(log2(MAX_BLOCK_N - 1)) = 16 candidate-row halvings always suffice;
# callers pass fewer steps when the layout's max block size is known.
MAX_SEARCH_STEPS = 16


def search_steps_for(max_block: int) -> int:
    """Binary-search depth covering row range [0, max_block - 2]."""
    span = max(2, max_block - 1)
    return min(MAX_SEARCH_STEPS, max(1, (span - 1).bit_length()))


def _tri_decode_kernel(local_ref, n_ref, i_ref, j_ref, *, steps: int):
    t = local_ref[...].astype(jnp.uint32)   # (BR, 128) local slot index
    n = n_ref[...].astype(jnp.uint32)       # (BR, 128) block size
    nm1 = n - 1
    lo = jnp.zeros_like(t)
    hi = jnp.where(n >= 2, n - 2, 0)
    for _ in range(steps):                  # static unroll, all in-register
        mid = (lo + hi + 1) // 2
        cum = mid * nm1 - (mid * (mid - 1)) // 2
        go_right = cum <= t
        lo = jnp.where(go_right, mid, lo)
        hi = jnp.where(go_right, hi, mid - 1)
    i = lo
    cum_i = i * nm1 - (i * (i - 1)) // 2
    j = t - cum_i + i + 1
    i_ref[...] = i.astype(jnp.int32)
    j_ref[...] = j.astype(jnp.int32)


def tri_decode_pallas(local: jnp.ndarray, n: jnp.ndarray, *,
                      steps: int = MAX_SEARCH_STEPS, block_rows: int = 8,
                      interpret: bool = False):
    """(R, 128) int32 local slot + block size -> (i, j) int32, i < j.

    R must divide block_rows (ops.py pads). ``steps`` must cover the
    largest block present (``search_steps_for``). Lanes with ``n < 2``
    produce garbage and must be masked by the caller.
    """
    rows, lanes = local.shape
    assert lanes == 128 and rows % block_rows == 0, (rows, lanes)
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, 128), lambda r: (r, 0))
    out = jax.ShapeDtypeStruct((rows, 128), jnp.int32)
    return pl.pallas_call(
        functools.partial(_tri_decode_kernel, steps=steps),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=(spec, spec),
        out_shape=(out, out),
        interpret=interpret,
    )(local.astype(jnp.int32), n.astype(jnp.int32))
