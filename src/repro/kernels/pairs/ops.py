"""Public jit'd pair-engine ops: chunked slot decode + sort-based dedupe.

The engine works in *pair-slot space*: a block of size ``n`` owns C(n, 2)
consecutive slots of the canonical enumeration (see ref.py). All device
work is fixed-shape:

- ``decode_chunk``: decode slots ``[base, base + C)`` (padded with an
  in-range validity mask) into (a, b, src_size). Because chunk slots are
  contiguous and the cumulative table is sorted, the slot -> block map is
  an O(B + C) scatter-of-block-starts + cumsum rather than a per-slot
  binary search (XLA's searchsorted costs ~17 gather rounds; the scan
  form measured ~30x cheaper on CPU). The triangular decode runs in the
  Pallas kernel (``use_kernel=True``) or an equivalent jnp integer
  binary search whose depth adapts to the layout's max block size
  (``search_steps_for``); the member gathers stay in XLA.
- ``decode_block_local``: same, but for pre-split (block, local) pairs —
  the sampling fallback splits its int64 slot draws host-side because
  global slot indices overflow int32 at scale.
- dedupe: "largest block wins" is ONE sort by the 62-bit word
  ``[a:23 | b:23 | (MAX-size):16]`` + a segment-start winner mask.
  ``pack_sort_words`` builds the word as a uint32 limb pair on device;
  ``dedupe_packed_host`` sorts it as a single u64 with ``np.sort``
  (numpy's radix-ish sort beats XLA CPU's comparator sort ~40x, and on
  CPU host==device memory so there is no transfer) while
  ``dedupe_packed_device`` / ``dedupe_device`` sort on device for real
  accelerators. All produce identical winners.

sort_backend contract: the on-device sort behind ``dedupe_device`` and
``dedupe_packed_device`` is selected by ``sort_backend`` —
``"comparator"`` is XLA's ``lax.sort`` (2-key over the packed limbs, or
the general-rid 3-key form), ``"radix"`` is the ``kernels.sort`` LSB
radix engine over the packed words (requires rids < 2**PACK_RID_BITS;
``radix_passes_for`` bounds the static pass count from the max rid, so
small keyspaces skip their constant high digits). Both orders are
bit-identical; the host driver in core/pairs.py resolves ``"auto"`` per
device backend and enforces the pack bound. Measured crossover on this
CPU container (~300k slots): comparator ~6x the jnp radix mirror (XLA
CPU serializes the per-pass scatter), so "auto" never picks radix on
CPU — the kernel targets accelerators, where the comparator's
O(log^2 n) cross-lane rounds are the documented bottleneck.

int32 contract (x64 stays off — see core/u64.py): record ids and the
materialized slot range must be < 2**31, block sizes <= MAX_BLOCK_N; the
host driver in core/pairs.py enforces both and falls back to numpy. The
packed dedupe additionally needs rids < 2**PACK_RID_BITS; the driver
falls back to ``dedupe_device`` beyond that.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .pairs import (tri_decode_pallas, search_steps_for,  # noqa: F401
                    MAX_BLOCK_N, MAX_SEARCH_STEPS)
from ..sort import ops as sort_ops

_INT32_MAX = 2**31 - 1
_LANES = 128
_TILE = 8 * _LANES  # minimum int32 tile footprint of the Pallas kernel

# 62-bit sort-word layout: [a: PACK_RID_BITS | b: PACK_RID_BITS | inv_size: 16]
PACK_RID_BITS = 23
_PACK_SIZE_BITS = 16
_SIZE_MASK = (1 << _PACK_SIZE_BITS) - 1  # == MAX_BLOCK_N
# splitmix64 seed of the pair-fingerprint shard routing (see ref.py mirror)
ROUTE_SEED = 0x9A12


def tri_decode_jnp(local: jnp.ndarray, n: jnp.ndarray,
                   steps: int = MAX_SEARCH_STEPS
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """jnp mirror of the Pallas kernel: exact uint32 binary search."""
    t = local.astype(jnp.uint32)
    n = n.astype(jnp.uint32)
    nm1 = n - 1
    lo = jnp.zeros_like(t)
    hi = jnp.where(n >= 2, n - 2, 0)
    for _ in range(steps):
        mid = (lo + hi + 1) // 2
        cum = mid * nm1 - (mid * (mid - 1)) // 2
        go_right = cum <= t
        lo = jnp.where(go_right, mid, lo)
        hi = jnp.where(go_right, hi, mid - 1)
    i = lo
    cum_i = i * nm1 - (i * (i - 1)) // 2
    j = t - cum_i + i + 1
    return i.astype(jnp.int32), j.astype(jnp.int32)


def _tri_decode(local, n, steps: int, use_kernel: bool, interpret: bool):
    if not use_kernel:
        return tri_decode_jnp(local, n, steps)
    flat = local.reshape(-1)
    pad = (-flat.shape[0]) % _TILE
    lp = jnp.pad(flat, (0, pad)).reshape(-1, _LANES)
    np_ = jnp.pad(n.reshape(-1), (0, pad)).reshape(-1, _LANES)
    i, j = tri_decode_pallas(lp, np_, steps=steps, interpret=interpret)
    sl = slice(0, flat.shape[0])
    return i.reshape(-1)[sl].reshape(local.shape), j.reshape(-1)[sl].reshape(local.shape)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "steps", "use_kernel", "interpret"))
def decode_chunk(cum: jnp.ndarray, start: jnp.ndarray, size: jnp.ndarray,
                 members: jnp.ndarray, base: jnp.ndarray, total: jnp.ndarray,
                 *, chunk: int, steps: int = MAX_SEARCH_STEPS,
                 use_kernel: bool = False, interpret: bool = True):
    """Decode pair slots [base, base+chunk) -> (a, b, src_size, valid).

    All CSR inputs are int32 device arrays; ``cum`` has length B+1 with
    ``cum[B] == total``. Slots >= total are masked invalid.
    """
    offsets = jnp.arange(chunk, dtype=jnp.int32)
    # base-relative validity: `base + offset` wraps int32 in padding lanes
    # when total approaches 2**31, so compare offsets against the remaining
    # slot count instead of comparing (possibly wrapped) absolute slots.
    valid = offsets < (total - base)
    slots = base + offsets
    # slot -> block: scatter each block's chunk-relative start, cumsum.
    # block[k] = #(blocks with cum[b] <= base + k) - 1, clipped into range.
    start_pos = jnp.clip(cum[:-1] - base, 0, chunk)
    delta = jnp.zeros((chunk + 1,), jnp.int32).at[start_pos].add(1)
    block = jnp.cumsum(delta[:chunk]) - 1
    block = jnp.clip(block, 0, cum.shape[0] - 2)
    local = jnp.where(valid, slots, 0) - cum[block]
    n = size[block]
    i, j = _tri_decode(local, n, steps, use_kernel, interpret)
    s0 = start[block]
    a = members[s0 + i]
    b = members[s0 + j]
    return (jnp.minimum(a, b), jnp.maximum(a, b), n, valid)


@functools.partial(jax.jit, static_argnames=("steps", "use_kernel", "interpret"))
def decode_block_local(start: jnp.ndarray, size: jnp.ndarray,
                       members: jnp.ndarray, block: jnp.ndarray,
                       local: jnp.ndarray, valid: jnp.ndarray,
                       *, steps: int = MAX_SEARCH_STEPS,
                       use_kernel: bool = False, interpret: bool = True):
    """Decode pre-split (block, local) slots (sampling fallback path)."""
    block = jnp.clip(block, 0, size.shape[0] - 1)
    n = size[block]
    i, j = _tri_decode(local, n, steps, use_kernel, interpret)
    s0 = start[block]
    a = members[s0 + i]
    b = members[s0 + j]
    return (jnp.minimum(a, b), jnp.maximum(a, b), n, valid)


# ---------------------------------------------------------------------------
# Largest-block-wins dedupe: one sort + segment-start winner mask
# ---------------------------------------------------------------------------


@jax.jit
def pack_sort_words(a: jnp.ndarray, b: jnp.ndarray, src_size: jnp.ndarray,
                    valid: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(a, b, size) -> uint32 limb pair (hi, lo) of the 62-bit sort word.

    Word = (a << 39) | (b << 16) | (MAX_BLOCK_N - size): ascending word
    order is (a, b) ascending with size DESCENDING inside each (a, b) run,
    so after any u64 sort the first element of a run is the largest-block
    winner. Invalid lanes become the all-ones sentinel (> any valid word).
    Requires a, b < 2**PACK_RID_BITS and size <= MAX_BLOCK_N.
    """
    au = a.astype(jnp.uint32)
    bu = b.astype(jnp.uint32)
    inv = (_SIZE_MASK - jnp.clip(src_size, 0, _SIZE_MASK)).astype(jnp.uint32)
    hi = (au << 7) | (bu >> 16)
    lo = (bu << 16) | inv
    sentinel = jnp.uint32(0xFFFFFFFF)
    return (jnp.where(valid, hi, sentinel), jnp.where(valid, lo, sentinel))


def dedupe_words_host(w: np.ndarray) -> np.ndarray:
    """u64 sort words -> sorted winner words (largest-block-wins).

    One ``np.sort``, sentinel truncation, and a first-of-(a, b)-run mask;
    the host mirror of ``dedupe_packed_device``. Shared by the
    single-device CPU driver and the per-shard buckets of the routed
    distributed dedupe.
    """
    w = np.sort(w)
    w = w[: np.searchsorted(w, np.uint64(1) << np.uint64(62))]  # drop sentinels
    if len(w) == 0:
        return w
    run = w >> np.uint64(_PACK_SIZE_BITS)  # the (a, b) part
    return w[np.concatenate([[True], run[1:] != run[:-1]])]


def dedupe_packed_host(hi: np.ndarray, lo: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host sort of packed words -> compacted (a, b, src_size) winners.

    ``np.sort`` on the single u64 word replaces XLA CPU's comparator
    sort; used by the driver when running on the CPU backend (host memory
    IS device memory there, so this costs no extra transfer).
    """
    w = (hi.astype(np.uint64) << np.uint64(32)) | lo.astype(np.uint64)
    return unpack_words_host(dedupe_words_host(w))


def pair_route_owner(a: jnp.ndarray, b: jnp.ndarray, valid: jnp.ndarray,
                     n_shards: int) -> jnp.ndarray:
    """Owning shard of pair (a, b) for the fingerprint-routed dedupe.

    The fingerprint hashes ONLY the 46-bit run id ``(a << 23) | b`` — the
    sort word WITHOUT its size bits — so every occurrence of a pair lands
    on the same shard no matter which block produced it (that invariant
    is what makes shard-local dedupe globally correct). Bit-exact numpy
    mirror: ``ref.np_pair_route_owner``. Invalid lanes get ``n_shards``
    (the route_buckets drop sentinel). Requires a, b < 2**PACK_RID_BITS.
    """
    from ...core import hashing  # local import: core.pairs imports this module
    au = a.astype(jnp.uint32)
    bu = b.astype(jnp.uint32)
    run_hi = au >> 9                              # (a << 23 | b) >> 32
    run_lo = ((au & 0x1FF) << 23) | bu            # low 32 bits of the run id
    _, h_lo = hashing.hash_u64((run_hi, run_lo), seed=ROUTE_SEED)
    owner = (h_lo % jnp.uint32(n_shards)).astype(jnp.int32)
    return jnp.where(valid, owner, jnp.int32(n_shards))


def radix_passes_for(max_rid: int) -> int:
    """Static radix pass count covering the 62-bit word for rids <= max_rid.

    The word's topmost varying bit is ``39 + bitlength(max a)`` (the
    a-field starts at bit 39); digits above it are constant zero on valid
    words and all-ones on the sentinel, which still sorts last (see
    ``kernels.sort.ops``). Clamped to at least the 16 size bits.
    """
    bits = _PACK_SIZE_BITS + PACK_RID_BITS + max(1, int(max_rid).bit_length())
    n = -(-bits // sort_ops.RADIX_BITS)
    return max(sort_ops.MIN_PASSES, min(sort_ops.MAX_PASSES, n))


def dedupe_packed_device(hi: jnp.ndarray, lo: jnp.ndarray,
                         sort_backend: str = "comparator",
                         n_passes: int = sort_ops.MAX_PASSES,
                         use_kernel: bool = False, interpret: bool = True):
    """Shard-local dedupe of packed sort words: one sort + winner mask.

    The device mirror of ``dedupe_packed_host`` for use INSIDE shard_map
    (jit-free so it inherits the caller's tracing): sorts the uint32 limb
    pair via ``kernels.sort.sort_words`` (``sort_backend="comparator"``
    is the 2-key ``lax.sort``, ``"radix"`` the LSB radix engine —
    identical order to the u64 word either way) and marks the first
    element of each (a, b) run. Sentinel (all-ones) lanes sort to the
    tail and are never winners. Returns (hi_sorted, lo_sorted,
    winner_mask).
    """
    shi, slo = sort_ops.sort_words(hi, lo, backend=sort_backend,
                                   n_passes=n_passes, use_kernel=use_kernel,
                                   interpret=interpret)
    # run id = word >> 16 == (a << 23) | b: equal iff hi AND lo>>16 match
    srun = slo >> 16
    live = ~((shi == jnp.uint32(0xFFFFFFFF)) & (slo == jnp.uint32(0xFFFFFFFF)))
    first = jnp.concatenate(
        [jnp.ones((1,), bool),
         (shi[1:] != shi[:-1]) | (srun[1:] != srun[:-1])])
    return shi, slo, live & first


def unpack_words_host(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """u64 sort words -> (a, b, src_size) int64 triplets (host side)."""
    a = (w >> np.uint64(39)).astype(np.int64)
    b = ((w >> np.uint64(16)) & np.uint64((1 << PACK_RID_BITS) - 1)).astype(np.int64)
    s = (np.uint64(_SIZE_MASK) - (w & np.uint64(_SIZE_MASK))).astype(np.int64)
    return a, b, s


@functools.partial(
    jax.jit, static_argnames=("sort_backend", "n_passes", "use_kernel",
                              "interpret"))
def dedupe_device(a: jnp.ndarray, b: jnp.ndarray, src_size: jnp.ndarray,
                  valid: jnp.ndarray, *, sort_backend: str = "comparator",
                  n_passes: int = sort_ops.MAX_PASSES,
                  use_kernel: bool = False, interpret: bool = True):
    """Device sort (a, b, size desc); mark each pair's largest-block winner.

    ``sort_backend="comparator"`` is the general-rid path (no
    PACK_RID_BITS bound): a 3-key ``lax.sort``. ``"radix"`` re-expresses
    the same order over the packed 62-bit sort words and runs the
    ``kernels.sort`` radix engine (caller must guarantee rids <
    2**PACK_RID_BITS — the core/pairs.py driver checks ``_packable``).
    Returns (a_sorted, b_sorted, size_sorted, winner_mask); invalid lanes
    sort to the tail and are never winners. Host compacts by the mask.
    """
    if sort_backend == "radix":
        hi, lo = pack_sort_words(a, b, src_size, valid)
        shi, slo, winner = dedupe_packed_device(
            hi, lo, sort_backend="radix", n_passes=n_passes,
            use_kernel=use_kernel, interpret=interpret)
        # unpack the winner words back to int32 triplets on device
        ua = (shi >> 7).astype(jnp.int32)
        ub = (((shi & jnp.uint32(0x7F)) << 16) | (slo >> 16)).astype(jnp.int32)
        us = (jnp.uint32(_SIZE_MASK) - (slo & jnp.uint32(_SIZE_MASK))
              ).astype(jnp.int32)
        return ua, ub, us, winner
    av = jnp.where(valid, a, _INT32_MAX)
    bv = jnp.where(valid, b, _INT32_MAX)
    skey = _INT32_MAX - jnp.where(valid, src_size, 0)  # ascending = size desc
    sa, sb, ss = jax.lax.sort((av, bv, skey), num_keys=3)
    live = ~((sa == _INT32_MAX) & (sb == _INT32_MAX))
    first = jnp.concatenate(
        [jnp.ones((1,), bool),
         (sa[1:] != sa[:-1]) | (sb[1:] != sb[:-1])])
    return sa, sb, _INT32_MAX - ss, live & first
