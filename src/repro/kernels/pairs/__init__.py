from .ops import (decode_chunk, decode_block_local, dedupe_device,  # noqa: F401
                  dedupe_packed_host, pack_sort_words, search_steps_for,
                  tri_decode_jnp, MAX_BLOCK_N, MAX_SEARCH_STEPS,
                  PACK_RID_BITS)
from .pairs import tri_decode_pallas  # noqa: F401
