from .ops import (decode_chunk, decode_block_local, dedupe_device,  # noqa: F401
                  dedupe_packed_device, dedupe_packed_host, dedupe_words_host,
                  pack_sort_words,
                  pair_route_owner, radix_passes_for, search_steps_for,
                  tri_decode_jnp,
                  unpack_words_host, MAX_BLOCK_N, MAX_SEARCH_STEPS,
                  PACK_RID_BITS, ROUTE_SEED)
from .pairs import tri_decode_pallas  # noqa: F401
