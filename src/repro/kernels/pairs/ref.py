"""Pure-numpy oracle for the pair materialization engine.

Defines the *canonical pair-slot enumeration order* every backend must
reproduce: blocks in CSR order, and within a block of size ``n`` the
strictly-upper-triangular pairs in row-major order, i.e. local slot
``t`` of the block maps to ``(i, j)`` with

    cum(i) = i*(n-1) - i*(i-1)/2        (pairs in rows < i)
    i      = max { r : cum(r) <= t }
    j      = t - cum(i) + i + 1

(the inverse of the paper's §3.1 bitmap index ``b(i,j,n)``). The oracle
decodes with a float64 closed form + integer fix-up — deliberately a
different algorithm from the device backends' integer binary search, so
parity tests are meaningful.

All arrays here are host int64: the oracle also serves as the sampling
path's slot splitter, where global slot indices exceed int32.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def cum_pair_counts(size: np.ndarray) -> np.ndarray:
    """Exclusive prefix sum of per-block C(n, 2), length B+1, int64."""
    size = np.asarray(size, np.int64)
    per = size * (size - 1) // 2
    return np.concatenate([[0], np.cumsum(per)])


def tri_decode_ref(local: np.ndarray, n: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Local triangular slot index -> (i, j), i < j < n. Vectorized.

    Closed form: ``i`` is the largest integer with
    ``i*(n-1) - i*(i-1)/2 <= t``; solving the quadratic gives
    ``i = floor(((2n-1) - sqrt((2n-1)^2 - 8t)) / 2)``, then two integer
    correction passes absorb any float64 rounding.
    """
    t = np.asarray(local, np.int64)
    n = np.asarray(n, np.int64)
    m = 2 * n - 1
    disc = np.maximum(m * m - 8 * t, 0).astype(np.float64)
    i = ((m - np.sqrt(disc)) // 2).astype(np.int64)
    i = np.clip(i, 0, np.maximum(n - 2, 0))

    def cum(r):
        return r * (n - 1) - r * (r - 1) // 2

    for _ in range(2):  # fix-up: float sqrt can be off by at most 1 per pass
        i = np.where((i + 1 <= n - 2) & (cum(i + 1) <= t), i + 1, i)
        i = np.where((i > 0) & (cum(i) > t), i - 1, i)
    j = t - cum(i) + i + 1
    return i, j


def decode_slots_ref(start: np.ndarray, size: np.ndarray, members: np.ndarray,
                     slots: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Global pair-slot indices -> (a, b, block_size), a < b.

    ``slots`` are int64 indices into the canonical enumeration described
    in the module docstring; out-of-range slots are the caller's bug.
    """
    start = np.asarray(start, np.int64)
    size = np.asarray(size, np.int64)
    slots = np.asarray(slots, np.int64)
    cum = cum_pair_counts(size)
    block = np.searchsorted(cum, slots, side="right") - 1
    local = slots - cum[block]
    n = size[block]
    i, j = tri_decode_ref(local, n)
    a = members[start[block] + i]
    b = members[start[block] + j]
    return np.minimum(a, b), np.maximum(a, b), n


def np_pair_route_owner(a: np.ndarray, b: np.ndarray, n_shards: int
                        ) -> np.ndarray:
    """Owning shard of each pair under fingerprint routing (host mirror).

    Bit-exact with ``ops.pair_route_owner``: splitmix64 of the 46-bit run
    id ``(a << 23) | b`` (the sort word without its size bits), low 32
    bits mod ``n_shards``. Defined here so oracle tests can build the
    expected per-shard partition without touching device code.
    """
    from ...core import hashing  # numpy mirror only; no device deps

    run = (np.asarray(a, np.uint64) << np.uint64(23)) | np.asarray(b, np.uint64)
    h = hashing.np_hash_u64_vec(run, seed=0x9A12)  # == ops.ROUTE_SEED
    return ((h & np.uint64(0xFFFFFFFF)).astype(np.uint32)
            % np.uint32(n_shards)).astype(np.int32)


def dedupe_routed_ref(a: np.ndarray, b: np.ndarray, src_size: np.ndarray,
                      n_shards: int
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Oracle for the routed layout: per-shard dedupe, then merge.

    Routes every raw pair to its fingerprint owner, runs the plain
    ``dedupe_ref`` independently per shard, and merges the shard outputs
    back into canonical (a, b) order. Because routing is a pure function
    of (a, b), the shards partition the distinct-pair set and the merge
    MUST equal a global ``dedupe_ref`` — that identity is what the parity
    tests assert.
    """
    owner = np_pair_route_owner(a, b, n_shards)
    outs = [dedupe_ref(a[owner == s], b[owner == s], src_size[owner == s])
            for s in range(n_shards)]
    ca = np.concatenate([o[0] for o in outs])
    cb = np.concatenate([o[1] for o in outs])
    cs = np.concatenate([o[2] for o in outs])
    order = np.lexsort((cb, ca))
    return ca[order], cb[order], cs[order]


def dedupe_ref(a: np.ndarray, b: np.ndarray, src_size: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Distinct (a, b) sorted ascending, keeping the LARGEST source block.

    This is the host mirror of the device sort + segment-start pass: sort
    by (a, b, -size); the first element of each (a, b) run wins.
    """
    if len(a) == 0:
        z = np.zeros((0,), np.int64)
        return z, z, z
    a = np.asarray(a, np.int64)
    b = np.asarray(b, np.int64)
    s = np.asarray(src_size, np.int64)
    order = np.lexsort((-s, b, a))
    a, b, s = a[order], b[order], s[order]
    first = np.concatenate([[True], (a[1:] != a[:-1]) | (b[1:] != b[:-1])])
    return a[first], b[first], s[first]
