"""Pallas TPU kernel for bulk 64-bit key mixing / combining.

The inner loop of IntersectKeys (paper Alg. 2 line 7) combines every pair
of a record's over-sized keys into a new 128-bit hash — here a ~45-op
splitmix64 chain on uint32 limb pairs. Fusing the chain into one VMEM-
resident kernel avoids ~12 HBM round trips for the intermediates that an
op-by-op jnp lowering can incur, turning a memory-bound chain into a
VPU-bound one.

Inputs are 2-D tiles (rows x lanes); ops.py reshapes flat key arrays into
lane-aligned tiles (last dim a multiple of 128).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core import u64, hashing


def _combine_kernel(ahi_ref, alo_ref, bhi_ref, blo_ref, ohi_ref, olo_ref):
    a = (ahi_ref[...], alo_ref[...])
    b = (bhi_ref[...], blo_ref[...])
    lo_key = u64.minimum(a, b)             # canonical (unordered) combine
    hi_key = u64.where(u64.eq(lo_key, a), b, a)
    hi, lo = hashing.combine(lo_key, hi_key)
    ohi_ref[...] = hi
    olo_ref[...] = lo


def _mix_kernel(ahi_ref, alo_ref, ohi_ref, olo_ref):
    hi, lo = hashing.mix64((ahi_ref[...], alo_ref[...]))
    ohi_ref[...] = hi
    olo_ref[...] = lo


def _launch(kernel, arrays, block_rows: int, block_lanes: int,
            num_out: int, interpret: bool):
    r, l = arrays[0].shape
    assert r % block_rows == 0 and l % block_lanes == 0
    grid = (r // block_rows, l // block_lanes)
    spec = pl.BlockSpec((block_rows, block_lanes), lambda i, j: (i, j))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * len(arrays),
        out_specs=[spec] * num_out,
        out_shape=[jax.ShapeDtypeStruct((r, l), jnp.uint32)] * num_out,
        interpret=interpret,
    )(*arrays)


def combine64_pallas(ahi, alo, bhi, blo, *, block_rows=8, block_lanes=512,
                     interpret=False):
    """Order-canonical combine of two u64 key arrays (2-D, tile-aligned)."""
    return _launch(_combine_kernel, [ahi, alo, bhi, blo], block_rows,
                   block_lanes, 2, interpret)


def mix64_pallas(ahi, alo, *, block_rows=8, block_lanes=512, interpret=False):
    """Bulk splitmix64 finalizer over a u64 array (2-D, tile-aligned)."""
    return _launch(_mix_kernel, [ahi, alo], block_rows, block_lanes, 2,
                   interpret)
