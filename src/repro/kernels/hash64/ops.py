"""Public wrappers: flatten/pad to lane-aligned tiles, dispatch kernel/ref."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .hash64 import combine64_pallas, mix64_pallas
from .ref import combine64_ref, mix64_ref

_LANES = 512


_ROWS = 8


def _tile(x: jnp.ndarray):
    """Flatten to (rows, _LANES), rows padded to the row-block multiple."""
    n = x.size
    flat = x.reshape(-1)
    pad = (-n) % (_LANES * _ROWS)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, _LANES), n


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def combine64(ahi, alo, bhi, blo, use_kernel: bool = True,
              interpret: bool = True):
    """Canonical pairwise key combine; shape-preserving over any rank."""
    if not use_kernel:
        return combine64_ref(ahi, alo, bhi, blo)
    shape = ahi.shape
    ta, n = _tile(ahi)
    tb, _ = _tile(alo)
    tc, _ = _tile(bhi)
    td, _ = _tile(blo)
    hi, lo = combine64_pallas(ta, tb, tc, td, block_rows=_ROWS,
                              interpret=interpret)
    return hi.reshape(-1)[:n].reshape(shape), lo.reshape(-1)[:n].reshape(shape)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def mix64_bulk(ahi, alo, use_kernel: bool = True, interpret: bool = True):
    if not use_kernel:
        return mix64_ref(ahi, alo)
    shape = ahi.shape
    ta, n = _tile(ahi)
    tb, _ = _tile(alo)
    hi, lo = mix64_pallas(ta, tb, block_rows=_ROWS,
                          interpret=interpret)
    return hi.reshape(-1)[:n].reshape(shape), lo.reshape(-1)[:n].reshape(shape)
