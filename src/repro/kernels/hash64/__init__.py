from .ops import combine64, mix64_bulk  # noqa: F401
