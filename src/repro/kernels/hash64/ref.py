"""Pure-jnp oracles for the hash64 kernels."""
from ...core import u64, hashing


def combine64_ref(ahi, alo, bhi, blo):
    a, b = (ahi, alo), (bhi, blo)
    lo_key = u64.minimum(a, b)
    hi_key = u64.where(u64.eq(lo_key, a), b, a)
    return hashing.combine(lo_key, hi_key)


def mix64_ref(ahi, alo):
    return hashing.mix64((ahi, alo))
