"""Pallas TPU MinHash kernel.

MinHash over every record's token set is the FLOP hot spot of LSH block
building (paper §2.1): R records x T tokens x M hash functions of ~40
integer ops each. A naive jnp implementation materializes an (R, T)
intermediate per hash function in HBM — M round trips. This kernel tiles
(rows x tokens) into VMEM and keeps the (BR, M) running minimum in the
output block across the token-tile grid axis, so each token is read from
HBM exactly once and all M hashes happen in-register.

Grid: (R/BR, T/BT); token axis is the minor (sequential) axis, so the
output block revision pattern is the standard Pallas accumulation idiom.
"""
from __future__ import annotations

import functools

import jax
import numpy as np
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core import u64
from ...core.minhash import _MH_SEED

_GAMMA = 0x9E3779B97F4A7C15
_M1 = 0xBF58476D1CE4E5B9
_M2 = 0x94D049BB133111EB
_MASK64 = (1 << 64) - 1


def _mix64_lo(hi: jnp.ndarray, lo: jnp.ndarray) -> jnp.ndarray:
    """splitmix64 finalizer, returning the low 32 bits (VPU-only int ops)."""
    x = (hi, lo)
    x = u64.xor(x, u64.shr(x, 30))
    x = u64.mul_const(x, _M1)
    x = u64.xor(x, u64.shr(x, 27))
    x = u64.mul_const(x, _M2)
    x = u64.xor(x, u64.shr(x, 31))
    return x[1]


def _minhash_kernel(tokens_ref, mask_ref, addhi_ref, addlo_ref, out_ref, *,
                    num_hashes: int):
    tok = tokens_ref[...]            # (BR, BT) uint32
    msk = mask_ref[...]              # (BR, BT) bool

    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, 0xFFFFFFFF)

    acc = out_ref[...]               # (BR, M) running minima
    for i in range(num_hashes):      # static unroll: all hashes in-register
        a_hi = addhi_ref[0, i]
        a_lo = addlo_ref[0, i]
        lo = tok + a_lo
        carry = (lo < tok).astype(jnp.uint32)
        hi = jnp.broadcast_to(a_hi, tok.shape) + carry
        h = _mix64_lo(hi, lo)        # (BR, BT)
        h = jnp.where(msk, h, np.uint32(0xFFFFFFFF))
        acc = acc.at[:, i].min(jnp.min(h, axis=1))
    out_ref[...] = acc


def minhash_pallas(tokens: jnp.ndarray, mask: jnp.ndarray, num_hashes: int,
                   seed: int = _MH_SEED, *, block_rows: int = 256,
                   block_tokens: int = 128, interpret: bool = False
                   ) -> jnp.ndarray:
    """(R, T) uint32 tokens + mask -> (R, M) uint32 MinHashes.

    R must divide block_rows, T must divide block_tokens (ops.py pads).
    """
    r, t = tokens.shape
    assert r % block_rows == 0 and t % block_tokens == 0, (r, t)
    consts = [((seed + 977 * i + 1) * _GAMMA) & _MASK64 for i in range(num_hashes)]
    add_hi = jnp.asarray([[c >> 32 for c in consts]], jnp.uint32)
    add_lo = jnp.asarray([[c & 0xFFFFFFFF for c in consts]], jnp.uint32)
    grid = (r // block_rows, t // block_tokens)
    return pl.pallas_call(
        functools.partial(_minhash_kernel, num_hashes=num_hashes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, block_tokens), lambda i, j: (i, j)),
            pl.BlockSpec((block_rows, block_tokens), lambda i, j: (i, j)),
            pl.BlockSpec((1, num_hashes), lambda i, j: (0, 0)),
            pl.BlockSpec((1, num_hashes), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, num_hashes), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, num_hashes), jnp.uint32),
        interpret=interpret,
    )(tokens.astype(jnp.uint32), mask, add_hi, add_lo)
