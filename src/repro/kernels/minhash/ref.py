"""Pure-jnp oracle for the MinHash kernel: the core library's reference
implementation IS the oracle (it is itself property-tested against the
analytic Jaccard/LSH behavior in tests/test_minhash.py)."""
from ...core.minhash import minhash_tokens as minhash_ref  # noqa: F401
