"""Public jit'd wrapper: pads to tile multiples, dispatches kernel/ref."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .minhash import minhash_pallas
from .ref import minhash_ref


@functools.partial(jax.jit, static_argnames=("num_hashes", "use_kernel",
                                             "interpret", "block_rows",
                                             "block_tokens"))
def minhash(tokens: jnp.ndarray, mask: jnp.ndarray, num_hashes: int,
            use_kernel: bool = True, interpret: bool = True,
            block_rows: int = 256, block_tokens: int = 128) -> jnp.ndarray:
    """MinHash matrix (R, num_hashes) for padded token sets.

    ``interpret=True`` is the CPU-container default; on real TPU pass
    ``interpret=False``.
    """
    if not use_kernel:
        return minhash_ref(tokens, mask, num_hashes)
    r, t = tokens.shape
    br = min(block_rows, max(8, r))
    bt = min(block_tokens, max(128, t))
    pad_r = (-r) % br
    pad_t = (-t) % bt
    if pad_r or pad_t:
        tokens = jnp.pad(tokens, ((0, pad_r), (0, pad_t)))
        mask = jnp.pad(mask, ((0, pad_r), (0, pad_t)))
    out = minhash_pallas(tokens, mask, num_hashes, block_rows=br,
                         block_tokens=bt, interpret=interpret)
    return out[:r]
