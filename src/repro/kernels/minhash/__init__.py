from .ops import minhash  # noqa: F401
