"""Pallas TPU kernel for Count-Min Sketch updates.

The CMS build (paper Alg. 3 line 2) is a depth-way scatter-add over the
sketch rows. TPUs serialize true scatters, so the kernel instead emulates
the scatter with a compare-against-iota histogram: for each width tile
``[w0, w0+BW)`` the per-key one-hot condition ``bucket_index == iota``
reduces over the key tile into the (depth, BW) histogram slab held in
VMEM. This trades scatter serialization for dense VPU compares — the
classic TPU histogram adaptation (DESIGN.md §3; an MXU one-hot-matmul
variant is possible when counts fit bf16's 8-bit mantissa per tile).

Grid: (width_tiles, key_tiles); key axis minor => output accumulation is
the standard revision idiom.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cms_kernel(idx_ref, mask_ref, out_ref, *, depth: int, block_width: int):
    # idx_ref: (depth, BK) int32 bucket indices; mask_ref: (1, BK) bool
    # out_ref: (depth, BW) int32 histogram slab for width tile program_id(0)
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w0 = pl.program_id(0) * block_width
    iota = jax.lax.broadcasted_iota(jnp.int32, (block_width, 1), 0) + w0
    msk = mask_ref[...]  # (1, BK)
    acc = out_ref[...]
    for d in range(depth):  # static, small
        idx = idx_ref[d, :][None, :]               # (1, BK)
        onehot = (iota == idx) & msk               # (BW, BK)
        acc = acc.at[d, :].add(jnp.sum(onehot.astype(jnp.int32), axis=1))
    out_ref[...] = acc


def cms_update_pallas(indices: jnp.ndarray, mask: jnp.ndarray, width: int, *,
                      block_keys: int = 1024, block_width: int = 2048,
                      interpret: bool = False) -> jnp.ndarray:
    """(depth, N) bucket indices -> (depth, width) int32 sketch."""
    depth, n = indices.shape
    assert n % block_keys == 0 and width % block_width == 0
    grid = (width // block_width, n // block_keys)
    return pl.pallas_call(
        functools.partial(_cms_kernel, depth=depth, block_width=block_width),
        grid=grid,
        in_specs=[
            pl.BlockSpec((depth, block_keys), lambda w, k: (0, k)),
            pl.BlockSpec((1, block_keys), lambda w, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((depth, block_width), lambda w, k: (0, w)),
        out_shape=jax.ShapeDtypeStruct((depth, width), jnp.int32),
        interpret=interpret,
    )(indices, mask)
