"""Pure-jnp oracle: scatter-add CMS build (the core library path)."""
import jax.numpy as jnp


def cms_update_ref(indices: jnp.ndarray, mask: jnp.ndarray, width: int):
    depth, _ = indices.shape
    upd = mask.reshape(-1).astype(jnp.int32)
    out = jnp.zeros((depth, width), jnp.int32)
    for d in range(depth):
        out = out.at[d].add(jnp.zeros((width,), jnp.int32).at[indices[d]].add(upd))
    return out
