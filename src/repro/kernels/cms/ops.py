"""Public wrapper: pads the key axis, dispatches kernel/ref."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .cms import cms_update_pallas
from .ref import cms_update_ref


@functools.partial(jax.jit, static_argnames=("width", "use_kernel",
                                             "interpret", "block_keys",
                                             "block_width"))
def cms_update(indices: jnp.ndarray, mask: jnp.ndarray, width: int,
               use_kernel: bool = True, interpret: bool = True,
               block_keys: int = 1024, block_width: int = 2048) -> jnp.ndarray:
    """Build a (depth, width) CMS from (depth, N) bucket indices + (N,) mask."""
    if not use_kernel:
        return cms_update_ref(indices, mask, width)
    depth, n = indices.shape
    bk = min(block_keys, max(128, n))
    bw = min(block_width, width)
    pad = (-n) % bk
    if pad:
        indices = jnp.pad(indices, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, (0, pad))
    return cms_update_pallas(indices, mask.reshape(1, -1), width,
                             block_keys=bk, block_width=bw,
                             interpret=interpret)
