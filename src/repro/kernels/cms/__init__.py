from .ops import cms_update  # noqa: F401
