"""Logical-axis sharding rules (MaxText-style) for DP/FSDP/TP/EP/SP.

Model code annotates activations with *logical* axis names via
``lshard(x, "batch", "seq", None)``; parameters get logical axes from the
path-pattern table in ``param_spec``. A ``ShardingRules`` context maps
logical names to mesh axes; with no active context every annotation is a
no-op, so the same model code runs in single-device smoke tests and on the
512-chip production mesh unchanged.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import re
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (str, tuple of str, or None)."""

    mesh: Mesh
    rules: Tuple[Tuple[str, object], ...]

    def axis(self, logical: Optional[str]):
        if logical is None:
            return None
        for name, mesh_axis in self.rules:
            if name == logical:
                return mesh_axis
        return None

    def spec(self, *logical: Optional[str]) -> P:
        return P(*[self.axis(l) for l in logical])


def production_rules(mesh: Mesh, *, fsdp: bool = True,
                     seq_shard: bool = False) -> ShardingRules:
    """Default rules for the assignment's meshes.

    batch -> all data-like axes (DP); heads/ffn/experts/vocab -> "model"
    (TP/EP); optional FSDP shards the params' embed axis over "data";
    seq_shard puts the sequence/KV-cache axis on "data" (SP) for the
    batch=1 long-context shapes.
    """
    data_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    data_axes = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
    rules = [
        ("batch", data_axes),
        ("seq", data_axes if seq_shard else None),
        ("kv_seq", data_axes if seq_shard else None),
        ("heads", "model"),
        ("kv_heads", "model"),
        ("ffn", "model"),
        ("experts", "model"),
        ("vocab", "model"),
        ("embed", None),
        ("fsdp", "data" if fsdp and "data" in mesh.axis_names else None),
        ("state", "model"),
        ("moe_ff", None),  # expert-internal ff dim (serving TP; see dryrun)
    ]
    return ShardingRules(mesh=mesh, rules=tuple(rules))


_ACTIVE: contextvars.ContextVar[Optional[ShardingRules]] = \
    contextvars.ContextVar("sharding_rules", default=None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    token = _ACTIVE.set(rules)
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def active_rules() -> Optional[ShardingRules]:
    return _ACTIVE.get()


def axis_size(mesh: Mesh, ax) -> int:
    """Total device count over a mesh axis, axis tuple, or None (=1).

    Shared by the logical-sharding guard below and the blocking-side
    routed exchanges (``core.distributed``), which need the flat shard
    count of their data-axes tuple.
    """
    if ax is None:
        return 1
    axes = ax if isinstance(ax, tuple) else (ax,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def guard_spec(mesh: Mesh, shape, spec: P) -> P:
    """Replicate any dim whose size doesn't divide its assigned axes.

    GQA archs with kv_heads < model-axis size, odd vocab, etc. fall back to
    replication for that dim instead of failing to lower.
    """
    fixed = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        fixed.append(ax if (ax is not None and dim % axis_size(mesh, ax) == 0)
                     else None)
    return P(*fixed)


def lshard(x, *logical: Optional[str]):
    """Constrain an activation to its logical sharding (no-op without rules)."""
    rules = _ACTIVE.get()
    if rules is None:
        return x
    spec = guard_spec(rules.mesh, x.shape, rules.spec(*logical))
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding: leaf-name pattern -> logical axes
# ---------------------------------------------------------------------------

# Patterns are matched against the '/'-joined param path. First match wins.
# Axis entries name the LOGICAL axis of each tensor dim (None = replicated).
_PARAM_PATTERNS: Sequence[Tuple[str, Tuple[Optional[str], ...]]] = (
    # embeddings / output head: vocab-parallel + FSDP on embed
    (r"embed/table$", ("vocab", "fsdp")),
    (r"lm_head/w$", ("fsdp", "vocab")),
    # attention
    (r"attn/wq$", ("fsdp", "heads", None)),
    (r"attn/wk$", ("fsdp", "kv_heads", None)),
    (r"attn/wv$", ("fsdp", "kv_heads", None)),
    (r"attn/wo$", ("heads", None, "fsdp")),
    # MLA
    (r"attn/w_dq$", ("fsdp", None)),
    (r"attn/w_uq$", (None, "heads", None)),
    (r"attn/w_dkv$", ("fsdp", None)),
    (r"attn/w_ukv$", (None, "heads", None)),
    (r"attn/w_kr$", ("fsdp", None)),
    # dense mlp
    (r"mlp/w_gate$", ("fsdp", "ffn")),
    (r"mlp/w_up$", ("fsdp", "ffn")),
    (r"mlp/w_down$", ("ffn", "fsdp")),
    # moe
    (r"moe/router$", ("fsdp", None)),
    (r"moe/w_gate$", ("experts", "fsdp", "moe_ff")),
    (r"moe/w_up$", ("experts", "fsdp", "moe_ff")),
    (r"moe/w_down$", ("experts", "moe_ff", "fsdp")),
    (r"moe/shared_.*$", ("fsdp", "ffn")),
    (r"moe/shared_down$", ("ffn", "fsdp")),
    # mamba
    (r"mamba/w_in$", ("fsdp", "ffn")),
    (r"mamba/w_z$", ("fsdp", "ffn")),
    (r"mamba/w_out$", ("ffn", "fsdp")),
    (r"mamba/(w_b|w_c|w_dt)$", ("ffn", None)),
    (r"mamba/(a_log|dt_bias)$", ("ffn",) + (None,)),
    (r"mamba/conv$", (None, "ffn")),
    # rwkv
    (r"rwkv/(w_r|w_k|w_v|w_g|w_w)$", ("fsdp", "ffn")),
    (r"rwkv/w_o$", ("ffn", "fsdp")),
    (r"rwkv/.*lora.*$", (None, None)),
    # norms / scalars: replicated
    (r".*(norm|ln|bias|scale).*$", None),
)


def logical_axes_for(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    for pattern, axes in _PARAM_PATTERNS:
        if re.search(pattern, path):
            if axes is None:
                return (None,) * ndim
            if len(axes) == ndim:
                return axes
            # stacked-over-layers leading dim (scan): prepend None
            if len(axes) == ndim - 1:
                return (None,) + tuple(axes)
    return (None,) * ndim


def param_sharding(params, rules: ShardingRules):
    """Pytree of NamedShardings matching `params` via the pattern table."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        path_str = "/".join(str(getattr(p, "key", p)) for p in path)
        axes = logical_axes_for(path_str, leaf.ndim)
        spec = guard_spec(rules.mesh, leaf.shape, rules.spec(*axes))
        out.append(NamedSharding(rules.mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)
