"""Distributed blocking launcher: run HDB itself on the production mesh.

The paper's own workload as a first-class job: records shard over all mesh
axes; sketches all-reduce; exact counts route via all_to_all
(core/distributed.py). Dry-runs with 512 emulated devices:

    PYTHONPATH=src python -m repro.launch.block --dryrun --mesh multi

or executes for real on however many devices exist (tests use 8).
"""
import os

if "--dryrun" in os.sys.argv:  # device count must be set before jax init
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                               + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import logging       # noqa: E402
import time          # noqa: E402

# CLI driver owns logging config; verbose [hdb]/[hdb-dist] stats are INFO
logging.basicConfig(level=logging.INFO, format="%(message)s")

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402

from ..core import blocks, distributed, hdb  # noqa: E402
from ..core.hdb import HDBConfig  # noqa: E402
from ..data import synthetic  # noqa: E402
from ..training import checkpoint  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .hlo_analysis import analyze  # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true",
                    help="lower+compile one iteration on the production mesh")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--entities", type=int, default=2000)
    ap.add_argument("--records", type=int, default=0,
                    help="dryrun: records per shard (default 4096)")
    ap.add_argument("--max-block-size", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--rep-capacity", type=int, default=0,
                    help="per-shard over-sized block rep capacity "
                         "(0 = DistConfig default; sizes the survivor-table "
                         "all-gather — see EXPERIMENTS.md §Perf-pipeline)")
    ap.add_argument("--route-slack", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = HDBConfig(max_block_size=args.max_block_size)
    dist_kw = {}
    if args.rep_capacity:
        dist_kw["rep_capacity_per_shard"] = args.rep_capacity
    if args.route_slack:
        dist_kw["route_slack"] = args.route_slack

    if args.dryrun:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        axes = tuple(mesh.axis_names)
        n_shards = mesh.devices.size
        per_shard = args.records or 4096
        n = per_shard * n_shards
        k = 24
        step = distributed.make_hdb_step(cfg, mesh, axes,
                                         distributed.DistConfig(**dist_kw))
        keys = jax.ShapeDtypeStruct((n, k, 2), jnp.uint32)
        valid = jax.ShapeDtypeStruct((n, k), jnp.bool_)
        psize = jax.ShapeDtypeStruct((n, k), jnp.int32)
        t0 = time.time()
        lowered = step.lower(keys, valid, psize)
        compiled = lowered.compile()
        roof, cost = analyze(compiled.as_text(), n_shards)
        print(f"[block-dryrun] mesh={args.mesh} chips={n_shards} "
              f"records={n:,} keys/rec={k}")
        print(f"[block-dryrun] compile ok in {time.time()-t0:.1f}s")
        print(f"[block-dryrun] mem: {compiled.memory_analysis()}")
        print(f"[block-dryrun] roofline: compute={roof.compute_seconds:.3g}s "
              f"memory={roof.memory_seconds:.3g}s "
              f"collective={roof.collective_seconds:.3g}s "
              f"dominant={roof.dominant}")
        print(f"[block-dryrun] collective bytes/dev: {cost.coll_by_kind}")
        return

    corpus = synthetic.generate(synthetic.SyntheticSpec(
        num_entities=args.entities, seed=3))
    keys, valid = blocks.build_keys(corpus.columns, corpus.blocking)
    n_dev = jax.device_count()
    if n_dev > 1:
        mesh = jax.make_mesh((n_dev,), ("data",))
        pad = (-valid.shape[0]) % n_dev
        if pad:
            keys = jnp.concatenate([keys, jnp.full((pad,) + keys.shape[1:],
                                                   0xFFFFFFFF, jnp.uint32)])
            valid = jnp.concatenate([valid,
                                     jnp.zeros((pad, valid.shape[1]), bool)])
        cb = None
        if args.ckpt_dir:
            cb = lambda it, st: checkpoint.save(args.ckpt_dir, it, st)
        res = distributed.distributed_hashed_dynamic_blocking(
            keys, valid, cfg, mesh, ("data",), checkpoint_cb=cb, verbose=True)
    else:
        res = hdb.hashed_dynamic_blocking(keys, valid, cfg, verbose=True)
    print(f"[block] accepted assignments: {len(res.rids):,} over "
          f"{res.num_records:,} records")


if __name__ == "__main__":
    main()
