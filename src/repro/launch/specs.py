"""Input construction per (arch x shape): concrete arrays for smoke tests,
ShapeDtypeStructs for the dry-run (weak-type-correct, no allocation)."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..models.model import Model


def _mk(concrete: bool, shape, dtype, rng: Optional[np.random.Generator],
        low=0, high=None):
    if not concrete:
        return jax.ShapeDtypeStruct(shape, dtype)
    rng = rng or np.random.default_rng(0)
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.asarray(rng.integers(low, high or 100, shape), dtype)
    return jnp.asarray(rng.standard_normal(shape), dtype)


def train_batch(cfg: ModelConfig, seq_len: int, batch: int,
                concrete: bool = False, rng=None) -> Dict[str, Any]:
    v = cfg.vocab_size
    if cfg.family == "encdec":
        dec_len = max(8, seq_len // cfg.encoder_seq_ratio)
        return {
            "frames": _mk(concrete, (batch, seq_len, cfg.d_model), cfg.cdtype, rng),
            "tokens": _mk(concrete, (batch, dec_len), jnp.int32, rng, high=v),
            "targets": _mk(concrete, (batch, dec_len), jnp.int32, rng, high=v),
        }
    if cfg.family == "vlm":
        text = max(8, seq_len - cfg.num_patches)
        return {
            "patches": _mk(concrete, (batch, cfg.num_patches, cfg.d_model),
                           cfg.cdtype, rng),
            "tokens": _mk(concrete, (batch, text), jnp.int32, rng, high=v),
            "targets": _mk(concrete, (batch, text), jnp.int32, rng, high=v),
        }
    return {
        "tokens": _mk(concrete, (batch, seq_len), jnp.int32, rng, high=v),
        "targets": _mk(concrete, (batch, seq_len), jnp.int32, rng, high=v),
    }


def decode_inputs(model: Model, seq_len: int, batch: int,
                  concrete: bool = False, rng=None):
    """(token, caches, extras) for one serve_step with a full cache."""
    cfg = model.cfg
    token = _mk(concrete, (batch, 1), jnp.int32, rng, high=cfg.vocab_size)
    if concrete:
        caches = model.init_caches(batch, seq_len)
        caches = jax.tree.map(lambda a: a, caches)
        caches = _set_pos(caches, seq_len - 1)
    else:
        caches = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
            jax.eval_shape(lambda: model.init_caches(batch, seq_len)))
    extras = {}
    if cfg.family == "encdec":
        extras["enc_out"] = _mk(concrete, (batch, seq_len, cfg.d_model),
                                cfg.cdtype, rng)
    return token, caches, extras


def _set_pos(caches, pos: int):
    def fix(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name == "pos":
            return jnp.full(leaf.shape, pos, leaf.dtype)
        return leaf
    return jax.tree_util.tree_map_with_path(fix, caches)
