"""Production mesh definitions (assignment-mandated shapes).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips single-pod; 2x16x16 = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def num_chips(mesh) -> int:
    return mesh.devices.size
