"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract memory / cost / collective roofline terms.

MUST be imported/run as a fresh process: the first two lines force 512
placeholder host devices before jax locks the device count. Never set this
in conftest/pyproject — smoke tests and benches see 1 device.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Optional  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import ARCH_IDS, get_config  # noqa: E402
from ..configs.shapes import SHAPES, applicable  # noqa: E402
from ..distributed.sharding import (ShardingRules, param_sharding,  # noqa: E402
                                    production_rules, use_rules)
from ..models.model import build_model  # noqa: E402
from ..training.optimizer import OptimizerConfig  # noqa: E402
from ..training.train_loop import TrainConfig, init_train_state, make_train_step  # noqa: E402
from . import specs  # noqa: E402
from .hlo_analysis import Roofline, analyze  # noqa: E402
from .mesh import data_axes, make_production_mesh, num_chips  # noqa: E402


def _batch_axes_or_none(rules, size_needed: int, mesh):
    ax = rules.axis("batch")
    axes = ax if isinstance(ax, tuple) else (ax,)
    dp = int(np.prod([mesh.shape[a] for a in axes if a]))
    return ax if size_needed % dp == 0 and size_needed >= dp else None


def batch_sharding(batch_tree, rules, mesh):
    def one(path, leaf):
        b = leaf.shape[0] if leaf.ndim else 1
        ax = _batch_axes_or_none(rules, b, mesh)
        spec = [ax] + [None] * (leaf.ndim - 1)
        return NamedSharding(rules.mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, batch_tree)


_CACHE_BASE_NDIM = {"k": 4, "v": 4, "c_kv": 3, "k_rope": 3, "pos": 0,
                    "h": 3, "conv": 3, "state": 4, "x_prev": 2}


def cache_sharding(cache_tree, rules, mesh, cfg, batch_size: int):
    """Leaf-name-based sharding for KV/SSM caches (stacked dims handled)."""
    model_n = mesh.shape["model"]
    kv_on_model = cfg.num_kv_heads % model_n == 0
    b_ax = _batch_axes_or_none(rules, batch_size, mesh)
    seq_ax = rules.axis("kv_seq")

    def one(path, leaf):
        name = None
        for p in reversed(path):
            k = getattr(p, "key", None)
            if isinstance(k, str):
                name = k
                break
        base = _CACHE_BASE_NDIM.get(name, leaf.ndim)
        stacked = leaf.ndim == base + 1
        if name in ("k", "v"):
            kv_ax = "model" if kv_on_model else None
            s_ax = seq_ax if kv_on_model else (seq_ax or "model")
            spec = [b_ax, s_ax, kv_ax, None]
        elif name in ("c_kv", "k_rope"):
            spec = [b_ax, seq_ax, None]
        elif name == "h":      # mamba (B, d_inner, N)
            spec = [b_ax, "model", None]
        elif name == "conv":   # (B, K, d_inner)
            spec = [b_ax, None, "model"]
        elif name == "state":  # rwkv (B, H, dk, dv)
            spec = [b_ax, "model", None, None]
        elif name == "x_prev":
            spec = [b_ax, None]
        elif name == "pos":
            spec = []
        else:
            spec = [None] * leaf.ndim
        if stacked:
            spec = [None] + spec
        # divisibility guard: replicate any axis that doesn't divide
        fixed = []
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                fixed.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(np.prod([mesh.shape[a] for a in axes]))
            fixed.append(ax if dim % n == 0 else None)
        return NamedSharding(rules.mesh, P(*fixed))

    return jax.tree_util.tree_map_with_path(one, cache_tree)


@dataclasses.dataclass
class CellOptions:
    """Perf knobs swept during §Perf hillclimbing."""
    remat: str = "full"
    grad_accum: int = 0           # 0 = auto (per-device microbatch ~2)
    fsdp: bool = True
    param_dtype: Optional[str] = None
    moment_dtype: str = "float32"
    attn_chunk: int = 1024
    scan_layers: bool = True
    rwkv_impl: str = "scan"       # "chunked" = GLA-style parallel form
    rwkv_chunk: int = 64
    serving_tp_all: bool = False  # decode: shard ffn/expert dims over ALL axes
    moe_impl: str = "psum"        # "a2a" = all_to_all EP dispatch


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opts: CellOptions = CellOptions(), *, mesh=None,
             cfg=None, shape=None) -> dict:
    """Lower+compile one cell. mesh/cfg/shape overrides exist so the test
    suite can exercise this exact path on small emulated meshes."""
    t0 = time.time()
    shape = shape or SHAPES[shape_name]
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    chips = num_chips(mesh)
    cfg = cfg or get_config(arch)
    cfg = dataclasses.replace(
        cfg, remat=opts.remat, attn_chunk_size=opts.attn_chunk,
        scan_layers=opts.scan_layers, rwkv_impl=opts.rwkv_impl,
        rwkv_chunk=opts.rwkv_chunk, moe_impl=opts.moe_impl,
        **({"param_dtype": opts.param_dtype} if opts.param_dtype else {}))
    rules = production_rules(mesh, fsdp=opts.fsdp,
                             seq_shard=(shape.global_batch == 1))
    if opts.serving_tp_all and shape.kind != "train":
        # weight-stationary serving: inner (ffn/state) dims sharded over
        # EVERY axis, expert-internal ff over the data axes — params
        # resident, activations psum'd (§Perf)
        all_axes = tuple(mesh.axis_names)
        d_axes = data_axes(mesh)
        remap = {"ffn": all_axes, "state": all_axes, "moe_ff": d_axes}
        rules = dataclasses.replace(rules, rules=tuple(
            (name, remap.get(name, ax)) for name, ax in rules.rules))
    model = build_model(cfg)

    dp = int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
    if shape.kind == "train":
        accum = opts.grad_accum or max(1, shape.global_batch // (dp * 2))
        while shape.global_batch % (accum * dp) or (shape.global_batch // accum) % dp:
            accum -= 1
        tcfg = TrainConfig(opt=OptimizerConfig(moment_dtype=opts.moment_dtype),
                           grad_accum=accum)
        state_shape = jax.eval_shape(
            lambda k: init_train_state(model, k, tcfg), jax.random.PRNGKey(0))
        p_shard = param_sharding(state_shape["params"], rules)
        state_shard = {
            "params": p_shard,
            "opt": {"mu": p_shard, "nu": p_shard,
                    "step": NamedSharding(mesh, P())},
            "step": NamedSharding(mesh, P()),
        }
        batch_shape = specs.train_batch(cfg, shape.seq_len, shape.global_batch)
        b_shard = batch_sharding(batch_shape, rules, mesh)
        step_fn = make_train_step(model, tcfg)

        with use_rules(rules):
            lowered = jax.jit(step_fn,  # repro: noqa[R005] compile-cost harness jits on purpose
                              in_shardings=(state_shard, b_shard),
                              donate_argnums=0).lower(state_shape, batch_shape)
    elif shape.kind == "prefill":
        params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        p_shard = param_sharding(params_shape, rules)
        batch_shape = specs.train_batch(cfg, shape.seq_len, shape.global_batch)
        batch_shape.pop("targets")
        b_shard = batch_sharding(batch_shape, rules, mesh)
        caches = jax.eval_shape(
            lambda: model.init_caches(shape.global_batch, shape.seq_len))
        c_shard = cache_sharding(caches, rules, mesh, cfg, shape.global_batch)

        def prefill_fn(params, batch, caches):
            return model.prefill(params, batch, caches)

        with use_rules(rules):
            lowered = jax.jit(prefill_fn,  # repro: noqa[R005] compile-cost harness jits on purpose
                              in_shardings=(p_shard, b_shard, c_shard),
                              donate_argnums=2).lower(params_shape, batch_shape,
                                                      caches)
    else:  # decode
        params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        p_shard = param_sharding(params_shape, rules)
        token, caches, extras = specs.decode_inputs(model, shape.seq_len,
                                                    shape.global_batch)
        t_shard = batch_sharding(token, rules, mesh)
        c_shard = cache_sharding(caches, rules, mesh, cfg, shape.global_batch)
        e_shard = batch_sharding(extras, rules, mesh) if extras else None

        def decode_fn(params, token, caches, extras):
            return model.decode_step(params, token, caches, extras or None)

        with use_rules(rules):
            lowered = jax.jit(  # repro: noqa[R005] compile-cost harness jits on purpose
                decode_fn,
                in_shardings=(p_shard, t_shard, c_shard,
                              e_shard if extras else {}),
                donate_argnums=2,
            ).lower(params_shape, token, caches, extras)

    t_lower = time.time()
    compiled = lowered.compile()
    t_compile = time.time()

    mem = compiled.memory_analysis()
    mem_d = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        mem_d[attr] = getattr(mem, attr, None)
    # newer jaxlibs return a per-device list of cost dicts, older ones a
    # bare dict (same normalization as tests/test_roofline.py)
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    roof, hlo_cost = analyze(hlo_text, chips)
    t_analyze = time.time()
    hlo_dir = os.environ.get("REPRO_SAVE_HLO")
    if hlo_dir:
        import gzip
        os.makedirs(hlo_dir, exist_ok=True)
        tag = os.environ.get("REPRO_HLO_TAG", "baseline")
        fn = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}__{tag}.hlo.gz"
        with gzip.open(os.path.join(hlo_dir, fn), "wt") as f:
            f.write(hlo_text)

    return {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": chips, "ok": True,
        "options": dataclasses.asdict(opts),
        "memory": mem_d,
        "xla_cost_analysis": {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))
                              and k in ("flops", "bytes accessed")},
        "collectives": {"bytes": hlo_cost.coll_by_kind,
                        "count": hlo_cost.coll_count},
        "roofline": roof.as_dict(),
        "seconds": {"lower": t_lower - t0, "compile": t_compile - t_lower,
                    "analyze": t_analyze - t_compile},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--grad-accum", type=int, default=0)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--param-dtype", default=None)
    ap.add_argument("--moment-dtype", default="float32")
    ap.add_argument("--attn-chunk", type=int, default=1024)
    ap.add_argument("--no-scan", action="store_true")
    ap.add_argument("--rwkv-impl", default="scan")
    ap.add_argument("--rwkv-chunk", type=int, default=64)
    ap.add_argument("--serving-tp-all", action="store_true")
    ap.add_argument("--moe-impl", default="psum")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()

    opts = CellOptions(remat=args.remat, grad_accum=args.grad_accum,
                       fsdp=not args.no_fsdp, param_dtype=args.param_dtype,
                       moment_dtype=args.moment_dtype,
                       attn_chunk=args.attn_chunk,
                       scan_layers=not args.no_scan,
                       rwkv_impl=args.rwkv_impl,
                       rwkv_chunk=args.rwkv_chunk,
                       serving_tp_all=args.serving_tp_all,
                       moe_impl=args.moe_impl)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                ok, why = applicable(arch, shape)
                if ok:
                    cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for mesh_kind in meshes:
            name = f"{arch}__{shape}__{mesh_kind}__{args.tag}.json"
            path = os.path.join(args.out, name)
            if os.path.exists(path) and args.all:
                print(f"[skip] {name}")
                continue
            try:
                res = run_cell(arch, shape, mesh_kind == "multi", opts)
            except Exception as e:  # noqa: BLE001
                failures += 1
                res = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                       "ok": False, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            status = "OK" if res.get("ok") else "FAIL"
            extra = ""
            if res.get("ok"):
                r = res["roofline"]
                extra = (f" flops/dev={r['flops_per_device']:.3g}"
                         f" bound={r['dominant']}"
                         f" t={r['compute_seconds']:.3g}/{r['memory_seconds']:.3g}"
                         f"/{r['collective_seconds']:.3g}s")
            print(f"[{status}] {arch} {shape} {mesh_kind}{extra}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
