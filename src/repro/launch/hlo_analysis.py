"""Loop-aware roofline-term extraction from compiled dry-run artifacts.

XLA's ``compiled.cost_analysis()`` reports per-device FLOPs/bytes but
counts while-loop bodies ONCE (verified empirically — see
tests/test_roofline.py), which under-counts scanned-layer models by the
layer count. This module therefore walks the optimized HLO text and
computes the three roofline terms itself:

  - per-computation FLOPs: dot ops exactly (output elements x contraction
    size), elementwise/reduce ops approximately (1 flop/output element);
  - per-computation HBM bytes: operand + output bytes of top-level ops
    (fusion-aware: inner ops of a fusion don't touch HBM);
  - collective bytes: payload bytes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute;

with while-loop bodies multiplied by their trip count (recovered from the
loop condition's comparison constant) and fusion/call/conditional edges
followed recursively. Everything is per-device: the module IS the
per-device SPMD program.

Hardware model (assignment): TPU v5e-class — 197 TFLOP/s bf16/chip,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce-start", "all-gather-start", "all-reduce",
                "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute-start", "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "rsqrt", "sqrt", "tanh", "negate", "abs", "compare",
    "select", "and", "or", "xor", "not", "clamp", "floor", "ceil",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "remainder", "sign", "cosine", "sine", "logistic", "atan2",
    "round-nearest-afz", "round-nearest-even", "expm1", "log1p", "cbrt",
}

_ZERO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "reshape", "broadcast", "transpose", "copy",
    "convert", "iota", "after-all", "partition-id", "replica-id", "domain",
    "slice", "dynamic-slice", "dynamic-update-slice", "pad", "concatenate",
    "reverse", "gather", "scatter", "rng-bit-generator", "optimization-barrier",
    "copy-start", "copy-done", "all-reduce-done", "all-gather-done",
    "collective-permute-done", "custom-call", "infeed", "outfeed",
}

# shapes like bf16[8,128]{1,0}
_SHAPE_RE = re.compile(r"(\w[\w$]*)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+) = (.*)$")
_OP_RE = re.compile(r"\s*([\w\-]+)\((.*)$", re.S)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")


def _parse_def(line: str):
    """Split '%name = SHAPE op(tail' robustly.

    Tuple shapes contain '/*index=N*/' comments (with '='), so the shape is
    extracted by paren matching, not by excluding '='.
    """
    m = _NAME_RE.match(line)
    if not m:
        return None
    name, rest = m.groups()
    if rest.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        shape_str, remainder = rest[: end + 1], rest[end + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape_str, remainder = rest[:sp], rest[sp + 1:]
    m2 = _OP_RE.match(remainder)
    if not m2:
        return None
    op, tail = m2.groups()
    return name, shape_str, op, tail


def _parse_shape(shape_str: str) -> Tuple[int, int]:
    """(elements, bytes) over all array shapes present in the string."""
    elems = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class Instr:
    name: str
    shape_str: str
    op: str
    rest: str  # operand list + attrs (un-parsed tail of the line)

    @property
    def out_elems(self) -> int:
        return _parse_shape(self.shape_str)[0]

    @property
    def out_bytes(self) -> int:
        return _parse_shape(self.shape_str)[1]


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: Dict[str, int] = dataclasses.field(default_factory=dict)


class HloCostModel:
    """Per-device FLOPs / HBM bytes / collective bytes from HLO text."""

    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[Instr]] = {}
        self.shape_of: Dict[str, str] = {}
        self.const_val: Dict[str, int] = {}
        self.entry: Optional[str] = None
        self._memo: Dict[str, CompCost] = {}
        self._dus_cache: Dict[str, bool] = {}
        self._ds_cache: Dict[str, bool] = {}
        self._parse(hlo_text)

    # ------------------------------------------------------------- parsing
    def _parse(self, text: str):
        current = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if line.endswith("{"):
                m = _COMP_RE.match(line.strip())
                if m:
                    current = m.group(1)
                    self.comps[current] = []
                    if line.strip().startswith("ENTRY"):
                        self.entry = current
                    continue
            if line.strip() == "}":
                current = None
                continue
            parsed = _parse_def(line)
            if parsed is None or current is None:
                continue
            name, shape_str, op, rest = parsed
            instr = Instr(name, shape_str, op, rest)
            self.comps[current].append(instr)
            self.shape_of[name] = shape_str
            if op == "constant":
                mc = re.match(r"(\d+)\)", rest)
                if mc:
                    self.const_val[name] = int(mc.group(1))

    # ------------------------------------------------------------ helpers
    def _operand_names(self, instr: Instr) -> List[str]:
        # operands are %name tokens before the first '),'
        head = instr.rest.split("),")[0]
        return re.findall(r"%([\w\.\-]+)", head)

    def _operand_bytes(self, instr: Instr) -> int:
        total = 0
        for name in self._operand_names(instr):
            if name in self.shape_of:
                total += _parse_shape(self.shape_of[name])[1]
        return total

    def _dot_flops(self, instr: Instr) -> float:
        out_elems = instr.out_elems
        mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
        ops = self._operand_names(instr)
        if not mc or not ops or ops[0] not in self.shape_of:
            return 2.0 * out_elems  # degenerate
        lhs_dims_m = _SHAPE_RE.search(self.shape_of[ops[0]])
        if not lhs_dims_m:
            return 2.0 * out_elems
        lhs_dims = [int(d) for d in lhs_dims_m.group(2).split(",") if d]
        contract = 1
        for idx in (int(i) for i in mc.group(1).split(",") if i):
            if idx < len(lhs_dims):
                contract *= lhs_dims[idx]
        return 2.0 * out_elems * contract

    def _called(self, instr: Instr, attr: str) -> Optional[str]:
        m = re.search(attr + r"=%?([\w\.\-]+)", instr.rest)
        return m.group(1) if m else None

    def _has_dus(self, comp_name: str) -> bool:
        if comp_name not in self._dus_cache:
            self._dus_cache[comp_name] = any(
                i.op == "dynamic-update-slice"
                for i in self.comps.get(comp_name, []))
        return self._dus_cache[comp_name]

    def _has_ds(self, comp_name: str) -> bool:
        if comp_name not in self._ds_cache:
            self._ds_cache[comp_name] = any(
                i.op in ("dynamic-slice", "slice")
                for i in self.comps.get(comp_name, []))
        return self._ds_cache[comp_name]

    def _trip_count(self, cond_name: str) -> int:
        """Largest integer constant referenced by the loop condition."""
        best = 1
        for instr in self.comps.get(cond_name, []):
            if instr.op == "constant" and instr.name in self.const_val:
                best = max(best, self.const_val[instr.name])
            for ref in re.findall(r"%(constant[\w\.\-]*)", instr.rest):
                if ref in self.const_val:
                    best = max(best, self.const_val[ref])
        return max(best, 1)

    # ------------------------------------------------------------- costing
    def comp_cost(self, name: str) -> CompCost:
        if name in self._memo:
            return self._memo[name]
        cost = CompCost()
        self._memo[name] = cost  # break cycles defensively
        for instr in self.comps.get(name, []):
            op = instr.op
            if op == "while":
                body = self._called(instr, "body")
                cond = self._called(instr, "condition")
                trips = self._trip_count(cond) if cond else 1
                for sub in (body, cond):
                    if sub:
                        c = self.comp_cost(sub)
                        cost.flops += trips * c.flops
                        cost.bytes += trips * c.bytes
                        cost.coll_bytes += trips * c.coll_bytes
                        for k, v in c.coll_by_kind.items():
                            cost.coll_by_kind[k] = cost.coll_by_kind.get(k, 0) + trips * v
                        for k, v in c.coll_count.items():
                            cost.coll_count[k] = cost.coll_count.get(k, 0) + trips * v
                continue
            if op == "fusion":
                called = self._called(instr, "calls")
                b = instr.out_bytes + self._operand_bytes(instr)
                if called:
                    c = self.comp_cost(called)
                    cost.flops += c.flops          # inner flops count
                    # inner bytes do NOT (fusion stays in registers/VMEM)
                    if self._has_dus(called):
                        # in-place (aliased) update fusion: the big buffer
                        # passes through untouched except the updated slice;
                        # drop the read+write of the aliased operand.
                        ops = [
                            _parse_shape(self.shape_of[n])[1]
                            for n in self._operand_names(instr)
                            if n in self.shape_of
                        ]
                        aliased = max((x for x in ops
                                       if x == instr.out_bytes), default=0)
                        if aliased == 0 and ops:
                            aliased = max(ops)
                        b = max(b - 2 * aliased, instr.out_bytes // 64 + 1)
                    elif self._has_ds(called):
                        # fusion slicing a big (stacked-over-layers) operand:
                        # only the slice is read — cap each oversized
                        # operand at the fusion's output size.
                        b = instr.out_bytes
                        for n in self._operand_names(instr):
                            if n in self.shape_of:
                                ob = _parse_shape(self.shape_of[n])[1]
                                b += min(ob, max(instr.out_bytes, 1))
                cost.bytes += b
                continue
            if op in ("call", "async-start", "async-done"):
                called = self._called(instr, "calls") or self._called(instr, "to_apply")
                if called:
                    c = self.comp_cost(called)
                    cost.flops += c.flops
                    cost.bytes += c.bytes
                    cost.coll_bytes += c.coll_bytes
                continue
            if op == "conditional":
                for attr in ("true_computation", "false_computation"):
                    called = self._called(instr, attr)
                    if called:
                        c = self.comp_cost(called)
                        cost.flops += c.flops
                        cost.bytes += c.bytes
                continue
            if op in _COLLECTIVES:
                kind = op.replace("-start", "")
                payload = max(instr.out_bytes, self._operand_bytes(instr))
                cost.coll_bytes += payload
                cost.coll_by_kind[kind] = cost.coll_by_kind.get(kind, 0) + payload
                cost.coll_count[kind] = cost.coll_count.get(kind, 0) + 1
                cost.bytes += instr.out_bytes + self._operand_bytes(instr)
                continue
            if op == "dot" or op == "convolution":
                cost.flops += self._dot_flops(instr)
                cost.bytes += instr.out_bytes + self._operand_bytes(instr)
                continue
            if op in ("reduce", "reduce-window"):
                cost.flops += self._operand_bytes(instr) / 2  # ~1 flop/elem
                cost.bytes += instr.out_bytes + self._operand_bytes(instr)
                continue
            if op == "sort":
                n = max(instr.out_elems, 1)
                cost.flops += n * max(1, int(n).bit_length())
                cost.bytes += instr.out_bytes + self._operand_bytes(instr)
                continue
            if op in _ELEMENTWISE:
                cost.flops += instr.out_elems
                # inside fused computations these don't touch HBM; only count
                # bytes for *top-level* elementwise ops, which XLA usually
                # wraps in fusions anyway — so skip bytes here.
                continue
            if op in _ZERO_COST:
                # slice-family ops move only their result (read + write), not
                # their full operands — counting operands would charge a
                # scanned layer-stack slice with the whole stack every trip.
                if op in ("copy", "gather", "concatenate", "slice",
                          "dynamic-slice", "reverse", "pad"):
                    cost.bytes += 2 * instr.out_bytes
                elif op in ("scatter", "dynamic-update-slice"):
                    # in-place (aliased) update: read+write the updated
                    # region only, not the whole destination buffer
                    ops_b = self._operand_bytes(instr)
                    upd = max(0, ops_b - instr.out_bytes)  # updates+indices
                    cost.bytes += 2 * min(max(upd, 1), instr.out_bytes)
                elif op == "custom-call":
                    cost.bytes += instr.out_bytes + self._operand_bytes(instr)
                continue
            # unknown op: be conservative, count bytes
            cost.bytes += instr.out_bytes
        return cost

    def entry_cost(self) -> CompCost:
        assert self.entry, "no ENTRY computation found"
        return self.comp_cost(self.entry)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    chips: int

    @property
    def compute_seconds(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_seconds(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_seconds(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_seconds,
                 "memory": self.memory_seconds,
                 "collective": self.collective_seconds}
        return max(terms, key=terms.get)

    @property
    def bound_seconds(self) -> float:
        return max(self.compute_seconds, self.memory_seconds,
                   self.collective_seconds)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips,
            "compute_seconds": self.compute_seconds,
            "memory_seconds": self.memory_seconds,
            "collective_seconds": self.collective_seconds,
            "dominant": self.dominant,
        }


def analyze(hlo_text: str, chips: int) -> Tuple[Roofline, CompCost]:
    model = HloCostModel(hlo_text)
    cost = model.entry_cost()
    roof = Roofline(
        flops_per_device=cost.flops,
        bytes_per_device=cost.bytes,
        collective_bytes_per_device=cost.coll_bytes,
        chips=chips,
    )
    return roof, cost
