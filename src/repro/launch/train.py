"""Production training launcher.

Wires: mesh + logical sharding rules -> sharded train state -> HDB-dedup'd
deterministic loader -> jitted train step (remat/accum/compression) ->
checkpoint manager + straggler monitor + preemption handler.

On this container it runs real steps on 1 device with reduced configs:

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 20

On a real pod the same entrypoint is launched per host (jax.distributed
initializes from cluster env), `--mesh single|multi` builds the production
mesh, and full configs shard per DESIGN.md §5.
"""
from __future__ import annotations

import argparse
import time

import jax

from ..configs import get_config, reduced_config
from ..core import hdb
from ..data import loader, pipeline, synthetic
from ..distributed.sharding import param_sharding, production_rules, use_rules
from ..models.model import build_model
from ..training import checkpoint
from ..training.optimizer import OptimizerConfig
from ..training.stragglers import PreemptionHandler, StragglerMonitor
from ..training.train_loop import TrainConfig, init_train_state, make_train_step
from .mesh import make_production_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--mesh", default="none", choices=["none", "single", "multi"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--dedup", action="store_true")
    ap.add_argument("--entities", type=int, default=3000)
    args = ap.parse_args(argv)

    if jax.process_count() > 1:  # multi-host: initialized by the cluster
        pass

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    tcfg = TrainConfig(
        opt=OptimizerConfig(lr=3e-4, warmup_steps=min(20, args.steps // 4),
                            total_steps=args.steps),
        grad_accum=args.grad_accum,
        compress_grads=args.compress_grads)

    rules = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        rules = production_rules(mesh)

    corpus = synthetic.generate(synthetic.SyntheticSpec(
        num_entities=args.entities, dup_rate=0.5, seed=13))
    survivors = None
    if args.dedup:
        rep = pipeline.dedup_corpus(corpus, hdb.HDBConfig(max_block_size=100))
        survivors = rep.survivors
        print(f"[train] dedup {corpus.num_records} -> {rep.num_survivors}")
    ld = loader.TokenStreamLoader(
        corpus, loader.LoaderConfig(batch_size=args.batch, seq_len=args.seq,
                                    vocab_size=cfg.vocab_size),
        survivors=survivors)

    with use_rules(rules) if rules else _null():
        state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
        if rules is not None:
            shard = param_sharding(state["params"], rules)
            state["params"] = jax.device_put(state["params"], shard)
            state["opt"]["mu"] = jax.device_put(state["opt"]["mu"], shard)
            state["opt"]["nu"] = jax.device_put(state["opt"]["nu"], shard)
        start = checkpoint.latest_step(args.ckpt_dir) or 0
        if start:
            state = checkpoint.restore(args.ckpt_dir,
                                       jax.eval_shape(lambda: state))
            print(f"[train] resumed from step {start}")
        step_fn = jax.jit(make_train_step(model, tcfg),  # repro: noqa[R005] one-shot launch driver
                          donate_argnums=0)
        monitor = StragglerMonitor()
        preempt = PreemptionHandler().install()
        t0 = time.time()
        for step in range(start, args.steps):
            monitor.start_step()
            inputs, targets = ld.batch(step)
            state, metrics = step_fn(state, {"tokens": inputs,
                                             "targets": targets})
            monitor.end_step(step)
            if step % 10 == 0:
                print(f"[train] step {step} loss {float(metrics['loss']):.4f}")
            if (step + 1) % args.ckpt_every == 0 or preempt.requested:
                checkpoint.save(args.ckpt_dir, step + 1, state)
                if preempt.requested:
                    print("[train] preempted; checkpoint written")
                    break
        preempt.uninstall()
        print(f"[train] done in {time.time() - t0:.1f}s "
              f"final loss {float(metrics['loss']):.4f}")


import contextlib  # noqa: E402


@contextlib.contextmanager
def _null():
    yield


if __name__ == "__main__":
    main()
