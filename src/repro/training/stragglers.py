"""Straggler mitigation + failure handling for thousand-node runs.

On a real multi-pod deployment each host runs this monitor around its
train loop:

- step-time EMA with outlier detection (a straggling host shows up as a
  slow all-reduce for EVERYBODY; the monitor attributes blame via the
  pre-collective barrier time so the orchestrator can evict the slow host),
- a heartbeat file that the cluster orchestrator watches (missed
  heartbeats => reschedule the job from the last checkpoint),
- graceful-degradation hook: on SIGTERM (preemption notice) an emergency
  checkpoint is requested before the process dies.

The container is single-host, so tests drive the monitor with injected
timings (tests/test_fault_tolerance.py); the logic is host-count agnostic.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import List, Optional


@dataclasses.dataclass
class StragglerConfig:
    ema_alpha: float = 0.1
    outlier_factor: float = 2.0     # step > factor * EMA  => straggler event
    trip_threshold: int = 3         # consecutive events before flagging
    heartbeat_path: Optional[str] = None
    heartbeat_every: int = 10       # steps


class StragglerMonitor:
    def __init__(self, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.ema: Optional[float] = None
        self.consecutive = 0
        self.events: List[dict] = []
        self._last = None
        self._steps = 0

    def start_step(self):
        self._last = time.perf_counter()

    def end_step(self, step: int, duration: Optional[float] = None) -> bool:
        """Record a step; returns True if this host is flagged a straggler."""
        if duration is None:
            duration = time.perf_counter() - self._last
        flagged = False
        if self.ema is None:
            self.ema = duration
        else:
            if duration > self.cfg.outlier_factor * self.ema:
                self.consecutive += 1
                self.events.append({"step": step, "duration": duration,
                                    "ema": self.ema})
                if self.consecutive >= self.cfg.trip_threshold:
                    flagged = True
            else:
                self.consecutive = 0
            self.ema = (1 - self.cfg.ema_alpha) * self.ema \
                + self.cfg.ema_alpha * duration
        self._steps += 1
        if (self.cfg.heartbeat_path
                and self._steps % self.cfg.heartbeat_every == 0):
            with open(self.cfg.heartbeat_path, "w") as f:
                f.write(f"{step} {time.time()}\n")
        return flagged


class PreemptionHandler:
    """SIGTERM -> request emergency checkpoint at the next step boundary."""

    def __init__(self):
        self.requested = False
        self._orig = None

    def install(self):
        self._orig = signal.signal(signal.SIGTERM, self._on_term)
        return self

    def _on_term(self, signum, frame):
        self.requested = True

    def uninstall(self):
        if self._orig is not None:
            signal.signal(signal.SIGTERM, self._orig)
