"""Gradient compression: int8 row-scaled quantization with error feedback.

For thousand-node DP, gradient all-reduce bytes dominate the step at small
per-device batch; int8 + EF cuts wire bytes 4x vs fp32 (2x vs bf16) with
negligible quality loss (the EF buffer re-injects quantization error next
step, preserving convergence — tests/test_training.py).

Without a mesh axis the quantize/dequantize still runs (worst-case noise
path for convergence tests); with ``axis`` it wraps an explicit shard_map
psum so the collective really carries int8.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-row (last-axis) int8 quantization."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum_grads(grads, error_fb, axis: Optional[str] = None):
    """Quantize (grad + error), (optionally) psum int8, dequantize; update EF."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        if axis is not None:
            # int32 accumulate of int8 payloads; scales reduced separately
            qsum = jax.lax.psum(q.astype(jnp.int32), axis)
            ssum = jax.lax.pmean(scale, axis)
            deq = qsum.astype(jnp.float32) * ssum / jax.lax.psum(1, axis)
        else:
            deq = dequantize_int8(q, scale)
        new_e = g32 - deq
        return deq.astype(g.dtype), new_e

    out = jax.tree.map(one, grads, error_fb)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    efb = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return deq, efb
