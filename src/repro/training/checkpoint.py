"""Fault-tolerant checkpointing (no orbax on box; built from scratch).

- Step-tagged directories, atomic rename on completion, crc32 integrity.
- Pytree leaves stored in a single .npz (+ msgpack'd treedef/meta).
- ``restore(..., sharding=...)`` re-device_puts leaves into any sharding,
  so resuming on a different mesh size (elastic scaling) just works.
- Works for BOTH training state and HDB pipeline iteration state — any
  pytree of arrays (bool/int/uint/float/bf16).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = "bfloat16"


def _leaf_to_np(x) -> np.ndarray:
    x = np.asarray(x)
    if x.dtype == jnp.bfloat16:
        return x.view(np.uint16)  # stored raw; dtype recorded in meta
    return x


def _np_to_leaf(x: np.ndarray, dtype: str):
    if dtype == _BF16:
        return jnp.asarray(x.view(jnp.bfloat16))
    return jnp.asarray(x)


def save(directory: str, step: int, tree: Any, *, blocking: bool = True,
         keep: int = 3) -> str:
    """Atomically write `tree` under directory/step_<step>."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays = {}
    meta = {"step": step, "num_leaves": len(leaves),
            "treedef": str(treedef), "dtypes": [], "crc": []}
    for i, leaf in enumerate(leaves):
        arr = _leaf_to_np(leaf)
        meta["dtypes"].append(str(np.asarray(leaf).dtype)
                              if np.asarray(leaf).dtype != jnp.bfloat16
                              else _BF16)
        meta["crc"].append(zlib.crc32(arr.tobytes()) & 0xFFFFFFFF)
        arrays[f"leaf_{i}"] = arr

    def _write():
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
            f.write(str(step))
        os.replace(os.path.join(directory, "LATEST.tmp"),
                   os.path.join(directory, "LATEST"))
        _gc(directory, keep)

    if blocking:
        _write()
    else:
        threading.Thread(target=_write, daemon=True).start()
    return final


def _gc(directory: str, keep: int):
    steps = sorted(d for d in os.listdir(directory) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    path = os.path.join(directory, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip())


def restore(directory: str, template: Any, step: Optional[int] = None,
            sharding=None) -> Any:
    """Restore into the structure of `template`; optional resharding.

    `sharding` may be a pytree of NamedShardings (elastic resume onto a
    different mesh) or None (single device).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    src = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(src, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(src, "arrays.npz"))
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    assert meta["num_leaves"] == len(leaves_t), "checkpoint/template mismatch"
    shard_leaves = (jax.tree_util.tree_flatten(sharding)[0]
                    if sharding is not None else [None] * len(leaves_t))
    out = []
    for i in range(len(leaves_t)):
        arr = data[f"leaf_{i}"]
        crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
        if crc != meta["crc"][i]:
            raise IOError(f"checkpoint corruption at leaf {i} "
                          f"(crc {crc} != {meta['crc'][i]})")
        leaf = _np_to_leaf(arr, meta["dtypes"][i])
        if shard_leaves[i] is not None:
            leaf = jax.device_put(leaf, shard_leaves[i])
        out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)
