"""Train step: value_and_grad + AdamW, with microbatch gradient
accumulation (lax.scan), optional int8 gradient compression with error
feedback, and reduce-scatter-friendly mean-grad semantics.

Under jit-with-shardings (GSPMD) the data-parallel gradient all-reduce is
inserted by XLA from the sharding constraints; the compression path makes
the quantize/dequantize explicit around a shard_map psum so the wire bytes
really shrink (tests/test_training.py checks convergence parity).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .optimizer import OptimizerConfig, adamw_update, init_opt_state
from .compression import compressed_psum_grads
from ..models.model import Model


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptimizerConfig = OptimizerConfig()
    grad_accum: int = 1           # microbatches per step
    compress_grads: bool = False  # int8 + error feedback DP sync
    compress_axis: Optional[str] = None  # mesh axis for explicit psum


def init_train_state(model: Model, rng, tcfg: TrainConfig) -> Dict[str, Any]:
    params = model.init(rng)
    state = {
        "params": params,
        "opt": init_opt_state(tcfg.opt, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if tcfg.compress_grads:
        state["error_fb"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def make_train_step(model: Model, tcfg: TrainConfig):
    """Returns train_step(state, batch) -> (state, metrics). Jit outside."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if tcfg.grad_accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        n = tcfg.grad_accum

        def micro(b):
            return jax.tree.map(
                lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), b)

        def acc_step(carry, mb):
            loss_a, grads_a = carry
            (loss, metrics), grads = grad_fn(params, mb)
            grads_a = jax.tree.map(jnp.add, grads_a, grads)
            return (loss_a + loss, grads_a), metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads_sum), metrics = jax.lax.scan(
            acc_step, (jnp.zeros(()), zeros), micro(batch))
        grads = jax.tree.map(lambda g: g / n, grads_sum)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / n, metrics, grads

    def train_step(state, batch):
        loss, metrics, grads = compute_grads(state["params"], batch)
        if tcfg.compress_grads:
            grads, new_efb = compressed_psum_grads(
                grads, state["error_fb"], axis=tcfg.compress_axis)
        new_params, new_opt, opt_metrics = adamw_update(
            tcfg.opt, state["params"], grads, state["opt"])
        new_state = dict(state, params=new_params, opt=new_opt,
                         step=state["step"] + 1)
        if tcfg.compress_grads:
            new_state["error_fb"] = new_efb
        out = {"loss": loss, **metrics, **opt_metrics}
        return new_state, out

    return train_step
