"""AdamW + warmup-cosine schedule, built from scratch (no optax on box).

Optimizer state mirrors the parameter pytree, so ZeRO-style sharding is
free: the moments inherit each param's NamedSharding. Moments are fp32 by
default with a bf16 option (``moment_dtype``) for HBM-tight configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(cfg: OptimizerConfig, params) -> Dict[str, Any]:
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: OptimizerConfig, params, grads, state
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g * g
        update = (mu32 / bc1) / (jnp.sqrt(nu32 / bc2) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * update
        return new_p.astype(p.dtype), mu32.astype(mu.dtype), nu32.astype(nu.dtype)

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
