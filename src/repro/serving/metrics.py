"""Serving metrics: counters + fixed-bin streaming histograms.

Pure-host bookkeeping (no jax import): a service records into ``Metrics``
on every step and exports ``snapshot()`` as a plain nested dict so benches
and tests can assert on it and `write_json` can serialize it verbatim.
Histograms are fixed-bin (log-spaced for latencies, linear for ratios):
O(1) per observation, O(bins) memory, and percentile estimates whose error
is bounded by the bin width — enough to tell p50 from p99 without keeping
per-request samples for millions of probes.
"""
from __future__ import annotations

import bisect
import math
from typing import Dict, List


class Counter:
    """Monotonic event counter."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Histogram:
    """Streaming histogram over fixed bin edges.

    ``counts[i]`` holds observations with ``edges[i-1] <= x < edges[i]``;
    the two extra slots catch under/overflow. Percentiles interpolate the
    bin midpoint, clamped to the observed [min, max] so small-count
    snapshots never report a value outside what was actually seen.
    """

    def __init__(self, edges: List[float]):
        if not edges or any(b <= a for a, b in zip(edges, edges[1:])):
            raise ValueError("edges must be strictly increasing and non-empty")
        self.edges = list(edges)
        self.counts = [0] * (len(edges) + 1)
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    @classmethod
    def log(cls, lo: float, hi: float, per_decade: int = 5) -> "Histogram":
        """Log-spaced edges from ``lo`` to ``hi`` (for latency-like data)."""
        decades = math.log10(hi / lo)
        n = max(int(round(decades * per_decade)), 1)
        return cls([lo * 10.0 ** (decades * i / n) for i in range(n + 1)])

    @classmethod
    def linear(cls, lo: float, hi: float, nbins: int = 20) -> "Histogram":
        """Evenly spaced edges (for bounded ratios like occupancy)."""
        step = (hi - lo) / nbins
        return cls([lo + step * i for i in range(nbins + 1)])

    def record(self, x: float) -> None:
        x = float(x)
        self.counts[bisect.bisect_right(self.edges, x)] += 1
        self.n += 1
        self.total += x
        if x < self.vmin:
            self.vmin = x
        if x > self.vmax:
            self.vmax = x

    def percentile(self, p: float) -> float:
        """Bin-midpoint estimate of the p-th percentile (0 if empty)."""
        if self.n == 0:
            return 0.0
        rank = p / 100.0 * self.n
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank and c:
                if i == 0:
                    mid = self.edges[0]
                elif i == len(self.edges):
                    mid = self.edges[-1]
                else:
                    mid = 0.5 * (self.edges[i - 1] + self.edges[i])
                return min(max(mid, self.vmin), self.vmax)
        return self.vmax

    def snapshot(self) -> dict:
        if self.n == 0:
            return {"count": 0, "sum": 0.0, "mean": 0.0, "min": 0.0,
                    "max": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0}
        return {
            "count": self.n,
            "sum": self.total,
            "mean": self.total / self.n,
            "min": self.vmin,
            "max": self.vmax,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }

    def reset(self) -> None:
        self.counts = [0] * len(self.counts)
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf


# histogram kinds: name -> factory (latencies span us..100s; unit ratios
# like occupancy live in [0, 1]; count-like data spans 1..1M rows)
_KINDS = {
    "latency": lambda: Histogram.log(1e-6, 100.0, per_decade=5),
    "unit": lambda: Histogram.linear(0.0, 1.0, nbins=20),
    "count": lambda: Histogram.log(0.5, 1e6, per_decade=4),
}


class Metrics:
    """Create-on-first-use registry of named counters and histograms."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._hists: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def histogram(self, name: str, kind: str = "latency") -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = _KINDS[kind]()
        return h

    def snapshot(self, **gauges) -> dict:
        """Plain-dict export; ``gauges`` carries instantaneous values the
        caller owns (queue depths, tenant count)."""
        return {
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "histograms": {k: h.snapshot() for k, h in sorted(self._hists.items())},
            "gauges": dict(gauges),
        }

    def reset(self) -> None:
        """Zero every counter and histogram (registry keys survive)."""
        for c in self._counters.values():
            c.value = 0
        for h in self._hists.values():
            h.reset()
