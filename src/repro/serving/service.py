"""DedupeService: async micro-batched blocking-probe service.

The paper's pipeline ends at a batch of candidate pairs; the north-star
traffic shape is millions of users issuing ``query_keys``-style probes
against hot ``BlockStore``s. This front-end turns the streaming subsystem
into that service:

- **Admission lanes.** Every tenant gets a bounded read (probe) queue and
  a bounded write (ingest) queue. Probes never stall behind ingest ledger
  syncs: each ``step()`` serves one probe micro-batch AND one ingest
  micro-batch from the separate lanes. A full lane rejects at submit time
  (``BackpressureError``); a probe whose deadline expires while queued is
  shed with an explicit ``"expired"`` response. Nothing is silently
  dropped.
- **Padded-bucket batching.** Queued probes are collated (skip-scan FIFO,
  see ``scheduler.collate_fifo``) up to ``probe_slots`` rows and padded to
  a power-of-two ``BucketLadder`` rung, so the jitted classify/intersect
  walk compiles once per rung, not once per batch size. Batched results
  are bit-identical to one-at-a-time ``DeltaBlocker.query_keys`` calls
  (property-tested for both ``include_probe`` modes).
- **Per-tenant isolation.** N independent ``BlockStore``s behind one
  service; round-robin fair-share across tenants with queued work, per
  lane, so one tenant's backlog cannot starve another's probes.
- **Metrics.** Counters + streaming histograms (``serving.metrics``)
  exported as a plain dict via ``snapshot()`` — QPS inputs, p50/p99 probe
  latency, batch occupancy, bucket compile count, queue depths, shed and
  reject counts. The metrics contract is documented in docs/SERVING.md.

Ingest requests carry no deadline: the write lane is the durability path
(a shed ingest would silently fork the store from its callers' view).
Everything here is host-side scheduling; device work happens inside the
tenant's ``DeltaBlocker``.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Dict, List, Optional

import numpy as np

from ..core import hdb as hdb_mod
from ..data.components import ClusterResult, cluster_edges
from ..streaming.delta import DeltaBlocker, IngestReport, QueryResult
from ..streaming.store import BlockStore, unpack_pair
from .buckets import BucketLadder, pad_probe_rows
from .metrics import Metrics
from .scheduler import collate_fifo, drain

STATUS_OK = "ok"
STATUS_EXPIRED = "expired"


class BackpressureError(RuntimeError):
    """Admission rejected: the target lane's bounded queue is full."""


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    probe_slots: int = 64          # max probe rows per micro-batch
    ingest_slots: int = 256        # max ingest rows per micro-batch
    max_read_queue: int = 1024     # queued probe requests per tenant
    max_write_queue: int = 256     # queued ingest requests per tenant
    min_bucket: int = 8            # smallest bucket-ladder rung
    default_deadline_s: Optional[float] = None   # probe deadline if unset
    sort_backend: str = "auto"     # pair-ledger dedupe-sort knob
    # > 1: tenants created by the service get a fingerprint-sharded
    # ShardedBlockStore (streaming/shard.py) instead of a single-host
    # BlockStore; results are bit-identical, the snapshot gains per-shard
    # occupancy/skew gauges
    n_shards: int = 1


@dataclasses.dataclass
class ProbeRequest:
    uid: int
    tenant: str
    keys: np.ndarray             # (n, K, 2) uint32, as from build_keys
    valid: np.ndarray            # (n, K) bool
    include_probe: bool
    deadline: Optional[float]    # absolute clock time, None = no deadline
    submitted_at: float

    @property
    def num_rows(self) -> int:
        return int(self.valid.shape[0])


@dataclasses.dataclass
class IngestRequest:
    uid: int
    tenant: str
    keys: np.ndarray
    valid: np.ndarray
    submitted_at: float

    @property
    def num_rows(self) -> int:
        return int(self.valid.shape[0])


@dataclasses.dataclass
class ProbeResponse:
    uid: int
    tenant: str
    status: str                  # STATUS_OK | STATUS_EXPIRED
    results: List[QueryResult]   # one per probe row ([] when shed)
    latency_s: float             # submit -> response


@dataclasses.dataclass
class IngestResponse:
    uid: int
    tenant: str
    status: str
    report: IngestReport         # shared by requests coalesced into one batch
    first_rid: int               # rid assigned to this request's first row
    num_rows: int
    latency_s: float


@dataclasses.dataclass
class Tenant:
    """One isolated store + blocker + its two admission lanes."""

    name: str
    store: BlockStore
    blocker: DeltaBlocker
    read_q: List[ProbeRequest] = dataclasses.field(default_factory=list)
    write_q: List[IngestRequest] = dataclasses.field(default_factory=list)
    # last refresh_clusters() outcome (None until first refresh)
    clusters: Optional[ClusterResult] = None


class DedupeService:
    """Micro-batched probe/ingest service over per-tenant BlockStores."""

    def __init__(self, cfg: hdb_mod.HDBConfig = hdb_mod.HDBConfig(),
                 service: ServiceConfig = ServiceConfig(),
                 clock: Callable[[], float] = time.monotonic):
        self.hdb_cfg = cfg
        self.cfg = service
        self.ladder = BucketLadder(min_bucket=service.min_bucket)
        self.metrics = Metrics()
        self.probe_responses: List[ProbeResponse] = []
        self.ingest_responses: List[IngestResponse] = []
        self._clock = clock
        self._tenants: Dict[str, Tenant] = {}
        self._order: List[str] = []   # round-robin order (insertion)
        self._rr_read = 0
        self._rr_write = 0
        self._uid = 0
        # (bucket, key width, include_probe) walk shapes this service has
        # sent to the compiled steps — new entries are compile events
        self._seen_shapes: set = set()

    # ------------------------------------------------------------------
    # tenants
    # ------------------------------------------------------------------

    def add_tenant(self, name: str,
                   store: Optional[BlockStore] = None) -> Tenant:
        if name in self._tenants:
            raise ValueError(f"tenant {name!r} already exists")
        if store is None:
            if self.cfg.n_shards > 1:
                from ..streaming.shard import ShardedBlockStore
                store = ShardedBlockStore(self.hdb_cfg,
                                          n_shards=self.cfg.n_shards)
            else:
                store = BlockStore(self.hdb_cfg)
        tenant = Tenant(name, store,
                        DeltaBlocker(store, sort_backend=self.cfg.sort_backend))
        self._tenants[name] = tenant
        self._order.append(name)
        return tenant

    def tenant(self, name: str) -> Tenant:
        """Existing tenant, or a fresh isolated store created on first use."""
        got = self._tenants.get(name)
        return got if got is not None else self.add_tenant(name)

    def refresh_clusters(self, name: str,
                         max_rounds: int = 64) -> ClusterResult:
        """Re-partition a tenant's pair ledger into entity clusters.

        Runs the fused device CC path (``components.cluster_edges``,
        pow-2 bucketed uploads -> bounded ``while_loop`` -> device
        survivor extraction) over the tenant store's exact packed
        ``a<<32|b`` ledger. Service tenants ingest pre-hashed keys, so
        this partitions the *candidate* graph — the blocking-level
        clusters that upper-bound any downstream matcher. The result is
        cached on the tenant and surfaced through ``snapshot()`` gauges;
        a truncated (non-converged) refresh bumps
        ``cluster_truncated_total`` — never silent.
        """
        t = self.tenant(name)
        t0 = self._clock()
        ma, mb = unpack_pair(np.asarray(t.store.led_pack, np.uint64))
        res = cluster_edges(int(t.store.num_records), ma, mb,
                            max_rounds=max_rounds)
        t.clusters = res
        self.metrics.counter("cluster_refreshes_total").inc()
        if not res.converged:
            self.metrics.counter("cluster_truncated_total").inc()
        self.metrics.histogram("cluster_refresh_s").record(
            self._clock() - t0)
        return res

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def submit_probe(self, tenant: str, keys, valid,
                     include_probe: bool = False,
                     deadline_s: Optional[float] = None) -> int:
        """Queue a probe micro-batch on the tenant's read lane.

        ``deadline_s`` is relative to now (falls back to the config's
        ``default_deadline_s``); an expired request is shed with an
        explicit "expired" response instead of being walked. Raises
        ``BackpressureError`` when the lane is full. Returns the request
        uid; the response lands in ``probe_responses``.
        """
        t = self.tenant(tenant)
        if len(t.read_q) >= self.cfg.max_read_queue:
            self.metrics.counter("rejected_total").inc()
            raise BackpressureError(
                f"read lane full for tenant {tenant!r} "
                f"({self.cfg.max_read_queue} queued)")
        now = self._clock()
        if deadline_s is None:
            deadline_s = self.cfg.default_deadline_s
        self._uid += 1
        t.read_q.append(ProbeRequest(
            uid=self._uid, tenant=tenant, keys=np.asarray(keys),
            valid=np.asarray(valid, bool), include_probe=bool(include_probe),
            deadline=None if deadline_s is None else now + deadline_s,
            submitted_at=now))
        return self._uid

    def submit_ingest(self, tenant: str, keys, valid) -> int:
        """Queue an ingest micro-batch on the tenant's write lane.

        Rids ``store.num_records..+n`` are assigned in service order when
        the batch lands (see ``IngestResponse.first_rid``). Raises
        ``BackpressureError`` when the lane is full.
        """
        t = self.tenant(tenant)
        if len(t.write_q) >= self.cfg.max_write_queue:
            self.metrics.counter("rejected_total").inc()
            raise BackpressureError(
                f"write lane full for tenant {tenant!r} "
                f"({self.cfg.max_write_queue} queued)")
        self._uid += 1
        t.write_q.append(IngestRequest(
            uid=self._uid, tenant=tenant, keys=np.asarray(keys),
            valid=np.asarray(valid, bool), submitted_at=self._clock()))
        return self._uid

    # ------------------------------------------------------------------
    # scheduling
    # ------------------------------------------------------------------

    @property
    def busy(self) -> bool:
        return any(t.read_q or t.write_q for t in self._tenants.values())

    def queue_depths(self) -> Dict[str, int]:
        return {"read": sum(len(t.read_q) for t in self._tenants.values()),
                "write": sum(len(t.write_q) for t in self._tenants.values())}

    def step(self) -> None:
        """Shed expired probes, then serve one probe micro-batch and one
        ingest micro-batch (read lane first: probes don't wait on syncs)."""
        self._shed_expired()
        self._step_read()
        self._step_write()

    def run(self, max_steps: int = 10_000):
        """Drain both lanes; warn if ``max_steps`` truncates the drain."""
        drain(self, max_steps)
        if self.busy:
            depths = self.queue_depths()
            warnings.warn(
                f"DedupeService.run stopped at max_steps={max_steps} with "
                f"{depths['read']} probe and {depths['write']} ingest "
                "requests still queued; call run() again to finish",
                RuntimeWarning, stacklevel=2)
        return self.probe_responses, self.ingest_responses

    def snapshot(self) -> dict:
        """Metrics snapshot (plain dict) with live queue-depth gauges.

        Tenants on sharded stores add occupancy gauges: ``store_shards``
        (max shard count), ``store_shard_skew_max`` (worst max/mean
        per-shard byte skew; 1.0 == balanced), and the two never-silent
        fallback counters (routed ledger syncs and routed key-table
        exchanges that dropped to the lossless host path). Tenants that
        have run ``refresh_clusters`` add ``clustered_tenants`` /
        ``cluster_components`` / ``cluster_rounds_max`` gauges alongside
        the ``cluster_refreshes_total`` / ``cluster_truncated_total``
        counters.
        """
        depths = self.queue_depths()
        shards = 1
        skew = 1.0
        ledger_fb = exchange_fb = 0
        clustered = cluster_components = cluster_rounds_max = 0
        for t in self._tenants.values():
            ledger_fb += getattr(t.blocker, "routed_fallback_total", 0)
            router = getattr(t.store, "router", None)
            if router is not None:
                shards = max(shards, t.store.n_shards)
                skew = max(skew, t.store.shard_skew())
                exchange_fb += router.exchange_fallback_total
            if t.clusters is not None:
                clustered += 1
                cluster_components += len(t.clusters.survivors)
                cluster_rounds_max = max(cluster_rounds_max,
                                         t.clusters.rounds)
        return self.metrics.snapshot(
            read_queue_depth=depths["read"],
            write_queue_depth=depths["write"],
            tenants=len(self._tenants),
            store_shards=shards,
            store_shard_skew_max=skew,
            ledger_routed_fallback_total=ledger_fb,
            store_exchange_fallback_total=exchange_fb,
            clustered_tenants=clustered,
            cluster_components=cluster_components,
            cluster_rounds_max=cluster_rounds_max)

    # ------------------------------------------------------------------

    def _shed_expired(self) -> None:
        now = self._clock()
        for t in self._tenants.values():
            if not any(r.deadline is not None and now >= r.deadline
                       for r in t.read_q):
                continue
            live: List[ProbeRequest] = []
            for r in t.read_q:
                if r.deadline is not None and now >= r.deadline:
                    self.metrics.counter("shed_total").inc()
                    self.probe_responses.append(ProbeResponse(
                        r.uid, t.name, STATUS_EXPIRED, [],
                        now - r.submitted_at))
                else:
                    live.append(r)
            t.read_q[:] = live

    def _pick_tenant(self, start: int, lane: str) -> Optional[int]:
        """Next round-robin position (from ``start``) with queued work."""
        n = len(self._order)
        for k in range(n):
            i = (start + k) % n
            if getattr(self._tenants[self._order[i]], lane):
                return i
        return None

    def _step_read(self) -> None:
        i = self._pick_tenant(self._rr_read, "read_q")
        if i is None:
            return
        self._rr_read = (i + 1) % len(self._order)
        t = self._tenants[self._order[i]]
        # one walk serves one include_probe mode; the head picks it and
        # collation skip-scans past the other mode (FIFO per uid holds)
        mode = t.read_q[0].include_probe
        taken = collate_fifo(
            t.read_q, self.cfg.probe_slots,
            size_fn=lambda r: r.num_rows,
            group_fn=lambda r: r.uid,
            take_if=lambda r: r.include_probe == mode)
        if not taken:
            return
        rows = sum(r.num_rows for r in taken)
        keys = np.concatenate([np.asarray(r.keys, np.uint32) for r in taken])
        valid = np.concatenate([r.valid for r in taken])
        bucket = self.ladder.bucket(rows)
        pad_k, pad_v = pad_probe_rows(keys, valid, bucket)
        shape = (bucket, pad_v.shape[1], mode)
        if shape not in self._seen_shapes:
            self._seen_shapes.add(shape)
            self.metrics.counter("bucket_compiles_total").inc()
        results = t.blocker.query_keys(pad_k, pad_v, include_probe=mode,
                                       n_real=rows)
        now = self._clock()
        self.metrics.counter("probe_batches_total").inc()
        self.metrics.counter("probe_rows_total").inc(rows)
        self.metrics.histogram("batch_occupancy", kind="unit").record(
            rows / bucket)
        self.metrics.histogram("probe_batch_rows", kind="count").record(rows)
        off = 0
        for r in taken:
            self.metrics.counter("probe_requests_total").inc()
            self.metrics.histogram("probe_latency_s").record(
                now - r.submitted_at)
            self.probe_responses.append(ProbeResponse(
                r.uid, t.name, STATUS_OK, results[off:off + r.num_rows],
                now - r.submitted_at))
            off += r.num_rows

    def _step_write(self) -> None:
        i = self._pick_tenant(self._rr_write, "write_q")
        if i is None:
            return
        self._rr_write = (i + 1) % len(self._order)
        t = self._tenants[self._order[i]]
        taken = collate_fifo(
            t.write_q, self.cfg.ingest_slots,
            size_fn=lambda r: r.num_rows,
            group_fn=lambda r: r.uid)
        if not taken:
            return
        keys = np.concatenate([np.asarray(r.keys, np.uint32) for r in taken])
        valid = np.concatenate([r.valid for r in taken])
        first_rid = t.store.num_records
        report = t.blocker.ingest_keys(keys, valid)
        now = self._clock()
        self.metrics.counter("ingest_batches_total").inc()
        self.metrics.counter("ingest_rows_total").inc(int(valid.shape[0]))
        off = 0
        for r in taken:
            self.metrics.counter("ingest_requests_total").inc()
            self.metrics.histogram("ingest_latency_s").record(
                now - r.submitted_at)
            self.ingest_responses.append(IngestResponse(
                r.uid, t.name, STATUS_OK, report, first_rid + off,
                r.num_rows, now - r.submitted_at))
            off += r.num_rows
