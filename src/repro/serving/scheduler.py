"""Shared slot-scheduler helpers for the serving front-ends.

Three engines run the same idiom — submissions queue host-side, ``step()``
drains fixed-budget micro-batches, ``run()`` loops while busy: the LM
``ServingEngine`` (serving/engine.py), the ``StreamingEngine``
(streaming/engine.py), and the ``DedupeService`` (serving/service.py).
This module keeps the two pieces they'd otherwise each reimplement:
FIFO collation under a slot budget, and the drain loop.

No jax/numpy here: these operate on host-side queue metadata only.
"""
from __future__ import annotations

from typing import Callable, List, Optional


def collate_fifo(queue: List, budget: int, size_fn: Callable,
                 group_fn: Optional[Callable] = None,
                 take_if: Optional[Callable] = None) -> List:
    """Remove and return queue entries up to ``budget`` total size.

    Skip-scan: an entry that does not fit the remaining budget (or fails
    ``take_if``) no longer blocks smaller entries queued behind it — the
    head-of-line fix over the old take-while-prefix collation. Ordering
    guarantees:

    - taken entries keep their queue order (never reordered);
    - per-group FIFO is preserved: once an entry of group ``group_fn(e)``
      is skipped, no later entry of that group is taken this call, so two
      submissions from one producer can't be answered out of order;
    - an OVERSIZED entry (alone it exceeds the budget) passes through
      alone once it reaches the first eligible position, so it cannot
      starve behind a stream of small entries.

    ``size_fn(entry) -> int`` gives each entry's slot cost; ``take_if``
    optionally gates eligibility (e.g. "same include_probe mode as the
    batch head"). Returns the taken entries; ``queue`` is mutated.
    """
    take_idx: List[int] = []
    total = 0
    skipped = set()
    for i, item in enumerate(queue):
        group = group_fn(item) if group_fn is not None else None
        eligible = (take_if is None or take_if(item)) and group not in skipped
        if eligible:
            size = size_fn(item)
            if not take_idx and size > budget:
                take_idx = [i]       # oversized head: pass through alone
                break
            if total + size <= budget:
                take_idx.append(i)
                total += size
                continue
        if group is not None:
            skipped.add(group)
    taken = [queue[i] for i in take_idx]
    for i in reversed(take_idx):
        del queue[i]
    return taken


def drain(engine, max_steps: int) -> int:
    """Step ``engine`` while it has queued work, up to ``max_steps``.

    Returns the number of steps taken. Callers decide what a truncated
    drain means — the engines warn when ``engine.busy`` is still true so
    a capped ``run()`` can't be mistaken for completion.
    """
    steps = 0
    while engine.busy and steps < max_steps:
        engine.step()
        steps += 1
    return steps
