"""Batched serving engine: continuous-batching decode over the model zoo.

A slot-based scheduler: a fixed batch of decode slots; finished sequences
free their slot, queued requests claim it (cache rows are reset per slot).
Everything device-side is fixed-shape: one jitted decode_step serves every
iteration — the scheduler only flips slot metadata host-side, which is
what production TPU serving stacks do to avoid recompiles.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model
from .scheduler import drain


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (L,) int32
    max_new_tokens: int = 32
    eos_id: int = 0


@dataclasses.dataclass
class Result:
    uid: int
    tokens: List[int]


class ServingEngine:
    def __init__(self, model: Model, params, batch_slots: int, max_len: int,
                 greedy: bool = True):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.greedy = greedy
        self.caches = model.init_caches(batch_slots, max_len)
        self.tokens = np.zeros((batch_slots, 1), np.int32)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_out: List[List[int]] = [[] for _ in range(batch_slots)]
        self.slot_remaining = np.zeros(batch_slots, np.int64)
        self.queue: List[Request] = []
        self.results: List[Result] = []
        self._step = jax.jit(model.decode_step)

    def submit(self, req: Request):
        self.queue.append(req)

    @property
    def busy(self) -> bool:
        return any(r is not None for r in self.slot_req) or bool(self.queue)

    def _admit(self):
        for slot in range(self.slots):
            if self.slot_req[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            self.slot_req[slot] = req
            self.slot_out[slot] = []
            self.slot_remaining[slot] = req.max_new_tokens
            # teacher-forced prefill of this slot: feed prompt tokens one at
            # a time through the shared decode step (slot-isolated caches
            # would need per-slot pos; we keep a shared pos => slots admit in
            # lockstep batches for simplicity at this scale)
            for t in req.prompt[:-1]:
                tok = np.zeros((self.slots, 1), np.int32)
                tok[slot, 0] = t
                _, self.caches = self._step(self.params, jnp.asarray(tok),
                                            self.caches, None)
            self.tokens[slot, 0] = req.prompt[-1]

    def step(self):
        """One decode iteration for every live slot."""
        self._admit()
        if not any(r is not None for r in self.slot_req):
            return
        logits, self.caches = self._step(self.params,
                                         jnp.asarray(self.tokens),
                                         self.caches, None)
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1)).astype(np.int32)
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            tok = int(nxt[slot])
            self.slot_out[slot].append(tok)
            self.slot_remaining[slot] -= 1
            self.tokens[slot, 0] = tok
            if tok == req.eos_id or self.slot_remaining[slot] <= 0:
                self.results.append(Result(req.uid, self.slot_out[slot]))
                self.slot_req[slot] = None

    def run(self, max_steps: int = 10_000) -> List[Result]:
        """Drain the queue; warn if ``max_steps`` truncates the drain."""
        drain(self, max_steps)
        if self.busy:
            live = sum(r is not None for r in self.slot_req)
            warnings.warn(
                f"ServingEngine.run stopped at max_steps={max_steps} with "
                f"{len(self.queue)} queued and {live} in-flight requests; "
                "call run() again to finish", RuntimeWarning, stacklevel=2)
        return self.results
