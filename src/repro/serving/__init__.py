"""Serving front-ends: the LM continuous-batching engine (``engine``) and
the dedupe probe service (``service``), built on the shared slot-scheduler
helpers (``scheduler``), the padded-bucket ladder (``buckets``), and the
metrics registry (``metrics``).

Re-exports are lazy so the two front-ends stay independent: importing the
``DedupeService`` does not pull in the model zoo, and importing the LM
``ServingEngine`` does not pull in the streaming subsystem (which itself
imports ``scheduler`` from this package — laziness also breaks that
cycle).
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    # dedupe probe service
    "DedupeService": "service",
    "ServiceConfig": "service",
    "Tenant": "service",
    "ProbeRequest": "service",
    "ProbeResponse": "service",
    "IngestRequest": "service",
    "IngestResponse": "service",
    "BackpressureError": "service",
    "STATUS_OK": "service",
    "STATUS_EXPIRED": "service",
    # shared pieces
    "Metrics": "metrics",
    "Counter": "metrics",
    "Histogram": "metrics",
    "BucketLadder": "buckets",
    "pad_probe_rows": "buckets",
    "collate_fifo": "scheduler",
    "drain": "scheduler",
    # LM engine
    "ServingEngine": "engine",
    "Request": "engine",
    "Result": "engine",
}


def __getattr__(name: str):
    module = _EXPORTS.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    value = getattr(importlib.import_module(f".{module}", __name__), name)
    globals()[name] = value   # cache: subsequent lookups skip __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
