"""Padded-bucket batching: a power-of-two shape ladder for probe batches.

The jitted classify/intersect walk specializes on array shapes, so feeding
it raw collated batch sizes would compile once per distinct size — an
unbounded cache under mixed traffic. Padding every batch up to the next
ladder rung bounds the compiled-variant count at O(log max_batch), and the
walk is row-local (every per-row decision in ``rough_classify`` /
``intersect_keys`` / the probe survivor dedupe depends only on that row),
so sentinel-key, all-invalid padding rows cannot change a real row's
result — the serving batching-invariance property test pins this
bit-for-bit against one-at-a-time queries.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

_SENT32 = np.uint32(0xFFFFFFFF)


@dataclasses.dataclass(frozen=True)
class BucketLadder:
    """Power-of-two batch-row buckets starting at ``min_bucket``."""

    min_bucket: int = 8

    def bucket(self, n: int) -> int:
        """Smallest rung >= max(n, min_bucket)."""
        p = max(int(self.min_bucket), 1)
        while p < n:
            p *= 2
        return p

    def rungs(self, max_rows: int) -> List[int]:
        """Every rung the ladder can emit for batches up to ``max_rows``."""
        out = [self.bucket(0)]
        while out[-1] < max_rows:
            out.append(out[-1] * 2)
        return out


def pad_probe_rows(keys: np.ndarray, valid: np.ndarray,
                   rows: int) -> Tuple[np.ndarray, np.ndarray]:
    """Pad a (q, K, 2)/(q, K) probe key matrix to ``rows`` rows.

    Padding rows are all-sentinel keys with ``valid=False`` — the same
    canonical dead-row encoding ``build_keys`` and the DeltaBlocker use —
    so they match nothing and survive nothing in the walk.
    """
    keys = np.asarray(keys, np.uint32)
    valid = np.asarray(valid, bool)
    q, k = valid.shape
    if rows < q:
        raise ValueError(f"bucket {rows} smaller than batch {q}")
    if rows == q:
        return keys, valid
    out_k = np.full((rows, k, 2), _SENT32, np.uint32)
    out_v = np.zeros((rows, k), bool)
    out_k[:q] = keys
    out_v[:q] = valid
    return out_k, out_v
