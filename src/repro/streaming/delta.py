"""DeltaBlocker: exact-incremental HDB iterations over a BlockStore.

Per micro-batch the blocker replays Algorithms 1-4 **only where the delta
can have changed a decision**, level by level:

1. fold the delta rows' (record, key) entries into the level's CMS
   (linear sketch: ``+`` in, ``-`` out — no rebuild) and mark the touched
   buckets,
2. re-estimate ONLY entries that hash into a touched bucket (cached
   bucket indices make this a gather, not a re-hash) and re-run the
   shared jitted ``hdb.rough_classify`` on them — the float32 progress
   heuristic must match the batch path bit-for-bit,
3. apply keep-bit flips to the key table (exact count ±1, fingerprint
   XOR — XOR is its own inverse, so retraction is exact),
4. re-run the shared jitted ``hdb.survivor_reps`` duplicate-block dedupe
   over the over-sized key-table slice,
5. refresh accept/survive bits for entries whose key's exact size or
   survivorship changed; rows whose surviving-key set (or its sizes)
   changed are *dirty* and get re-intersected through the shared jitted
   ``hdb.intersect_keys``; their next-level state replaces the cached one
   and the change cascades,
6. reconcile the accepted-assignment adds/retracts into the blocks CSR
   and candidate-pair ledger: only blocks whose membership changed are
   re-materialized through the ``kernels/pairs`` engine (delta x old ∪
   delta x delta), and largest-block-wins provenance is restored exactly
   by joining affected pairs against their endpoints' unaffected accepted
   keys.

The result after any ingest sequence is bit-identical to one batch
``hashed_dynamic_blocking`` run on the union (proven by the streaming
property tests), except when the batch path's fixed ``rep_capacity``
overflows — the store has no such cap.
"""
from __future__ import annotations

import dataclasses
import logging
import time
import warnings
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import hashing
from ..core import hdb as hdb_mod
from ..core import pairs as pairs_mod
from ..core import sketches
from ..core.hdb import RepCapacityWarning
from .store import (INT32_MAX, BlockStore, LevelState, gather_segments,
                    pack_key64, pack_pair, reduce_by_key, searchsorted_mask,
                    unpack_key64, unpack_pair)

logger = logging.getLogger(__name__)

_SENT32 = np.uint32(0xFFFFFFFF)

# the shared batch-iteration pieces, jitted once for streaming use
_rough_classify = jax.jit(hdb_mod.rough_classify, static_argnums=0)
_intersect_keys = jax.jit(hdb_mod.intersect_keys, static_argnums=0)


def _pow2(n: int, floor: int = 256) -> int:
    p = floor
    while p < n:
        p *= 2
    return p


def probe_jit_cache_sizes() -> dict:
    """Compiled-variant counts of the shared jitted probe-walk steps.

    The serving bench's recompile guard: after warmup the padded-bucket
    ladder must keep these counts constant across batch sizes. Returns -1
    per entry if the jax version doesn't expose ``_cache_size``.
    """
    out = {}
    for name, fn in (("rough_classify", _rough_classify),
                     ("intersect_keys", _intersect_keys)):
        size = getattr(fn, "_cache_size", None)
        out[name] = int(size()) if callable(size) else -1
    return out


@jax.jit
def _shared_max_src(ka_hi, ka_lo, sa, kb_hi, kb_lo, sb):
    """Max size over keys shared by the two padded key lists of a pair.

    Sentinel lanes carry size 0, so sentinel-sentinel matches contribute
    nothing. ``sb`` is accepted for symmetry (sizes agree on shared keys).
    """
    del sb
    eq = ((ka_hi[:, :, None] == kb_hi[:, None, :])
          & (ka_lo[:, :, None] == kb_lo[:, None, :]))
    return jnp.max(jnp.where(eq, sa[:, :, None], 0), axis=(1, 2))


@dataclasses.dataclass
class LevelReport:
    level: int
    n_replaced: int          # rows whose cached state was swapped
    n_reclassified: int      # entries re-run through rough_classify
    n_changed_keys: int      # key-table rows whose count/fp/survivor changed
    n_dirty_rows: int        # rows re-intersected


@dataclasses.dataclass
class IngestReport:
    """What one micro-batch did to the store."""

    num_records: int                    # records in this delta
    pairs_added: Tuple[np.ndarray, np.ndarray, np.ndarray]   # (a, b, src)
    pairs_retracted: Tuple[np.ndarray, np.ndarray]           # (a, b)
    levels: List[LevelReport]
    seconds: float

    @property
    def num_pairs_added(self) -> int:
        return len(self.pairs_added[0])


@dataclasses.dataclass
class QueryResult:
    candidates: np.ndarray   # (C,) distinct candidate rids, sorted
    n_blocks_hit: int        # accepted store blocks the probe matched
    levels_walked: int
    # sizes of the matched accepted blocks, sorted ascending; under
    # ``include_probe`` these count the probe itself (size + 1)
    block_sizes: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0,), np.int64))


class DeltaBlocker:
    """Runs the incremental iteration loop against one BlockStore.

    ``sort_backend`` threads into every pair-ledger sync's
    ``pairs.dedupe_pairs`` call (the "auto"/"comparator"/"radix" dedupe-
    sort knob of the pair engine); results are bit-identical across
    choices, only the sync's sort speed differs.

    ``store`` is duck-typed: a single-host ``BlockStore`` or a
    ``streaming.shard.ShardedBlockStore``. When the store carries a mesh
    (``store.mesh``/``store.axis_names``), every ledger sync's exact pair
    dedupe runs through ``core.distributed.dedupe_pairs_distributed`` —
    same fingerprint-routed shards as the store's ledger partition — and
    any lossless fallback to the single-device engine is re-warned (never
    silent) and counted in ``routed_fallback_total``.
    """

    def __init__(self, store: BlockStore, sort_backend: str = "auto"):
        self.store = store
        self.cfg = store.cfg
        self.sort_backend = sort_backend
        self.mesh = getattr(store, "mesh", None)
        self.mesh_axis_names = tuple(getattr(store, "axis_names", ("data",)))
        self.routed_fallback_total = 0

    # ------------------------------------------------------------------
    # ingest
    # ------------------------------------------------------------------

    def ingest_keys(self, keys_packed, valid) -> IngestReport:
        """Ingest a micro-batch given its top-level key matrix.

        Args:
          keys_packed: (n, K, 2) uint32 keys from ``blocks.build_keys`` on
            the delta records (K must match previous ingests).
          valid: (n, K) bool.
        Record ids ``store.num_records .. +n`` are assigned in order.
        """
        t0 = time.perf_counter()
        cfg = self.cfg
        keys = np.array(np.asarray(keys_packed), np.uint32, copy=True)
        valid = np.asarray(valid, bool)
        n = keys.shape[0]
        rids = np.arange(self.store.num_records, self.store.num_records + n,
                         dtype=np.int64)
        self.store.num_records += n
        keys[~valid] = _SENT32  # canonical sentinel padding, as in build_keys
        psize = np.full(valid.shape, INT32_MAX, np.int32)

        r = (rids, keys, valid, psize)
        dead = np.zeros((0,), np.int64)
        add_k: List[np.ndarray] = []
        add_r: List[np.ndarray] = []
        ret_k: List[np.ndarray] = []
        ret_r: List[np.ndarray] = []
        reports: List[LevelReport] = []
        for lev in range(cfg.max_iterations):
            if len(r[0]) == 0 and len(dead) == 0:
                break
            width = r[1].shape[1] if len(r[0]) else None
            if width == 0:
                break
            state = (self.store.level(lev, width) if width is not None
                     else self.store.levels[lev])
            if state is None:
                break
            r, dead, la_k, la_r, lr_k, lr_r, rep = self._process_level(
                lev, state, *r, dead)
            add_k.append(la_k)
            add_r.append(la_r)
            ret_k.append(lr_k)
            ret_r.append(lr_r)
            reports.append(rep)

        added, retracted = self._sync_pairs(
            np.concatenate(add_k) if add_k else np.zeros((0,), np.uint64),
            np.concatenate(add_r) if add_r else np.zeros((0,), np.int64),
            np.concatenate(ret_k) if ret_k else np.zeros((0,), np.uint64),
            np.concatenate(ret_r) if ret_r else np.zeros((0,), np.int64))
        # not a benchmark clock: every output above is already host numpy
        # (the ledger sync materializes), so the window is synchronous
        report = IngestReport(num_records=n, pairs_added=added,
                              pairs_retracted=retracted, levels=reports,
                              seconds=time.perf_counter() - t0)  # repro: noqa[R004]
        logger.debug("[streaming] ingest n=%d pairs+%d pairs-%d %.3fs", n,
                     len(added[0]), len(retracted[0]), report.seconds)
        return report

    # ------------------------------------------------------------------

    def _process_level(self, lev: int, state: LevelState, r_rids, r_keys,
                       r_valid, r_psize, dead_rids):
        """Replace ``r_*`` rows' level state (all-invalid row == removal),
        remove ``dead_rids`` rows, and propagate consequences level-wide.

        Returns (next_repl 4-tuple, next_dead, adds_k, adds_r, rets_k,
        rets_r, LevelReport).
        """
        cfg = self.cfg
        depth = cfg.cms_depth
        adds_k: List[np.ndarray] = []
        adds_r: List[np.ndarray] = []
        rets_k: List[np.ndarray] = []
        rets_r: List[np.ndarray] = []
        tab_dk: List[np.ndarray] = []
        tab_dc: List[np.ndarray] = []
        tab_df: List[np.ndarray] = []

        # ---- fold replacement rows into (removals, additions) ----
        k64_new = pack_key64(r_keys)
        any_valid = r_valid.any(axis=1)
        pos, exists = state.row_index(r_rids)
        noop = np.zeros(len(r_rids), bool)
        if np.any(exists):
            ex = np.flatnonzero(exists)
            rows = pos[ex]
            same = ((state.valid[rows] == r_valid[ex]).all(axis=1)
                    & (state.key64[rows] == k64_new[ex]).all(axis=1)
                    & (state.psize[rows] == r_psize[ex]).all(axis=1))
            noop[ex[same]] = True
        keepm = ~noop & (exists | any_valid)
        r_rids, r_keys, r_valid, r_psize, k64_new, any_valid = (
            r_rids[keepm], r_keys[keepm], r_valid[keepm], r_psize[keepm],
            k64_new[keepm], any_valid[keepm])
        pos, exists = state.row_index(r_rids)

        # dead rows: replacement rows going fully invalid join explicit deads
        dpos, dfound = state.row_index(dead_rids)
        dead_here = dead_rids[dfound]
        next_dead = [dead_here,
                     r_rids[exists & ~any_valid]]  # stale deeper state

        changed_b = np.zeros((depth, cfg.cms.width), bool)

        # ---- remove old versions (replaced + dead rows) ----
        rm_rows = np.concatenate([pos[exists], dpos[dfound]])
        n_replaced = len(rm_rows)
        if len(rm_rows):
            old_idx = state.idx[:, rm_rows]
            old_valid = state.valid[rm_rows]
            rm_e_idx = old_idx[:, old_valid]
            for j in range(depth):
                changed_b[j][rm_e_idx[j]] = True
            if rm_e_idx.shape[1]:
                state.cms_apply(state.key64[rm_rows][old_valid],
                                rm_e_idx, -1)
            old_keep = state.keep[rm_rows]
            if old_keep.any():
                orid = np.broadcast_to(state.rids[rm_rows][:, None],
                                       old_keep.shape)[old_keep]
                tab_dk.append(state.key64[rm_rows][old_keep])
                tab_dc.append(np.full(len(orid), -1, np.int64))
                tab_df.append(hashing.np_fingerprint_rid(orid))
            old_acc = state.accept[rm_rows]
            if old_acc.any():
                rets_k.append(state.key64[rm_rows][old_acc])
                rets_r.append(np.broadcast_to(
                    state.rids[rm_rows][:, None], old_acc.shape)[old_acc])
            state.drop_rows(rm_rows)

        # ---- add new versions (rows with at least one valid key) ----
        nv = np.flatnonzero(any_valid)
        if len(nv):
            idx = sketches.np_cms_indices(cfg.cms, k64_new[nv])
            v = r_valid[nv]
            for j in range(depth):
                changed_b[j][idx[j][v]] = True
            add_e_idx = idx[:, v]
            if add_e_idx.shape[1]:
                state.cms_apply(k64_new[nv][v], add_e_idx, 1)
            state.append_rows(r_rids[nv], r_keys[nv], k64_new[nv], v,
                              r_psize[nv], idx)

        # ---- re-estimate entries hashing into a touched bucket ----
        aff = np.zeros(state.valid.shape, bool)
        for j in range(depth):
            np.logical_or(aff, changed_b[j][state.idx[j]], out=aff)
        aff &= state.valid
        rpos, rfound = state.row_index(r_rids[nv] if len(nv) else r_rids[:0])
        live_repl_rows = rpos[rfound]
        if len(live_repl_rows):
            aff[live_repl_rows] |= state.valid[live_repl_rows]
        n_aff = int(aff.sum())
        if n_aff:
            cg = state.cms_lookup(state.idx[:, aff])
            est = cg[0]
            for j in range(1, depth):
                np.minimum(est, cg[j], out=est)
            p = _pow2(n_aff)
            est_p = np.zeros(p, np.int32)
            est_p[:n_aff] = est
            val_p = np.zeros(p, bool)
            val_p[:n_aff] = True
            psz_p = np.full(p, INT32_MAX, np.int32)
            psz_p[:n_aff] = state.psize[aff]
            right, keepb, _ = _rough_classify(
                cfg, jnp.asarray(est_p), jnp.asarray(val_p),
                jnp.asarray(psz_p))
            right = np.asarray(right)[:n_aff]
            keepb = np.asarray(keepb)[:n_aff]
            old_keep = state.keep[aff]
            erid = np.broadcast_to(
                state.rids[:, None], state.valid.shape)[aff]
            ekey = state.key64[aff]
            for sel, sign in ((keepb & ~old_keep, 1), (~keepb & old_keep, -1)):
                if sel.any():
                    tab_dk.append(ekey[sel])
                    tab_dc.append(np.full(int(sel.sum()), sign, np.int64))
                    tab_df.append(hashing.np_fingerprint_rid(erid[sel]))
            state.right[aff] = right
            state.keep[aff] = keepb

        # ---- key table update (exact counts + XOR fingerprints) ----
        changed_keys = np.zeros((0,), np.uint64)
        if tab_dk:
            dk, dc, df = reduce_by_key(np.concatenate(tab_dk),
                                       np.concatenate(tab_dc),
                                       np.concatenate(tab_df))
            nz = (dc != 0) | (df != 0)
            changed_keys = dk[nz]
            state.update_keytab(dk[nz], dc[nz], df[nz])

        # ---- duplicate-block dedupe over the over-sized table slice ----
        o_key, o_cnt, o_fp = state.oversized(cfg.max_block_size)
        n_over = len(o_key)
        surv_flags = np.zeros(n_over, bool)
        if n_over:
            p = _pow2(n_over, floor=64)
            xhi = np.full(p, _SENT32, np.uint32)
            xlo = np.full(p, _SENT32, np.uint32)
            sz = np.full(p, INT32_MAX, np.int32)
            khi = np.full(p, _SENT32, np.uint32)
            klo = np.full(p, _SENT32, np.uint32)
            fhi, flo = unpack_key64(o_fp)
            xhi[:n_over], xlo[:n_over] = fhi, flo
            sz[:n_over] = o_cnt.astype(np.int32)
            khi[:n_over], klo[:n_over] = unpack_key64(o_key)
            _, _, surv = hdb_mod.survivor_reps(
                jnp.asarray(xhi), jnp.asarray(xlo), jnp.asarray(sz),
                jnp.asarray(khi), jnp.asarray(klo))
            surv_flags = np.asarray(surv)[:n_over]
        # set_survivors runs even with no over-keys: stale flags from the
        # previous ingest must clear (on every shard of a sharded store)
        sv_changed = state.set_survivors(o_key, surv_flags)
        if len(sv_changed):
            changed_keys = np.union1d(changed_keys, sv_changed)

        # ---- refresh accept/survive where a decision input changed ----
        refresh = aff
        if len(changed_keys):
            _, touched = searchsorted_mask(changed_keys,
                                           state.key64.reshape(-1))
            refresh = refresh | (touched.reshape(state.key64.shape)
                                 & state.valid)
        dirty_rows = np.zeros(state.num_rows, bool)
        if refresh.any():
            ekey = state.key64[refresh]
            cnt, surv, _ = state.lookup(ekey)
            kb = state.keep[refresh]
            sz = np.where(kb, cnt, 0).astype(np.int32)
            new_accept = state.right[refresh] | (
                kb & (cnt <= cfg.max_block_size))
            new_survive = kb & (cnt > cfg.max_block_size) & surv
            old_accept = state.accept[refresh]
            old_survive = state.survive[refresh]
            old_size = state.size[refresh]
            erid = np.broadcast_to(
                state.rids[:, None], state.valid.shape)[refresh]
            on = new_accept & ~old_accept
            off = ~new_accept & old_accept
            if on.any():
                adds_k.append(ekey[on])
                adds_r.append(erid[on])
            if off.any():
                rets_k.append(ekey[off])
                rets_r.append(erid[off])
            state.accept[refresh] = new_accept
            state.survive[refresh] = new_survive
            state.size[refresh] = sz
            entry_dirty = ((new_survive != old_survive)
                           | (new_survive & (sz != old_size)))
            if entry_dirty.any():
                dirty_rows[np.nonzero(refresh)[0][entry_dirty]] = True
        dirty_rows[live_repl_rows] = True

        # ---- re-intersect dirty rows through the shared jitted step ----
        dirty = np.flatnonzero(dirty_rows)
        ko = min(cfg.max_oversize_keys, state.width)
        out_w = ko * (ko - 1) // 2
        if len(dirty) == 0 or out_w == 0:
            if out_w == 0:
                next_dead.append(state.rids[dirty])
            next_repl = (np.zeros((0,), np.int64),
                         np.zeros((0, max(out_w, 1), 2), np.uint32),
                         np.zeros((0, max(out_w, 1)), bool),
                         np.zeros((0, max(out_w, 1)), np.int32))
        else:
            d = len(dirty)
            p = _pow2(d, floor=64)

            def pad_rows(x, fill):
                out = np.full((p,) + x.shape[1:], fill, x.dtype)
                out[:d] = x
                return out

            khi = pad_rows(state.keys[dirty][:, :, 0], _SENT32)
            klo = pad_rows(state.keys[dirty][:, :, 1], _SENT32)
            sv = pad_rows(state.survive[dirty], False)
            sz = pad_rows(state.size[dirty], 0)
            (nkhi, nklo), nvalid, npsize, _ = _intersect_keys(
                cfg, (jnp.asarray(khi), jnp.asarray(klo)),
                jnp.asarray(sv), jnp.asarray(sz))
            nkeys = np.stack([np.asarray(nkhi)[:d], np.asarray(nklo)[:d]],
                             axis=-1)
            next_repl = (state.rids[dirty], nkeys,
                         np.asarray(nvalid)[:d], np.asarray(npsize)[:d])

        rep = LevelReport(level=lev, n_replaced=n_replaced,
                          n_reclassified=n_aff,
                          n_changed_keys=len(changed_keys),
                          n_dirty_rows=len(dirty))

        def cat(parts, dtype):
            return (np.concatenate(parts) if parts
                    else np.zeros((0,), dtype))

        return (next_repl, np.concatenate(next_dead),
                cat(adds_k, np.uint64), cat(adds_r, np.int64),
                cat(rets_k, np.uint64), cat(rets_r, np.int64), rep)

    # ------------------------------------------------------------------
    # pair reconciliation
    # ------------------------------------------------------------------

    @staticmethod
    def _cancel_common(add_k, add_r, ret_k, ret_r):
        """Drop (key, rid) assignments present in both lists (a replaced
        row re-accepting the same key is a net no-op)."""
        if len(add_k) == 0 or len(ret_k) == 0:
            return add_k, add_r, ret_k, ret_r
        allk = np.concatenate([add_k, ret_k])
        allr = np.concatenate([add_r, ret_r])
        src = np.concatenate([np.zeros(len(add_k), np.int8),
                              np.ones(len(ret_k), np.int8)])
        order = np.lexsort((src, allr, allk))
        allk, allr, src = allk[order], allr[order], src[order]
        match = np.zeros(len(allk), bool)
        nxt = ((allk[1:] == allk[:-1]) & (allr[1:] == allr[:-1])
               & (src[1:] != src[:-1]))
        match[:-1] |= nxt
        match[1:] |= nxt
        keep = ~match
        is_add = src == 0
        return (allk[keep & is_add], allr[keep & is_add],
                allk[keep & ~is_add], allr[keep & ~is_add])

    @staticmethod
    def _nontrivial(blk: pairs_mod.Blocks) -> pairs_mod.Blocks:
        """Restrict a CSR slice to blocks that can produce pairs."""
        keep = blk.size >= 2
        members = gather_segments(blk.start[keep], blk.size[keep],
                                  blk.members)
        return pairs_mod.Blocks(
            blk.key_hi[keep], blk.key_lo[keep],
            np.concatenate([[0], np.cumsum(blk.size[keep])])[:-1]
            .astype(np.int64),
            blk.size[keep], members)

    def _dedupe_blocks(self, blk: pairs_mod.Blocks,
                       budget: int) -> pairs_mod.PairSet:
        """One exact pair dedupe, routed over the store's mesh if any.

        ``dedupe_pairs_distributed`` already guarantees lossless output
        (it falls back to the single-device engine on capacity overflow
        or when the routed contract is unavailable); this wrapper makes
        every such fallback loud — re-warned with streaming context and
        counted in ``routed_fallback_total`` for the metrics snapshot.
        """
        if self.mesh is not None:
            from ..core import distributed as dist_mod
            with warnings.catch_warnings(record=True) as caught:
                warnings.simplefilter("always")
                ps = dist_mod.dedupe_pairs_distributed(
                    blk, self.mesh, self.mesh_axis_names, budget=budget,
                    sort_backend=self.sort_backend)
            for w in caught:
                if issubclass(w.category, (RepCapacityWarning,
                                           RuntimeWarning)):
                    self.routed_fallback_total += 1
                    warnings.warn(
                        "[streaming] routed ledger sync fell back to the "
                        f"single-device pair engine: {w.message}",
                        w.category, stacklevel=3)
                else:
                    warnings.warn_explicit(w.message, w.category,
                                           w.filename, w.lineno)
            return ps
        return pairs_mod.dedupe_pairs(blk, budget=budget, backend="auto",
                                      sort_backend=self.sort_backend)

    def _sync_pairs(self, add_k, add_r, ret_k, ret_r):
        """Apply assignment deltas; return ((a, b, src) added, (a, b)
        retracted) ledger changes, keeping the ledger equal to an exact
        batch ``dedupe_pairs`` of the current accepted blocks.

        A pair's ledger entry can only need *downward* revision (smaller
        src, or retraction) if it had a source among the *shrink* keys —
        keys that lost a member this ingest. Every other affected pair's
        sources monotonically grew, so ``max(current, new affected src)``
        is exact without re-deriving its unaffected coverage. The
        expensive join therefore runs only over the shrink keys' old
        pairs; pure-growth ingests never pay it.
        """
        empty = ((np.zeros((0,), np.int64),) * 3,
                 (np.zeros((0,), np.int64),) * 2)
        add_k, add_r, ret_k, ret_r = self._cancel_common(
            add_k, add_r, ret_k, ret_r)
        if len(add_k) == 0 and len(ret_k) == 0:
            return empty
        shrink = np.unique(ret_k)
        affected, shrink_old_csr, new_csr = self.store.apply_assignment_deltas(
            add_k, add_r, ret_k, ret_r, snapshot_keys=shrink)

        def pair_set(csr):
            blk = self._nontrivial(csr)
            if blk.num_blocks == 0:
                return (np.zeros((0,), np.uint64), np.zeros((0,), np.int64))
            ps = self._dedupe_blocks(blk, blk.num_pair_slots + 1)
            return pack_pair(ps.a, ps.b), ps.src_size

        join_pack, _ = pair_set(shrink_old_csr)   # may have LOST a source
        new_pack, new_src = pair_set(new_csr)     # all affected, post-splice
        # growth branch: sources only grew -> max with the current entry
        _, in_join = searchsorted_mask(join_pack, new_pack)
        grow_pack = new_pack[~in_join]
        grow_aff = new_src[~in_join]
        cur, lfound = self.store.ledger_src(grow_pack)
        grow_src = np.maximum(cur, grow_aff)
        touch = ~lfound | (grow_src != cur)       # skip no-op upserts
        # join branch: full recompute (affected part + unaffected coverage)
        if len(join_pack):
            aff_src = np.zeros(len(join_pack), np.int64)
            if len(new_pack):
                jpos, jhit = searchsorted_mask(new_pack, join_pack)
                aff_src[jhit] = new_src[np.minimum(
                    jpos, len(new_pack) - 1)][jhit]
            unaff = self._unaffected_src(join_pack, affected)
            join_src = np.maximum(aff_src, unaff)
        else:
            join_src = np.zeros((0,), np.int64)
        pairs_all = np.concatenate([grow_pack[touch], join_pack])
        src_all = np.concatenate([grow_src[touch], join_src])
        if len(pairs_all) == 0:
            return empty
        added_pack, added_src, retr_pack = self.store.apply_pair_deltas(
            pairs_all, src_all)
        aa, ab = unpack_pair(added_pack)
        ra, rb = unpack_pair(retr_pack)
        return (aa, ab, added_src), (ra, rb)

    def _unaffected_src(self, pair_pack: np.ndarray,
                        affected: np.ndarray) -> np.ndarray:
        """Per pair: largest accepted block containing both endpoints whose
        key is NOT in ``affected`` (0 if none). Exact join through the
        cached per-level accept bits."""
        store = self.store
        a, b = unpack_pair(pair_pack)
        recs = np.unique(np.concatenate([a, b]))
        ks: List[np.ndarray] = []
        rs: List[np.ndarray] = []
        for state in store.levels:
            if state is None or state.num_rows == 0:
                continue
            rpos, rfound = state.row_index(recs)
            rows = rpos[rfound]
            if len(rows) == 0:
                continue
            acc = state.accept[rows]
            if not acc.any():
                continue
            ks.append(state.key64[rows][acc])
            rs.append(np.broadcast_to(
                state.rids[rows][:, None], acc.shape)[acc])
        if not ks:
            return np.zeros(len(pair_pack), np.int64)
        key = np.concatenate(ks)
        rid = np.concatenate(rs)
        _, isaff = searchsorted_mask(affected, key)
        key, rid = key[~isaff], rid[~isaff]
        if len(key) == 0:
            return np.zeros(len(pair_pack), np.int64)
        size = store.block_size_of(key)
        # dense padded (record -> key list) matrix
        uidx = np.searchsorted(recs, rid)
        counts = np.bincount(uidx, minlength=len(recs))
        kmax = _pow2(int(counts.max()), floor=4)
        order = np.argsort(uidx, kind="stable")
        u_s, k_s, s_s = uidx[order], key[order], size[order]
        starts = np.concatenate([[0], np.cumsum(counts)])[:-1]
        col = np.arange(len(u_s)) - starts[u_s]
        kmat = np.full((len(recs), kmax), np.uint64(0xFFFFFFFFFFFFFFFF))
        smat = np.zeros((len(recs), kmax), np.int32)
        kmat[u_s, col] = k_s
        smat[u_s, col] = s_s
        # sentinel lanes decode on purpose: they carry smat == 0, so
        # they can never win the shared-max below
        khi, klo = unpack_key64(kmat)  # repro: noqa[R007]
        ra = np.searchsorted(recs, a)
        rb = np.searchsorted(recs, b)
        n_p = len(pair_pack)
        chunk = 8192
        pad = (-n_p) % chunk
        if pad:  # fixed chunk shape -> one compiled kernel per kmax
            ra = np.concatenate([ra, np.zeros(pad, ra.dtype)])
            rb = np.concatenate([rb, np.zeros(pad, rb.dtype)])
        out = np.zeros(n_p + pad, np.int64)
        for off in range(0, n_p + pad, chunk):
            sl = slice(off, off + chunk)
            got = _shared_max_src(
                jnp.asarray(khi[ra[sl]]), jnp.asarray(klo[ra[sl]]),
                jnp.asarray(smat[ra[sl]]),
                jnp.asarray(khi[rb[sl]]), jnp.asarray(klo[rb[sl]]),
                jnp.asarray(smat[rb[sl]]))
            out[sl] = np.asarray(got).astype(np.int64)
        return out[:n_p]

    # ------------------------------------------------------------------
    # query
    # ------------------------------------------------------------------

    @staticmethod
    def _probe_self_survivors(k64, valid, cnt_adj, fp, max_block_size):
        """Survivor mask of each probe row's post-probe over-sized keys.

        With the probe counted in, a held block's membership fingerprint
        becomes ``fp ^ probe_fp`` and its size ``cnt + 1`` — a uniform
        shift, so the only duplicate groups that remain among one row's
        held keys are those sharing the ORIGINAL store (fp, cnt) (an
        adjusted block colliding with an unrelated store block would need
        a 64-bit fingerprint coincidence, the same odds the batch path
        accepts). The smallest key of each group survives, mirroring
        ``hdb.dedupe_oversized_reps``.
        """
        surv = np.zeros(valid.shape, bool)
        q, k = valid.shape
        flat = np.flatnonzero(((cnt_adj > max_block_size) & valid).reshape(-1))
        if len(flat) == 0:
            return surv
        row = flat // k
        fpv = fp.reshape(-1)[flat]
        cntv = cnt_adj.reshape(-1)[flat]
        keyv = k64.reshape(-1)[flat]
        order = np.lexsort((keyv, cntv, fpv, row))
        r_s, f_s, c_s = row[order], fpv[order], cntv[order]
        first = np.concatenate([[True], (r_s[1:] != r_s[:-1])
                                | (f_s[1:] != f_s[:-1])
                                | (c_s[1:] != c_s[:-1])])
        surv.reshape(-1)[flat[order[first]]] = True
        return surv

    def query_keys(self, keys_packed, valid,
                   include_probe: bool = False,
                   n_real: Optional[int] = None) -> List[QueryResult]:
        """Candidate ids per probe record (serving-style, read-only).

        Walks the store's levels with the probe's key matrix: accepted
        probe keys contribute the matching stored block's members; keys
        landing on surviving over-sized blocks are pairwise-intersected
        (same jitted ``intersect_keys``) and the walk descends. A query
        never mutates the store.

        ``n_real`` is the serving batcher's padding contract: only the
        first ``n_real`` rows get a ``QueryResult`` (the rest are padding
        the caller added to hit a bucket shape). Every per-row decision in
        the walk is row-local and ``levels_walked`` is counted per row, so
        a row's result is bit-identical no matter what rows it is batched
        or padded with.

        ``include_probe=False`` keeps the historical behavior: the
        probe's own (absent) +1 on matched block sizes is NOT simulated.
        ``include_probe=True`` replays the walk as if the probe had been
        ingested (each probe independently): CMS estimates gain the
        probe's exact per-bucket self-contribution, exact counts gain +1
        on held keys, over-sized duplicate-block survivorship is
        re-derived for the post-probe fingerprints, and the descent's
        ``psize`` carries the adjusted sizes — so the decisions (and the
        ``block_sizes`` stats) match what ingesting the probe would
        decide for it, as long as the probe does not tip an UNRELATED
        store block across ``max_block_size`` (that cascade re-blocks
        other records' state, which a read-only walk cannot see; the
        streaming oracle test pins the non-tipping case exactly).
        """
        cfg = self.cfg
        keys = np.array(np.asarray(keys_packed), np.uint32, copy=True)
        valid = np.asarray(valid, bool)
        q = keys.shape[0]
        keys[~valid] = _SENT32
        psize = np.full(valid.shape, INT32_MAX, np.int32)
        cand_probe: List[np.ndarray] = []
        cand_rid: List[np.ndarray] = []
        size_probe: List[np.ndarray] = []
        size_val: List[np.ndarray] = []
        hits = np.zeros(q, np.int64)
        # per-row: a row stops walking when ITS keys die, independent of
        # batch mates — required for batching invariance of the stat
        levels_walked = np.zeros(q, np.int64)
        for lev in range(cfg.max_iterations):
            state = self.store.levels[lev]
            if state is None or state.num_rows == 0 or keys.shape[1] == 0:
                break
            if not valid.any():
                break
            levels_walked += valid.any(axis=1)
            k64 = pack_key64(keys)
            idx = sketches.np_cms_indices(cfg.cms, k64)
            cnts = state.cms_lookup(idx)
            est = None
            for j in range(cfg.cms_depth):
                e = cnts[j].astype(np.int64)
                if include_probe:
                    # the probe's own fold-in: +1 per probe entry landing
                    # in the bucket (exact, incl. self-collisions)
                    same = ((idx[j][:, :, None] == idx[j][:, None, :])
                            & valid[:, None, :])
                    e = e + same.sum(axis=2)
                est = e if est is None else np.minimum(est, e)
            est = est.astype(np.int32)
            p = _pow2(q * keys.shape[1], floor=64)
            est_p = np.zeros(p, np.int32)
            val_p = np.zeros(p, bool)
            psz_p = np.full(p, INT32_MAX, np.int32)
            m = q * keys.shape[1]
            est_p[:m] = est.reshape(-1)
            val_p[:m] = valid.reshape(-1)
            psz_p[:m] = psize.reshape(-1)
            right, keepb, _ = _rough_classify(
                cfg, jnp.asarray(est_p), jnp.asarray(val_p),
                jnp.asarray(psz_p))
            right = np.asarray(right)[:m].reshape(valid.shape)
            keepb = np.asarray(keepb)[:m].reshape(valid.shape)
            cnt, surv, _ = state.lookup(k64)
            if include_probe:
                cnt = cnt + valid.astype(cnt.dtype)
                surv = self._probe_self_survivors(
                    k64, valid, cnt, state.lookup_fp(k64),
                    cfg.max_block_size)
            accept = right | (keepb & (cnt <= cfg.max_block_size))
            survive = keepb & (cnt > cfg.max_block_size) & surv
            size = np.where(keepb, cnt, 0).astype(np.int32)
            # collect members (and sizes) of matching accepted blocks; the
            # stat size comes from the accepted-blocks CSR (the key table
            # never sees CMS-accepted keys), +1 when the probe counts
            hit_keys = k64[accept]
            if len(hit_keys):
                probe_of = np.broadcast_to(
                    np.arange(q)[:, None], accept.shape)[accept]
                members = self.store.members_of(hit_keys)
                for pi, mem in zip(probe_of, members):
                    if len(mem):
                        hits[pi] += 1
                        cand_probe.append(np.full(len(mem), pi))
                        cand_rid.append(mem)
                        size_probe.append(np.asarray([pi]))
                        size_val.append(np.asarray(
                            [len(mem) + int(include_probe)], np.int64))
            if not survive.any():
                break
            ko = min(cfg.max_oversize_keys, keys.shape[1])
            if ko < 2:
                break
            p = _pow2(q, floor=64)

            def pad_rows(x, fill):
                out = np.full((p,) + x.shape[1:], fill, x.dtype)
                out[:q] = x
                return out

            (nkhi, nklo), nvalid, npsize, _ = _intersect_keys(
                cfg, (jnp.asarray(pad_rows(keys[:, :, 0], _SENT32)),
                      jnp.asarray(pad_rows(keys[:, :, 1], _SENT32))),
                jnp.asarray(pad_rows(survive, False)),
                jnp.asarray(pad_rows(size, 0)))
            keys = np.stack([np.asarray(nkhi)[:q], np.asarray(nklo)[:q]],
                            axis=-1)
            valid = np.asarray(nvalid)[:q]
            psize = np.asarray(npsize)[:q]
        if cand_probe:
            cp = np.concatenate(cand_probe)
            cr = np.concatenate(cand_rid)
            sp = np.concatenate(size_probe)
            sv = np.concatenate(size_val)
        else:
            cp = np.zeros((0,), np.int64)
            cr = np.zeros((0,), np.int64)
            sp = np.zeros((0,), np.int64)
            sv = np.zeros((0,), np.int64)
        out = []
        for pi in range(q if n_real is None else min(n_real, q)):
            out.append(QueryResult(
                candidates=np.unique(cr[cp == pi]),
                n_blocks_hit=int(hits[pi]),
                levels_walked=int(levels_walked[pi]),
                block_sizes=np.sort(sv[sp == pi])))
        return out
