"""BlockStore: the persistent device-shaped blocking state between calls.

One store holds, for every HDB iteration level ``i``:

- the per-record iteration state exactly as the batch driver would hold it
  at iteration ``i`` on the union of everything ingested so far: dense
  ``(R_i, W_i)`` key/valid/psize arrays restricted to live rows, plus the
  cached decision bits (right/keep/accept/survive) and per-entry exact
  sizes from the last ingest,
- the level's **key space** (``LevelKeys``): the Count-Min Sketch over
  its live (record, key) entries, kept current by *linear fold-in/
  fold-out* (``sketches.cms_fold`` / ``cms_subtract``), and the key table
  (sorted u64 keys -> exact keep-entry count, XOR membership fingerprint,
  survivor flag) — the incremental mirror of Algorithm 4's sort-based
  exact counting,

and globally:

- the accepted-blocks CSR (``BlockCsr``: sorted block keys -> member rid
  runs), i.e. ``pairs.build_blocks`` of the union's accepted assignments,
  maintained by splicing only blocks whose membership changed,
- the candidate-pair ledger (``PairLedger``: packed ``a << 32 | b`` u64
  keys -> size of the largest source block), i.e. ``pairs.dedupe_pairs``
  of the CSR, maintained from per-ingest pair deltas.

The key space, CSR, and ledger are *interfaces* as well as containers:
``DeltaBlocker`` only talks to them through ``LevelState`` delegation and
the ``BlockStore`` surface (``update_keytab`` / ``lookup`` / ``oversized``
/ ``block_size_of`` / ``ledger_src`` / ...), never raw arrays. That seam
is what lets ``streaming.shard.ShardedBlockStore`` swap in
fingerprint-partitioned slices (one ``LevelKeys``/``BlockCsr``/
``PairLedger`` per shard, routed by ``splitmix64(key) % n_shards``)
without the delta algorithm changing — see ``streaming/shard.py``.

All arrays are host numpy; the delta blocker stages fixed-shape slices
through the same jitted functions the batch path uses. See
``streaming/__init__`` for the memory-layout overview and `delta.py` for
the update algorithm.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import hdb as hdb_mod
from ..core import pairs as pairs_mod
from ..core import sketches

INT32_MAX = np.iinfo(np.int32).max


def pack_key64(keys: np.ndarray) -> np.ndarray:
    """(..., 2) uint32 storage keys -> uint64."""
    k = np.asarray(keys, np.uint32)
    return (k[..., 0].astype(np.uint64) << np.uint64(32)) | k[..., 1]


def unpack_key64(key64: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    key64 = np.asarray(key64, np.uint64)
    return ((key64 >> np.uint64(32)).astype(np.uint32),
            (key64 & np.uint64(0xFFFFFFFF)).astype(np.uint32))


def pack_pair(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Canonical (a < b) rid pair -> sortable uint64."""
    return (np.asarray(a, np.uint64) << np.uint64(32)) | np.asarray(b, np.uint64)


def unpack_pair(p: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    p = np.asarray(p, np.uint64)
    return ((p >> np.uint64(32)).astype(np.int64),
            (p & np.uint64(0xFFFFFFFF)).astype(np.int64))


def gather_segments(starts: np.ndarray, sizes: np.ndarray,
                    pool: np.ndarray) -> np.ndarray:
    """Concatenate ``pool[start : start + size]`` runs (vectorized)."""
    total = int(sizes.sum())
    offs = (np.arange(total, dtype=np.int64)
            - np.repeat(np.cumsum(sizes) - sizes, sizes))
    return pool[np.repeat(starts, sizes) + offs]


def blocks_from_segments(key64: np.ndarray, sizes: np.ndarray,
                         members: np.ndarray) -> pairs_mod.Blocks:
    """Compact (key, size, concatenated members) runs into a Blocks CSR."""
    hi, lo = unpack_key64(key64)
    start = np.concatenate([[0], np.cumsum(sizes)])[:-1].astype(np.int64)
    return pairs_mod.Blocks(hi, lo, start, sizes.astype(np.int64),
                            members.astype(np.int64))


def merge_blocks(parts: Sequence[pairs_mod.Blocks]) -> pairs_mod.Blocks:
    """Merge per-shard CSR slices (disjoint keys) into one key-sorted CSR.

    The sharded store's output contract: every merged view must be
    bit-identical to the single-host store's, so the concatenated parts
    are re-sorted by packed key (keys are disjoint across shards — the
    partition function guarantees it — so the order is total).
    """
    parts = [b for b in parts if b.num_blocks]
    if not parts:
        z64 = np.zeros((0,), np.uint64)
        return blocks_from_segments(z64, np.zeros((0,), np.int64),
                                    np.zeros((0,), np.int64))
    key64 = np.concatenate([
        (b.key_hi.astype(np.uint64) << np.uint64(32))
        | b.key_lo.astype(np.uint64) for b in parts])
    sizes = np.concatenate([b.size for b in parts]).astype(np.int64)
    offs = np.cumsum([0] + [len(b.members) for b in parts])[:-1]
    starts = np.concatenate([b.start + off
                             for b, off in zip(parts, offs)]).astype(np.int64)
    pool = np.concatenate([b.members for b in parts])
    order = np.argsort(key64)
    members = gather_segments(starts[order], sizes[order], pool)
    return blocks_from_segments(key64[order], sizes[order], members)


def searchsorted_mask(sorted_arr: np.ndarray, queries: np.ndarray
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """(positions, found_mask) of ``queries`` in a sorted array."""
    pos = np.searchsorted(sorted_arr, queries)
    safe = np.minimum(pos, max(len(sorted_arr) - 1, 0))
    found = ((pos < len(sorted_arr)) & (sorted_arr[safe] == queries)
             if len(sorted_arr) else np.zeros(len(queries), bool))
    return pos, found


def set_subtract_pairs(cand_k: np.ndarray, cand_r: np.ndarray,
                       ret_k: np.ndarray, ret_r: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Sorted set difference on (key64, rid) pairs.

    ``cand`` holds distinct pairs; every ``ret`` pair occurs in ``cand``.
    Returns the surviving pairs sorted by (key, rid). Vectorized via one
    stable lexsort with a source flag: each retract lands immediately
    after its matching candidate and deletes it.
    """
    if len(ret_k) == 0:
        order = np.lexsort((cand_r, cand_k))
        return cand_k[order], cand_r[order]
    allk = np.concatenate([cand_k, ret_k])
    allr = np.concatenate([cand_r, ret_r])
    src = np.concatenate([np.zeros(len(cand_k), np.int8),
                          np.ones(len(ret_k), np.int8)])
    order = np.lexsort((src, allr, allk))
    allk, allr, src = allk[order], allr[order], src[order]
    dead = np.zeros(len(allk), bool)
    ret_pos = np.flatnonzero(src == 1)
    dead[ret_pos - 1] = True  # the matching candidate right before each ret
    keep = (src == 0) & ~dead
    return allk[keep], allr[keep]


def reduce_by_key(keys: np.ndarray, cnt: np.ndarray, fp: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Aggregate (count sum, fingerprint XOR) per distinct key."""
    order = np.argsort(keys, kind="stable")
    keys, cnt, fp = keys[order], cnt[order], fp[order]
    starts = np.flatnonzero(np.concatenate([[True], keys[1:] != keys[:-1]]))
    uk = keys[starts]
    ucnt = np.add.reduceat(cnt, starts)
    ufp = np.bitwise_xor.reduceat(fp, starts)
    return uk, ucnt, ufp


@dataclasses.dataclass
class LevelKeys:
    """One key-space slice at one level: CMS + exact key table.

    This is the unit of sharding: the single-host store has exactly one
    per level; ``ShardedLevelKeys`` composes N of them (each owning the
    keys whose fingerprint routes to its shard) behind the same method
    surface. All methods take/return host numpy.
    """

    cms: np.ndarray       # (depth, width) int32
    tab_key: np.ndarray   # (K,) uint64, sorted
    tab_cnt: np.ndarray   # (K,) int64
    tab_fp: np.ndarray    # (K,) uint64
    tab_surv: np.ndarray  # (K,) bool

    @staticmethod
    def empty(cms_cfg: sketches.CMSConfig) -> "LevelKeys":
        return LevelKeys(
            cms=np.zeros((cms_cfg.depth, cms_cfg.width), np.int32),
            tab_key=np.zeros((0,), np.uint64),
            tab_cnt=np.zeros((0,), np.int64),
            tab_fp=np.zeros((0,), np.uint64),
            tab_surv=np.zeros((0,), bool),
        )

    # ---- CMS (linear sketch: fold-in/out = elementwise +/-) ----

    def cms_apply(self, key64: np.ndarray, idx: np.ndarray,
                  sign: int) -> None:
        """Fold entry occurrences in (+1) or out (-1) of the sketch.

        ``key64`` is the entries' packed keys (unused here; the sharded
        key space routes on it) and ``idx`` their (depth, M) cached
        bucket indices.
        """
        del key64
        for j in range(len(self.cms)):
            np.add.at(self.cms[j], idx[j], sign)

    def cms_lookup(self, idx: np.ndarray) -> np.ndarray:
        """Gather per-depth bucket counts: (depth, *entry_shape) int32."""
        return np.stack([self.cms[j][idx[j]] for j in range(len(self.cms))])

    # ---- exact key table ----

    def update_keytab(self, d_key: np.ndarray, d_cnt: np.ndarray,
                      d_fp: np.ndarray) -> np.ndarray:
        """Apply aggregated (count, fingerprint) deltas; returns the keys
        whose table row changed (including inserts and deletions).

        ``d_key`` must be sorted unique (``reduce_by_key`` output order) —
        ``np.insert`` relies on it to keep the table sorted.
        """
        if len(d_key) == 0:
            return d_key
        pos, found = searchsorted_mask(self.tab_key, d_key)
        # in-place update of existing rows
        upd = np.flatnonzero(found)
        if len(upd):
            rows = pos[upd]
            self.tab_cnt[rows] += d_cnt[upd]
            self.tab_fp[rows] ^= d_fp[upd]
        # insert new rows
        new = np.flatnonzero(~found)
        if len(new):
            at = pos[new]
            self.tab_key = np.insert(self.tab_key, at, d_key[new])
            self.tab_cnt = np.insert(self.tab_cnt, at, d_cnt[new])
            self.tab_fp = np.insert(self.tab_fp, at, d_fp[new])
            self.tab_surv = np.insert(self.tab_surv, at, False)
        # drop zero-count rows (all their entries un-kept)
        dead = self.tab_cnt == 0
        if dead.any():
            self.tab_key = self.tab_key[~dead]
            self.tab_cnt = self.tab_cnt[~dead]
            self.tab_fp = self.tab_fp[~dead]
            self.tab_surv = self.tab_surv[~dead]
        return d_key

    def lookup(self, key64: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(count, survivor flag, found) per query key (count 0 if absent)."""
        if len(self.tab_key) == 0:
            return (np.zeros(key64.shape, np.int64),
                    np.zeros(key64.shape, bool),
                    np.zeros(key64.shape, bool))
        pos, found = searchsorted_mask(self.tab_key, key64.reshape(-1))
        safe = np.minimum(pos, len(self.tab_key) - 1)
        cnt = np.where(found, self.tab_cnt[safe], 0)
        surv = np.where(found, self.tab_surv[safe], False)
        return (cnt.reshape(key64.shape).astype(np.int64),
                surv.reshape(key64.shape),
                found.reshape(key64.shape))

    def lookup_fp(self, key64: np.ndarray) -> np.ndarray:
        """Membership XOR-fingerprint per query key (0 if absent)."""
        if len(self.tab_key) == 0:
            return np.zeros(key64.shape, np.uint64)
        pos, found = searchsorted_mask(self.tab_key, key64.reshape(-1))
        safe = np.minimum(pos, len(self.tab_key) - 1)
        return np.where(found, self.tab_fp[safe],
                        np.uint64(0)).reshape(key64.shape)

    def oversized(self, max_block_size: int
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(key, count, fingerprint) of over-sized table rows, key-sorted.

        Key order is part of the bit-identity contract: the duplicate-
        block survivor pass feeds these to ``hdb.survivor_reps`` and must
        see the same order regardless of how the key space is sharded.
        """
        over = self.tab_cnt > max_block_size
        return self.tab_key[over], self.tab_cnt[over], self.tab_fp[over]

    def set_survivors(self, over_key: np.ndarray,
                      surv: np.ndarray) -> np.ndarray:
        """Replace ALL survivor flags (rows not in ``over_key`` clear);
        returns the keys whose flag flipped."""
        new_surv = np.zeros(len(self.tab_key), bool)
        if len(over_key):
            pos, found = searchsorted_mask(self.tab_key, over_key)
            new_surv[pos[found]] = surv[found]
        changed = new_surv != self.tab_surv
        self.tab_surv = new_surv
        return self.tab_key[changed]

    @property
    def num_keys(self) -> int:
        return len(self.tab_key)

    @property
    def keytab_bytes(self) -> int:
        return (self.tab_key.nbytes + self.tab_cnt.nbytes
                + self.tab_fp.nbytes + self.tab_surv.nbytes)

    @property
    def cms_bytes(self) -> int:
        return self.cms.nbytes


class BlockCsr:
    """Accepted-blocks CSR: sorted block keys -> member-rid runs.

    == ``pairs.build_blocks(min_size=1)`` of the union's accepted
    assignments, spliced per ingest only where membership changed. One
    per store — or one per shard, holding the keys that shard owns.
    """

    def __init__(self):
        self.key = np.zeros((0,), np.uint64)
        self.start = np.zeros((0,), np.int64)
        self.size = np.zeros((0,), np.int64)
        self.members = np.zeros((0,), np.int64)

    def members_of(self, key64: np.ndarray) -> List[np.ndarray]:
        """Member rid arrays per query block key (empty when absent)."""
        out = []
        pos, found = searchsorted_mask(self.key, np.asarray(key64, np.uint64))
        for p, f in zip(pos, found):
            if f:
                s = self.start[p]
                out.append(self.members[s:s + self.size[p]])
            else:
                out.append(np.zeros((0,), np.int64))
        return out

    def affected_slice(self, keys: np.ndarray) -> pairs_mod.Blocks:
        """CSR restricted to ``keys`` (sorted unique), for the pair engine."""
        pos, found = searchsorted_mask(self.key, keys)
        pos = pos[found]
        members = gather_segments(self.start[pos], self.size[pos],
                                  self.members)
        return blocks_from_segments(self.key[pos], self.size[pos], members)

    def size_of(self, key64: np.ndarray) -> np.ndarray:
        """int64 block size per query key (0 when absent)."""
        if len(self.key) == 0:
            return np.zeros(len(key64), np.int64)
        pos, found = searchsorted_mask(self.key, key64)
        return np.where(found, self.size[np.minimum(pos, len(self.key) - 1)],
                        0).astype(np.int64)

    def splice(self, add_k: np.ndarray, add_r: np.ndarray,
               ret_k: np.ndarray, ret_r: np.ndarray,
               snapshot_keys: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, pairs_mod.Blocks, pairs_mod.Blocks]:
        """Splice accepted-assignment adds/retracts into the CSR.

        Returns (affected_keys_sorted, old_snapshot_csr, new_affected_csr).
        The old snapshot covers ``snapshot_keys`` (default: all affected
        keys) as they were BEFORE the splice; the new slice covers all
        affected keys after.
        """
        affected = np.unique(np.concatenate([add_k, ret_k]))
        old_csr = self.affected_slice(
            affected if snapshot_keys is None else snapshot_keys)

        # rebuild the affected keys' member lists
        pos, found = searchsorted_mask(self.key, affected)
        aff_pos = pos[found]
        old_sizes = self.size[aff_pos]
        old_k = np.repeat(self.key[aff_pos], old_sizes)
        old_r = gather_segments(self.start[aff_pos], old_sizes, self.members)
        cand_k = np.concatenate([old_k, add_k])
        cand_r = np.concatenate([old_r, add_r])
        new_k, new_r = set_subtract_pairs(cand_k, cand_r, ret_k, ret_r)
        uk_starts = np.flatnonzero(
            np.concatenate([[True], new_k[1:] != new_k[:-1]])
        ) if len(new_k) else np.zeros((0,), np.int64)
        uk = new_k[uk_starts]
        usz = np.diff(np.concatenate([uk_starts, [len(new_k)]])).astype(np.int64)

        # new global CSR = unaffected segments merged with rebuilt segments
        unaff = np.ones(len(self.key), bool)
        unaff[aff_pos] = False
        pool = np.concatenate([self.members, new_r])
        seg_key = np.concatenate([self.key[unaff], uk])
        seg_start = np.concatenate(
            [self.start[unaff],
             len(self.members) + np.concatenate([[0], np.cumsum(usz)])[:-1]]
        ).astype(np.int64)
        seg_size = np.concatenate([self.size[unaff], usz])
        order = np.argsort(seg_key, kind="stable")
        seg_key = seg_key[order]
        seg_start = seg_start[order]
        seg_size = seg_size[order]
        self.members = gather_segments(seg_start, seg_size, pool)
        self.key = seg_key
        self.size = seg_size
        self.start = (np.concatenate([[0], np.cumsum(seg_size)])[:-1]
                      .astype(np.int64))

        new_csr = blocks_from_segments(uk, usz, new_r)
        return affected, old_csr, new_csr

    def view(self, min_size: int = 1) -> pairs_mod.Blocks:
        """The CSR as a Blocks slice restricted to ``size >= min_size``."""
        keep = self.size >= min_size
        members = gather_segments(self.start[keep], self.size[keep],
                                  self.members)
        return blocks_from_segments(self.key[keep], self.size[keep], members)

    @property
    def num_blocks(self) -> int:
        return len(self.key)

    @property
    def num_assignments(self) -> int:
        return len(self.members)

    @property
    def nbytes(self) -> int:
        return (self.key.nbytes + self.start.nbytes + self.size.nbytes
                + self.members.nbytes)


class PairLedger:
    """Candidate-pair ledger: packed pair u64 -> largest source block size.

    == ``pairs.dedupe_pairs`` of the accepted-blocks CSR, maintained from
    per-ingest pair deltas. One per store — or one per shard, holding the
    pairs whose fingerprint routes to it.
    """

    def __init__(self):
        self.pack = np.zeros((0,), np.uint64)
        self.src = np.zeros((0,), np.int64)

    def apply(self, pair_pack: np.ndarray, src: np.ndarray
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Upsert/retract affected pairs; ``src == 0`` means uncovered.

        Returns (added_pack, added_src, retracted_pack), each sorted.
        """
        if len(pair_pack) == 0:
            z = np.zeros((0,), np.uint64)
            return z, np.zeros((0,), np.int64), z
        order = np.argsort(pair_pack)
        pair_pack, src = pair_pack[order], src[order]
        pos, found = searchsorted_mask(self.pack, pair_pack)
        to_del = found & (src == 0)
        to_upd = found & (src > 0)
        to_ins = ~found & (src > 0)
        retracted = pair_pack[to_del]
        if np.any(to_upd):
            self.src[pos[to_upd]] = src[to_upd]
        if np.any(to_ins):
            at = pos[to_ins]
            self.pack = np.insert(self.pack, at, pair_pack[to_ins])
            self.src = np.insert(self.src, at, src[to_ins])
        if np.any(to_del):
            # positions shift after insert; recompute by search
            dpos, dfound = searchsorted_mask(self.pack, retracted)
            keep = np.ones(len(self.pack), bool)
            keep[dpos[dfound]] = False
            self.pack = self.pack[keep]
            self.src = self.src[keep]
        return pair_pack[to_ins], src[to_ins], retracted

    def src_of(self, pack: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(current src size, found mask) per packed pair (0 when absent)."""
        if len(self.pack) == 0:
            return (np.zeros(len(pack), np.int64),
                    np.zeros(len(pack), bool))
        pos, found = searchsorted_mask(self.pack, pack)
        cur = np.zeros(len(pack), np.int64)
        cur[found] = self.src[np.minimum(pos, len(self.pack) - 1)][found]
        return cur, found

    @property
    def num_pairs(self) -> int:
        return len(self.pack)

    @property
    def nbytes(self) -> int:
        return self.pack.nbytes + self.src.nbytes


@dataclasses.dataclass
class LevelState:
    """Cached union state at one HDB iteration level (see module doc).

    Row state (everything per (record, key-slot)) lives here; the key
    space (CMS + key table) lives in ``keyspace`` — a ``LevelKeys`` on the
    single-host store or a ``streaming.shard.ShardedLevelKeys`` on the
    sharded one. The delegation methods below are the ONLY key-space
    surface the delta algorithm uses, which is what makes the two
    interchangeable.
    """

    width: int
    rids: np.ndarray      # (R,) int64, sorted
    keys: np.ndarray      # (R, W, 2) uint32, sentinel where ~valid
    key64: np.ndarray     # (R, W) uint64 packed mirror of keys
    valid: np.ndarray     # (R, W) bool
    psize: np.ndarray     # (R, W) int32
    idx: np.ndarray       # (depth, R, W) int32 CMS bucket indices
    right: np.ndarray     # (R, W) bool  CMS says right-sized
    keep: np.ndarray      # (R, W) bool  survives rough detection
    accept: np.ndarray    # (R, W) bool  accepted assignment
    survive: np.ndarray   # (R, W) bool  on a surviving over-sized block
    size: np.ndarray      # (R, W) int32 exact keep-count (0 where ~keep)
    keyspace: LevelKeys   # CMS + key table (or a sharded composite)

    @property
    def num_rows(self) -> int:
        return len(self.rids)

    @property
    def num_entries(self) -> int:
        return int(self.valid.sum())

    @staticmethod
    def empty(width: int, cms_cfg: sketches.CMSConfig,
              keyspace: Optional[LevelKeys] = None) -> "LevelState":
        depth = cms_cfg.depth
        return LevelState(
            width=width,
            rids=np.zeros((0,), np.int64),
            keys=np.zeros((0, width, 2), np.uint32),
            key64=np.zeros((0, width), np.uint64),
            valid=np.zeros((0, width), bool),
            psize=np.zeros((0, width), np.int32),
            idx=np.zeros((depth, 0, width), np.int32),
            right=np.zeros((0, width), bool),
            keep=np.zeros((0, width), bool),
            accept=np.zeros((0, width), bool),
            survive=np.zeros((0, width), bool),
            size=np.zeros((0, width), np.int32),
            keyspace=LevelKeys.empty(cms_cfg) if keyspace is None
            else keyspace,
        )

    # ---- key-space delegation (the delta algorithm's only key-space API) --

    def cms_apply(self, key64: np.ndarray, idx: np.ndarray,
                  sign: int) -> None:
        self.keyspace.cms_apply(key64, idx, sign)

    def cms_lookup(self, idx: np.ndarray) -> np.ndarray:
        return self.keyspace.cms_lookup(idx)

    def update_keytab(self, d_key: np.ndarray, d_cnt: np.ndarray,
                      d_fp: np.ndarray) -> np.ndarray:
        return self.keyspace.update_keytab(d_key, d_cnt, d_fp)

    def lookup(self, key64: np.ndarray):
        return self.keyspace.lookup(key64)

    def lookup_fp(self, key64: np.ndarray) -> np.ndarray:
        return self.keyspace.lookup_fp(key64)

    def oversized(self, max_block_size: int):
        return self.keyspace.oversized(max_block_size)

    def set_survivors(self, over_key: np.ndarray,
                      surv: np.ndarray) -> np.ndarray:
        return self.keyspace.set_survivors(over_key, surv)

    @property
    def num_keys(self) -> int:
        return self.keyspace.num_keys

    # ---- row state ----

    def row_index(self, rids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(row positions, found mask) for record ids."""
        return searchsorted_mask(self.rids, np.asarray(rids, np.int64))

    def drop_rows(self, rows: np.ndarray) -> None:
        keep = np.ones(len(self.rids), bool)
        keep[rows] = False
        self.rids = self.rids[keep]
        self.keys = self.keys[keep]
        self.key64 = self.key64[keep]
        self.valid = self.valid[keep]
        self.psize = self.psize[keep]
        self.idx = self.idx[:, keep]
        self.right = self.right[keep]
        self.keep = self.keep[keep]
        self.accept = self.accept[keep]
        self.survive = self.survive[keep]
        self.size = self.size[keep]

    def append_rows(self, rids, keys, key64, valid, psize, idx) -> None:
        n = len(rids)
        w = self.width
        self.rids = np.concatenate([self.rids, np.asarray(rids, np.int64)])
        self.keys = np.concatenate([self.keys, keys])
        self.key64 = np.concatenate([self.key64, key64])
        self.valid = np.concatenate([self.valid, valid])
        self.psize = np.concatenate([self.psize, psize])
        self.idx = np.concatenate([self.idx, idx], axis=1)
        zb = np.zeros((n, w), bool)
        zi = np.zeros((n, w), np.int32)
        self.right = np.concatenate([self.right, zb])
        self.keep = np.concatenate([self.keep, zb.copy()])
        self.accept = np.concatenate([self.accept, zb.copy()])
        self.survive = np.concatenate([self.survive, zb.copy()])
        self.size = np.concatenate([self.size, zi])
        order = np.argsort(self.rids, kind="stable")
        if not np.array_equal(order, np.arange(len(order))):
            self.rids = self.rids[order]
            self.keys = self.keys[order]
            self.key64 = self.key64[order]
            self.valid = self.valid[order]
            self.psize = self.psize[order]
            self.idx = self.idx[:, order]
            self.right = self.right[order]
            self.keep = self.keep[order]
            self.accept = self.accept[order]
            self.survive = self.survive[order]
            self.size = self.size[order]


class BlockStore:
    """Persistent blocking state for streaming ingest + candidate queries."""

    def __init__(self, cfg: hdb_mod.HDBConfig = hdb_mod.HDBConfig()):
        self.cfg = cfg
        self.num_records = 0
        self.levels: List[Optional[LevelState]] = [None] * cfg.max_iterations
        # accepted blocks CSR (== pairs.build_blocks(min_size=1) of the union)
        self.csr = BlockCsr()
        # candidate-pair ledger (== pairs.dedupe_pairs of the CSR, exact)
        self.ledger = PairLedger()

    # ------------------------------------------------------------------
    # back-compat array views (benches / data pipeline read these)
    # ------------------------------------------------------------------

    @property
    def bk_key(self) -> np.ndarray:
        return self.csr.key

    @property
    def bk_start(self) -> np.ndarray:
        return self.csr.start

    @property
    def bk_size(self) -> np.ndarray:
        return self.csr.size

    @property
    def bk_members(self) -> np.ndarray:
        return self.csr.members

    @property
    def led_pack(self) -> np.ndarray:
        return self.ledger.pack

    @property
    def led_src(self) -> np.ndarray:
        return self.ledger.src

    # ------------------------------------------------------------------
    # level access
    # ------------------------------------------------------------------

    def level(self, i: int, width: Optional[int] = None) -> LevelState:
        st = self.levels[i]
        if st is None:
            assert width is not None, f"level {i} accessed before first ingest"
            st = LevelState.empty(width, self.cfg.cms)
            self.levels[i] = st
        elif width is not None and st.width != width:
            raise ValueError(
                f"level {i} width mismatch: store has {st.width}, delta has "
                f"{width} (top-level key schema must be stable)")
        return st

    # ------------------------------------------------------------------
    # accepted-blocks CSR
    # ------------------------------------------------------------------

    def members_of(self, key64: np.ndarray) -> List[np.ndarray]:
        """Member rid arrays per query block key (empty when absent)."""
        return self.csr.members_of(key64)

    def affected_slice(self, keys: np.ndarray) -> pairs_mod.Blocks:
        """CSR restricted to ``keys`` (sorted unique), for the pair engine."""
        return self.csr.affected_slice(keys)

    def block_size_of(self, key64: np.ndarray) -> np.ndarray:
        """int64 accepted-block size per query key (0 when absent)."""
        return self.csr.size_of(key64)

    def apply_assignment_deltas(self, add_k: np.ndarray, add_r: np.ndarray,
                                ret_k: np.ndarray, ret_r: np.ndarray,
                                snapshot_keys: Optional[np.ndarray] = None
                                ) -> Tuple[np.ndarray, pairs_mod.Blocks,
                                           pairs_mod.Blocks]:
        """Splice accepted-assignment adds/retracts into the blocks CSR.

        Returns (affected_keys_sorted, old_snapshot_csr, new_affected_csr)
        — see ``BlockCsr.splice``.
        """
        return self.csr.splice(add_k, add_r, ret_k, ret_r, snapshot_keys)

    # ------------------------------------------------------------------
    # ledger
    # ------------------------------------------------------------------

    def apply_pair_deltas(self, pair_pack: np.ndarray, src: np.ndarray
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Upsert/retract affected pairs; ``src == 0`` means uncovered.

        Returns (added_pack, added_src, retracted_pack).
        """
        return self.ledger.apply(pair_pack, src)

    def ledger_src(self, pack: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(current src size, found mask) per packed pair (0 when absent)."""
        return self.ledger.src_of(pack)

    # ------------------------------------------------------------------
    # views
    # ------------------------------------------------------------------

    def accepted_blocks(self, min_size: int = 1) -> pairs_mod.Blocks:
        """Current union accepted blocks (== build_blocks of a batch run)."""
        return self.csr.view(min_size)

    def candidate_pairs(self) -> pairs_mod.PairSet:
        """Current candidate-pair set (== dedupe_pairs of a batch run)."""
        a, b = unpack_pair(self.led_pack)
        blk = self.accepted_blocks(min_size=2)
        return pairs_mod.PairSet(a=a, b=b, src_size=self.led_src.copy(),
                                 exact=True, total_slots=blk.num_pair_slots)

    def memory_stats(self) -> Dict[str, int]:
        out = {"num_records": self.num_records,
               "ledger_pairs": len(self.led_pack),
               "accepted_blocks": len(self.bk_key),
               "accepted_assignments": len(self.bk_members)}
        keytab_bytes = cms_bytes = 0
        for i, st in enumerate(self.levels):
            if st is not None:
                out[f"level{i}_rows"] = st.num_rows
                out[f"level{i}_entries"] = st.num_entries
                out[f"level{i}_keys"] = st.num_keys
                keytab_bytes += st.keyspace.keytab_bytes
                cms_bytes += st.keyspace.cms_bytes
        out["keytab_bytes"] = keytab_bytes
        out["cms_bytes"] = cms_bytes
        out["csr_bytes"] = self.csr.nbytes
        out["ledger_bytes"] = self.ledger.nbytes
        return out
