"""Fingerprint-sharded BlockStore: N key-partitioned slices, one surface.

The single-host ``BlockStore`` keeps one ``LevelKeys`` (CMS + key table)
per level plus one ``BlockCsr`` and one ``PairLedger``. This module
partitions all three by fingerprint over ``core.routing``'s shared owner
rule and re-exposes the exact ``BlockStore`` surface:

- **Key space** (``ShardedLevelKeys``): key-table rows and CMS fold-ins
  route to ``owner = splitmix64(key64, KEY_OWNER_SEED) % n_shards`` — the
  SAME partition the distributed batch step uses for its exact-count
  exchange, so a batch shard and a streaming shard agree on who owns a
  key. Each shard's CMS slice holds only its keys' entries; because the
  CMS is a linear sketch their elementwise sum IS the union sketch, and
  the composite keeps that psum-merged replica current for estimates
  (mirroring ``jax.lax.psum(cms)`` in ``core.distributed``).
- **Accepted-blocks CSR** (``StoreShard.csr``): partitioned by block-key
  owner — the shard that counts a key also materializes its block.
- **Pair ledger** (``StoreShard.ledger``): partitioned by pair-pack
  fingerprint (``REP_OWNER_SEED``), matching how
  ``dedupe_pairs_distributed`` meets all occurrences of a pair on one
  shard.

Routing invariants (see docs/STREAMING.md):

- Every routed update is *aggregated first* (``reduce_by_key``), so one
  ingest sends at most one key-table delta per (level, key) — one
  ``route_buckets`` + ``exchange``/``all_to_all`` per level when a mesh
  is attached, mirroring the distributed HDB step's dataflow.
- Shard key sets are disjoint, so merged views (``accepted_blocks``,
  ``candidate_pairs``, splice/pair deltas) are re-sorted concatenations —
  bit-identical to the single-host store's output, property-tested.
- ``n_shards=1`` degenerates exactly to today's behavior: one shard owns
  every key, every routed exchange is the identity.
- Bucket overflow on the mesh path is *counted, never silent*: the
  exchange warns (``RepCapacityWarning``), falls back losslessly to host
  grouping, and bumps ``ShardRouter.exchange_fallback_total`` (surfaced
  in the serving metrics snapshot).
"""
from __future__ import annotations

import functools
import warnings
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core import hdb as hdb_mod
from ..core import pairs as pairs_mod
from ..core import routing, sketches
from ..core.hdb import RepCapacityWarning
from .store import (BlockCsr, LevelKeys, LevelState, PairLedger,
                    merge_blocks, unpack_key64)

_SENT32 = np.uint32(0xFFFFFFFF)


def _ceil_pow2(n: int, floor: int = 256) -> int:
    p = floor
    while p < n:
        p *= 2
    return p


@functools.lru_cache(maxsize=32)
def _make_keytab_exchange(mesh, axes: Tuple[str, ...], n_shards: int,
                          rows: int, cap: int):
    """Jitted shard_mapped key-table delta exchange (one per level call).

    Each source shard scatters its (key, count, fingerprint) deltas into
    fixed-``cap`` per-destination buckets by key owner and swaps them
    with ONE ``all_to_all`` (``routing.exchange``). Absent lanes carry
    all-ones sentinel keys. Statics (rows per shard is padded to a power
    of two by the caller) bound the compile cache — the repro.analysis
    R005 contract, same builder pattern as ``core.distributed``.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from ..core import hashing, u64

    def local(khi, klo, cnt, fhi, flo):
        live = ~u64.is_sentinel((khi, klo))
        _, oh = hashing.hash_u64((khi, klo), seed=routing.KEY_OWNER_SEED)
        owner = jnp.where(live,
                          (oh % jnp.uint32(n_shards)).astype(jnp.int32),
                          jnp.int32(n_shards))
        bhi, blo, (bcnt, bfhi, bflo), ovf = routing.route_buckets(
            khi, klo, [cnt, fhi, flo], owner, n_shards, cap)
        bhi, blo, bcnt, bfhi, bflo = routing.exchange(
            axes, bhi, blo, bcnt, bfhi, bflo)
        return bhi, blo, bcnt, bfhi, bflo, jax.lax.psum(ovf, axes)

    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(P(axes),) * 5,
        out_specs=(P(axes, None),) * 5 + (P(),),
        check_rep=False))


class ShardRouter:
    """Owner computation + the mesh-backed routed key-delta exchange.

    Without a mesh the exchange is a host owner-grouping mirror — bit-
    identical, used by tests/benches and as the lossless overflow
    fallback. With a mesh it stages deltas through ``route_buckets`` +
    one ``all_to_all`` per call on emulated or real devices.
    """

    def __init__(self, n_shards: int, mesh=None,
                 axis_names: Sequence[str] = ("data",),
                 route_slack: float = 2.0):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = n_shards
        self.mesh = mesh
        self.axis_names = tuple(axis_names)
        self.route_slack = route_slack
        self.exchange_total = 0
        self.exchange_fallback_total = 0
        if mesh is not None:
            from ..distributed import sharding
            size = sharding.axis_size(mesh, self.axis_names)
            if size != n_shards:
                raise ValueError(
                    f"mesh axes {self.axis_names} have {size} devices but "
                    f"the store has {n_shards} shards — they must match "
                    "(one shard per device)")

    def key_owner(self, key64: np.ndarray) -> np.ndarray:
        return routing.np_owner_u64(key64, self.n_shards,
                                    seed=routing.KEY_OWNER_SEED)

    def pair_owner(self, pack: np.ndarray) -> np.ndarray:
        return routing.np_owner_u64(pack, self.n_shards,
                                    seed=routing.REP_OWNER_SEED)

    # ------------------------------------------------------------------

    def _group_host(self, d_key, d_cnt, d_fp):
        owner = self.key_owner(d_key)
        out = []
        for s in range(self.n_shards):
            m = owner == s
            out.append((d_key[m], d_cnt[m], d_fp[m]))
        return out

    def exchange_key_deltas(self, d_key: np.ndarray, d_cnt: np.ndarray,
                            d_fp: np.ndarray
                            ) -> List[Tuple[np.ndarray, np.ndarray,
                                            np.ndarray]]:
        """Route aggregated key-table deltas to their owner shards.

        Returns one (key, cnt, fp) triple per shard, key-sorted (the
        ``update_keytab`` input contract). ``d_key`` is sorted unique
        (``reduce_by_key`` output), so every key crosses the wire exactly
        once and the received slices need no re-aggregation.
        """
        self.exchange_total += 1
        if self.mesh is None or self.n_shards == 1 or len(d_key) == 0:
            return self._group_host(d_key, d_cnt, d_fp)
        import jax.numpy as jnp

        n = self.n_shards
        rows = _ceil_pow2(-(-len(d_key) // n), floor=64)
        cap = max(8, int(np.ceil(rows / n * self.route_slack)))
        total = n * rows
        khi = np.full(total, _SENT32, np.uint32)
        klo = np.full(total, _SENT32, np.uint32)
        hi, lo = unpack_key64(d_key)
        khi[:len(d_key)], klo[:len(d_key)] = hi, lo
        # per-ingest count deltas are bounded by the micro-batch entry
        # count, so int32 lanes are exact (the table itself stays int64)
        cnt = np.zeros(total, np.int32)
        cnt[:len(d_key)] = d_cnt.astype(np.int32)
        fhi = np.zeros(total, np.uint32)
        flo = np.zeros(total, np.uint32)
        fhi[:len(d_key)], flo[:len(d_key)] = unpack_key64(d_fp)
        step = _make_keytab_exchange(self.mesh, self.axis_names, n, rows, cap)
        bhi, blo, bcnt, bfhi, bflo, ovf = step(
            jnp.asarray(khi), jnp.asarray(klo), jnp.asarray(cnt),
            jnp.asarray(fhi), jnp.asarray(flo))
        if int(np.asarray(ovf)):
            warnings.warn(
                f"sharded key-table exchange overflowed a bucket (cap {cap}, "
                f"slack {self.route_slack}); falling back to host grouping "
                "for this delta — raise route_slack to keep the routed path",
                RepCapacityWarning, stacklevel=3)
            self.exchange_fallback_total += 1
            return self._group_host(d_key, d_cnt, d_fp)
        bhi = np.asarray(bhi).reshape(n, -1)
        blo = np.asarray(blo).reshape(n, -1)
        bcnt = np.asarray(bcnt).reshape(n, -1)
        bfhi = np.asarray(bfhi).reshape(n, -1)
        bflo = np.asarray(bflo).reshape(n, -1)
        out = []
        for d in range(n):
            live = ~((bhi[d] == _SENT32) & (blo[d] == _SENT32))
            key = ((bhi[d][live].astype(np.uint64) << np.uint64(32))
                   | blo[d][live].astype(np.uint64))
            fp = ((bfhi[d][live].astype(np.uint64) << np.uint64(32))
                  | bflo[d][live].astype(np.uint64))
            c = bcnt[d][live].astype(np.int64)
            order = np.argsort(key)
            out.append((key[order], c[order], fp[order]))
        return out


class ShardedLevelKeys:
    """N per-shard ``LevelKeys`` slices + a psum-merged CMS replica.

    Presents the exact ``LevelKeys`` method surface to ``LevelState``.
    Per-shard sketches are the authoritative partitioned state (each
    fold-in lands on the entry's key owner); their elementwise sum equals
    the merged replica at all times (CMS linearity), which serves every
    estimate without a gather across shards.
    """

    def __init__(self, cms_cfg: sketches.CMSConfig,
                 slices: List[LevelKeys], router: ShardRouter):
        self.cms_cfg = cms_cfg
        self.slices = slices
        self.router = router
        self.cms = np.zeros((cms_cfg.depth, cms_cfg.width), np.int32)

    # ---- CMS ----

    def cms_apply(self, key64: np.ndarray, idx: np.ndarray,
                  sign: int) -> None:
        for j in range(len(self.cms)):
            np.add.at(self.cms[j], idx[j], sign)
        owner = self.router.key_owner(key64)
        for s, sl in enumerate(self.slices):
            m = owner == s
            if m.any():
                sl.cms_apply(key64[m], idx[:, m], sign)

    def cms_lookup(self, idx: np.ndarray) -> np.ndarray:
        return np.stack([self.cms[j][idx[j]] for j in range(len(self.cms))])

    # ---- key table ----

    def update_keytab(self, d_key: np.ndarray, d_cnt: np.ndarray,
                      d_fp: np.ndarray) -> np.ndarray:
        parts = self.router.exchange_key_deltas(d_key, d_cnt, d_fp)
        for sl, (k, c, f) in zip(self.slices, parts):
            if len(k):
                sl.update_keytab(k, c, f)
        return d_key

    def lookup(self, key64: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        flat = np.asarray(key64, np.uint64).reshape(-1)
        owner = self.router.key_owner(flat)
        cnt = np.zeros(flat.shape, np.int64)
        surv = np.zeros(flat.shape, bool)
        found = np.zeros(flat.shape, bool)
        for s, sl in enumerate(self.slices):
            m = owner == s
            if m.any():
                c, sv, f = sl.lookup(flat[m])
                cnt[m], surv[m], found[m] = c, sv, f
        shape = np.asarray(key64, np.uint64).shape
        return cnt.reshape(shape), surv.reshape(shape), found.reshape(shape)

    def lookup_fp(self, key64: np.ndarray) -> np.ndarray:
        flat = np.asarray(key64, np.uint64).reshape(-1)
        owner = self.router.key_owner(flat)
        fp = np.zeros(flat.shape, np.uint64)
        for s, sl in enumerate(self.slices):
            m = owner == s
            if m.any():
                fp[m] = sl.lookup_fp(flat[m])
        return fp.reshape(np.asarray(key64, np.uint64).shape)

    def oversized(self, max_block_size: int
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        ks, cs, fs = [], [], []
        for sl in self.slices:
            k, c, f = sl.oversized(max_block_size)
            ks.append(k)
            cs.append(c)
            fs.append(f)
        key = np.concatenate(ks)
        # global key order restores the single-host survivor-pass input
        # order exactly (shard key sets are disjoint)
        order = np.argsort(key)
        return (key[order], np.concatenate(cs)[order],
                np.concatenate(fs)[order])

    def set_survivors(self, over_key: np.ndarray,
                      surv: np.ndarray) -> np.ndarray:
        owner = self.router.key_owner(over_key)
        changed = []
        for s, sl in enumerate(self.slices):
            m = owner == s
            # every shard is called even with no over-keys: its stale
            # survivor flags from the previous ingest must clear
            ch = sl.set_survivors(over_key[m], surv[m])
            if len(ch):
                changed.append(ch)
        if not changed:
            return np.zeros((0,), np.uint64)
        return np.sort(np.concatenate(changed))

    @property
    def num_keys(self) -> int:
        return sum(sl.num_keys for sl in self.slices)

    @property
    def keytab_bytes(self) -> int:
        return sum(sl.keytab_bytes for sl in self.slices)

    @property
    def cms_bytes(self) -> int:
        return self.cms.nbytes + sum(sl.cms_bytes for sl in self.slices)


class StoreShard:
    """One shard's slice of the partitioned persistent blocking state.

    Owns the per-level ``LevelKeys`` (keys whose fingerprint routes
    here), the accepted-blocks CSR restricted to its block keys, and the
    pair-ledger slice for its pair fingerprints. Pure container + byte
    accounting; all cross-shard coordination lives in
    ``ShardedBlockStore``/``ShardedLevelKeys``.
    """

    def __init__(self, cfg: hdb_mod.HDBConfig, shard_id: int):
        self.cfg = cfg
        self.shard_id = shard_id
        self.level_keys: List[Optional[LevelKeys]] = (
            [None] * cfg.max_iterations)
        self.csr = BlockCsr()
        self.ledger = PairLedger()

    def keys_at(self, level: int) -> LevelKeys:
        ks = self.level_keys[level]
        if ks is None:
            ks = LevelKeys.empty(self.cfg.cms)
            self.level_keys[level] = ks
        return ks

    @property
    def keytab_bytes(self) -> int:
        return sum(ks.keytab_bytes for ks in self.level_keys
                   if ks is not None)

    @property
    def num_keys(self) -> int:
        return sum(ks.num_keys for ks in self.level_keys if ks is not None)

    @property
    def total_bytes(self) -> int:
        return self.keytab_bytes + self.csr.nbytes + self.ledger.nbytes


class ShardedBlockStore:
    """N fingerprint-routed ``StoreShard``s behind the BlockStore surface.

    Duck-typed drop-in for ``BlockStore`` everywhere the streaming and
    serving layers use one (``DeltaBlocker``, ``StreamingEngine``,
    ``DedupeService`` tenants): same constructor-compatible ``cfg``, same
    methods, and every merged view is bit-identical to the single-host
    store after the same ingest sequence. ``mesh``/``axis_names`` attach
    the device-routed exchange (one ``all_to_all`` per level per ingest)
    and tell ``DeltaBlocker`` to sync the pair ledger through
    ``dedupe_pairs_distributed``; without a mesh the routing runs through
    the bit-identical host mirror.
    """

    def __init__(self, cfg: hdb_mod.HDBConfig = hdb_mod.HDBConfig(),
                 n_shards: int = 1, mesh=None,
                 axis_names: Sequence[str] = ("data",),
                 route_slack: float = 2.0):
        self.cfg = cfg
        self.n_shards = n_shards
        self.mesh = mesh
        self.axis_names = tuple(axis_names)
        self.router = ShardRouter(n_shards, mesh=mesh, axis_names=axis_names,
                                  route_slack=route_slack)
        self.shards = [StoreShard(cfg, s) for s in range(n_shards)]
        self.num_records = 0
        self.levels: List[Optional[LevelState]] = [None] * cfg.max_iterations

    # ------------------------------------------------------------------
    # level access
    # ------------------------------------------------------------------

    def level(self, i: int, width: Optional[int] = None) -> LevelState:
        st = self.levels[i]
        if st is None:
            assert width is not None, f"level {i} accessed before first ingest"
            keyspace = ShardedLevelKeys(
                self.cfg.cms, [sh.keys_at(i) for sh in self.shards],
                self.router)
            st = LevelState.empty(width, self.cfg.cms, keyspace=keyspace)
            self.levels[i] = st
        elif width is not None and st.width != width:
            raise ValueError(
                f"level {i} width mismatch: store has {st.width}, delta has "
                f"{width} (top-level key schema must be stable)")
        return st

    # ------------------------------------------------------------------
    # accepted-blocks CSR (key-owner partitioned)
    # ------------------------------------------------------------------

    def members_of(self, key64: np.ndarray) -> List[np.ndarray]:
        key64 = np.asarray(key64, np.uint64)
        owner = self.router.key_owner(key64)
        out: List[Optional[np.ndarray]] = [None] * len(key64)
        for s, sh in enumerate(self.shards):
            m = np.flatnonzero(owner == s)
            if len(m):
                for qi, mem in zip(m, sh.csr.members_of(key64[m])):
                    out[qi] = mem
        return out  # type: ignore[return-value]

    def affected_slice(self, keys: np.ndarray) -> pairs_mod.Blocks:
        owner = self.router.key_owner(keys)
        return merge_blocks([sh.csr.affected_slice(keys[owner == s])
                             for s, sh in enumerate(self.shards)])

    def block_size_of(self, key64: np.ndarray) -> np.ndarray:
        owner = self.router.key_owner(key64)
        size = np.zeros(len(key64), np.int64)
        for s, sh in enumerate(self.shards):
            m = owner == s
            if m.any():
                size[m] = sh.csr.size_of(key64[m])
        return size

    def apply_assignment_deltas(self, add_k: np.ndarray, add_r: np.ndarray,
                                ret_k: np.ndarray, ret_r: np.ndarray,
                                snapshot_keys: Optional[np.ndarray] = None
                                ) -> Tuple[np.ndarray, pairs_mod.Blocks,
                                           pairs_mod.Blocks]:
        ao = self.router.key_owner(add_k)
        ro = self.router.key_owner(ret_k)
        so = (None if snapshot_keys is None
              else self.router.key_owner(snapshot_keys))
        affected, olds, news = [], [], []
        for s, sh in enumerate(self.shards):
            aff_s, old_s, new_s = sh.csr.splice(
                add_k[ao == s], add_r[ao == s],
                ret_k[ro == s], ret_r[ro == s],
                None if snapshot_keys is None else snapshot_keys[so == s])
            affected.append(aff_s)
            olds.append(old_s)
            news.append(new_s)
        return (np.sort(np.concatenate(affected)),
                merge_blocks(olds), merge_blocks(news))

    # ------------------------------------------------------------------
    # ledger (pair-fingerprint partitioned)
    # ------------------------------------------------------------------

    def apply_pair_deltas(self, pair_pack: np.ndarray, src: np.ndarray
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        if len(pair_pack) == 0:
            z = np.zeros((0,), np.uint64)
            return z, np.zeros((0,), np.int64), z
        owner = self.router.pair_owner(pair_pack)
        add_p, add_s, retr = [], [], []
        for s, sh in enumerate(self.shards):
            m = owner == s
            ap, asrc, rp = sh.ledger.apply(pair_pack[m], src[m])
            add_p.append(ap)
            add_s.append(asrc)
            retr.append(rp)
        ap = np.concatenate(add_p)
        asrc = np.concatenate(add_s)
        order = np.argsort(ap)
        return ap[order], asrc[order], np.sort(np.concatenate(retr))

    def ledger_src(self, pack: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        owner = self.router.pair_owner(pack)
        cur = np.zeros(len(pack), np.int64)
        found = np.zeros(len(pack), bool)
        for s, sh in enumerate(self.shards):
            m = owner == s
            if m.any():
                c, f = sh.ledger.src_of(pack[m])
                cur[m], found[m] = c, f
        return cur, found

    # ------------------------------------------------------------------
    # merged views (bit-identical to the single-host store)
    # ------------------------------------------------------------------

    @property
    def led_pack(self) -> np.ndarray:
        return np.sort(np.concatenate(
            [sh.ledger.pack for sh in self.shards]))

    @property
    def led_src(self) -> np.ndarray:
        pack = np.concatenate([sh.ledger.pack for sh in self.shards])
        src = np.concatenate([sh.ledger.src for sh in self.shards])
        return src[np.argsort(pack)]

    def accepted_blocks(self, min_size: int = 1) -> pairs_mod.Blocks:
        return merge_blocks([sh.csr.view(min_size) for sh in self.shards])

    def candidate_pairs(self) -> pairs_mod.PairSet:
        pack = np.concatenate([sh.ledger.pack for sh in self.shards])
        src = np.concatenate([sh.ledger.src for sh in self.shards])
        order = np.argsort(pack)
        from .store import unpack_pair
        a, b = unpack_pair(pack[order])
        blk = self.accepted_blocks(min_size=2)
        return pairs_mod.PairSet(a=a, b=b, src_size=src[order].copy(),
                                 exact=True, total_slots=blk.num_pair_slots)

    # ------------------------------------------------------------------
    # stats
    # ------------------------------------------------------------------

    def shard_skew(self) -> float:
        """max/mean ratio of per-shard state bytes (1.0 == balanced)."""
        per = [sh.total_bytes for sh in self.shards]
        mean = sum(per) / max(len(per), 1)
        return float(max(per) / mean) if mean else 1.0

    def memory_stats(self) -> dict:
        out = {"num_records": self.num_records,
               "n_shards": self.n_shards,
               "ledger_pairs": sum(sh.ledger.num_pairs
                                   for sh in self.shards),
               "accepted_blocks": sum(sh.csr.num_blocks
                                      for sh in self.shards),
               "accepted_assignments": sum(sh.csr.num_assignments
                                           for sh in self.shards)}
        keytab_bytes = cms_bytes = 0
        for i, st in enumerate(self.levels):
            if st is not None:
                out[f"level{i}_rows"] = st.num_rows
                out[f"level{i}_entries"] = st.num_entries
                out[f"level{i}_keys"] = st.num_keys
                keytab_bytes += st.keyspace.keytab_bytes
                cms_bytes += st.keyspace.cms_bytes
        out["keytab_bytes"] = keytab_bytes
        out["cms_bytes"] = cms_bytes
        out["csr_bytes"] = sum(sh.csr.nbytes for sh in self.shards)
        out["ledger_bytes"] = sum(sh.ledger.nbytes for sh in self.shards)
        for s, sh in enumerate(self.shards):
            out[f"shard{s}_keytab_bytes"] = sh.keytab_bytes
            out[f"shard{s}_csr_bytes"] = sh.csr.nbytes
            out[f"shard{s}_ledger_bytes"] = sh.ledger.nbytes
        out["shard_skew"] = self.shard_skew()
        out["exchange_fallback_total"] = self.router.exchange_fallback_total
        return out
