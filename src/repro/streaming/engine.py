"""Slot-scheduled front-end for the streaming blocker.

Modeled on ``serving/engine.py``'s continuous-batching loop: submissions
(ingest record batches, query probes) queue host-side; each ``step()``
drains at most one fixed-size ingest micro-batch and one fixed-size query
batch, padding to the slot count so every step reuses the same compiled
classify/intersect family — the scheduler only flips host metadata, the
device never sees a new shape. Optionally scores each ingest's new
candidate pairs with the pairwise matcher, feeding it the pair buffers
directly (no host round trip of the pair arrays).
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Dict, List, Optional

import numpy as np

from ..core import blocks as blocks_mod
from ..core import hdb as hdb_mod
from ..serving.scheduler import collate_fifo, drain
from .delta import DeltaBlocker, IngestReport, QueryResult
from .store import BlockStore


@dataclasses.dataclass
class RecordBatch:
    """A micro-batch of records in the corpus column format.

    ``columns`` maps column name -> (tokens (n, T) uint32, mask (n, T)
    bool); widths and the blocking spec must match the engine's schema
    across batches (the top-level key width is part of the store state).
    """

    columns: Dict[str, tuple]
    num_records: int

    @staticmethod
    def from_corpus(corpus, idx: np.ndarray) -> "RecordBatch":
        idx = np.asarray(idx)
        cols = {name: (np.asarray(col.tokens)[idx], np.asarray(col.mask)[idx])
                for name, col in corpus.columns.items()}
        return RecordBatch(columns=cols, num_records=len(idx))


@functools.lru_cache(maxsize=1)
def _row_patch_fn():
    """Jitted row-patch for ColumnCache (built lazily: jax stays a local
    import). One compile per (buffer, patch) shape pair — bounded by the
    power-of-two capacity/bucket scheme. Eager dynamic_update_slice with
    jnp.int32 scalar offsets would be an implicit transfer per append
    (repro.analysis R001/R005)."""
    import jax
    import jax.numpy as jnp

    @jax.jit
    def patch_rows(buf, patch, start):
        return jax.lax.dynamic_update_slice(buf, patch, (start, jnp.int32(0)))

    return patch_rows


class ColumnCache:
    """Device-resident token columns with amortized-growth appends.

    The matcher needs every ingested record's columns on device to score
    new candidate pairs. Re-concatenating and re-uploading the whole
    history per delta would be O(N) data movement per ingest — the exact
    waste the streaming subsystem exists to avoid. Instead the cache
    keeps power-of-two-capacity padded device buffers: within capacity an
    append uploads ONLY the delta rows (`lax.dynamic_update_slice`, delta
    padded to a power-of-two row bucket so the compile cache stays
    bounded); on overflow the capacity doubles and the buffer is rebuilt
    once — amortized O(N) total. Padding rows carry ``mask=False`` and
    are never indexed by a real pair, so scores are unchanged. Buffer
    shapes only change on doubling, so the matcher kernel recompiles
    O(log N) times over a service's lifetime.
    """

    def __init__(self):
        self.num_records = 0
        self._cap = 0
        self._host_t: Dict[str, np.ndarray] = {}
        self._host_m: Dict[str, np.ndarray] = {}
        self._dev: Dict[str, blocks_mod.TokenColumn] = {}

    def append(self, columns: Dict[str, tuple]) -> None:
        import jax
        import jax.numpy as jnp
        n = next(iter(columns.values()))[0].shape[0]
        new_len = self.num_records + n
        if new_len > self._cap:
            cap = 1024
            while cap < new_len:
                cap *= 2
            for name, (t, m) in columns.items():
                ht = np.zeros((cap, t.shape[1]), t.dtype)
                hm = np.zeros((cap, m.shape[1]), bool)
                if name in self._host_t:
                    ht[:self.num_records] = self._host_t[name][:self.num_records]
                    hm[:self.num_records] = self._host_m[name][:self.num_records]
                ht[self.num_records:new_len] = t
                hm[self.num_records:new_len] = m
                self._host_t[name], self._host_m[name] = ht, hm
                self._dev[name] = blocks_mod.TokenColumn(
                    jnp.asarray(ht), jnp.asarray(hm))  # one rebuild upload
            self._cap = cap
        else:
            bucket = 64
            while bucket < n:
                bucket *= 2
            bucket = min(bucket, self._cap - self.num_records)
            patch_rows = _row_patch_fn()
            start = jax.device_put(np.int32(self.num_records))
            for name, (t, m) in columns.items():
                self._host_t[name][self.num_records:new_len] = t
                self._host_m[name][self.num_records:new_len] = m
                # delta-only upload; rows [n, bucket) re-write zero padding
                pt = np.zeros((bucket, t.shape[1]), t.dtype)
                pm = np.zeros((bucket, m.shape[1]), bool)
                pt[:n], pm[:n] = t, m
                col = self._dev[name]
                self._dev[name] = blocks_mod.TokenColumn(
                    patch_rows(col.tokens, jnp.asarray(pt), start),
                    patch_rows(col.mask, jnp.asarray(pm), start))
        self.num_records = new_len

    def columns(self) -> Dict[str, blocks_mod.TokenColumn]:
        return dict(self._dev)


@dataclasses.dataclass
class IngestResult:
    uids: List[int]     # every submission coalesced into this micro-batch
    first_rid: int
    report: IngestReport
    match_scores: Optional[np.ndarray] = None   # scores of pairs_added
    # fused match_backend only: packed a<<32|b words of the MATCHED new
    # pairs (match_scores stays None — the full score vector never
    # crosses to the host on that path)
    matched_pairs: Optional[np.ndarray] = None


@dataclasses.dataclass
class ProbeResult:
    uid: int
    result: QueryResult


class StreamingEngine:
    """Micro-batch ingest + probe queries over one BlockStore."""

    def __init__(self, blocking: Dict[str, blocks_mod.ColumnBlocking],
                 cfg: hdb_mod.HDBConfig = hdb_mod.HDBConfig(),
                 ingest_slots: int = 256, query_slots: int = 64,
                 matcher_cfg=None, sort_backend: str = "auto",
                 n_shards: int = 1, match_backend: str = "host"):
        self.blocking = blocking
        # "host" (default): score every new pair, scores land host-side
        # (IngestResult.match_scores). "auto"/"jnp"/"pallas": the fused
        # kernels/match path — only the packed matched pairs come back
        # (IngestResult.matched_pairs).
        if match_backend != "host":
            from ..data.matcher import resolve_match_backend
            match_backend = resolve_match_backend(match_backend)
        self.match_backend = match_backend
        if n_shards > 1:
            from .shard import ShardedBlockStore
            self.store = ShardedBlockStore(cfg, n_shards=n_shards)
        else:
            self.store = BlockStore(cfg)
        # sort_backend: pair-engine dedupe-sort knob for ledger syncs
        self.blocker = DeltaBlocker(self.store, sort_backend=sort_backend)
        self.ingest_slots = ingest_slots
        self.query_slots = query_slots
        self.matcher_cfg = matcher_cfg
        self._uid = 0
        self._ingest_queue: List[tuple] = []   # (uid, RecordBatch)
        self._query_queue: List[tuple] = []    # (uid, RecordBatch)
        self.ingest_results: List[IngestResult] = []
        self.probe_results: List[ProbeResult] = []
        # retained columns for matcher scoring of new pairs
        self.column_cache = ColumnCache()

    # ------------------------------------------------------------------

    def submit_ingest(self, batch: RecordBatch) -> int:
        self._uid += 1
        self._ingest_queue.append((self._uid, batch))
        return self._uid

    def submit_query(self, batch: RecordBatch) -> int:
        self._uid += 1
        self._query_queue.append((self._uid, batch))
        return self._uid

    @property
    def busy(self) -> bool:
        return bool(self._ingest_queue) or bool(self._query_queue)

    # ------------------------------------------------------------------

    def _build_keys(self, batch: RecordBatch):
        import jax.numpy as jnp
        cols = {name: blocks_mod.TokenColumn(jnp.asarray(t), jnp.asarray(m))
                for name, (t, m) in batch.columns.items()}
        keys, valid = blocks_mod.build_keys(cols, self.blocking)
        return np.asarray(keys), np.asarray(valid)

    def _pad_batch(self, batches: List[tuple], slots: int) -> List[tuple]:
        """Coalesce queued (uid, batch) entries up to one slot budget.

        Skip-scan collation (``serving.scheduler.collate_fifo``): an entry
        too big for the remaining budget no longer blocks smaller entries
        queued behind it; per-uid FIFO holds and an oversized entry still
        passes through alone once it reaches the head.
        """
        return collate_fifo(batches, slots,
                            size_fn=lambda e: e[1].num_records,
                            group_fn=lambda e: e[0])

    @staticmethod
    def _merge_columns(taken: List[tuple]) -> RecordBatch:
        merged = {name: (np.concatenate([b.columns[name][0] for _, b in taken]),
                         np.concatenate([b.columns[name][1] for _, b in taken]))
                  for name in taken[0][1].columns}
        return RecordBatch(merged, sum(b.num_records for _, b in taken))

    def step(self) -> None:
        """Process one ingest micro-batch and one query batch, if queued."""
        ingest = self._pad_batch(self._ingest_queue, self.ingest_slots)
        if ingest:
            uids = [u for u, _ in ingest]
            batch = self._merge_columns(ingest)
            if self.matcher_cfg is not None:
                self.column_cache.append(batch.columns)
            first_rid = self.store.num_records
            keys, valid = self._build_keys(batch)
            report = self.blocker.ingest_keys(keys, valid)
            scores = matched = None
            if self.matcher_cfg is not None and report.num_pairs_added:
                if self.match_backend == "host":
                    scores = self._score_new_pairs(report)
                else:
                    matched = self._match_new_pairs(report)
            self.ingest_results.append(IngestResult(
                uids=uids, first_rid=first_rid, report=report,
                match_scores=scores, matched_pairs=matched))
        queries = self._pad_batch(self._query_queue, self.query_slots)
        if queries:
            batch = self._merge_columns(queries)
            keys, valid = self._build_keys(batch)
            results = self.blocker.query_keys(keys, valid)
            off = 0
            for uid, qb in queries:
                for r in results[off:off + qb.num_records]:
                    self.probe_results.append(ProbeResult(uid=uid, result=r))
                off += qb.num_records

    @property
    def queue_depth(self) -> int:
        """Submissions still queued across both lanes."""
        return len(self._ingest_queue) + len(self._query_queue)

    def run(self, max_steps: int = 10_000):
        """Drain the queues; warn if ``max_steps`` truncates the drain (the
        returned results would otherwise be indistinguishable from a
        completed run — check ``queue_depth``/``busy`` and call ``run()``
        again to finish)."""
        drain(self, max_steps)
        if self.busy:
            warnings.warn(
                f"StreamingEngine.run stopped at max_steps={max_steps} with "
                f"{self.queue_depth} submissions still queued; call run() "
                "again to finish the drain", RuntimeWarning, stacklevel=2)
        return self.ingest_results, self.probe_results

    # ------------------------------------------------------------------

    def _score_new_pairs(self, report: IngestReport) -> np.ndarray:
        """Matcher scores for this ingest's new candidate pairs, fed the
        pair buffers directly (device arrays stay device-side)."""
        import jax
        import jax.numpy as jnp
        from ..data import matcher
        a, b, _ = report.pairs_added
        if not isinstance(a, jax.Array):
            # host buffers: pre-cast then upload explicitly (dtype-coercing
            # jnp.asarray is an implicit transfer — repro.analysis R001)
            a = jnp.asarray(np.asarray(a, np.int32))
            b = jnp.asarray(np.asarray(b, np.int32))
        return matcher.score_pairs(self.column_cache.columns(), a, b,
                                   self.matcher_cfg)

    def _match_new_pairs(self, report: IngestReport) -> np.ndarray:
        """Fused match over this ingest's new pairs: packed ``a<<32|b``
        words of the matched subset — the per-pair score vector stays on
        device (no host round trip of the pair list)."""
        from ..data import matcher
        from ..kernels.match import packed_host
        a, b, _ = report.pairs_added
        ca, cb, cnt = matcher.match_compact(
            self.column_cache.columns(), a, b, self.matcher_cfg,
            backend=self.match_backend)
        return packed_host(ca, cb, int(np.asarray(cnt)))
