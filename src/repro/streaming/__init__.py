"""Streaming incremental blocking: micro-batch ingest + candidate queries
over persistent Hashed-Dynamic-Blocking state.

The batch driver (``core/hdb.py``) re-derives everything from scratch per
run; this package keeps the state resident so records arriving
continuously cost work proportional to what they *change*, not to the
corpus. Two operations: ``ingest(records)`` (micro-batch of new rows) and
``query(record)`` (candidate ids for one probe, serving-style, read-only).

BlockStore memory layout
------------------------

Everything is dense numpy, host-resident, staged through the same
fixed-shape jitted functions the batch path uses:

- **Per iteration level i** (``store.levels[i]``): the union's iteration
  state exactly as batch HDB would hold it entering iteration ``i`` —
  ``(R_i, W_i)`` key/valid/psize arrays over live rows (rows sorted by
  rid; ``W_0`` = top-level key width, ``W_{i+1} = C(min(max_oversize_keys,
  W_i), 2)``), the cached decision bits (right/keep/accept/survive) and
  per-entry exact block sizes, the level's Count-Min Sketch with cached
  per-entry bucket indices, and the key table: sorted u64 key -> (exact
  keep-entry count, XOR-of-rid-fingerprints membership hash, survivor
  flag), i.e. the incremental mirror of Algorithm 4's sort.
- **Accepted-blocks CSR**: sorted block keys -> member-rid runs — the
  live equivalent of ``pairs.build_blocks`` on a batch result, spliced
  per ingest only where membership changed.
- **Candidate-pair ledger**: packed ``a << 32 | b`` u64 -> largest source
  block size — the live equivalent of ``pairs.dedupe_pairs``; each ingest
  returns exactly the pairs added/retracted.

All three state families are partitionable by key fingerprint:
``shard.ShardedBlockStore`` routes them over N shards with the batch
layer's ``core.routing`` owner rule and stays bit-identical to the
single-host store (docs/STREAMING.md covers the shard contract).

Why the CMS makes this work (the fold-in argument)
--------------------------------------------------

Algorithm 3's rough over-size detection is the one global, approximate
stage — its decisions depend on every live entry in the corpus, which is
what usually forces a full re-run. But the Count-Min Sketch is a *linear*
sketch: ``cms(union) == cms(corpus) + cms(delta)`` exactly, bucket by
bucket, and removal is subtraction (``sketches.cms_fold`` /
``cms_subtract``). So a micro-batch folds into the global sketch with one
``+`` — no rebuild — and, because the store caches every entry's bucket
indices, the entries whose estimate could possibly have moved are exactly
those hashing into a touched bucket. Only they are re-classified (through
the same jitted ``hdb.rough_classify``), and only rows whose surviving
over-sized key set changed are re-intersected. The result after any
ingest sequence is bit-identical to one batch run on the union — the
streaming property tests assert it pair-for-pair.

Front-end
---------

``StreamingEngine`` wraps a store + delta blocker behind the shared slot
scheduler (``serving/scheduler.py``, also driving the LM engine):
submissions queue host-side, ``step()`` drains one fixed-size micro-batch
(padded, so ingest batches and query probes of any size reuse one
compiled step family without recompiles), and results carry the
per-ingest pair deltas, optionally matcher-scored straight from the
device pair buffers. The service-grade front-end — admission lanes,
deadlines/backpressure, padded-bucket probe batching, per-tenant stores,
metrics — is ``repro.serving.DedupeService`` (docs/SERVING.md).
"""
from .store import BlockStore, LevelState  # noqa: F401
from .delta import DeltaBlocker, IngestReport, QueryResult  # noqa: F401
from .engine import StreamingEngine, RecordBatch  # noqa: F401
from .shard import ShardedBlockStore, StoreShard, ShardRouter  # noqa: F401
