"""TinyLlama-1.1B [arXiv:2401.02385; hf] — llama2-arch small.
Assigned: 22L d_model=2048 32H (kv=4) d_ff=5632 vocab=32000."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab_size=32000,
    rope_theta=10_000.0,
    param_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=8, num_kv_heads=2,
        head_dim=8, d_ff=128, vocab_size=256,
        param_dtype="float32", compute_dtype="float32")
