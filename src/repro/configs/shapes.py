"""Assigned input shapes and per-(arch x shape) applicability.

  train_4k     seq 4096,   global_batch 256   (train_step)
  prefill_32k  seq 32768,  global_batch 32    (serve prefill)
  decode_32k   seq 32768,  global_batch 128   (serve decode: 1 new token,
                                               KV cache of seq_len)
  long_500k    seq 524288, global_batch 1     (long-context decode; only
                                               sub-quadratic archs)
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

from . import ARCH_IDS


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k requires sub-quadratic attention: only ssm/hybrid run it
# (DESIGN.md §Shape skips); full-attention archs skip it.
_SUBQUADRATIC = {"rwkv6-1.6b", "jamba-1.5-large-398b"}


def applicable(arch_id: str, shape_name: str) -> Tuple[bool, str]:
    if shape_name == "long_500k" and arch_id not in _SUBQUADRATIC:
        return False, "long_500k needs sub-quadratic attention (skip per assignment)"
    return True, ""


def all_cells() -> List[Tuple[str, str]]:
    cells = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            ok, _ = applicable(arch, shape)
            if ok:
                cells.append((arch, shape))
    return cells
