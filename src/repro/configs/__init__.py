"""Architecture registry: ``get_config(arch_id)`` / ``reduced_config(arch_id)``.

One module per assigned architecture; each exports ``CONFIG`` (the exact
assigned spec) and ``reduced()`` (a tiny same-family config for CPU smoke
tests)."""
from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ModelConfig

ARCH_IDS: List[str] = [
    "deepseek-v3-671b",
    "olmoe-1b-7b",
    "whisper-medium",
    "jamba-1.5-large-398b",
    "internlm2-20b",
    "tinyllama-1.1b",
    "mistral-nemo-12b",
    "stablelm-3b",
    "rwkv6-1.6b",
    "internvl2-76b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def _mod(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch '{arch_id}'; known: {ARCH_IDS}")
    return importlib.import_module(f".{_MODULES[arch_id]}", __package__)


def get_config(arch_id: str) -> ModelConfig:
    return _mod(arch_id).CONFIG


def reduced_config(arch_id: str) -> ModelConfig:
    return _mod(arch_id).reduced()
