"""Jamba-1.5-Large 398B [arXiv:2403.19887; hf] — Mamba+attn 1:7 interleave,
MoE 16e top-2 every other layer. Assigned: 72L d_model=8192 64H (kv=8)
d_ff=24576 vocab=65536. Runs long_500k (hybrid => sub-quadratic)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    attn_period=8,             # 1 attention layer per 8 (1:7)
    moe_num_experts=16,
    moe_top_k=2,
    moe_d_ff=24576,
    moe_layer_period=2,        # every other layer
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=8, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256, moe_num_experts=4, moe_top_k=2,
        moe_d_ff=64, mamba_d_state=8, mamba_chunk=16,
        param_dtype="float32", compute_dtype="float32")
