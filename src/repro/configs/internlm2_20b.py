"""InternLM2-20B [arXiv:2403.17297; hf] — dense GQA.
Assigned: 48L d_model=6144 48H (kv=8) d_ff=16384 vocab=92544."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=8, num_kv_heads=2,
        head_dim=8, d_ff=128, vocab_size=256,
        param_dtype="float32", compute_dtype="float32")
