"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892] — attention-free, data-dependent
decay. Assigned: 24L d_model=2048 d_ff=7168 vocab=65536.
Runs long_500k (O(1)-state decode)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,              # 2048 / 64 rwkv heads
    num_kv_heads=32,
    head_dim=64,
    rwkv_head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    param_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, rwkv_head_dim=16, d_ff=128, vocab_size=256,
        param_dtype="float32", compute_dtype="float32")
