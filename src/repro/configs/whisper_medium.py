"""Whisper-medium [arXiv:2212.04356] — enc-dec; conv frontend is a STUB
(input_specs supplies precomputed frame embeddings). Assigned: 24L
d_model=1024 16H d_ff=4096 vocab=51865. Decoder token length is
seq_len // 8 of the assigned shape (frames dominate whisper sequences);
decoder positions are extended past 448 to cover assigned shapes."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=48,            # 24 encoder + 24 decoder
    encoder_layers=24,
    decoder_layers=24,
    encoder_seq_ratio=8,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    tie_embeddings=True,
    param_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=4, encoder_layers=2, decoder_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
        param_dtype="float32", compute_dtype="float32")
