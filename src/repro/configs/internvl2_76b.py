"""InternVL2-76B [arXiv:2404.16821] — InternViT (STUB: input_specs supplies
projected patch embeddings) + llama3-70b-class language backbone.
Assigned: 80L d_model=8192 64H (kv=8) d_ff=28672 vocab=128256, 256 patches."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    num_patches=256,
    rope_theta=500_000.0,
    param_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=8, num_kv_heads=2,
        head_dim=8, d_ff=128, vocab_size=256, num_patches=4,
        param_dtype="float32", compute_dtype="float32")
