"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407] — dense GQA,
128k ctx. Assigned: 40L d_model=5120 32H (kv=8) d_ff=14336 vocab=131072."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=8, num_kv_heads=2,
        head_dim=8, d_ff=128, vocab_size=256,
        param_dtype="float32", compute_dtype="float32")
