"""DeepSeek-V3 671B [arXiv:2412.19437; hf] — MLA, 1 shared + 256 routed
top-8, MTP. Assigned: 61L d_model=7168 128H d_ff(expert)=2048 vocab=129280.
First 3 layers use a dense FFN (18432) per the HF config."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,          # MLA: latent-compressed, heads share the latent
    head_dim=128,
    d_ff=18432,                # dense FFN of the first 3 layers
    vocab_size=129280,
    moe_num_experts=256,
    moe_top_k=8,
    moe_shared_experts=1,
    moe_d_ff=2048,
    moe_layer_period=1,
    moe_first_dense=3,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    mtp=True,
    rope_theta=10_000.0,
    param_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=5, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=256, moe_num_experts=8, moe_top_k=2,
        moe_d_ff=32, moe_first_dense=2, q_lora_rank=32, kv_lora_rank=16,
        rope_head_dim=8, nope_head_dim=16, v_head_dim=16,
        param_dtype="float32", compute_dtype="float32")
