"""StableLM-3B [hf:stabilityai/stablelm-2-1_6b family] — dense MHA.
Assigned: 32L d_model=2560 32H (kv=32) d_ff=6912 vocab=50304."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab_size=50304,
    rope_theta=10_000.0,
    param_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=8, num_kv_heads=8,
        head_dim=8, d_ff=128, vocab_size=256,
        param_dtype="float32", compute_dtype="float32")
