"""OLMoE-1B-7B [arXiv:2409.02060; hf] — 64 experts top-8, every layer MoE.
Assigned: 16L d_model=2048 16H (kv=16) d_ff(expert)=1024 vocab=50304."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    moe_num_experts=64,
    moe_top_k=8,
    moe_d_ff=1024,
    moe_layer_period=1,
    rope_theta=10_000.0,
    param_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=64, vocab_size=256, moe_num_experts=8, moe_top_k=2,
        moe_d_ff=32, param_dtype="float32", compute_dtype="float32")
