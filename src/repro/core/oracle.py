"""Pure-python reference implementation of Hashed Dynamic Blocking.

An INDEPENDENT implementation of Algorithms 1-4 over python ints/sets/
dicts — no CMS (counts are exact, which equals the JAX path whenever the
sketch is wide enough to not over-count), same key-combine hashes, same
caps and heuristics. The end-to-end property test
(tests/test_hdb_oracle.py) checks the fixed-shape JAX implementation
produces EXACTLY this accepted (rid, key) set on randomized corpora.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Set, Tuple


from . import hashing
from .hdb import HDBConfig


def oracle_hdb(record_keys: List[Set[int]], cfg: HDBConfig
               ) -> Set[Tuple[int, int]]:
    """record_keys[rid] = set of 64-bit top-level blocking keys.

    Returns the accepted (rid, key) assignment set.
    """
    accepted: Set[Tuple[int, int]] = set()
    # live: rid -> {key: parent_size}
    live: Dict[int, Dict[int, int]] = {
        rid: {k: None for k in ks} for rid, ks in enumerate(record_keys)}

    for _ in range(cfg.max_iterations):
        # exact block sizes + membership
        members: Dict[int, List[int]] = defaultdict(list)
        for rid, ks in live.items():
            for k in ks:
                members[k].append(rid)

        right, over = {}, {}
        for k, rids in members.items():
            size = len(rids)
            psize = None
            # the progress heuristic uses the MIN parent size over records?
            # parent size is a per-(rid, key) attribute but identical for
            # every record holding the key (same parents) — take any.
            for rid in rids:
                psize = live[rid][k]
                break
            if size <= cfg.max_block_size:
                right[k] = rids
            elif psize is None or size <= cfg.max_similarity * psize:
                over[k] = rids
            # else: dropped by similarity

        for k, rids in right.items():
            for rid in rids:
                accepted.add((rid, k))

        # dedupe over-sized blocks by exact membership; smallest key wins
        by_membership: Dict[frozenset, List[int]] = defaultdict(list)
        for k, rids in over.items():
            by_membership[frozenset(rids)].append(k)
        survivors: Dict[int, List[int]] = {}
        for rids, keys in by_membership.items():
            survivors[min(keys)] = sorted(rids)

        if not survivors:
            break

        # intersect per record (Alg. 2)
        new_live: Dict[int, Dict[int, int]] = defaultdict(dict)
        sizes = {k: len(r) for k, r in survivors.items()}
        rid_keys: Dict[int, List[int]] = defaultdict(list)
        for k, rids in survivors.items():
            for rid in rids:
                rid_keys[rid].append(k)
        any_entries = False
        for rid, ks in rid_keys.items():
            if len(ks) > cfg.max_keys:
                continue  # record dropped from further processing
            # keep the MAX_OVERSIZE_KEYS smallest blocks (ties: key value)
            ks = sorted(ks, key=lambda k: (sizes[k], k))[: cfg.max_oversize_keys]
            for i in range(len(ks)):
                for j in range(i + 1, len(ks)):
                    a, b = ks[i], ks[j]
                    lo, hi = (a, b) if a < b else (b, a)
                    child = hashing.np_combine(lo, hi)
                    psize = min(sizes[a], sizes[b])
                    prev = new_live[rid].get(child)
                    if prev is None or psize < prev:
                        new_live[rid][child] = psize
                    any_entries = True
        live = dict(new_live)
        if not any_entries:
            break
    return accepted
