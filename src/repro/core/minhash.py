"""MinHash + LSH(b, w) banding — the paper's §2.1 block building.

For a record's token set S, `m = b*w` MinHashes are computed; each band of
`w` consecutive MinHashes is hashed into one 64-bit blocking key. Two
records with Jaccard similarity j share at least one band key with
probability ``LSH(b, w, j) = 1 - (1 - j^w)^b`` (paper Fig. 1a).

The pure-jnp implementation here is the reference path; the Pallas TPU
kernel in ``repro.kernels.minhash`` computes the same MinHash matrix with
VMEM tiling and is validated against this module.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from . import u64, hashing
from .u64 import U64

_MH_SEED = 0x3141


def minhash_tokens(tokens: jnp.ndarray, mask: jnp.ndarray, num_hashes: int,
                   seed: int = _MH_SEED) -> jnp.ndarray:
    """MinHash matrix for padded token sets.

    Args:
      tokens: (R, T) uint32 token hashes.
      mask:   (R, T) bool validity (False = padding).
      num_hashes: m, number of independent hash functions.

    Returns:
      (R, m) uint32 MinHash values. Rows with no valid token get 0xFFFFFFFF.
    """
    tokens = tokens.astype(jnp.uint32)
    # Per-hash seed addends precomputed as u64 constants (a traced loop index
    # cannot multiply 64-bit python constants).
    gamma = 0x9E3779B97F4A7C15
    consts = [((seed + 977 * i + 1) * gamma) & ((1 << 64) - 1) for i in range(num_hashes)]
    add_hi = jnp.asarray([c >> 32 for c in consts], jnp.uint32)
    add_lo = jnp.asarray([c & 0xFFFFFFFF for c in consts], jnp.uint32)

    def one_hash(i, acc):
        x = u64.add(u64.from_u32(tokens), (add_hi[i], add_lo[i]))
        _, lo = hashing.mix64(x)  # (R, T) uint32
        lo = jnp.where(mask, lo, jnp.uint32(0xFFFFFFFF))
        return acc.at[:, i].set(jnp.min(lo, axis=1))

    init = jnp.zeros((tokens.shape[0], num_hashes), jnp.uint32)
    return jax.lax.fori_loop(0, num_hashes, one_hash, init)


def band_keys(minhashes: jnp.ndarray, bands: int, rows_per_band: int,
              column_seed: int = 0) -> U64:
    """Hash each band of `rows_per_band` MinHashes into one u64 blocking key.

    Returns (hi, lo) of shape (R, bands). `column_seed` namespaces keys per
    source column (the paper applies LSH per column, not whole-record).
    """
    r, m = minhashes.shape
    assert m == bands * rows_per_band, (m, bands, rows_per_band)
    grouped = minhashes.reshape(r, bands, rows_per_band)
    h = u64.full((r, bands), 0)
    h = hashing.hash_u64(h, seed=0x15A4 + column_seed)
    for k in range(rows_per_band):  # static small loop: sponge over the band
        tok = u64.from_u32(grouped[:, :, k])
        h = hashing.mix64(u64.add(u64.xor(h, tok), u64.from_int(0x9E3779B97F4A7C15)))
    # add band index so band 0 of one column never collides with band 1
    band_idx = jnp.broadcast_to(jnp.arange(bands, dtype=jnp.uint32)[None, :], (r, bands))
    h = hashing.mix64(u64.xor(h, u64.from_u32(band_idx)))
    return h


@functools.partial(jax.jit,
                   static_argnames=("bands", "rows_per_band", "column_seed"))
def lsh_keys(tokens: jnp.ndarray, mask: jnp.ndarray, bands: int,
             rows_per_band: int, column_seed: int = 0) -> Tuple[U64, jnp.ndarray]:
    """LSH blocking keys + validity for a padded token-set column.

    Rows with zero valid tokens emit no keys (valid=False). Jitted: the
    MinHash sponge builds its per-hash seed tables as host constants,
    which eager dispatch would upload implicitly per call
    (repro.analysis R001; rejected by the transfer-guarded tests).
    """
    mh = minhash_tokens(tokens, mask, bands * rows_per_band)
    keys = band_keys(mh, bands, rows_per_band, column_seed)
    any_tok = jnp.any(mask, axis=1, keepdims=True)
    valid = jnp.broadcast_to(any_tok, keys[0].shape)
    return keys, valid


def lsh_probability(bands: int, rows_per_band: int, jaccard) -> jnp.ndarray:
    """Analytic LSH(b, w, j) = 1 - (1 - j^w)^b (paper Fig. 1a)."""
    # float32 throughout: x64 is disabled, so jnp.float64 would silently
    # be 32-bit anyway (repro.analysis R002); the curve needs ~3 digits
    j = jnp.asarray(jaccard, jnp.float32)
    return 1.0 - (1.0 - j ** rows_per_band) ** bands
