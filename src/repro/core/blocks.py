"""Top-level block building (paper §2): Identity / Token / LSH builders.

A corpus column is a padded token matrix ``(N, T)`` uint32 + mask. Each
builder maps a column to u64 blocking keys per record:

- identity: one key = hash of the whole (normalized) value, namespaced by
  the column id — "foo" in two columns gives two different keys.
- token: one key per token, NOT namespaced by column (schema-agnostic
  Token Blocking of Papadakis et al., used for the DBPEDIA/FREEB-style
  runs in the paper).
- lsh(b, w): b band keys from b*w MinHashes, namespaced by column.

``build_keys`` concatenates all columns' keys into the dense per-record
key matrix that seeds Hashed Dynamic Blocking, deduplicating keys within
each record (set semantics, as in the paper's Spark implementation).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import u64, hashing, minhash
from .u64 import U64


@dataclasses.dataclass(frozen=True)
class TokenColumn:
    """Padded token-hash matrix for one attribute."""

    tokens: jnp.ndarray  # (N, T) uint32
    mask: jnp.ndarray    # (N, T) bool


@dataclasses.dataclass(frozen=True)
class ColumnBlocking:
    """How to build blocking keys for one column."""

    kind: str  # "identity" | "token" | "lsh"
    bands: int = 0
    rows_per_band: int = 0

    @staticmethod
    def identity() -> "ColumnBlocking":
        return ColumnBlocking("identity")

    @staticmethod
    def token() -> "ColumnBlocking":
        return ColumnBlocking("token")

    @staticmethod
    def lsh(bands: int, rows_per_band: int) -> "ColumnBlocking":
        return ColumnBlocking("lsh", bands=bands, rows_per_band=rows_per_band)

    def num_keys(self, column_width: int) -> int:
        if self.kind == "identity":
            return 1
        if self.kind == "token":
            return column_width
        if self.kind == "lsh":
            return self.bands
        raise ValueError(self.kind)


@functools.partial(jax.jit, static_argnames=("column_seed",))
def _identity_keys(tokens: jnp.ndarray, mask: jnp.ndarray, *,
                   column_seed: int) -> Tuple[U64, jnp.ndarray]:
    n, t = tokens.shape
    h = hashing.hash_u64(u64.full((n,), t), seed=0x1DE0 + column_seed)
    for k in range(t):  # static width
        tok = u64.from_u32(jnp.where(mask[:, k], tokens[:, k], 0))
        # include the mask bit so "padding" differs from a real 0 token
        tok = u64.add(tok, u64.from_u32(mask[:, k].astype(jnp.uint32) << 31))
        h = hashing.mix64(u64.add(u64.xor(h, tok), u64.from_int(0x9E3779B97F4A7C15)))
    valid = jnp.any(mask, axis=1)
    return (h[0][:, None], h[1][:, None]), valid[:, None]


def identity_keys(col: TokenColumn, column_seed: int) -> Tuple[U64, jnp.ndarray]:
    """One key per record: sponge over the column's (ordered) tokens.

    Jitted (via ``_identity_keys``): the sponge runs hot per column and
    eager dispatch would implicitly upload each round's hash constants —
    the repro.analysis R001 hazard the transfer-guarded tests reject.
    """
    return _identity_keys(col.tokens, col.mask, column_seed=column_seed)


@functools.partial(jax.jit, static_argnames=("seed",))
def _token_keys(tokens: jnp.ndarray, *, seed: int) -> U64:
    return hashing.hash_u32(tokens, seed=seed)


def token_keys(col: TokenColumn, _: int) -> Tuple[U64, jnp.ndarray]:
    """One key per token, shared across columns (schema-agnostic)."""
    return _token_keys(col.tokens, seed=0x70CE), col.mask


def build_keys(
    columns: Dict[str, TokenColumn],
    blocking: Dict[str, ColumnBlocking],
    max_width: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Build the dense per-record top-level key matrix.

    Returns:
      keys:  (N, K, 2) uint32 packed u64 keys (sentinel-padded)
      valid: (N, K) bool
    K = sum over columns of keys-per-column (possibly truncated to
    max_width, keeping column order).
    """
    all_hi, all_lo, all_valid = [], [], []
    for seed, name in enumerate(sorted(columns)):
        col = columns[name]
        spec = blocking[name]
        if spec.kind == "identity":
            (hi, lo), valid = identity_keys(col, seed)
        elif spec.kind == "token":
            (hi, lo), valid = token_keys(col, seed)
        elif spec.kind == "lsh":
            (hi, lo), valid = minhash.lsh_keys(
                col.tokens, col.mask, spec.bands, spec.rows_per_band, column_seed=seed)
        else:
            raise ValueError(spec.kind)
        all_hi.append(hi)
        all_lo.append(lo)
        all_valid.append(valid)
    hi = jnp.concatenate(all_hi, axis=1)
    lo = jnp.concatenate(all_lo, axis=1)
    valid = jnp.concatenate(all_valid, axis=1)
    return _finalize_keys(hi, lo, valid, max_width=max_width)


@functools.partial(jax.jit, static_argnames=("max_width",))
def _finalize_keys(hi, lo, valid, *, max_width: Optional[int]):
    """Truncate to max_width and dedupe per-record keys (jitted: eager
    slicing and the sentinel masking would be implicit transfers)."""
    if max_width is not None and hi.shape[1] > max_width:
        hi, lo, valid = hi[:, :max_width], lo[:, :max_width], valid[:, :max_width]
    hi, lo, valid = dedupe_row_keys(hi, lo, valid)
    return jnp.stack([hi, lo], axis=-1), valid


@jax.jit
def dedupe_row_keys(hi: jnp.ndarray, lo: jnp.ndarray, valid: jnp.ndarray):
    """Enforce per-record set semantics: drop duplicate keys within a row.

    Sorts each row (invalid -> sentinel -> tail) and masks repeats. Row
    order is not meaningful afterwards.
    """
    hi = jnp.where(valid, hi, jnp.uint32(0xFFFFFFFF))
    lo = jnp.where(valid, lo, jnp.uint32(0xFFFFFFFF))
    hi, lo = jax.lax.sort((hi, lo), num_keys=2, dimension=1)
    same_as_prev = jnp.concatenate(
        [jnp.zeros((hi.shape[0], 1), bool),
         (hi[:, 1:] == hi[:, :-1]) & (lo[:, 1:] == lo[:, :-1])], axis=1)
    valid = ~same_as_prev & ~((hi == jnp.uint32(0xFFFFFFFF)) & (lo == jnp.uint32(0xFFFFFFFF)))
    return hi, lo, valid
