"""Distributed HDB: the paper's Spark dataflow mapped onto a TPU pod mesh.

Sharding: records (and their key rows) are sharded over the mesh's
data-like axes; the model axis of the production mesh simply joins the
record sharding (blocking has no "model" dimension). Per iteration:

  - CMS:     built per shard, merged with ONE psum (linear sketch).
  - Exact:   surviving entries hash-route to an owner shard with ONE
             all_to_all; owner computes exact counts + XOR membership
             fingerprints with a local sort (keys are fully local after
             routing).
  - Dedupe:  block representatives hash-route BY FINGERPRINT with a second
             (much smaller) all_to_all; survivors are all-gathered as the
             paper's "broadcasted counts map"; a Bloom filter over ALL
             over-sized keys is OR-merged so shards can recover
             CMS-over-counted right-sized blocks exactly as in Algorithm 4
             (key not in Bloom => right-sized; in counts map => over-sized;
             otherwise duplicate, dropped).
  - Intersect: purely record-local (Alg. 2), no communication.

Record payloads never move; the only shuffled bytes are 8-byte key hashes
and int32 sizes of the *shrinking* survivor set — the paper's minimal-
data-movement thesis, with fixed-capacity buffers instead of dynamic
shuffles (capacity overflows are counted, never silent). The shared
bucketing/exchange primitives live in ``core.routing``.

Pair materialization (§3.1) reuses the same dataflow:
``dedupe_pairs_distributed`` shards the canonical pair-slot space, packs
every decoded pair into the kernels' 62-bit sort word, and hash-routes it
BY PAIR FINGERPRINT (splitmix64 of the word's (a, b) bits) with one
all_to_all per round, so the largest-block-wins sort-dedupe is
shard-local and no device ever materializes the full pair set.

Routed-dedupe contract:
  - Bit-identical PairSets to single-device ``core.pairs.dedupe_pairs``
    on every mesh shape (the fingerprint partitions pairs, per-shard
    winners are disjoint, and the budget-exceeded path decodes the same
    seeded global slot sample as every other backend).
  - Per-shard peak pair-buffer: n_rounds * n_shards * cap words with
    cap = ceil(chunk_per_shard / n_shards * route_slack), i.e.
    ~ceil(total_slots / n_shards) * route_slack — the distributed
    engine's memory knob.
  - ``route_slack`` tuning: slack s bounds the tolerated per-destination
    skew of the pair-fingerprint hash within one chunk; splitmix64 is
    close to uniform, so bucket occupancy is ~Binomial(chunk, 1/n_shards)
    and the default s=2.0 puts overflow many sigma out for chunks >= 4k.
    Raise it (cost: linearly larger buckets) only if the driver warns —
    overflow triggers a lossless fallback to the single-device engine,
    never silent pair drops. Small chunks with few slots per shard
    amplify relative skew; prefer fewer, larger rounds.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
import warnings
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import hashing, routing, segments, sketches, u64
from ..distributed import sharding
from .hdb import (BlockingResult, HDBConfig, INT32_MAX, IterationStats,
                  RepCapacityWarning, intersect_keys)
from .routing import route_buckets as _route

logger = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class DistConfig:
    """Fixed buffer capacities for the distributed exchanges."""

    route_slack: float = 2.0       # all_to_all bucket slack over the mean
    rep_capacity_per_shard: int = 1 << 14
    bloom_slots: int = 1 << 22
    bloom_hashes: int = 20


def make_hdb_step(cfg: HDBConfig, mesh: Mesh,
                  axis_names: Sequence[str],
                  dist: DistConfig = DistConfig()):
    """Build the jitted, shard_mapped distributed HDB iteration.

    Thin wrapper that normalizes ``axis_names`` so the lru-cached builder
    keys on hashable statics only — repeated drivers over the same mesh
    geometry reuse the compiled step instead of re-jitting per call (the
    repro.analysis R005 hazard; the routed-dedupe builders below already
    worked this way).
    """
    return _make_hdb_step_cached(cfg, mesh, tuple(axis_names), dist)


@functools.lru_cache(maxsize=16)
def _make_hdb_step_cached(cfg: HDBConfig, mesh: Mesh,
                          axis_names: Tuple[str, ...],
                          dist: DistConfig):
    n_shards = sharding.axis_size(mesh, tuple(axis_names))
    axes = tuple(axis_names)
    bloom_cfg = sketches.BloomConfig(dist.bloom_slots, dist.bloom_hashes)

    def local_step(keys_packed, valid, psize):
        n_loc, k = valid.shape
        shard = routing.linear_shard_index(mesh, axes)
        rid0 = shard * jnp.int32(n_loc)
        key = (keys_packed[..., 0], keys_packed[..., 1])

        # ---- rough over-size detection (Alg. 3), CMS merged via psum ----
        flat_key = (key[0].reshape(-1), key[1].reshape(-1))
        flat_valid = valid.reshape(-1)
        cms = sketches.cms_build(cfg.cms, flat_key, flat_valid)
        cms = jax.lax.psum(cms, axes)
        s = sketches.cms_query(cfg.cms, cms, flat_key).reshape(valid.shape)
        right_cms = valid & (s <= cfg.max_block_size)
        progress = s.astype(jnp.float32) <= cfg.max_similarity * psize.astype(jnp.float32)
        keep = valid & ~right_cms & progress
        dropped_sim = valid & ~right_cms & ~progress

        # ---- exact count: route surviving entries to key-owner shards ----
        L = n_loc * k
        flat_keep = keep.reshape(-1)
        khi = jnp.where(flat_keep, flat_key[0], jnp.uint32(0xFFFFFFFF))
        klo = jnp.where(flat_keep, flat_key[1], jnp.uint32(0xFFFFFFFF))
        rid = rid0 + jnp.broadcast_to(
            jnp.arange(n_loc, dtype=jnp.int32)[:, None], (n_loc, k)).reshape(-1)
        _, owner_h = hashing.hash_u64((khi, klo), seed=routing.KEY_OWNER_SEED)
        owner = jnp.where(flat_keep,
                          (owner_h % jnp.uint32(n_shards)).astype(jnp.int32),
                          jnp.int32(n_shards))
        cap = int(np.ceil(L / n_shards * dist.route_slack))
        bhi, blo, (brid,), route_overflow = _route(khi, klo, [rid], owner, n_shards, cap)
        bhi, blo, brid = routing.exchange(axes, bhi, blo, brid)

        # ---- owner-side exact counts + fingerprints (local sort) ----
        fhi, flo, frid = bhi.reshape(-1), blo.reshape(-1), brid.reshape(-1)
        (shi, slo), (srid,) = segments.sort_by_key((fhi, flo), [frid])
        skey = (shi, slo)
        live = ~u64.is_sentinel(skey)
        sizes = segments.segment_counts(skey)
        fp = hashing.fingerprint_rid(srid)
        fp = (jnp.where(live, fp[0], 0), jnp.where(live, fp[1], 0))
        xors = segments.segment_xor(skey, fp)
        over = live & (sizes > cfg.max_block_size)
        reps = segments.segment_starts(skey) & over

        # Bloom over ALL over-sized keys (H_O), OR-merged across shards
        bloom = sketches.bloom_build(bloom_cfg, skey, reps)
        bloom = jax.lax.pmax(bloom, axes)

        # ---- dedupe: route representatives by membership fingerprint ----
        rcap = dist.rep_capacity_per_shard
        n_reps = jnp.sum(reps.astype(jnp.int32))
        rep_overflow = jnp.maximum(n_reps - rcap, 0)
        rep_idx = jnp.nonzero(reps, size=rcap, fill_value=skey[0].shape[0] - 1)[0]
        rep_ok = jnp.arange(rcap, dtype=jnp.int32) < n_reps
        r_khi = jnp.where(rep_ok, shi[rep_idx], jnp.uint32(0xFFFFFFFF))
        r_klo = jnp.where(rep_ok, slo[rep_idx], jnp.uint32(0xFFFFFFFF))
        r_xhi = jnp.where(rep_ok, xors[0][rep_idx], jnp.uint32(0xFFFFFFFF))
        r_xlo = jnp.where(rep_ok, xors[1][rep_idx], jnp.uint32(0xFFFFFFFF))
        r_sz = jnp.where(rep_ok, sizes[rep_idx], INT32_MAX)
        _, xo = hashing.hash_u64((r_xhi, r_xlo), seed=routing.REP_OWNER_SEED)
        xowner = jnp.where(rep_ok, (xo % jnp.uint32(n_shards)).astype(jnp.int32),
                           jnp.int32(n_shards))
        xcap = int(np.ceil(rcap / n_shards * dist.route_slack)) + 8
        r_live = rep_ok.astype(jnp.int32)
        xhi_b, xlo_b, (xsz_b, xkhi_b, xklo_b, xlive_b), x_overflow = _route(
            r_xhi, r_xlo, [r_sz, r_khi, r_klo, r_live], xowner, n_shards, xcap)
        xhi_b, xlo_b, xsz_b, xkhi_b, xklo_b, xlive_b = routing.exchange(
            axes, xhi_b, xlo_b, xsz_b, xkhi_b, xklo_b, xlive_b)
        g_xhi, g_xlo, g_sz, g_khi, g_klo, g_live = jax.lax.sort(
            (xhi_b.reshape(-1), xlo_b.reshape(-1), xsz_b.reshape(-1),
             xkhi_b.reshape(-1), xklo_b.reshape(-1), xlive_b.reshape(-1)),
            num_keys=5)
        dup = ((g_xhi == jnp.roll(g_xhi, 1)) & (g_xlo == jnp.roll(g_xlo, 1))
               & (g_sz == jnp.roll(g_sz, 1)))
        dup = dup.at[0].set(False)
        is_real = g_live > 0
        survivor = is_real & ~dup
        n_dup = jnp.sum((is_real & dup).astype(jnp.int32))
        n_dup = jax.lax.psum(n_dup, axes)

        # ---- broadcast the survivor counts map (all_gather + sort) ----
        t_khi = jnp.where(survivor, g_khi, jnp.uint32(0xFFFFFFFF))
        t_klo = jnp.where(survivor, g_klo, jnp.uint32(0xFFFFFFFF))
        t_sz = jnp.where(survivor, g_sz, 0)
        t_khi = jax.lax.all_gather(t_khi, axes, tiled=True)
        t_klo = jax.lax.all_gather(t_klo, axes, tiled=True)
        t_sz = jax.lax.all_gather(t_sz, axes, tiled=True)
        t_khi, t_klo, t_sz = jax.lax.sort((t_khi, t_klo, t_sz), num_keys=2)

        # ---- classify original local entries (paper Alg. 4 lines 9-19) ----
        in_bloom = sketches.bloom_query(bloom_cfg, bloom, (khi, klo)).reshape(valid.shape)
        hit, ex_size = segments.lookup_u64((t_khi, t_klo), t_sz, (khi, klo), 0)
        hit = hit.reshape(valid.shape)
        ex_size = ex_size.reshape(valid.shape)
        right_exact = keep & ~in_bloom
        survive = keep & hit
        accepted = right_cms | right_exact

        # ---- intersect locally (Alg. 2) ----
        new_key, new_valid, new_psize, n_dropped_mk = intersect_keys(
            cfg, key, survive, ex_size)

        def tot(x):
            return jax.lax.psum(jnp.sum(x.astype(jnp.int32)), axes)

        stats = {
            "n_live_keys": tot(valid),
            "n_right_cms": tot(right_cms),
            "n_right_exact": tot(right_exact),
            "n_dropped_similarity": tot(dropped_sim),
            "n_dropped_max_keys": jax.lax.psum(n_dropped_mk, axes),
            "n_duplicate_blocks": n_dup,
            "n_surviving_oversized": jax.lax.psum(
                jnp.sum(survivor.astype(jnp.int32)), axes),
            "n_surviving_entries": tot(survive),
            "rep_overflow": jax.lax.psum(rep_overflow + route_overflow
                                         + x_overflow, axes),
        }
        new_packed = jnp.stack([new_key[0], new_key[1]], axis=-1)
        return accepted, new_packed, new_valid, new_psize, stats

    spec3 = P(axes, None, None)
    spec2 = P(axes, None)
    stats_spec = {k: P() for k in [
        "n_live_keys", "n_right_cms", "n_right_exact", "n_dropped_similarity",
        "n_dropped_max_keys", "n_duplicate_blocks", "n_surviving_oversized",
        "n_surviving_entries", "rep_overflow"]}
    mapped = shard_map(
        local_step, mesh=mesh,
        in_specs=(spec3, spec2, spec2),
        out_specs=(spec2, spec3, spec2, spec2, stats_spec),
        check_rep=False)
    return jax.jit(mapped)


def distributed_hashed_dynamic_blocking(
    keys_packed, valid, cfg: HDBConfig, mesh: Mesh,
    axis_names: Sequence[str] = ("data",),
    dist: DistConfig = DistConfig(),
    checkpoint_cb=None,
    start_iteration: int = 0,
    verbose: bool = False,
) -> BlockingResult:
    """Multi-device HDB driver (Algorithm 1) over a shard_mapped step.

    ``checkpoint_cb(iteration, state_pytree)`` — optional fault-tolerance
    hook invoked after every iteration with the (sharded) live state.
    """
    n = valid.shape[0]
    axes = tuple(axis_names)
    n_shards = sharding.axis_size(mesh, axes)
    assert n % n_shards == 0, (n, n_shards)
    sharding3 = NamedSharding(mesh, P(axes, None, None))
    sharding2 = NamedSharding(mesh, P(axes, None))
    keys_packed = jax.device_put(keys_packed, sharding3)
    valid = jax.device_put(valid, sharding2)
    psize = jax.device_put(np.full(valid.shape, INT32_MAX, np.int32), sharding2)

    step = make_hdb_step(cfg, mesh, axes, dist)
    acc_rid: List[np.ndarray] = []
    acc_hi: List[np.ndarray] = []
    acc_lo: List[np.ndarray] = []
    all_stats: List[IterationStats] = []
    for it in range(start_iteration, cfg.max_iterations):
        accepted, new_keys, new_valid, new_psize, stats = step(keys_packed, valid, psize)
        acc = np.asarray(accepted)
        ridx, kidx = np.nonzero(acc)
        keys_np = np.asarray(keys_packed)
        acc_rid.append(ridx.astype(np.int64))
        acc_hi.append(keys_np[ridx, kidx, 0])
        acc_lo.append(keys_np[ridx, kidx, 1])
        st = IterationStats(iteration=it, **{k: int(v) for k, v in stats.items()})
        all_stats.append(st)
        logger.log(logging.INFO if verbose else logging.DEBUG,
                   "[hdb-dist] iter=%d %s", it, st)
        if st.rep_overflow:
            warnings.warn(
                f"[hdb-dist] buffer overflow ({st.rep_overflow} entries "
                "dropped); raise DistConfig capacities",
                RepCapacityWarning, stacklevel=2)
        keys_packed, valid, psize = new_keys, new_valid, new_psize
        if checkpoint_cb is not None:
            checkpoint_cb(it, {"keys": keys_packed, "valid": valid, "psize": psize})
        if st.n_surviving_entries == 0:
            break
    return BlockingResult(
        rids=np.concatenate(acc_rid) if acc_rid else np.zeros((0,), np.int64),
        key_hi=np.concatenate(acc_hi) if acc_hi else np.zeros((0,), np.uint32),
        key_lo=np.concatenate(acc_lo) if acc_lo else np.zeros((0,), np.uint32),
        stats=all_stats,
        num_records=n,
    )


# ---------------------------------------------------------------------------
# Distributed pair materialization + fingerprint-routed dedupe (paper §3.1
# over the mesh)
# ---------------------------------------------------------------------------


def _pair_contract_reason(blocks, budget: int, per_round: int,
                          exact: bool) -> Optional[str]:
    """None if the routed distributed engine applies, else why not."""
    from . import pairs as pairs_lib
    from ..kernels import pairs as pairs_kernels

    reason = pairs_lib._device_contract_ok(blocks, budget)
    if reason is not None:
        return reason
    if not pairs_lib._packable(blocks):
        return (f"record ids >= 2**{pairs_kernels.PACK_RID_BITS} break the "
                "62-bit sort-word pack")
    if exact and blocks.num_pair_slots + per_round > INT32_MAX:
        # shard bases of the padded final round would wrap int32: base =
        # r0 + shard*chunk can reach total + per_round - chunk - 1. The
        # single-device guards in core/pairs.py never see per-shard
        # offsets, so this check must live here.
        return (f"slot space {blocks.num_pair_slots} + round {per_round} "
                "overflows int32 at the per-shard slot offsets")
    return None


@functools.lru_cache(maxsize=64)
def _make_routed_round_step(mesh, axes, n_shards: int, chunk: int, cap: int,
                            steps: int, interpret: bool, sampled: bool):
    """Build the jitted shard_mapped decode+pack+route+exchange round.

    Exact mode decodes slots [base, base+chunk) per shard (``total`` is a
    traced scalar operand so different datasets share one executable);
    sampled mode decodes pre-split (block, local) slot chunks. Both
    return this shard's routed sort-word buckets plus the psum'd route
    overflow. Cached: repeated drivers over the same mesh geometry reuse
    the compiled step instead of re-jitting per call.
    """
    from ..kernels import pairs as pairs_kernels

    def shared_tail(a, b, s, v):
        hi, lo = pairs_kernels.pack_sort_words(a, b, s, v)
        owner = pairs_kernels.pair_route_owner(a, b, v, n_shards)
        bhi, blo, _, overflow = routing.route_buckets(
            hi, lo, [], owner, n_shards, cap)
        bhi, blo = routing.exchange(axes, bhi, blo)
        return (bhi.reshape(-1), blo.reshape(-1),
                jax.lax.psum(overflow, axes))

    if sampled:
        def local_round(start, size, members, block, local, valid):
            a, b, s, v = pairs_kernels.decode_block_local(
                start, size, members, block[0], local[0], valid[0],
                steps=steps, use_kernel=False, interpret=interpret)
            return shared_tail(a, b, s, v)

        in_specs = (P(), P(), P(), P(axes, None), P(axes, None),
                    P(axes, None))
    else:
        def local_round(cum, start, size, members, base, total):
            a, b, s, v = pairs_kernels.decode_chunk(
                cum, start, size, members, base[0], total,
                chunk=chunk, steps=steps, use_kernel=False,
                interpret=interpret)
            return shared_tail(a, b, s, v)

        in_specs = (P(), P(), P(), P(), P(axes), P())

    return jax.jit(shard_map(
        local_round, mesh=mesh, in_specs=in_specs,
        out_specs=(P(axes), P(axes), P()), check_rep=False))


@functools.lru_cache(maxsize=64)
def _make_local_dedupe(mesh, axes, n_rounds: int,
                       sort_backend: str = "comparator",
                       n_passes: int = 16, interpret: bool = True):
    """Build the shard-local sort-dedupe over the accumulated buckets.

    ``sort_backend`` picks the in-shard sort engine (comparator
    ``lax.sort`` vs the ``kernels/sort`` radix kernel) — part of the
    cache key, like every other static of the compiled step.
    """
    from ..kernels import pairs as pairs_kernels

    def local_dedupe(*bufs):
        hi = jnp.concatenate(bufs[:n_rounds])
        lo = jnp.concatenate(bufs[n_rounds:])
        return pairs_kernels.dedupe_packed_device(
            hi, lo, sort_backend=sort_backend, n_passes=n_passes,
            use_kernel=False, interpret=interpret)

    specs = (P(axes),) * (2 * n_rounds)
    return jax.jit(shard_map(
        local_dedupe, mesh=mesh, in_specs=specs,
        out_specs=(P(axes), P(axes), P(axes)), check_rep=False))


@functools.lru_cache(maxsize=64)
def _make_decode_round_step(mesh, axes, chunk: int, interpret: bool):
    """Decode-only round of the legacy global-sort path (cached jit)."""
    from ..kernels import pairs as pairs_kernels

    def local_decode(cum, start, size, members, base, total):
        return pairs_kernels.decode_chunk(
            cum, start, size, members, base[0], total,
            chunk=chunk, use_kernel=False, interpret=interpret)

    return jax.jit(shard_map(
        local_decode, mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(axes), P()),
        out_specs=(P(axes), P(axes), P(axes), P(axes)),
        check_rep=False))


def dedupe_pairs_distributed(
    blocks, mesh: Mesh, axis_names: Sequence[str] = ("data",),
    budget: int = 50_000_000, chunk_per_shard: int = 1 << 18,
    route_slack: float = 2.0, interpret: bool = True, sample_seed: int = 0,
    sort_backend: str = "auto",
):
    """Fingerprint-routed distributed pair dedupe (no global sort).

    Mirrors the HDB all_to_all dataflow: every shard decodes its slice of
    the canonical pair-slot space in fixed ``chunk_per_shard`` chunks
    (``kernels.pairs.decode_chunk``), packs each pair into the 62-bit
    sort word, and routes it to ``owner = splitmix64((a << 23) | b) %
    n_shards`` with the shared ``routing.route_buckets`` + one
    ``all_to_all`` per round. Since ownership depends only on (a, b),
    all occurrences of a pair meet on one shard, so the largest-block-
    wins sort-dedupe runs SHARD-LOCALLY over ~total/n_shards words —
    no device ever holds the full pair set. Shard winner sets are
    disjoint by construction; the host merges them with one u64 sort of
    the (much smaller) deduped output.

    Contract: bit-identical PairSets to single-device
    ``core.pairs.dedupe_pairs`` (any backend) for both the exact and the
    budget-exceeded sampled path (the uniform slot sample is global and
    seeded, shared with every other backend). Per-shard peak pair-buffer
    size is ceil(total/n_shards) * route_slack words (n_rounds *
    n_shards * cap with cap = ceil(chunk/n_shards * route_slack)).
    Routing overflow beyond ``route_slack`` is detected per round and
    falls back to the single-device driver rather than dropping pairs.

    ``sort_backend`` picks the shard-local dedupe sort: ``"auto"`` keeps
    the per-platform winner (per-shard numpy u64 ``np.sort`` on the CPU
    backend, the radix kernel on real accelerators), ``"comparator"`` /
    ``"radix"`` force the on-device engine either way — same contract as
    ``core.pairs.dedupe_pairs``, and still bit-identical.
    """
    from . import pairs as pairs_lib
    from ..kernels import pairs as pairs_kernels
    from ..kernels.pairs import ref as pairs_ref

    axes = tuple(axis_names)
    n_shards = sharding.axis_size(mesh, axes)
    if sort_backend not in pairs_lib._SORT_BACKENDS:
        raise ValueError(
            f"sort_backend must be one of {pairs_lib._SORT_BACKENDS}, "
            f"got {sort_backend!r}")
    total = blocks.num_pair_slots
    exact = total <= budget
    # the backend-shared seeded global sample (bit-identical to every
    # single-device backend); drawn up front so the chunk clamp below
    # sees the real workload
    slots = (None if exact
             else pairs_lib._sample_slots(total, budget, sample_seed))
    workload = total if exact else len(slots)
    if total > 0 and workload == 0:
        # budget <= 0 draws an empty sample; every backend returns the
        # empty inexact PairSet (counting stays exact via total_slots)
        return pairs_lib._empty_pairset(False, total)
    # clamp the per-shard chunk to the workload (mirrors _dedupe_device):
    # small samples/totals must not pay for full chunk_per_shard lanes
    chunk = min(chunk_per_shard,
                pairs_lib._round_up(max(1, -(-workload // n_shards)), 1024))
    per_round = n_shards * chunk
    reason = _pair_contract_reason(blocks, budget, per_round, exact)
    if total == 0 or reason is not None:
        if reason is not None:
            warnings.warn(f"routed distributed pairs unavailable ({reason}); "
                          "using single-device driver", RuntimeWarning,
                          stacklevel=2)
        return pairs_lib.dedupe_pairs(blocks, budget=budget,
                                      sample_seed=sample_seed,
                                      interpret=interpret,
                                      sort_backend=sort_backend)

    # host casts + explicit uploads: dtype-coercing jnp.asarray and scalar
    # jnp dtype constructors are implicit host->device transfers, rejected
    # under jax.transfer_guard("disallow") (repro.analysis R001)
    start32 = jnp.asarray(blocks.start.astype(np.int32))
    size32 = jnp.asarray(blocks.size.astype(np.int32))
    mem32 = jnp.asarray(blocks.members.astype(np.int32))
    steps = pairs_kernels.search_steps_for(int(blocks.size.max()))
    cap = int(np.ceil(chunk / n_shards * route_slack))
    step = _make_routed_round_step(mesh, axes, n_shards, chunk, cap,
                                   steps, interpret, sampled=not exact)

    rhi, rlo, ovfs = [], [], []
    if exact:
        cum32 = jnp.asarray(
            pairs_ref.cum_pair_counts(blocks.size).astype(np.int32))
        total32 = jax.device_put(np.int32(total))
        shard_offsets = np.arange(n_shards, dtype=np.int32) * chunk
        for r0 in range(0, total, per_round):
            base = jnp.asarray(np.int32(r0) + shard_offsets)
            bhi, blo, ovf = step(cum32, start32, size32, mem32, base, total32)
            rhi.append(bhi); rlo.append(blo); ovfs.append(ovf)
    else:
        # budget-exceeded: decode the sample drawn above, split
        # block/local host-side because global slot indices are int64
        cum = pairs_ref.cum_pair_counts(blocks.size)
        block = (np.searchsorted(cum, slots, side="right") - 1).astype(np.int32)
        local = (slots - cum[block]).astype(np.int32)
        valid = np.ones(len(slots), bool)
        pad = (-len(slots)) % per_round
        if pad:
            block = np.pad(block, (0, pad))
            local = np.pad(local, (0, pad))
            valid = np.pad(valid, (0, pad))
        for off in range(0, len(block), per_round):
            sl = slice(off, off + per_round)
            bhi, blo, ovf = step(start32, size32, mem32,
                                 jnp.asarray(block[sl].reshape(n_shards, chunk)),
                                 jnp.asarray(local[sl].reshape(n_shards, chunk)),
                                 jnp.asarray(valid[sl].reshape(n_shards, chunk)))
            rhi.append(bhi); rlo.append(blo); ovfs.append(ovf)
    # one deferred host sync: rounds pipeline freely in the common
    # no-overflow case, and the fallback discards the buckets anyway
    if any(int(o) for o in ovfs):
        warnings.warn(
            f"routed pair dedupe overflowed a bucket (cap {cap}, slack "
            f"{route_slack}); falling back to the single-device driver — "
            "raise route_slack to keep the routed path",
            RepCapacityWarning, stacklevel=2)
        return pairs_lib.dedupe_pairs(blocks, budget=budget,
                                      sample_seed=sample_seed,
                                      interpret=interpret,
                                      sort_backend=sort_backend)

    # routed pairs always satisfy the pack bound (contract check above),
    # so "auto" resolves to the per-platform winner and "radix" never
    # degrades here
    sort_kind = pairs_lib._resolve_sort_backend(sort_backend, blocks)
    if sort_kind == "host":
        # CPU mirror of the single-device driver's packed strategy: each
        # shard's routed bucket is sorted with numpy's u64 sort (host ==
        # device memory on CPU, and np.sort beats XLA CPU's comparator
        # sort ~40x). Still shard-local: one bounded bucket at a time.
        per_round_words = [
            ((np.asarray(h).astype(np.uint64) << np.uint64(32))
             | np.asarray(l).astype(np.uint64)).reshape(n_shards, -1)
            for h, l in zip(rhi, rlo)]
        words = np.concatenate([
            pairs_kernels.dedupe_words_host(
                np.concatenate([wr[s] for wr in per_round_words]))
            for s in range(n_shards)])
    else:
        # data-dependent pass count only for the radix sort (n_passes is
        # part of the lru_cache key; the comparator ignores it)
        n_passes = (pairs_lib._radix_passes_for_blocks(blocks)
                    if sort_kind == "radix" else 16)
        dedupe = _make_local_dedupe(mesh, axes, len(rhi), sort_kind,
                                    n_passes, interpret)
        shi, slo, winner = dedupe(*rhi, *rlo)
        w = np.asarray(winner)
        words = ((np.asarray(shi).astype(np.uint64) << np.uint64(32))
                 | np.asarray(slo).astype(np.uint64))[w]
    # shard winner sets are disjoint: one host sort of the deduped output
    # restores the canonical global (a, b) order
    a, b, s = pairs_kernels.unpack_words_host(np.sort(words))
    return pairs_lib.PairSet(a=a, b=b, src_size=s, exact=exact,
                             total_slots=total)


def materialize_pairs_distributed(
    blocks, mesh: Mesh, axis_names: Sequence[str] = ("data",),
    budget: int = 50_000_000, chunk_per_shard: int = 1 << 18,
    interpret: bool = True, sample_seed: int = 0,
    dedupe: str = "routed", route_slack: float = 2.0,
    sort_backend: str = "auto",
):
    """Shard pair-slot decoding over the mesh and dedupe the result.

    ``dedupe="routed"`` (default) is the fingerprint-routed shard-local
    dedupe (``dedupe_pairs_distributed``); ``dedupe="global"`` keeps the
    legacy single global sort over the gathered pair buffer — retained as
    the benchmark baseline (``benchmarks/bench_pairs.py --mesh``) and for
    A/B debugging. Both are bit-identical to the single-device engine,
    and both route their dedupe sort through the shared ``sort_backend``
    knob (``"auto"``/``"comparator"``/``"radix"``) — the global
    baseline's one big sort is just the same abstraction over the whole
    pair buffer instead of per-shard buckets.
    """
    if dedupe == "routed":
        return dedupe_pairs_distributed(
            blocks, mesh, axis_names, budget=budget,
            chunk_per_shard=chunk_per_shard, route_slack=route_slack,
            interpret=interpret, sample_seed=sample_seed,
            sort_backend=sort_backend)
    if dedupe != "global":
        raise ValueError(f"dedupe must be 'routed' or 'global', got {dedupe!r}")

    from . import pairs as pairs_lib
    from ..kernels import pairs as pairs_kernels
    from ..kernels.pairs import ref as pairs_ref

    axes = tuple(axis_names)
    n_shards = sharding.axis_size(mesh, axes)
    chunk = chunk_per_shard
    per_round = n_shards * chunk
    total = blocks.num_pair_slots
    reason = pairs_lib._device_contract_ok(blocks, budget)
    if reason is None and total + per_round > INT32_MAX:
        # shard bases of the padded final round would wrap int32
        reason = f"slot space {total} + round {per_round} overflows int32"
    if total == 0 or total > budget or reason is not None:
        if reason is not None:
            warnings.warn(f"distributed pairs unavailable ({reason}); "
                          "using single-device driver", RuntimeWarning,
                          stacklevel=2)
        return pairs_lib.dedupe_pairs(blocks, budget=budget,
                                      sample_seed=sample_seed,
                                      interpret=interpret,
                                      sort_backend=sort_backend)

    cum32 = jnp.asarray(pairs_ref.cum_pair_counts(blocks.size).astype(np.int32))
    start32 = jnp.asarray(blocks.start.astype(np.int32))
    size32 = jnp.asarray(blocks.size.astype(np.int32))
    mem32 = jnp.asarray(blocks.members.astype(np.int32))
    total32 = jax.device_put(np.int32(total))
    mapped = _make_decode_round_step(mesh, axes, chunk, interpret)

    shard_offsets = np.arange(n_shards, dtype=np.int32) * chunk
    out_a, out_b, out_s, out_v = [], [], [], []
    for r0 in range(0, total, per_round):
        base = jnp.asarray(np.int32(r0) + shard_offsets)
        a, b, s, v = mapped(cum32, start32, size32, mem32, base, total32)
        out_a.append(np.asarray(a)); out_b.append(np.asarray(b))
        out_s.append(np.asarray(s)); out_v.append(np.asarray(v))
    # the legacy baseline is "one big device sort": "host" (a CPU-only
    # shortcut of the routed/single-device drivers) maps to the
    # comparator here so the baseline stays a device sort measurement
    sort_kind = pairs_lib._resolve_sort_backend(sort_backend, blocks)
    if sort_kind == "host":
        sort_kind = "comparator"
    kw = {}
    if sort_kind == "radix":
        kw["n_passes"] = pairs_lib._radix_passes_for_blocks(blocks)
    sa, sb, ss, winner = pairs_kernels.dedupe_device(
        jnp.asarray(np.concatenate(out_a)), jnp.asarray(np.concatenate(out_b)),
        jnp.asarray(np.concatenate(out_s)), jnp.asarray(np.concatenate(out_v)),
        sort_backend=sort_kind, use_kernel=False, interpret=interpret, **kw)
    w = np.asarray(winner)
    return pairs_lib.PairSet(
        a=np.asarray(sa)[w].astype(np.int64),
        b=np.asarray(sb)[w].astype(np.int64),
        src_size=np.asarray(ss)[w].astype(np.int64),
        exact=True, total_slots=total)
