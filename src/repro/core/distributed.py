"""Distributed HDB: the paper's Spark dataflow mapped onto a TPU pod mesh.

Sharding: records (and their key rows) are sharded over the mesh's
data-like axes; the model axis of the production mesh simply joins the
record sharding (blocking has no "model" dimension). Per iteration:

  - CMS:     built per shard, merged with ONE psum (linear sketch).
  - Exact:   surviving entries hash-route to an owner shard with ONE
             all_to_all; owner computes exact counts + XOR membership
             fingerprints with a local sort (keys are fully local after
             routing).
  - Dedupe:  block representatives hash-route BY FINGERPRINT with a second
             (much smaller) all_to_all; survivors are all-gathered as the
             paper's "broadcasted counts map"; a Bloom filter over ALL
             over-sized keys is OR-merged so shards can recover
             CMS-over-counted right-sized blocks exactly as in Algorithm 4
             (key not in Bloom => right-sized; in counts map => over-sized;
             otherwise duplicate, dropped).
  - Intersect: purely record-local (Alg. 2), no communication.

Record payloads never move; the only shuffled bytes are 8-byte key hashes
and int32 sizes of the *shrinking* survivor set — the paper's minimal-
data-movement thesis, with fixed-capacity buffers instead of dynamic
shuffles (capacity overflows are counted, never silent).
"""
from __future__ import annotations

import dataclasses
import functools
import logging
import warnings
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import hashing, segments, sketches, u64
from .hdb import (BlockingResult, HDBConfig, INT32_MAX, IterationStats,
                  RepCapacityWarning, intersect_keys)

logger = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class DistConfig:
    """Fixed buffer capacities for the distributed exchanges."""

    route_slack: float = 2.0       # all_to_all bucket slack over the mean
    rep_capacity_per_shard: int = 1 << 14
    bloom_slots: int = 1 << 22
    bloom_hashes: int = 20


def _linear_shard_index(axis_names: Sequence[str]) -> jnp.ndarray:
    idx = jnp.int32(0)
    for name in axis_names:
        idx = idx * jax.lax.axis_size(name) + jax.lax.axis_index(name)
    return idx


def _route(khi, klo, payloads, owner, n_shards: int, cap: int):
    """Scatter entries into per-destination buckets and all_to_all them.

    Args:
      owner: int32 destination shard per entry; use n_shards for "drop".
    Returns routed (khi, klo, payloads, overflow_count); absent slots carry
    sentinel keys.
    """
    # rank within destination group via sort by owner
    n = owner.shape[0]
    order = jnp.argsort(owner)  # stable not required; ranks only need uniqueness
    owner_s = owner[order]
    start = jnp.searchsorted(owner_s, owner, side="left")
    # rank of each (unsorted) entry: position among same-owner entries
    rank_sorted = jnp.arange(n, dtype=jnp.int32) - jnp.searchsorted(
        owner_s, owner_s, side="left").astype(jnp.int32)
    rank = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)
    del start
    pos = owner * cap + rank
    ok = (owner < n_shards) & (rank < cap)
    overflow = jnp.sum(((owner < n_shards) & (rank >= cap)).astype(jnp.int32))
    flat_pos = jnp.where(ok, pos, n_shards * cap)  # OOB -> dropped

    def scatter(x, fill):
        buf = jnp.full((n_shards * cap,), fill, x.dtype)
        return buf.at[flat_pos].set(x, mode="drop").reshape(n_shards, cap)

    bhi = scatter(khi, jnp.uint32(0xFFFFFFFF))
    blo = scatter(klo, jnp.uint32(0xFFFFFFFF))
    bpl = [scatter(p, jnp.asarray(0, p.dtype)) for p in payloads]
    return bhi, blo, bpl, overflow


def make_hdb_step(cfg: HDBConfig, mesh: Mesh,
                  axis_names: Sequence[str],
                  dist: DistConfig = DistConfig()):
    """Build the jitted, shard_mapped distributed HDB iteration."""
    n_shards = int(np.prod([mesh.shape[a] for a in axis_names]))
    axes = tuple(axis_names)
    bloom_cfg = sketches.BloomConfig(dist.bloom_slots, dist.bloom_hashes)

    def local_step(keys_packed, valid, psize):
        n_loc, k = valid.shape
        shard = _linear_shard_index(axes)
        rid0 = shard * jnp.int32(n_loc)
        key = (keys_packed[..., 0], keys_packed[..., 1])

        # ---- rough over-size detection (Alg. 3), CMS merged via psum ----
        flat_key = (key[0].reshape(-1), key[1].reshape(-1))
        flat_valid = valid.reshape(-1)
        cms = sketches.cms_build(cfg.cms, flat_key, flat_valid)
        cms = jax.lax.psum(cms, axes)
        s = sketches.cms_query(cfg.cms, cms, flat_key).reshape(valid.shape)
        right_cms = valid & (s <= cfg.max_block_size)
        progress = s.astype(jnp.float32) <= cfg.max_similarity * psize.astype(jnp.float32)
        keep = valid & ~right_cms & progress
        dropped_sim = valid & ~right_cms & ~progress

        # ---- exact count: route surviving entries to key-owner shards ----
        L = n_loc * k
        flat_keep = keep.reshape(-1)
        khi = jnp.where(flat_keep, flat_key[0], jnp.uint32(0xFFFFFFFF))
        klo = jnp.where(flat_keep, flat_key[1], jnp.uint32(0xFFFFFFFF))
        rid = rid0 + jnp.broadcast_to(
            jnp.arange(n_loc, dtype=jnp.int32)[:, None], (n_loc, k)).reshape(-1)
        _, owner_h = hashing.hash_u64((khi, klo), seed=0xA110)
        owner = jnp.where(flat_keep,
                          (owner_h % jnp.uint32(n_shards)).astype(jnp.int32),
                          jnp.int32(n_shards))
        cap = int(np.ceil(L / n_shards * dist.route_slack))
        bhi, blo, (brid,), route_overflow = _route(khi, klo, [rid], owner, n_shards, cap)
        bhi = jax.lax.all_to_all(bhi, axes, 0, 0, tiled=True)
        blo = jax.lax.all_to_all(blo, axes, 0, 0, tiled=True)
        brid = jax.lax.all_to_all(brid, axes, 0, 0, tiled=True)

        # ---- owner-side exact counts + fingerprints (local sort) ----
        fhi, flo, frid = bhi.reshape(-1), blo.reshape(-1), brid.reshape(-1)
        (shi, slo), (srid,) = segments.sort_by_key((fhi, flo), [frid])
        skey = (shi, slo)
        live = ~u64.is_sentinel(skey)
        sizes = segments.segment_counts(skey)
        fp = hashing.fingerprint_rid(srid)
        fp = (jnp.where(live, fp[0], 0), jnp.where(live, fp[1], 0))
        xors = segments.segment_xor(skey, fp)
        over = live & (sizes > cfg.max_block_size)
        reps = segments.segment_starts(skey) & over

        # Bloom over ALL over-sized keys (H_O), OR-merged across shards
        bloom = sketches.bloom_build(bloom_cfg, skey, reps)
        bloom = jax.lax.pmax(bloom, axes)

        # ---- dedupe: route representatives by membership fingerprint ----
        rcap = dist.rep_capacity_per_shard
        n_reps = jnp.sum(reps.astype(jnp.int32))
        rep_overflow = jnp.maximum(n_reps - rcap, 0)
        rep_idx = jnp.nonzero(reps, size=rcap, fill_value=skey[0].shape[0] - 1)[0]
        rep_ok = jnp.arange(rcap, dtype=jnp.int32) < n_reps
        r_khi = jnp.where(rep_ok, shi[rep_idx], jnp.uint32(0xFFFFFFFF))
        r_klo = jnp.where(rep_ok, slo[rep_idx], jnp.uint32(0xFFFFFFFF))
        r_xhi = jnp.where(rep_ok, xors[0][rep_idx], jnp.uint32(0xFFFFFFFF))
        r_xlo = jnp.where(rep_ok, xors[1][rep_idx], jnp.uint32(0xFFFFFFFF))
        r_sz = jnp.where(rep_ok, sizes[rep_idx], INT32_MAX)
        _, xo = hashing.hash_u64((r_xhi, r_xlo), seed=0xDED0)
        xowner = jnp.where(rep_ok, (xo % jnp.uint32(n_shards)).astype(jnp.int32),
                           jnp.int32(n_shards))
        xcap = int(np.ceil(rcap / n_shards * dist.route_slack)) + 8
        r_live = rep_ok.astype(jnp.int32)
        xhi_b, xlo_b, (xsz_b, xkhi_b, xklo_b, xlive_b), x_overflow = _route(
            r_xhi, r_xlo, [r_sz, r_khi, r_klo, r_live], xowner, n_shards, xcap)
        xhi_b = jax.lax.all_to_all(xhi_b, axes, 0, 0, tiled=True)
        xlo_b = jax.lax.all_to_all(xlo_b, axes, 0, 0, tiled=True)
        xsz_b = jax.lax.all_to_all(xsz_b, axes, 0, 0, tiled=True)
        xkhi_b = jax.lax.all_to_all(xkhi_b, axes, 0, 0, tiled=True)
        xklo_b = jax.lax.all_to_all(xklo_b, axes, 0, 0, tiled=True)
        xlive_b = jax.lax.all_to_all(xlive_b, axes, 0, 0, tiled=True)
        g_xhi, g_xlo, g_sz, g_khi, g_klo, g_live = jax.lax.sort(
            (xhi_b.reshape(-1), xlo_b.reshape(-1), xsz_b.reshape(-1),
             xkhi_b.reshape(-1), xklo_b.reshape(-1), xlive_b.reshape(-1)),
            num_keys=5)
        dup = ((g_xhi == jnp.roll(g_xhi, 1)) & (g_xlo == jnp.roll(g_xlo, 1))
               & (g_sz == jnp.roll(g_sz, 1)))
        dup = dup.at[0].set(False)
        is_real = g_live > 0
        survivor = is_real & ~dup
        n_dup = jnp.sum((is_real & dup).astype(jnp.int32))
        n_dup = jax.lax.psum(n_dup, axes)

        # ---- broadcast the survivor counts map (all_gather + sort) ----
        t_khi = jnp.where(survivor, g_khi, jnp.uint32(0xFFFFFFFF))
        t_klo = jnp.where(survivor, g_klo, jnp.uint32(0xFFFFFFFF))
        t_sz = jnp.where(survivor, g_sz, 0)
        t_khi = jax.lax.all_gather(t_khi, axes, tiled=True)
        t_klo = jax.lax.all_gather(t_klo, axes, tiled=True)
        t_sz = jax.lax.all_gather(t_sz, axes, tiled=True)
        t_khi, t_klo, t_sz = jax.lax.sort((t_khi, t_klo, t_sz), num_keys=2)

        # ---- classify original local entries (paper Alg. 4 lines 9-19) ----
        in_bloom = sketches.bloom_query(bloom_cfg, bloom, (khi, klo)).reshape(valid.shape)
        hit, ex_size = segments.lookup_u64((t_khi, t_klo), t_sz, (khi, klo), 0)
        hit = hit.reshape(valid.shape)
        ex_size = ex_size.reshape(valid.shape)
        right_exact = keep & ~in_bloom
        survive = keep & hit
        accepted = right_cms | right_exact

        # ---- intersect locally (Alg. 2) ----
        new_key, new_valid, new_psize, n_dropped_mk = intersect_keys(
            cfg, key, survive, ex_size)

        def tot(x):
            return jax.lax.psum(jnp.sum(x.astype(jnp.int32)), axes)

        stats = {
            "n_live_keys": tot(valid),
            "n_right_cms": tot(right_cms),
            "n_right_exact": tot(right_exact),
            "n_dropped_similarity": tot(dropped_sim),
            "n_dropped_max_keys": jax.lax.psum(n_dropped_mk, axes),
            "n_duplicate_blocks": n_dup,
            "n_surviving_oversized": jax.lax.psum(
                jnp.sum(survivor.astype(jnp.int32)), axes),
            "n_surviving_entries": tot(survive),
            "rep_overflow": jax.lax.psum(rep_overflow + route_overflow
                                         + x_overflow, axes),
        }
        new_packed = jnp.stack([new_key[0], new_key[1]], axis=-1)
        return accepted, new_packed, new_valid, new_psize, stats

    spec3 = P(axes, None, None)
    spec2 = P(axes, None)
    stats_spec = {k: P() for k in [
        "n_live_keys", "n_right_cms", "n_right_exact", "n_dropped_similarity",
        "n_dropped_max_keys", "n_duplicate_blocks", "n_surviving_oversized",
        "n_surviving_entries", "rep_overflow"]}
    mapped = shard_map(
        local_step, mesh=mesh,
        in_specs=(spec3, spec2, spec2),
        out_specs=(spec2, spec3, spec2, spec2, stats_spec),
        check_rep=False)
    return jax.jit(mapped)


def distributed_hashed_dynamic_blocking(
    keys_packed, valid, cfg: HDBConfig, mesh: Mesh,
    axis_names: Sequence[str] = ("data",),
    dist: DistConfig = DistConfig(),
    checkpoint_cb=None,
    start_iteration: int = 0,
    verbose: bool = False,
) -> BlockingResult:
    """Multi-device HDB driver (Algorithm 1) over a shard_mapped step.

    ``checkpoint_cb(iteration, state_pytree)`` — optional fault-tolerance
    hook invoked after every iteration with the (sharded) live state.
    """
    n = valid.shape[0]
    axes = tuple(axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    assert n % n_shards == 0, (n, n_shards)
    sharding3 = NamedSharding(mesh, P(axes, None, None))
    sharding2 = NamedSharding(mesh, P(axes, None))
    keys_packed = jax.device_put(keys_packed, sharding3)
    valid = jax.device_put(valid, sharding2)
    psize = jax.device_put(jnp.full(valid.shape, INT32_MAX, jnp.int32), sharding2)

    step = make_hdb_step(cfg, mesh, axes, dist)
    acc_rid: List[np.ndarray] = []
    acc_hi: List[np.ndarray] = []
    acc_lo: List[np.ndarray] = []
    all_stats: List[IterationStats] = []
    for it in range(start_iteration, cfg.max_iterations):
        accepted, new_keys, new_valid, new_psize, stats = step(keys_packed, valid, psize)
        acc = np.asarray(accepted)
        ridx, kidx = np.nonzero(acc)
        keys_np = np.asarray(keys_packed)
        acc_rid.append(ridx.astype(np.int64))
        acc_hi.append(keys_np[ridx, kidx, 0])
        acc_lo.append(keys_np[ridx, kidx, 1])
        st = IterationStats(iteration=it, **{k: int(v) for k, v in stats.items()})
        all_stats.append(st)
        logger.log(logging.INFO if verbose else logging.DEBUG,
                   "[hdb-dist] iter=%d %s", it, st)
        if st.rep_overflow:
            warnings.warn(
                f"[hdb-dist] buffer overflow ({st.rep_overflow} entries "
                "dropped); raise DistConfig capacities",
                RepCapacityWarning, stacklevel=2)
        keys_packed, valid, psize = new_keys, new_valid, new_psize
        if checkpoint_cb is not None:
            checkpoint_cb(it, {"keys": keys_packed, "valid": valid, "psize": psize})
        if st.n_surviving_entries == 0:
            break
    return BlockingResult(
        rids=np.concatenate(acc_rid) if acc_rid else np.zeros((0,), np.int64),
        key_hi=np.concatenate(acc_hi) if acc_hi else np.zeros((0,), np.uint32),
        key_lo=np.concatenate(acc_lo) if acc_lo else np.zeros((0,), np.uint32),
        stats=all_stats,
        num_records=n,
    )


# ---------------------------------------------------------------------------
# Distributed pair materialization (paper §3.1 over the mesh)
# ---------------------------------------------------------------------------


def materialize_pairs_distributed(
    blocks, mesh: Mesh, axis_names: Sequence[str] = ("data",),
    budget: int = 50_000_000, chunk_per_shard: int = 1 << 18,
    interpret: bool = True, sample_seed: int = 0,
):
    """Shard pair-slot decoding over the mesh; dedupe once at the end.

    The canonical pair-slot space [0, total) is round-robined over shards
    in fixed ``chunk_per_shard`` chunks via shard_map — slot decoding is
    embarrassingly parallel (every shard holds the replicated CSR arrays
    and decodes a disjoint contiguous slot range, the same computation as
    ``kernels.pairs.decode_chunk``). The largest-block-wins dedupe needs
    one global sort, which runs once over the bounded (<= budget + pad)
    pair buffer. Output is bit-identical to
    ``core.pairs.dedupe_pairs(blocks)`` on a single device.

    Budget-exceeded (sampling) and int32-contract fallbacks delegate to
    the single-device driver.
    """
    from . import pairs as pairs_lib
    from ..kernels import pairs as pairs_kernels
    from ..kernels.pairs import ref as pairs_ref

    axes = tuple(axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    chunk = chunk_per_shard
    per_round = n_shards * chunk
    total = blocks.num_pair_slots
    reason = pairs_lib._device_contract_ok(blocks, budget)
    if reason is None and total + per_round > INT32_MAX:
        # shard bases of the padded final round would wrap int32
        reason = f"slot space {total} + round {per_round} overflows int32"
    if total == 0 or total > budget or reason is not None:
        if reason is not None:
            warnings.warn(f"distributed pairs unavailable ({reason}); "
                          "using single-device driver", RuntimeWarning,
                          stacklevel=2)
        return pairs_lib.dedupe_pairs(blocks, budget=budget,
                                      sample_seed=sample_seed,
                                      interpret=interpret)

    cum32 = jnp.asarray(pairs_ref.cum_pair_counts(blocks.size), jnp.int32)
    start32 = jnp.asarray(blocks.start, jnp.int32)
    size32 = jnp.asarray(blocks.size, jnp.int32)
    mem32 = jnp.asarray(blocks.members, jnp.int32)

    def local_decode(cum, start, size, members, base):
        return pairs_kernels.decode_chunk(
            cum, start, size, members, base[0], jnp.int32(total),
            chunk=chunk, use_kernel=False, interpret=interpret)

    mapped = jax.jit(shard_map(
        local_decode, mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(axes)),
        out_specs=(P(axes), P(axes), P(axes), P(axes)),
        check_rep=False))

    shard_offsets = np.arange(n_shards, dtype=np.int32) * chunk
    out_a, out_b, out_s, out_v = [], [], [], []
    for r0 in range(0, total, per_round):
        base = jnp.asarray(np.int32(r0) + shard_offsets)
        a, b, s, v = mapped(cum32, start32, size32, mem32, base)
        out_a.append(np.asarray(a)); out_b.append(np.asarray(b))
        out_s.append(np.asarray(s)); out_v.append(np.asarray(v))
    sa, sb, ss, winner = pairs_kernels.dedupe_device(
        jnp.asarray(np.concatenate(out_a)), jnp.asarray(np.concatenate(out_b)),
        jnp.asarray(np.concatenate(out_s)), jnp.asarray(np.concatenate(out_v)))
    w = np.asarray(winner)
    return pairs_lib.PairSet(
        a=np.asarray(sa)[w].astype(np.int64),
        b=np.asarray(sb)[w].astype(np.int64),
        src_size=np.asarray(ss)[w].astype(np.int64),
        exact=True, total_slots=total)
