"""Shard routing primitives shared by the distributed HDB step and the
fingerprint-routed pair dedupe.

Every distributed exchange in this codebase follows the same HDB pattern
(paper §4): compute an int32 ``owner`` shard per entry, scatter entries
into fixed-capacity per-destination buckets (``route_buckets``), and swap
the buckets with ONE ``all_to_all`` (``exchange``). Fixed capacities keep
every buffer shape static under jit; overflows are *counted*, never
silent — callers decide whether to warn (HDB accepts lossy routing of a
shrinking survivor set) or fall back (pair dedupe must stay exact).

``linear_shard_index`` linearizes a multi-axis mesh position into the
flat shard id used by ``owner % n_shards`` routing. Axis sizes are taken
from the mesh *statically* (``jax.lax.axis_size`` does not exist on the
pinned JAX version, and sizes are compile-time constants anyway).

Ownership seeds are shared constants: ``KEY_OWNER_SEED`` partitions
64-bit block keys (the HDB exact-count exchange AND the sharded
streaming ``BlockStore``'s key-table/CMS/CSR slices — same partition, so
a batch shard and a streaming shard agree on who owns a key) and
``REP_OWNER_SEED`` partitions membership fingerprints / pair packs.
``np_owner_u64`` is the bit-exact host mirror of the device rule
(low 32 hash bits mod n_shards), letting host-resident streaming state
route without staging keys through the device.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from . import hashing

# Shared fingerprint-routing seeds (see module doc).
KEY_OWNER_SEED = 0xA110
REP_OWNER_SEED = 0xDED0

# Group ranks come from a one-hot running count (O(n * n_shards)
# vectorized adds; beats XLA's comparator argsort by a wide margin on CPU)
# only while the (n, n_shards+1) transient stays small; big routes (the
# HDB key exchange at production L) and wide meshes (> 64 shards) keep
# the O(n log n) argsort path — ``route_buckets`` is valid for ANY
# n_shards, the constants below only pick the rank strategy.
_ONEHOT_RANK_MAX_SHARDS = 64
_ONEHOT_RANK_MAX_ELEMS = 1 << 23  # int32 transient cap: 32 MiB


def np_owner_u64(x: np.ndarray, n_shards: int,
                 seed: int = KEY_OWNER_SEED) -> np.ndarray:
    """int32 owner shard per packed u64 value (host mirror).

    Bit-exact with the device rule used by ``core.distributed``:
    ``(low 32 bits of hash_u64(x, seed)) % n_shards``.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    h = hashing.np_hash_u64_vec(np.asarray(x, np.uint64), seed=seed)
    return ((h & np.uint64(0xFFFFFFFF))
            % np.uint64(n_shards)).astype(np.int32)


def linear_shard_index(mesh: Mesh, axis_names: Sequence[str]) -> jnp.ndarray:
    """Flat shard id of the calling device inside a shard_mapped fn.

    Row-major over ``axis_names``: consistent with how ``all_to_all`` over
    the same axis tuple orders its tiles, so ``owner == linear id`` routes
    to the right device.
    """
    idx = jnp.int32(0)
    for name in axis_names:
        idx = idx * int(mesh.shape[name]) + jax.lax.axis_index(name)
    return idx


def route_buckets(khi, klo, payloads, owner, n_shards: int, cap: int):
    """Scatter entries into per-destination buckets (pre-``all_to_all``).

    Args:
      khi, klo: uint32 limb pair of each entry's 64-bit key.
      payloads: extra per-entry arrays routed alongside the key.
      owner: int32 destination shard per entry; use ``n_shards`` to drop.
      cap: per-destination bucket capacity (static).

    Returns ``(bhi, blo, bucketed_payloads, overflow_count)`` with bucket
    shape ``(n_shards, cap)``; absent slots carry all-ones sentinel keys
    and zero payloads. ``overflow_count`` is the number of live entries
    that exceeded their destination bucket's capacity (dropped).
    """
    n = owner.shape[0]
    if (n_shards <= _ONEHOT_RANK_MAX_SHARDS
            and n * (n_shards + 1) <= _ONEHOT_RANK_MAX_ELEMS):
        # rank within destination group via one-hot running count:
        # rank[i] = #(j < i : owner[j] == owner[i])
        onehot = (owner[:, None]
                  == jnp.arange(n_shards + 1, dtype=owner.dtype)[None, :])
        rank = jnp.take_along_axis(
            jnp.cumsum(onehot.astype(jnp.int32), axis=0),
            jnp.clip(owner, 0, n_shards)[:, None], axis=1)[:, 0] - 1
    else:
        # general path: sort by owner; rank = position among same-owner
        order = jnp.argsort(owner)  # stable not required; ranks only need uniqueness
        owner_s = owner[order]
        rank_sorted = jnp.arange(n, dtype=jnp.int32) - jnp.searchsorted(
            owner_s, owner_s, side="left").astype(jnp.int32)
        rank = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)
    pos = owner * cap + rank
    ok = (owner < n_shards) & (rank < cap)
    overflow = jnp.sum(((owner < n_shards) & (rank >= cap)).astype(jnp.int32))
    flat_pos = jnp.where(ok, pos, n_shards * cap)  # OOB -> dropped

    def scatter(x, fill):
        buf = jnp.full((n_shards * cap,), fill, x.dtype)
        return buf.at[flat_pos].set(x, mode="drop").reshape(n_shards, cap)

    bhi = scatter(khi, jnp.uint32(0xFFFFFFFF))
    blo = scatter(klo, jnp.uint32(0xFFFFFFFF))
    bpl = [scatter(p, jnp.asarray(0, p.dtype)) for p in payloads]
    return bhi, blo, bpl, overflow


def exchange(axis_names: Sequence[str], *buckets) -> Tuple[jnp.ndarray, ...]:
    """all_to_all each ``(n_shards, cap)`` bucket over the mesh axes.

    After the exchange, row ``p`` of each returned array is the bucket
    this shard received from source shard ``p``.
    """
    out: List[jnp.ndarray] = []
    for b in buckets:
        out.append(jax.lax.all_to_all(b, tuple(axis_names), 0, 0, tiled=True))
    return tuple(out)
