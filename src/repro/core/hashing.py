"""Hash mixing for blocking keys, built on the u64 limb library.

The paper (§3.1) represents blocking keys as 128-bit murmur3 hashes and
record IDs as 64-bit longs, and combines keys during intersection with
``MURMUR3(key_i, key_j)``. We use the splitmix64 finalizer family (Steele
et al.) — the same avalanche quality class — on 64-bit values held as
uint32 limb pairs (see DESIGN.md §6 for the width rationale).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import u64
from .u64 import U64

# splitmix64 constants
_GAMMA = 0x9E3779B97F4A7C15
_M1 = 0xBF58476D1CE4E5B9
_M2 = 0x94D049BB133111EB


def mix64(x: U64) -> U64:
    """splitmix64 finalizer: full-avalanche bijective mixer on u64."""
    x = u64.xor(x, u64.shr(x, 30))
    x = u64.mul_const(x, _M1)
    x = u64.xor(x, u64.shr(x, 27))
    x = u64.mul_const(x, _M2)
    x = u64.xor(x, u64.shr(x, 31))
    return x


def hash_u64(x: U64, seed: int = 0) -> U64:
    """Seeded hash of a u64 value: mix(x + (seed+1)*gamma)."""
    return mix64(u64.add(x, u64.from_int((seed + 1) * _GAMMA)))


def hash_u32(x: jnp.ndarray, seed: int = 0) -> U64:
    """Seeded 64-bit hash of a uint32 array."""
    return hash_u64(u64.from_u32(x), seed)


def combine(a: U64, b: U64) -> U64:
    """Order-sensitive combine of two keys into a new key.

    Used for Algorithm 2 line 7 (intersection key = hash of the two parent
    keys). Both operands pass through the mixer so chains of intersections
    stay well distributed. Callers canonicalize order (a < b) so that
    combine(a,b) is the same key for the same unordered parent pair.
    """
    h = u64.xor(mix64(a), u64.rotl(b, 29))
    h = u64.add(h, u64.from_int(_GAMMA))
    return mix64(h)


def fingerprint_rid(rid: jnp.ndarray) -> U64:
    """64-bit membership fingerprint of a record id (uint32/int32 array).

    XOR-accumulated per block to form the paper's block-membership hash
    (Algorithm 4 line 4): since XOR is commutative/associative the result
    is independent of record order and computable with a segmented XOR.
    """
    return hash_u32(rid.astype(jnp.uint32), seed=0xB10C)


# ---------------------------------------------------------------------------
# numpy mirror (host-side tokenization / test oracles)
# ---------------------------------------------------------------------------

_MASK64 = (1 << 64) - 1


def np_mix64_vec(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer on a uint64 array (mirrors mix64).

    Bit-exact with the jnp limb-pair path; the streaming BlockStore uses it
    to maintain CMS bucket indices and membership fingerprints host-side
    without a device round trip per delta.
    """
    x = x.astype(np.uint64)
    x = x ^ (x >> np.uint64(30))
    x = (x * np.uint64(_M1)) & np.uint64(_MASK64)
    x = x ^ (x >> np.uint64(27))
    x = (x * np.uint64(_M2)) & np.uint64(_MASK64)
    x = x ^ (x >> np.uint64(31))
    return x


def np_hash_u64_vec(x: np.ndarray, seed: int = 0) -> np.ndarray:
    """Vectorized seeded hash of a uint64 array (mirrors hash_u64)."""
    gamma = ((seed + 1) * _GAMMA) & _MASK64
    return np_mix64_vec(x.astype(np.uint64) + np.uint64(gamma))


def np_fingerprint_rid(rid: np.ndarray) -> np.ndarray:
    """Vectorized uint64 mirror of fingerprint_rid (same 0xB10C seed)."""
    rid32 = rid.astype(np.uint32).astype(np.uint64)
    return np_hash_u64_vec(rid32, seed=0xB10C)


def np_mix64(x: int) -> int:
    x &= _MASK64
    x ^= x >> 30
    x = (x * _M1) & _MASK64
    x ^= x >> 27
    x = (x * _M2) & _MASK64
    x ^= x >> 31
    return x


def np_hash_u64(x: int, seed: int = 0) -> int:
    return np_mix64((x + (seed + 1) * _GAMMA) & _MASK64)


def np_rotl64(x: int, n: int) -> int:
    x &= _MASK64
    return ((x << n) | (x >> (64 - n))) & _MASK64


def np_combine(a: int, b: int) -> int:
    """Python mirror of combine() for the oracle tests (canonical order is
    the caller's job, as in the JAX path)."""
    h = (np_mix64(a) ^ np_rotl64(b, 29)) & _MASK64
    h = (h + _GAMMA) & _MASK64
    return np_mix64(h)


def np_hash_bytes(data: bytes, seed: int = 0) -> int:
    """Deterministic 64-bit hash of a byte string (host-side tokenizer).

    splitmix-style sponge over 8-byte little-endian chunks. Not crypto;
    just a stable, well-mixed fingerprint identical across runs/platforms.
    """
    h = np_hash_u64(len(data), seed)
    for i in range(0, len(data), 8):
        chunk = int.from_bytes(data[i : i + 8], "little")
        h = np_mix64((h ^ chunk) + _GAMMA & _MASK64)
    return h


def np_to_u64_arrays(values) -> np.ndarray:
    """Python ints -> packed (..., 2) uint32 array (storage form)."""
    arr = np.asarray(values, dtype=np.uint64)
    hi = (arr >> np.uint64(32)).astype(np.uint32)
    lo = (arr & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    return np.stack([hi, lo], axis=-1)
