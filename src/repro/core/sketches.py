"""Count-Min Sketch and Bloom filter — the paper's approximate structures.

Both are *linear* sketches over fixed-size dense arrays, which is exactly
what makes HDB distribution-friendly on a TPU pod: per-shard sketches are
built locally and merged with a single all-reduce (`+` for CMS, max/OR for
Bloom) instead of the Spark shuffle the paper's implementation uses
(DESIGN.md §2).

Count-Min semantics (paper §3.1 "Rough Over-sized Block Detection"): the
approximate count is never *less* than the true count, so no truly
over-sized block can be reported right-sized.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from . import hashing
from .u64 import U64


@dataclasses.dataclass(frozen=True)
class CMSConfig:
    depth: int = 4
    width: int = 1 << 20  # power of two; index = hash & (width-1)

    def __post_init__(self):
        assert self.width & (self.width - 1) == 0, "width must be a power of 2"


def cms_indices(cfg: CMSConfig, key: U64) -> jnp.ndarray:
    """(depth, *key_shape) int32 bucket indices for a u64 key array."""
    idx = []
    for j in range(cfg.depth):
        _, lo = hashing.hash_u64(key, seed=0xC0DE + j)
        idx.append((lo & jnp.uint32(cfg.width - 1)).astype(jnp.int32))
    return jnp.stack(idx, axis=0)


def cms_build(cfg: CMSConfig, key: U64, mask: jnp.ndarray) -> jnp.ndarray:
    """Build a (depth, width) int32 CMS from a flat array of keys."""
    idx = cms_indices(cfg, key)  # (depth, n)
    upd = mask.astype(jnp.int32)
    sketch = jnp.zeros((cfg.depth, cfg.width), jnp.int32)
    for j in range(cfg.depth):  # static, small depth
        sketch = sketch.at[j].add(jnp.zeros((cfg.width,), jnp.int32).at[idx[j]].add(upd))
    return sketch


def cms_query(cfg: CMSConfig, sketch: jnp.ndarray, key: U64) -> jnp.ndarray:
    """Approximate count per key: min over depth rows. Never undercounts."""
    idx = cms_indices(cfg, key)
    est = sketch[0, idx[0]]
    for j in range(1, cfg.depth):
        est = jnp.minimum(est, sketch[j, idx[j]])
    return est


def cms_merge(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """CMS is a linear sketch: merging = elementwise add (== psum)."""
    return a + b


def cms_fold(global_sketch, delta_sketch):
    """Fold a delta-batch sketch into a persistent global sketch.

    This one-liner is the core streaming-ingest argument: because the CMS
    is linear, the sketch of (corpus ∪ delta) is EXACTLY
    ``cms(corpus) + cms(delta)`` — no rebuild over the historical corpus,
    no approximation beyond what the CMS already carries. Works on jnp or
    np arrays (int add either way).
    """
    return global_sketch + delta_sketch


def cms_subtract(global_sketch, delta_sketch):
    """Remove previously-folded entries (linear sketch: subtraction).

    Exact — not the lossy "deletion" of probabilistic filters — because
    every removed entry was added with the same +1 updates, so counts
    stay the true non-negative bucket sums. The streaming delta blocker
    relies on this to retract a record's old key entries when its live
    key set changes between iterations.
    """
    return global_sketch - delta_sketch


def cms_decay(sketch, shift: int = 1):
    """Exponential decay hook for long-running streaming services.

    Halves every bucket ``shift`` times (integer right-shift). Ages out
    stale mass so a bounded-width CMS can run indefinitely under churn.
    NOTE: decay breaks the never-undercounts guarantee for entries that
    survive the decay, so exact batch/stream parity holds only between
    decay events; production use pairs this with re-ingesting live keys.
    """
    return sketch >> shift


def np_cms_indices(cfg: CMSConfig, key64) -> "np.ndarray":
    """Host mirror of cms_indices on packed uint64 keys.

    Bit-exact with the jnp path (same splitmix seeds 0xC0DE+j, same
    width mask); lets the streaming store compute bucket indices for
    delta entries without staging them through the device.
    """
    key64 = np.asarray(key64, np.uint64)
    idx = np.empty((cfg.depth,) + key64.shape, np.int32)
    for j in range(cfg.depth):
        h = hashing.np_hash_u64_vec(key64, seed=0xC0DE + j)
        idx[j] = (h & np.uint64(cfg.width - 1)).astype(np.int32)
    return idx


# ---------------------------------------------------------------------------
# Bloom filter
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BloomConfig:
    """Byte-per-bit Bloom filter (merge = elementwise max / OR).

    The paper packs bits (<=100MB at 530M rows); at this container's scale a
    byte-per-bit uint8 array is simpler and still small. Sizing follows the
    standard m = -n ln p / (ln 2)^2, k = (m/n) ln 2.
    """

    num_slots: int = 1 << 22
    num_hashes: int = 8

    @staticmethod
    def for_capacity(capacity: int, fpr: float = 1e-8) -> "BloomConfig":
        capacity = max(capacity, 1)
        m = int(-capacity * math.log(fpr) / (math.log(2) ** 2))
        m = 1 << max(10, math.ceil(math.log2(m)))
        k = max(1, round(m / capacity * math.log(2)))
        return BloomConfig(num_slots=m, num_hashes=min(k, 30))


def bloom_positions(cfg: BloomConfig, key: U64) -> jnp.ndarray:
    """(num_hashes, *shape) positions via Kirsch–Mitzenmacher double hashing."""
    _, h1 = hashing.hash_u64(key, seed=0xB100)
    _, h2 = hashing.hash_u64(key, seed=0xB101)
    h2 = h2 | jnp.uint32(1)  # odd => full-period stepping over power-of-2 table
    mask = jnp.uint32(cfg.num_slots - 1)
    return jnp.stack(
        [((h1 + jnp.uint32(i) * h2) & mask).astype(jnp.int32) for i in range(cfg.num_hashes)],
        axis=0,
    )


def bloom_build(cfg: BloomConfig, key: U64, mask: jnp.ndarray) -> jnp.ndarray:
    pos = bloom_positions(cfg, key)  # (k, n)
    bits = jnp.zeros((cfg.num_slots,), jnp.uint8)
    upd = mask.astype(jnp.uint8)
    for i in range(cfg.num_hashes):
        bits = bits.at[pos[i]].max(upd)
    return bits


def bloom_query(cfg: BloomConfig, bits: jnp.ndarray, key: U64) -> jnp.ndarray:
    pos = bloom_positions(cfg, key)
    hit = bits[pos[0]] > 0
    for i in range(1, cfg.num_hashes):
        hit = hit & (bits[pos[i]] > 0)
    return hit


def bloom_merge(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(a, b)
