"""Hashed Dynamic Blocking — Algorithms 1–4 of the paper, in fixed-shape JAX.

The iteration state is a dense per-record key matrix (records never move;
only 64-bit key hashes flow — the paper's data-movement thesis). Each host-
level iteration runs one jit-compiled step:

  1. ROUGH OVER-SIZE DETECTION (Alg. 3): build a Count-Min Sketch over all
     live (record, key) entries, query approximate block sizes. Keys with
     ``s <= MAX_BLOCK_SIZE`` are right-sized (CMS never undercounts, so this
     is safe). Keys failing the progress heuristic ``s/psize > MAX_SIMILARITY``
     are discarded.
  2. EXACTLY COUNT AND DEDUPE (Alg. 4): sort surviving entries by key;
     segmented count + XOR-of-rid-fingerprints give every entry its exact
     block size and its block's membership hash. Blocks the CMS over-counted
     are recovered as right-sized. Over-sized blocks with identical
     membership hashes are duplicates — one survivor is kept (smallest key).
  3. INTERSECT KEYS (Alg. 2): each record combines pairs of its surviving
     over-sized keys into new candidate keys carrying
     ``psize = min(parent sizes)``; records holding more than ``MAX_KEYS``
     keys are dropped from further processing.

Single-device path below; the shard_map-distributed path (sketch
all-reduce + all_to_all exact counting + Bloom/table broadcast, faithful
to the paper's Spark dataflow) lives in ``core/distributed.py`` and reuses
these functions.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
import warnings
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from . import u64, hashing, segments, sketches
from .u64 import U64

INT32_MAX = np.iinfo(np.int32).max
logger = logging.getLogger(__name__)


class RepCapacityWarning(RuntimeWarning):
    """Fixed-capacity representative/route buffers overflowed; some blocks
    were dropped. Raise the relevant capacity config."""


@dataclasses.dataclass(frozen=True)
class HDBConfig:
    """Hyper-parameters (paper §5 defaults)."""

    max_block_size: int = 500
    max_keys: int = 80            # Alg. 2 line 2: per-record key cap
    max_similarity: float = 0.9   # progress heuristic (Alg. 3 line 11)
    max_oversize_keys: int = 16   # TPU adaptation: keys carried into intersection
    max_iterations: int = 8
    cms_depth: int = 4
    cms_width: int = 1 << 20
    rep_capacity: int = 1 << 20   # capacity for over-sized block representatives

    @property
    def cms(self) -> sketches.CMSConfig:
        return sketches.CMSConfig(self.cms_depth, self.cms_width)

    @property
    def intersect_width(self) -> int:
        ko = self.max_oversize_keys
        return ko * (ko - 1) // 2


@dataclasses.dataclass
class IterationStats:
    iteration: int
    n_live_keys: int
    n_right_cms: int        # accepted by CMS bound
    n_right_exact: int      # recovered from CMS over-count
    n_dropped_similarity: int
    n_dropped_max_keys: int
    n_duplicate_blocks: int
    n_surviving_oversized: int  # unique over-sized blocks after dedupe
    n_surviving_entries: int
    rep_overflow: int


@dataclasses.dataclass
class BlockingResult:
    """Accepted (record, key) assignments across all iterations."""

    rids: np.ndarray        # (M,) int64 record ids
    key_hi: np.ndarray      # (M,) uint32
    key_lo: np.ndarray      # (M,) uint32
    stats: List[IterationStats]
    num_records: int

    @property
    def rep_overflow_total(self) -> int:
        """Over-sized block representatives dropped by the fixed
        ``rep_capacity`` buffer, summed over iterations.

        Nonzero means this result silently diverges from a capless run
        (e.g. the streaming BlockStore, which has no representative
        cap): dropped representatives never enter the survivor table, so
        their blocks neither dedupe nor intersect. The per-iteration
        counts are in ``stats[i].rep_overflow``; a ``RepCapacityWarning``
        fires as the overflow happens.
        """
        return sum(st.rep_overflow for st in self.stats)


# ---------------------------------------------------------------------------
# Jitted single-device iteration
# ---------------------------------------------------------------------------


def rough_classify(cfg: HDBConfig, s: jnp.ndarray, valid: jnp.ndarray,
                   psize: jnp.ndarray):
    """Algorithm 3 decision rule, given CMS estimates ``s``.

    Shared by the batch iteration (which builds the CMS from the live
    entries it is classifying) and the streaming delta path (which queries
    the persistent fold-in CMS held by a BlockStore): both must apply the
    same float32 progress comparison bit-for-bit for the incremental
    result to reproduce the batch result exactly.

    Returns (right_mask, keep_mask, dropped_similarity_mask).
    """
    right = valid & (s <= cfg.max_block_size)
    progress = s.astype(jnp.float32) <= cfg.max_similarity * psize.astype(jnp.float32)
    keep = valid & ~right & progress
    dropped_sim = valid & ~right & ~progress
    return right, keep, dropped_sim


def rough_oversize_detection(cfg: HDBConfig, key: U64, valid: jnp.ndarray,
                             psize: jnp.ndarray):
    """Algorithm 3. Returns (right_mask, keep_mask, dropped_mask, approx_counts)."""
    flat_key = (key[0].reshape(-1), key[1].reshape(-1))
    flat_valid = valid.reshape(-1)
    cms = sketches.cms_build(cfg.cms, flat_key, flat_valid)
    s = sketches.cms_query(cfg.cms, cms, flat_key).reshape(valid.shape)
    right, keep, dropped_sim = rough_classify(cfg, s, valid, psize)
    return right, keep, dropped_sim, s


def dedupe_oversized_reps(r_xhi: jnp.ndarray, r_xlo: jnp.ndarray,
                          r_sz: jnp.ndarray, r_khi: jnp.ndarray,
                          r_klo: jnp.ndarray):
    """Deduplicate over-sized block representatives (Alg. 4 lines 6-9).

    One representative per over-sized block, described by its membership
    fingerprint ``(r_xhi, r_xlo)``, exact size ``r_sz`` and block key
    ``(r_khi, r_klo)``; invalid lanes carry sentinel keys/fingerprints and
    ``INT32_MAX`` size. Blocks with identical (fingerprint, size) are
    duplicates; the smallest key of each group survives.

    Shared by the batch iteration (reps extracted from the global sort)
    and the streaming delta path (reps taken from the BlockStore key
    table). Returns:
      table: ((t_khi, t_klo), t_sz) survivor keys sorted by key
      n_dup: number of duplicate representatives dropped
      survivor_in: bool mask aligned with the INPUT lanes marking survivors
    """
    m = r_khi.shape[0]
    orig = jnp.arange(m, dtype=jnp.int32)
    # sort by (xor, size, key): duplicates (same membership) become adjacent;
    # the smallest key of each duplicate group survives (full lexicographic
    # sort makes the survivor deterministic).
    r_xhi, r_xlo, r_sz, r_khi, r_klo, orig = jax.lax.sort(
        (r_xhi, r_xlo, r_sz, r_khi, r_klo, orig), num_keys=5)
    same_prev = (
        (r_xhi == jnp.roll(r_xhi, 1)) & (r_xlo == jnp.roll(r_xlo, 1))
        & (r_sz == jnp.roll(r_sz, 1)))
    same_prev = same_prev.at[0].set(False)
    rep_valid_sorted = ~((r_khi == jnp.uint32(0xFFFFFFFF)) & (r_klo == jnp.uint32(0xFFFFFFFF)))
    survivor = rep_valid_sorted & ~same_prev
    n_dup = jnp.sum((rep_valid_sorted & same_prev).astype(jnp.int32))

    # survivor table sorted by key for O(log) lookups (the paper's
    # "broadcasted counts map")
    t_khi = jnp.where(survivor, r_khi, jnp.uint32(0xFFFFFFFF))
    t_klo = jnp.where(survivor, r_klo, jnp.uint32(0xFFFFFFFF))
    t_sz = jnp.where(survivor, r_sz, 0)
    t_khi, t_klo, t_sz = jax.lax.sort((t_khi, t_klo, t_sz), num_keys=2)
    table = ((t_khi, t_klo), t_sz)
    survivor_in = jnp.zeros((m,), bool).at[orig].set(survivor)
    return table, n_dup, survivor_in


survivor_reps = jax.jit(dedupe_oversized_reps)


def exactly_count_and_dedupe(cfg: HDBConfig, key: U64, keep: jnp.ndarray):
    """Algorithm 4 (single-shard fast path — see core/distributed.py for the
    all_to_all + Bloom-broadcast variant).

    Returns dense (same shape as keep):
      right_exact: mask of entries whose block the CMS over-counted
      survive:     mask of entries on surviving (deduped) over-sized blocks
      size:        exact block size for `survive` entries
      plus (survivor key table, diagnostics) for downstream use.
    """
    n, k = keep.shape
    flat = keep.reshape(-1)
    nk = n * k
    rid = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, k)).reshape(-1)
    khi = jnp.where(flat, key[0].reshape(-1), jnp.uint32(0xFFFFFFFF))
    klo = jnp.where(flat, key[1].reshape(-1), jnp.uint32(0xFFFFFFFF))
    orig = jnp.arange(nk, dtype=jnp.int32)
    (shi, slo), (srid, sorig) = segments.sort_by_key((khi, klo), [rid, orig])
    skey = (shi, slo)
    live = ~u64.is_sentinel(skey)
    sizes = segments.segment_counts(skey)
    fp = hashing.fingerprint_rid(srid)
    fp = (jnp.where(live, fp[0], 0), jnp.where(live, fp[1], 0))
    xors = segments.segment_xor(skey, fp)

    over = live & (sizes > cfg.max_block_size)
    right_exact_sorted = live & ~over

    # --- dedupe over-sized blocks by membership fingerprint (XOR, size) ---
    reps = segments.segment_starts(skey) & over
    n_reps = jnp.sum(reps.astype(jnp.int32))
    rep_idx = jnp.nonzero(reps, size=cfg.rep_capacity, fill_value=nk - 1)[0]
    rep_valid = jnp.arange(cfg.rep_capacity, dtype=jnp.int32) < n_reps
    rep_overflow = jnp.maximum(n_reps - cfg.rep_capacity, 0)
    r_xhi = jnp.where(rep_valid, xors[0][rep_idx], jnp.uint32(0xFFFFFFFF))
    r_xlo = jnp.where(rep_valid, xors[1][rep_idx], jnp.uint32(0xFFFFFFFF))
    r_sz = jnp.where(rep_valid, sizes[rep_idx], INT32_MAX)
    r_khi = jnp.where(rep_valid, shi[rep_idx], jnp.uint32(0xFFFFFFFF))
    r_klo = jnp.where(rep_valid, slo[rep_idx], jnp.uint32(0xFFFFFFFF))
    table, n_dup, survivor = dedupe_oversized_reps(r_xhi, r_xlo, r_sz,
                                                   r_khi, r_klo)
    (t_khi, t_klo), t_sz = table

    # classify sorted entries: over-sized entries survive iff their key is in
    # the survivor table (duplicates' keys are absent -> dropped).
    hit, _ = segments.lookup_u64((t_khi, t_klo), t_sz, skey, 0)
    survive_sorted = over & hit

    # scatter back to dense layout
    def unsort(x_sorted, fill):
        out = jnp.full((nk,), fill, x_sorted.dtype)
        return out.at[sorig].set(x_sorted)

    right_exact = unsort(right_exact_sorted, False).reshape(n, k) & keep
    survive = unsort(survive_sorted, False).reshape(n, k) & keep
    size = unsort(jnp.where(live, sizes, 0), 0).reshape(n, k)
    n_survivors = jnp.sum(survivor.astype(jnp.int32))
    return right_exact, survive, size, table, n_dup, n_survivors, rep_overflow


def intersect_keys(cfg: HDBConfig, key: U64, survive: jnp.ndarray,
                   size: jnp.ndarray):
    """Algorithm 2: pairwise-intersect each record's over-sized keys.

    Keeps the ``max_oversize_keys`` smallest surviving blocks per record
    (rarest = most discriminative; DESIGN.md §2) and emits all pairwise
    combinations with ``psize = min(parent sizes)``.
    """
    n, k = survive.shape
    ko = min(cfg.max_oversize_keys, k)
    n_keys = jnp.sum(survive.astype(jnp.int32), axis=1)
    row_dead = n_keys > cfg.max_keys  # Alg. 2 line 2
    # order keys: surviving first, then by exact size ascending; key value
    # breaks ties so the cap selection is deterministic (oracle-testable)
    sort_sz = jnp.where(survive, size, INT32_MAX)
    sort_sz, khi_s, klo_s, surv_s = jax.lax.sort(
        (sort_sz, key[0], key[1], survive.astype(jnp.int32)), num_keys=3, dimension=1)
    khi_s, klo_s = khi_s[:, :ko], klo_s[:, :ko]
    sz_s = sort_sz[:, :ko]
    ok = (surv_s[:, :ko] > 0) & ~row_dead[:, None]

    ii, jj = np.triu_indices(ko, 1)
    a = (khi_s[:, ii], klo_s[:, ii])
    b = (khi_s[:, jj], klo_s[:, jj])
    lo_key = u64.minimum(a, b)
    hi_key = u64.where(u64.eq(lo_key, a), b, a)
    new_key = hashing.combine(lo_key, hi_key)
    new_psize = jnp.minimum(sz_s[:, ii], sz_s[:, jj])
    new_valid = ok[:, ii] & ok[:, jj]
    new_khi = jnp.where(new_valid, new_key[0], jnp.uint32(0xFFFFFFFF))
    new_klo = jnp.where(new_valid, new_key[1], jnp.uint32(0xFFFFFFFF))
    # per-record set semantics: one row-sort carrying psize, then mask repeats
    s_khi, s_klo, s_psize, s_valid = jax.lax.sort(
        (new_khi, new_klo, new_psize, new_valid.astype(jnp.int32)),
        num_keys=2, dimension=1)
    same_prev = jnp.concatenate(
        [jnp.zeros((s_khi.shape[0], 1), bool),
         (s_khi[:, 1:] == s_khi[:, :-1]) & (s_klo[:, 1:] == s_klo[:, :-1])], axis=1)
    out_valid = (s_valid > 0) & ~same_prev
    n_dropped_max_keys = jnp.sum(row_dead.astype(jnp.int32))
    return (s_khi, s_klo), out_valid, s_psize, n_dropped_max_keys


@functools.partial(jax.jit, static_argnums=0)
def hdb_iteration(cfg: HDBConfig, keys_packed: jnp.ndarray, valid: jnp.ndarray,
                  psize: jnp.ndarray):
    """One full HDB iteration. Returns (accepted_mask, new_state, stats)."""
    key = (keys_packed[..., 0], keys_packed[..., 1])
    right_cms, keep, dropped_sim, _ = rough_oversize_detection(cfg, key, valid, psize)
    (right_exact, survive, size, _table, n_dup, n_survivors,
     rep_overflow) = exactly_count_and_dedupe(cfg, key, keep)
    accepted = right_cms | right_exact
    new_key, new_valid, new_psize, n_dropped_mk = intersect_keys(cfg, key, survive, size)
    stats = {
        "n_live_keys": jnp.sum(valid.astype(jnp.int32)),
        "n_right_cms": jnp.sum(right_cms.astype(jnp.int32)),
        "n_right_exact": jnp.sum(right_exact.astype(jnp.int32)),
        "n_dropped_similarity": jnp.sum(dropped_sim.astype(jnp.int32)),
        "n_dropped_max_keys": n_dropped_mk,
        "n_duplicate_blocks": n_dup,
        "n_surviving_oversized": n_survivors,
        "n_surviving_entries": jnp.sum(survive.astype(jnp.int32)),
        "rep_overflow": rep_overflow,
    }
    new_state = (jnp.stack([new_key[0], new_key[1]], axis=-1), new_valid, new_psize)
    return accepted, new_state, stats


# ---------------------------------------------------------------------------
# Host-side driver (Algorithm 1)
# ---------------------------------------------------------------------------


def hashed_dynamic_blocking(
    keys_packed: jnp.ndarray,
    valid: jnp.ndarray,
    cfg: HDBConfig = HDBConfig(),
    verbose: bool = False,
) -> BlockingResult:
    """Run HDB to convergence over a dense top-level key matrix.

    Args:
      keys_packed: (N, K, 2) uint32 u64 keys from ``blocks.build_keys``.
      valid: (N, K) bool.
    """
    n = valid.shape[0]
    # explicit upload: eager jnp.full is an implicit host->device transfer
    # (rejected under jax.transfer_guard("disallow") — repro.analysis R001)
    psize = jnp.asarray(np.full(valid.shape, INT32_MAX, np.int32))
    acc_rid: List[np.ndarray] = []
    acc_hi: List[np.ndarray] = []
    acc_lo: List[np.ndarray] = []
    all_stats: List[IterationStats] = []
    for it in range(cfg.max_iterations):
        accepted, (new_keys, new_valid, new_psize), stats = hdb_iteration(
            cfg, keys_packed, valid, psize)
        acc_np = np.asarray(accepted)
        ridx, kidx = np.nonzero(acc_np)
        keys_np = np.asarray(keys_packed)
        acc_rid.append(ridx.astype(np.int64))
        acc_hi.append(keys_np[ridx, kidx, 0])
        acc_lo.append(keys_np[ridx, kidx, 1])
        st = IterationStats(iteration=it, **{k: int(v) for k, v in stats.items()})
        all_stats.append(st)
        logger.log(logging.INFO if verbose else logging.DEBUG,
                   "[hdb] iter=%d %s", it, st)
        if st.rep_overflow:
            warnings.warn(
                f"[hdb] representative capacity overflow ({st.rep_overflow} "
                "blocks dropped); raise HDBConfig.rep_capacity",
                RepCapacityWarning, stacklevel=2)
        keys_packed, valid, psize = new_keys, new_valid, new_psize
        if st.n_surviving_entries == 0:
            break
    else:
        leftover = int(jnp.sum(valid.astype(jnp.int32)))
        if leftover:
            logger.info("[hdb] max_iterations reached with %d live keys dropped",
                        leftover)
    return BlockingResult(
        rids=np.concatenate(acc_rid) if acc_rid else np.zeros((0,), np.int64),
        key_hi=np.concatenate(acc_hi) if acc_hi else np.zeros((0,), np.uint32),
        key_lo=np.concatenate(acc_lo) if acc_lo else np.zeros((0,), np.uint32),
        stats=all_stats,
        num_records=n,
    )
