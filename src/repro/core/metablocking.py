"""Parallel Meta-blocking baseline (paper §4.2 / §5, Efthymiou et al. [11]).

Meta-blocking builds a graph whose nodes are records and whose edges are
record pairs co-occurring in at least one block, weights the edges, and
prunes weak ones. We implement the standard pipeline the paper benchmarks
against:

  1. Block purging: discard blocks above a size cap (the paper's PMB purges
     the very largest blocks to bound the comparison count).
  2. Block filtering [22]: each record keeps only its ``filter_ratio``
     smallest blocks.
  3. Edge weighting: CBS (common blocks scheme) = number of shared blocks.
  4. Weighted Edge Pruning (WEP): keep edges with weight >= global mean.

Meta-blocking is linear in the *input comparison count* (the paper's
central criticism of it — §4.2), so at this container's scale it is
bounded by an explicit pair budget; exceeding the budget raises,
mirroring the paper's observation that PMB fails outright on their 50M+
datasets. Candidate-edge enumeration (stage 3, the hot loop) streams
through the device-side pair engine (``core.pairs.enumerate_pairs``,
selectable via ``MetaBlockingConfig.pairs_backend``); purge/filter/CBS
weighting stay numpy host-side.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from . import pairs as pairs_lib
from .hdb import BlockingResult, IterationStats


class MetaBlockingBudgetError(RuntimeError):
    """Raised when the candidate-edge count exceeds the memory budget
    (the analog of PMB's OOM failures on the paper's large datasets)."""


@dataclasses.dataclass(frozen=True)
class MetaBlockingConfig:
    purge_block_size: int = 2_000      # stage 1
    filter_ratio: float = 0.8          # stage 2 (keep smallest 80% of a record's blocks)
    edge_budget: int = 60_000_000      # candidate edges (with multiplicity)
    min_block_size: int = 2
    pairs_backend: str = "auto"        # stage 3 enumeration engine


def _blocks_from_keys(keys_np: np.ndarray, valid_np: np.ndarray):
    """(N,K,2)+(N,K) -> flat (key64, rid) sorted by key."""
    n, k = valid_np.shape
    rid = np.broadcast_to(np.arange(n, dtype=np.int64)[:, None], (n, k))[valid_np]
    khi = keys_np[..., 0][valid_np].astype(np.uint64)
    klo = keys_np[..., 1][valid_np].astype(np.uint64)
    key64 = (khi << np.uint64(32)) | klo
    order = np.lexsort((rid, key64))
    return key64[order], rid[order]


def meta_blocking(keys_packed, valid, cfg: MetaBlockingConfig = MetaBlockingConfig(),
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns pruned candidate pairs (a, b) with a < b."""
    keys_np = np.asarray(keys_packed)
    valid_np = np.asarray(valid)
    key64, rid = _blocks_from_keys(keys_np, valid_np)
    if len(key64) == 0:
        z = np.zeros((0,), np.int64)
        return z, z
    starts = np.flatnonzero(np.concatenate([[True], key64[1:] != key64[:-1]]))
    sizes = np.diff(np.concatenate([starts, [len(key64)]]))

    # --- stage 1: block purging ---
    keep_block = (sizes >= cfg.min_block_size) & (sizes <= cfg.purge_block_size)

    # --- stage 2: block filtering (keep each record's smallest blocks) ---
    block_id = np.repeat(np.arange(len(starts)), sizes)
    entry_keep = np.repeat(keep_block, sizes)
    ent_rid = rid[entry_keep]
    ent_block = block_id[entry_keep]
    ent_bsize = np.repeat(sizes, sizes)[entry_keep]
    # per record: sort by (rid, block size) and keep ceil(ratio * deg)
    order = np.lexsort((ent_bsize, ent_rid))
    ent_rid, ent_block, ent_bsize = ent_rid[order], ent_block[order], ent_bsize[order]
    r_starts = np.flatnonzero(np.concatenate([[True], ent_rid[1:] != ent_rid[:-1]]))
    r_sizes = np.diff(np.concatenate([r_starts, [len(ent_rid)]]))
    rank = np.arange(len(ent_rid)) - np.repeat(r_starts, r_sizes)
    keep_n = np.ceil(cfg.filter_ratio * r_sizes).astype(np.int64)
    entry_ok = rank < np.repeat(keep_n, r_sizes)
    ent_rid, ent_block = ent_rid[entry_ok], ent_block[entry_ok]

    # --- stage 3: candidate edges with CBS multiplicity ---
    order = np.lexsort((ent_rid, ent_block))
    b_sorted = ent_block[order]
    r_sorted = ent_rid[order]
    b_starts = np.flatnonzero(np.concatenate([[True], b_sorted[1:] != b_sorted[:-1]]))
    b_sizes = np.diff(np.concatenate([b_starts, [len(b_sorted)]]))
    total_edges = int(np.sum(b_sizes * (b_sizes - 1) // 2))
    if total_edges > cfg.edge_budget:
        raise MetaBlockingBudgetError(
            f"meta-blocking needs {total_edges:.3g} candidate edges "
            f"(> budget {cfg.edge_budget:.3g}); linear-in-comparisons cost "
            "is the paper's §4.2 criticism")
    edge_blocks = pairs_lib.Blocks(
        key_hi=np.zeros(len(b_starts), np.uint32),
        key_lo=np.zeros(len(b_starts), np.uint32),
        start=b_starts.astype(np.int64),
        size=b_sizes.astype(np.int64),
        members=r_sorted.astype(np.int64),
    )
    a_l, b_l = [], []
    for ca, cb, _ in pairs_lib.enumerate_pairs(edge_blocks,
                                               backend=cfg.pairs_backend):
        a_l.append(ca)
        b_l.append(cb)
    if not a_l:
        z = np.zeros((0,), np.int64)
        return z, z
    ea = np.concatenate(a_l)
    eb = np.concatenate(b_l)
    lo, hi = np.minimum(ea, eb), np.maximum(ea, eb)
    # CBS weight = multiplicity of (lo, hi)
    order = np.lexsort((hi, lo))
    lo, hi = lo[order], hi[order]
    first = np.concatenate([[True], (lo[1:] != lo[:-1]) | (hi[1:] != hi[:-1])])
    e_starts = np.flatnonzero(first)
    weights = np.diff(np.concatenate([e_starts, [len(lo)]]))
    ulo, uhi = lo[e_starts], hi[e_starts]

    # --- stage 4: WEP (keep weight >= mean) ---
    keep = weights >= weights.mean()
    return ulo[keep], uhi[keep]


def meta_blocking_result(keys_packed, valid,
                         cfg: MetaBlockingConfig = MetaBlockingConfig()
                         ) -> BlockingResult:
    """Wrap PMB's pair output as a BlockingResult (each pair = a 2-block)
    so the shared metrics/evaluation path applies."""
    a, b = meta_blocking(keys_packed, valid, cfg)
    # synthesize one unique key per pair
    pair_id = np.arange(len(a), dtype=np.uint64)
    key_hi = (pair_id >> np.uint64(32)).astype(np.uint32) | np.uint32(0x80000000)
    key_lo = (pair_id & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    stats = IterationStats(
        iteration=0, n_live_keys=int(np.asarray(valid).sum()), n_right_cms=0,
        n_right_exact=2 * len(a), n_dropped_similarity=0, n_dropped_max_keys=0,
        n_duplicate_blocks=0, n_surviving_oversized=0, n_surviving_entries=0,
        rep_overflow=0)
    return BlockingResult(
        rids=np.concatenate([a, b]),
        key_hi=np.concatenate([key_hi, key_hi]),
        key_lo=np.concatenate([key_lo, key_lo]),
        stats=[stats],
        num_records=valid.shape[0],
    )
