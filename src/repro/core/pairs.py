"""Pair materialization + deduplication (paper §3.1 "Pair Deduplication").

This is the *output* stage — the paper materializes pairs once, after all
iterations, because it is the single most expensive data-movement step
(68B pairs on the 530M-row run). The enumeration + "largest block wins"
cross-block dedupe therefore runs on device through the
``repro.kernels.pairs`` engine, with this module as a thin host driver:

- block reconstruction (group accepted (rid, key) assignments by key)
  into the CSR ``Blocks`` form,
- backend selection: ``backend="numpy"`` is the host reference
  implementation (the original shift-method enumeration + lexsort
  dedupe); ``"jax"`` decodes pair slots with fused XLA integer ops;
  ``"pallas"`` routes the triangular decode through the Pallas TPU kernel
  (interpret mode on CPU). ``"auto"`` picks ``"jax"`` when the int32
  device contract holds (all rids < 2**31, block sizes <=
  ``kernels.pairs.MAX_BLOCK_N``, budget < 2**31) and falls back to numpy
  otherwise; ``"distributed"`` dispatches to the fingerprint-routed
  shard-local dedupe over a device mesh
  (``core.distributed.dedupe_pairs_distributed``).
- chunking contract: device backends enumerate the canonical pair-slot
  space (blocks in CSR order, row-major triangle within a block — see
  ``kernels/pairs/ref.py``) in fixed-shape chunks of ``chunk_pairs``
  slots, so compilation is amortized across chunks and datasets and
  device memory stays bounded by ``budget + chunk_pairs`` pair slots
  regardless of corpus size. The final dedupe is ONE device sort by
  (a, b, size-descending) + a segment-start winner mask — no host hash
  pass.
- pair-budget guard: beyond ``budget`` total slots the engine switches to
  exact *counting* plus uniform slot *sampling* (``sample_seed``-seeded,
  shared across backends so they stay bit-identical), mirroring the
  paper's observation that one machine cannot materialize 68B pairs.
- the paper's strictly-upper-triangular pair *bitmap* encoding
  ``b(i,j,n) = i*(n-1) - (i-1)*i/2 + j - i - 1`` for compactly shipping a
  filtered subset of a block's pairs to pairwise matching.

Measured on this container's CPU (benchmarks/bench_pairs.py, 1M pair
slots): the numpy path is enumeration-bound and the device path
sort-bound; the crossover is around ~10k pair slots — below that, jit
dispatch overhead dominates and ``backend="numpy"`` wins; above it the
JAX path is ~5.6x faster on many-small-block layouts (the shift method's
worst case: one pass per diagonal offset), ~5.2x on medium (16-64) and
~2.4-2.5x on large/zipf layouts where numpy's per-block meshgrid path is
less penalized. Pallas interpret-mode timings are parity checks only.

sort_backend (the dedupe-sort knob, threaded through every device
dedupe call site down to ``kernels/sort``): ``"auto"`` keeps the
per-platform winner — the packed-u64 ``np.sort`` host path on the CPU
backend, the radix engine on real accelerators when rids fit the 62-bit
pack; ``"comparator"`` / ``"radix"`` force XLA's ``lax.sort`` vs the
LSB radix kernel. Measured on this CPU (``bench_pairs.py
--sort-backend radix``, ~300k slots): host np.sort ~4-8x the
comparator, and the comparator ~6x the jnp radix mirror — XLA CPU
lowers the per-pass scatter sequentially, so radix only pays off where
the comparator network's O(log^2 n) shuffle rounds dominate (TPU/GPU);
the knob exists so hardware runs can measure exactly that crossover.
All choices are bit-identical on every parity suite.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .hdb import BlockingResult
from ..kernels import pairs as pairs_kernels
from ..kernels.pairs import ref as pairs_ref

INT32_MAX = np.iinfo(np.int32).max


@dataclasses.dataclass
class Blocks:
    """Accepted blocks in CSR-ish form, sorted by (key, rid)."""

    key_hi: np.ndarray   # (B,) uint32 block key
    key_lo: np.ndarray   # (B,) uint32
    start: np.ndarray    # (B,) int64 offset into members
    size: np.ndarray     # (B,) int64
    members: np.ndarray  # (M,) int64 rids, sorted within each block

    @property
    def num_blocks(self) -> int:
        return len(self.start)

    @property
    def num_pair_slots(self) -> int:
        """Sum over blocks of C(n,2) — pairs BEFORE cross-block dedupe."""
        return int(np.sum(self.size * (self.size - 1) // 2))


def build_blocks(result: BlockingResult, min_size: int = 2) -> Blocks:
    """Group accepted (rid, key) assignments into blocks."""
    key64 = (result.key_hi.astype(np.uint64) << np.uint64(32)) | result.key_lo.astype(np.uint64)
    order = np.lexsort((result.rids, key64))
    key64 = key64[order]
    rids = result.rids[order]
    if len(key64) == 0:
        z64 = np.zeros((0,), np.int64)
        zu = np.zeros((0,), np.uint32)
        return Blocks(zu, zu, z64, z64, z64)
    starts = np.flatnonzero(np.concatenate([[True], key64[1:] != key64[:-1]]))
    sizes = np.diff(np.concatenate([starts, [len(key64)]]))
    keep = sizes >= min_size
    starts, sizes = starts[keep], sizes[keep]
    keys = key64[starts]
    return Blocks(
        key_hi=(keys >> np.uint64(32)).astype(np.uint32),
        key_lo=(keys & np.uint64(0xFFFFFFFF)).astype(np.uint32),
        start=starts.astype(np.int64),
        size=sizes.astype(np.int64),
        members=rids,
    )


def iter_block_pairs(blocks: Blocks, chunk_pairs: int = 2_000_000
                     ) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Yield (a, b, block_size) pair chunks across all blocks (HOST path).

    This is the numpy reference enumeration. Small blocks are emitted with
    the vectorized shift method: for offset d, every element pairs with
    the element d positions later iff both are in the same block. Large
    blocks fall back to per-block meshgrid emission. Chunk ORDER differs
    from the canonical slot order of the device engine; only the deduped
    pair *set* is order-canonical.
    """
    small_cut = 64
    small = blocks.size <= small_cut
    # --- small blocks: shift method over one concatenated array ---
    if np.any(small):
        s_start = blocks.start[small]
        s_size = blocks.size[small]
        total = int(s_size.sum())
        # vectorized gather of each kept block's member range
        offs = np.arange(total) - np.repeat(np.cumsum(s_size) - s_size, s_size)
        mem = blocks.members[np.repeat(s_start, s_size) + offs]
        seg = np.repeat(np.arange(len(s_size)), s_size)
        bsz = np.repeat(s_size, s_size)
        max_d = int(s_size.max())
        buf_a, buf_b, buf_s, buffered = [], [], [], 0
        for d in range(1, max_d):
            ok = seg[d:] == seg[:-d]
            if not ok.any():
                continue
            buf_a.append(mem[:-d][ok])
            buf_b.append(mem[d:][ok])
            buf_s.append(bsz[:-d][ok])
            buffered += int(ok.sum())
            if buffered >= chunk_pairs:
                yield np.concatenate(buf_a), np.concatenate(buf_b), np.concatenate(buf_s)
                buf_a, buf_b, buf_s, buffered = [], [], [], 0
        if buffered:
            yield np.concatenate(buf_a), np.concatenate(buf_b), np.concatenate(buf_s)
    # --- large blocks: per-block triangular emission ---
    for bi in np.flatnonzero(~small):
        s, n = int(blocks.start[bi]), int(blocks.size[bi])
        m = blocks.members[s : s + n]
        ii, jj = np.triu_indices(n, 1)
        for off in range(0, len(ii), chunk_pairs):
            sl = slice(off, off + chunk_pairs)
            yield m[ii[sl]], m[jj[sl]], np.full(len(ii[sl]), n, np.int64)


@dataclasses.dataclass
class PairSet:
    """Distinct pairs with largest-source-block provenance."""

    a: np.ndarray          # (P,) int64, a < b, sorted by (a, b)
    b: np.ndarray          # (P,) int64
    src_size: np.ndarray   # (P,) int64 size of largest block producing the pair
    exact: bool            # False => uniform slot sampling (budget exceeded)
    total_slots: int       # sum C(n,2) before dedupe
    # device-resident (a, b) from the device dedupe path, when it ran —
    # lets the matcher consume the pair buffer without a host round trip
    device_a: Optional[jax.Array] = None
    device_b: Optional[jax.Array] = None

    def pair_buffers(self):
        """(a, b) as device arrays; zero-copy when the device engine
        produced them, a single upload otherwise."""
        if self.device_a is not None:
            return self.device_a, self.device_b
        # pre-cast host-side: uploading int64 under x64-off would be a
        # dtype-coercing implicit transfer (repro.analysis R001)
        return (jnp.asarray(np.asarray(self.a, np.int32)),
                jnp.asarray(np.asarray(self.b, np.int32)))


# ---------------------------------------------------------------------------
# Backend selection + sampling fallback (shared host plumbing)
# ---------------------------------------------------------------------------

_BACKENDS = ("auto", "numpy", "jax", "pallas", "distributed")
_SORT_BACKENDS = ("auto", "comparator", "radix")
# below this many pair slots, jit dispatch overhead beats the numpy loop
# (measured crossover, see module docstring); "auto" stays host-side there
_AUTO_NUMPY_CROSSOVER = 10_000


def _device_contract_ok(blocks: Blocks, budget: int) -> Optional[str]:
    """None if the int32 device engine applies, else the reason it doesn't."""
    if budget >= INT32_MAX:
        return f"budget {budget} >= int32 max"
    if blocks.num_blocks == 0:
        return None
    max_n = int(blocks.size.max())
    if max_n > pairs_kernels.MAX_BLOCK_N:
        return f"block size {max_n} > MAX_BLOCK_N {pairs_kernels.MAX_BLOCK_N}"
    if len(blocks.members) and int(blocks.members.max()) >= INT32_MAX:
        return "record ids >= int32 max"
    return None


def _resolve_backend(backend: str, blocks: Blocks, budget: int) -> str:
    if backend not in _BACKENDS:
        raise ValueError(f"backend must be one of {_BACKENDS}, got {backend!r}")
    assert backend != "distributed"  # dispatched before resolution
    if backend == "numpy":
        return "numpy"
    if backend == "auto" and blocks.num_pair_slots < _AUTO_NUMPY_CROSSOVER:
        return "numpy"
    reason = _device_contract_ok(blocks, budget)
    if reason is None:
        return "jax" if backend == "auto" else backend
    if backend != "auto":
        warnings.warn(f"pairs backend {backend!r} unavailable ({reason}); "
                      "falling back to numpy", RuntimeWarning, stacklevel=3)
    return "numpy"


def _sample_slots(total: int, budget: int, seed: int) -> np.ndarray:
    """Deterministic uniform pair-slot sample (shared across backends).

    Returns exactly ``min(budget, total)`` sorted distinct int64 slot
    indices, allocating O(budget) memory regardless of ``total`` (the
    slot space reaches 68B pairs at paper scale — materializing it, as a
    full permutation would, is off the table). Dense draws
    (``2 * budget >= total``) permute the slot range, which is already
    O(budget); sparse draws reject duplicates in geometrically-growing
    with-replacement rounds and then subsample the distinct set
    uniformly — by slot exchangeability that is an exact uniform draw
    without replacement.
    """
    rng = np.random.default_rng(seed)
    budget = max(0, min(budget, total))
    if budget == 0:
        return np.zeros((0,), np.int64)
    if 2 * budget >= total:
        return np.sort(rng.permutation(total)[:budget]).astype(np.int64)
    uniq = np.zeros((0,), np.int64)
    while len(uniq) < budget:
        need = budget - len(uniq)
        draws = rng.integers(0, total, size=int(need * 1.1) + 16,
                             dtype=np.int64)
        uniq = np.unique(np.concatenate([uniq, draws]))
    if len(uniq) > budget:
        # subsample uniformly — truncating the SORTED uniques would
        # systematically exclude the top of the slot space
        uniq = np.sort(uniq[rng.choice(len(uniq), budget, replace=False)])
    return uniq


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _empty_pairset(exact: bool, total: int) -> PairSet:
    z = np.zeros((0,), np.int64)
    return PairSet(z, z, z, exact, total)


# ---------------------------------------------------------------------------
# Backend pair-materialization paths
# ---------------------------------------------------------------------------


def _dedupe_numpy(blocks: Blocks, slots: Optional[np.ndarray]) -> Tuple[np.ndarray, ...]:
    """Host reference: full shift-method enumeration (exact path) or
    canonical slot decode (sampled path), then lexsort dedupe."""
    if slots is None:
        chunks = list(iter_block_pairs(blocks))
        if not chunks:
            z = np.zeros((0,), np.int64)
            return z, z, z
        a = np.concatenate([np.minimum(ca, cb) for ca, cb, _ in chunks])
        b = np.concatenate([np.maximum(ca, cb) for ca, cb, _ in chunks])
        s = np.concatenate([cs for _, _, cs in chunks])
    else:
        a, b, s = pairs_ref.decode_slots_ref(
            blocks.start, blocks.size, blocks.members, slots)
    return pairs_ref.dedupe_ref(a, b, s)


def _packable(blocks: Blocks) -> bool:
    """Do all rids fit the 62-bit sort-word layout?"""
    return (len(blocks.members) == 0
            or int(blocks.members.max()) < (1 << pairs_kernels.PACK_RID_BITS))


def _radix_passes_for_blocks(blocks: Blocks) -> int:
    """Static radix pass count covering this layout's packed sort words
    (single source for every radix call site — an under-covered pass
    count would silently mis-sort high rid bits)."""
    return pairs_kernels.radix_passes_for(
        int(blocks.members.max()) if len(blocks.members) else 0)


def _resolve_sort_backend(sort_backend: str, blocks: Blocks) -> str:
    """Map the user knob onto a concrete dedupe-sort strategy.

    Returns one of "host" (packed u64 ``np.sort`` — CPU only, where host
    memory IS device memory), "radix" (``kernels.sort`` LSB radix over
    packed words), or "comparator" (``lax.sort``). ``"auto"`` keeps the
    measured winner per platform: the host sort on CPU, radix on real
    accelerators when the rids fit the 62-bit pack, comparator otherwise.
    Forcing ``"radix"`` beyond the pack bound warns and degrades to the
    comparator (the only order-preserving option there).
    """
    if sort_backend not in _SORT_BACKENDS:
        raise ValueError(f"sort_backend must be one of {_SORT_BACKENDS}, "
                         f"got {sort_backend!r}")
    packable = _packable(blocks)
    on_cpu = jax.default_backend() == "cpu"
    if sort_backend == "auto":
        if on_cpu and packable:
            return "host"
        return "radix" if packable else "comparator"
    if sort_backend == "radix" and not packable:
        warnings.warn(
            "sort_backend='radix' needs rids < "
            f"2**{pairs_kernels.PACK_RID_BITS} to pack the 62-bit sort "
            "word; using the comparator sort", RuntimeWarning, stacklevel=4)
        return "comparator"
    return sort_backend


def _dedupe_device(blocks: Blocks, slots: Optional[np.ndarray], total: int,
                   chunk_pairs: int, use_kernel: bool, interpret: bool,
                   sort_backend: str = "auto") -> Tuple[np.ndarray, ...]:
    """Device engine: chunked slot decode + one sort-dedupe pass.

    The dedupe sort strategy comes from ``_resolve_sort_backend``:
    ``"auto"`` packs the words on device and sorts with ``np.sort`` on
    the CPU backend (host == device memory there, and numpy's u64 sort
    is ~40x faster than XLA CPU's comparator sort) and radix-sorts on
    device elsewhere; ``"comparator"``/``"radix"`` force the device sort
    flavor (useful to exercise and benchmark either on any platform).
    """
    # host-side casts + explicit uploads: dtype-coercing jnp.asarray and
    # jnp.int32(py_scalar) are implicit host->device transfers (rejected
    # under jax.transfer_guard("disallow") — repro.analysis R001)
    start32 = jnp.asarray(blocks.start.astype(np.int32))
    size32 = jnp.asarray(blocks.size.astype(np.int32))
    mem32 = jnp.asarray(blocks.members.astype(np.int32))
    steps = pairs_kernels.search_steps_for(int(blocks.size.max()))
    out_a, out_b, out_s, out_v = [], [], [], []
    if slots is None:
        # exact path: enumerate [0, total) on device
        cum = pairs_ref.cum_pair_counts(blocks.size)
        cum32 = jnp.asarray(cum.astype(np.int32))
        chunk = min(chunk_pairs, _round_up(max(total, 1), 1024))
        total32 = jax.device_put(np.int32(total))
        for base in range(0, total, chunk):
            a, b, s, v = pairs_kernels.decode_chunk(
                cum32, start32, size32, mem32,
                jax.device_put(np.int32(base)), total32,
                chunk=chunk, steps=steps, use_kernel=use_kernel,
                interpret=interpret)
            out_a.append(a); out_b.append(b); out_s.append(s); out_v.append(v)
    else:
        # sampled path: slots are int64 host-side; split block/local on
        # host (global indices overflow int32), decode on device
        cum = pairs_ref.cum_pair_counts(blocks.size)
        block = np.searchsorted(cum, slots, side="right") - 1
        local = (slots - cum[block]).astype(np.int32)
        block = block.astype(np.int32)
        chunk = min(chunk_pairs, _round_up(max(len(slots), 1), 1024))
        pad = (-len(slots)) % chunk
        valid = np.ones(len(slots), bool)
        if pad:
            block = np.pad(block, (0, pad))
            local = np.pad(local, (0, pad))
            valid = np.pad(valid, (0, pad))
        for off in range(0, len(block), chunk):
            sl = slice(off, off + chunk)
            a, b, s, v = pairs_kernels.decode_block_local(
                start32, size32, mem32, jnp.asarray(block[sl]),
                jnp.asarray(local[sl]), jnp.asarray(valid[sl]),
                steps=steps, use_kernel=use_kernel, interpret=interpret)
            out_a.append(a); out_b.append(b); out_s.append(s); out_v.append(v)
    if not out_a:
        z = np.zeros((0,), np.int64)
        return z, z, z, None
    sort_kind = _resolve_sort_backend(sort_backend, blocks)
    if sort_kind == "host":
        his, los = [], []
        for a, b, s, v in zip(out_a, out_b, out_s, out_v):
            hi, lo = pairs_kernels.pack_sort_words(a, b, s, v)
            his.append(np.asarray(hi)); los.append(np.asarray(lo))
        return pairs_kernels.dedupe_packed_host(
            np.concatenate(his), np.concatenate(los)) + (None,)
    # n_passes is a static jit arg: derive it from the data only when the
    # radix sort actually consumes it, so comparator graphs don't retrace
    # as the rid span crosses digit boundaries
    kw = {}
    if sort_kind == "radix":
        kw["n_passes"] = _radix_passes_for_blocks(blocks)
    sa, sb, ss, winner = pairs_kernels.dedupe_device(
        jnp.concatenate(out_a), jnp.concatenate(out_b),
        jnp.concatenate(out_s), jnp.concatenate(out_v),
        sort_backend=sort_kind, use_kernel=use_kernel, interpret=interpret,
        **kw)
    # compact host-side (the winner count is data-dependent, so the mask
    # gather can't stay on device without a dynamic shape; indexing the
    # device array with a host mask would be an implicit transfer) and
    # re-upload the compacted buffers explicitly for device consumers
    w = np.asarray(winner)
    a_host = np.asarray(sa)[w]
    b_host = np.asarray(sb)[w]
    dev = (jnp.asarray(a_host), jnp.asarray(b_host))
    return (a_host.astype(np.int64), b_host.astype(np.int64),
            np.asarray(ss)[w].astype(np.int64), dev)


def dedupe_pairs(blocks: Blocks, budget: int = 50_000_000,
                 backend: str = "auto", chunk_pairs: int = 1 << 20,
                 sample_seed: int = 0, interpret: bool = True,
                 mesh=None, axis_names: Tuple[str, ...] = ("data",),
                 route_slack: float = 2.0,
                 sort_backend: str = "auto") -> PairSet:
    """RemoveDupePairs: distinct (a, b), keeping the largest source block.

    Within ``budget`` total pair slots the result is exact; beyond it the
    engine decodes a deterministic uniform sample of ``budget`` slots
    (``exact=False``) — counting stays exact via ``total_slots``. All
    backends produce bit-identical PairSets for the same arguments; see
    the module docstring for the backend/chunking contract.

    ``sort_backend`` selects the dedupe-sort engine of the device
    backends (``"comparator"`` = ``lax.sort``, ``"radix"`` = the
    ``kernels/sort`` LSB radix kernel over packed words, ``"auto"`` =
    the measured per-platform winner — see ``_resolve_sort_backend``);
    every choice is bit-identical, only speed differs (measured
    crossover in the module docstring). The numpy backend ignores it.

    ``backend="distributed"`` routes through the fingerprint-routed
    shard-local dedupe over ``mesh`` (all local devices on one "data"
    axis when ``mesh`` is None) — see
    ``core.distributed.dedupe_pairs_distributed`` for the contract;
    ``chunk_pairs`` becomes the per-shard chunk and the budget sample
    stays the seeded global one, so results remain bit-identical to
    every single-device backend.
    """
    if sort_backend not in _SORT_BACKENDS:
        # validate eagerly: the numpy shortcut below never consults the
        # knob, and a typo must not pass on small workloads only
        raise ValueError(f"sort_backend must be one of {_SORT_BACKENDS}, "
                         f"got {sort_backend!r}")
    total = blocks.num_pair_slots
    if total == 0:
        return _empty_pairset(True, total)
    if backend == "distributed":
        from . import distributed as dist_lib
        if mesh is None:
            mesh = jax.make_mesh((len(jax.devices()),), ("data",))
            axis_names = ("data",)
        return dist_lib.dedupe_pairs_distributed(
            blocks, mesh, axis_names, budget=budget,
            chunk_per_shard=chunk_pairs, route_slack=route_slack,
            interpret=interpret, sample_seed=sample_seed,
            sort_backend=sort_backend)
    exact = total <= budget
    slots = None if exact else _sample_slots(total, budget, sample_seed)
    backend = _resolve_backend(backend, blocks, budget)
    if backend == "numpy":
        a, b, s = _dedupe_numpy(blocks, slots)
        dev = None
    else:
        a, b, s, dev = _dedupe_device(blocks, slots, total, chunk_pairs,
                                      use_kernel=(backend == "pallas"),
                                      interpret=interpret,
                                      sort_backend=sort_backend)
    return PairSet(a, b, s, exact, total,
                   device_a=None if dev is None else dev[0],
                   device_b=None if dev is None else dev[1])


def enumerate_pairs(blocks: Blocks, backend: str = "auto",
                    chunk_pairs: int = 1 << 20, interpret: bool = True
                    ) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Stream raw (a, b, block_size) numpy chunks WITHOUT dedupe.

    Device backends decode the canonical slot order in fixed-shape
    chunks; the numpy backend streams the legacy shift-method order.
    Used by consumers that need multiplicities (e.g. meta-blocking's CBS
    edge weighting) rather than the deduped pair set.
    """
    if backend == "distributed":
        raise ValueError(
            "enumerate_pairs streams raw pre-dedupe chunks and has no "
            "distributed backend; use dedupe_pairs(backend='distributed') "
            "or a single-device backend here")
    # enumeration is always exact, so the WHOLE slot space must fit the
    # device's int32 slot indices (dedupe_pairs only needs budget to fit —
    # its sampled path never materializes global slot indices on device);
    # min() maps an overflowing total onto the budget >= INT32_MAX check.
    backend = _resolve_backend(backend, blocks,
                               budget=min(blocks.num_pair_slots, INT32_MAX))
    if backend == "numpy":
        yield from iter_block_pairs(blocks, chunk_pairs)
        return
    total = blocks.num_pair_slots
    if total == 0:
        return
    cum32 = jnp.asarray(pairs_ref.cum_pair_counts(blocks.size).astype(np.int32))
    start32 = jnp.asarray(blocks.start.astype(np.int32))
    size32 = jnp.asarray(blocks.size.astype(np.int32))
    mem32 = jnp.asarray(blocks.members.astype(np.int32))
    steps = pairs_kernels.search_steps_for(int(blocks.size.max()))
    chunk = min(chunk_pairs, _round_up(max(total, 1), 1024))
    total32 = jax.device_put(np.int32(total))
    for base in range(0, total, chunk):
        a, b, s, v = pairs_kernels.decode_chunk(
            cum32, start32, size32, mem32,
            jax.device_put(np.int32(base)), total32,
            chunk=chunk, steps=steps, use_kernel=(backend == "pallas"),
            interpret=interpret)
        vm = np.asarray(v)
        yield (np.asarray(a)[vm].astype(np.int64),
               np.asarray(b)[vm].astype(np.int64),
               np.asarray(s)[vm].astype(np.int64))


# ---------------------------------------------------------------------------
# Triangular pair bitmap (paper §3.1 equation for b_{i,j})
# ---------------------------------------------------------------------------


def pair_bit_index(i: np.ndarray, j: np.ndarray, n: int) -> np.ndarray:
    """Bit index of pair (i, j), i < j, in the C(n,2) upper-triangular map."""
    i = np.asarray(i, np.int64)
    j = np.asarray(j, np.int64)
    return i * (n - 1) - (i - 1) * i // 2 + j - i - 1


def pair_from_bit_index(bit: np.ndarray, n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Inverse of pair_bit_index (vectorized)."""
    bit = np.asarray(bit, np.int64)
    # row i satisfies cum(i) <= bit < cum(i+1), cum(i) = i*(n-1) - (i-1)i/2
    i_all = np.arange(n, dtype=np.int64)
    cum = i_all * (n - 1) - (i_all - 1) * i_all // 2
    i = np.searchsorted(cum, bit, side="right") - 1
    j = bit - cum[i] + i + 1
    return i, j


def build_pair_bitmap(n: int, kept_i: np.ndarray, kept_j: np.ndarray) -> np.ndarray:
    """Packed uint8 bitmap of C(n,2) bits with the kept pairs set."""
    nbits = n * (n - 1) // 2
    bits = np.zeros(nbits, np.uint8)
    bits[pair_bit_index(kept_i, kept_j, n)] = 1
    return np.packbits(bits)


def read_pair_bitmap(n: int, bitmap: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    nbits = n * (n - 1) // 2
    bits = np.unpackbits(bitmap, count=nbits)
    return pair_from_bit_index(np.flatnonzero(bits), n)


# ---------------------------------------------------------------------------
# Membership utilities for recall (PC) evaluation without full materialization
# ---------------------------------------------------------------------------


def pair_covered(result: BlockingResult, pairs_a: np.ndarray, pairs_b: np.ndarray
                 ) -> np.ndarray:
    """For labeled pairs (a, b): does any accepted block contain both?

    Evaluated via a hash set of (key, rid) assignments — no pair
    materialization, so it works at any scale (used for PC on datasets
    whose full pair set exceeds the budget).
    """
    key64 = (result.key_hi.astype(np.uint64) << np.uint64(32)) | result.key_lo.astype(np.uint64)
    assign = np.stack([key64, result.rids.astype(np.uint64)], axis=1)
    # dictionary of key -> sorted rid ranges via lexsort
    order = np.lexsort((assign[:, 1], assign[:, 0]))
    k_sorted = assign[order, 0]
    r_sorted = assign[order, 1]
    covered = np.zeros(len(pairs_a), bool)
    # group keys of record a: need per-record key lists -> sort by rid
    order_r = np.lexsort((key64, result.rids))
    rid_sorted = result.rids[order_r]
    key_by_rid = key64[order_r]
    for idx, (a, b) in enumerate(zip(pairs_a, pairs_b)):
        lo = np.searchsorted(rid_sorted, a, "left")
        hi = np.searchsorted(rid_sorted, a, "right")
        for key in key_by_rid[lo:hi]:
            klo = np.searchsorted(k_sorted, key, "left")
            khi = np.searchsorted(k_sorted, key, "right")
            pos = np.searchsorted(r_sorted[klo:khi], np.uint64(b))
            if pos < khi - klo and r_sorted[klo + pos] == np.uint64(b):
                covered[idx] = True
                break
    return covered
