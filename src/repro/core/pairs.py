"""Pair materialization + deduplication (paper §3.1 "Pair Deduplication").

Runs host-side in numpy: this is the *output* stage — the paper also only
materializes pairs once, after all iterations, because it is the single
most expensive data-movement step. Features:

- block reconstruction (group accepted (rid, key) assignments by key),
- exact distinct-pair emission with "largest block wins" provenance,
- the paper's strictly-upper-triangular pair *bitmap* encoding
  ``b(i,j,n) = i*(n-1) - (i-1)*i/2 + j - i - 1`` for compactly shipping a
  filtered subset of a block's pairs to pairwise matching,
- a pair-budget guard: beyond ``budget`` pairs we fall back to exact
  *counting* plus uniform pair sampling (one CPU core cannot materialize
  the paper's 68B pairs; DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

from .hdb import BlockingResult


@dataclasses.dataclass
class Blocks:
    """Accepted blocks in CSR-ish form, sorted by (key, rid)."""

    key_hi: np.ndarray   # (B,) uint32 block key
    key_lo: np.ndarray   # (B,) uint32
    start: np.ndarray    # (B,) int64 offset into members
    size: np.ndarray     # (B,) int64
    members: np.ndarray  # (M,) int64 rids, sorted within each block

    @property
    def num_blocks(self) -> int:
        return len(self.start)

    @property
    def num_pair_slots(self) -> int:
        """Sum over blocks of C(n,2) — pairs BEFORE cross-block dedupe."""
        return int(np.sum(self.size * (self.size - 1) // 2))


def build_blocks(result: BlockingResult, min_size: int = 2) -> Blocks:
    """Group accepted (rid, key) assignments into blocks."""
    key64 = (result.key_hi.astype(np.uint64) << np.uint64(32)) | result.key_lo.astype(np.uint64)
    order = np.lexsort((result.rids, key64))
    key64 = key64[order]
    rids = result.rids[order]
    if len(key64) == 0:
        z64 = np.zeros((0,), np.int64)
        zu = np.zeros((0,), np.uint32)
        return Blocks(zu, zu, z64, z64, z64)
    starts = np.flatnonzero(np.concatenate([[True], key64[1:] != key64[:-1]]))
    sizes = np.diff(np.concatenate([starts, [len(key64)]]))
    keep = sizes >= min_size
    starts, sizes = starts[keep], sizes[keep]
    keys = key64[starts]
    return Blocks(
        key_hi=(keys >> np.uint64(32)).astype(np.uint32),
        key_lo=(keys & np.uint64(0xFFFFFFFF)).astype(np.uint32),
        start=starts.astype(np.int64),
        size=sizes.astype(np.int64),
        members=rids,
    )


def iter_block_pairs(blocks: Blocks, chunk_pairs: int = 2_000_000
                     ) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Yield (a, b, block_size) pair chunks across all blocks.

    Small blocks are emitted with the vectorized shift method: for offset d,
    every element pairs with the element d positions later iff both are in
    the same block. Large blocks fall back to per-block meshgrid emission.
    """
    small_cut = 64
    small = blocks.size <= small_cut
    # --- small blocks: shift method over one concatenated array ---
    if np.any(small):
        s_start = blocks.start[small]
        s_size = blocks.size[small]
        total = int(s_size.sum())
        # vectorized gather of each kept block's member range
        offs = np.arange(total) - np.repeat(np.cumsum(s_size) - s_size, s_size)
        mem = blocks.members[np.repeat(s_start, s_size) + offs]
        seg = np.repeat(np.arange(len(s_size)), s_size)
        bsz = np.repeat(s_size, s_size)
        max_d = int(s_size.max())
        buf_a, buf_b, buf_s, buffered = [], [], [], 0
        for d in range(1, max_d):
            ok = seg[d:] == seg[:-d]
            if not ok.any():
                continue
            buf_a.append(mem[:-d][ok])
            buf_b.append(mem[d:][ok])
            buf_s.append(bsz[:-d][ok])
            buffered += int(ok.sum())
            if buffered >= chunk_pairs:
                yield np.concatenate(buf_a), np.concatenate(buf_b), np.concatenate(buf_s)
                buf_a, buf_b, buf_s, buffered = [], [], [], 0
        if buffered:
            yield np.concatenate(buf_a), np.concatenate(buf_b), np.concatenate(buf_s)
    # --- large blocks: per-block triangular emission ---
    for bi in np.flatnonzero(~small):
        s, n = int(blocks.start[bi]), int(blocks.size[bi])
        m = blocks.members[s : s + n]
        ii, jj = np.triu_indices(n, 1)
        for off in range(0, len(ii), chunk_pairs):
            sl = slice(off, off + chunk_pairs)
            yield m[ii[sl]], m[jj[sl]], np.full(len(ii[sl]), n, np.int64)


@dataclasses.dataclass
class PairSet:
    """Distinct pairs with largest-source-block provenance."""

    a: np.ndarray          # (P,) int64, a < b
    b: np.ndarray          # (P,) int64
    src_size: np.ndarray   # (P,) int64 size of largest block producing the pair
    exact: bool            # False => truncated by budget
    total_slots: int       # sum C(n,2) before dedupe


def dedupe_pairs(blocks: Blocks, budget: int = 50_000_000) -> PairSet:
    """RemoveDupePairs: distinct (a, b), keeping the largest source block."""
    total = blocks.num_pair_slots
    chunks_a, chunks_b, chunks_s = [], [], []
    seen = 0
    exact = True
    for a, b, s in iter_block_pairs(blocks):
        lo = np.minimum(a, b)
        hi = np.maximum(a, b)
        chunks_a.append(lo)
        chunks_b.append(hi)
        chunks_s.append(s)
        seen += len(lo)
        if seen > budget:
            exact = False
            break
    if not chunks_a:
        z = np.zeros((0,), np.int64)
        return PairSet(z, z, z, True, total)
    a = np.concatenate(chunks_a)
    b = np.concatenate(chunks_b)
    s = np.concatenate(chunks_s)
    # sort by (a, b, -size); first of each (a, b) wins
    order = np.lexsort((-s, b, a))
    a, b, s = a[order], b[order], s[order]
    first = np.concatenate([[True], (a[1:] != a[:-1]) | (b[1:] != b[:-1])])
    return PairSet(a[first], b[first], s[first], exact, total)


# ---------------------------------------------------------------------------
# Triangular pair bitmap (paper §3.1 equation for b_{i,j})
# ---------------------------------------------------------------------------


def pair_bit_index(i: np.ndarray, j: np.ndarray, n: int) -> np.ndarray:
    """Bit index of pair (i, j), i < j, in the C(n,2) upper-triangular map."""
    i = np.asarray(i, np.int64)
    j = np.asarray(j, np.int64)
    return i * (n - 1) - (i - 1) * i // 2 + j - i - 1


def pair_from_bit_index(bit: np.ndarray, n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Inverse of pair_bit_index (vectorized)."""
    bit = np.asarray(bit, np.int64)
    # row i satisfies cum(i) <= bit < cum(i+1), cum(i) = i*(n-1) - (i-1)i/2
    i_all = np.arange(n, dtype=np.int64)
    cum = i_all * (n - 1) - (i_all - 1) * i_all // 2
    i = np.searchsorted(cum, bit, side="right") - 1
    j = bit - cum[i] + i + 1
    return i, j


def build_pair_bitmap(n: int, kept_i: np.ndarray, kept_j: np.ndarray) -> np.ndarray:
    """Packed uint8 bitmap of C(n,2) bits with the kept pairs set."""
    nbits = n * (n - 1) // 2
    bits = np.zeros(nbits, np.uint8)
    bits[pair_bit_index(kept_i, kept_j, n)] = 1
    return np.packbits(bits)


def read_pair_bitmap(n: int, bitmap: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    nbits = n * (n - 1) // 2
    bits = np.unpackbits(bitmap, count=nbits)
    return pair_from_bit_index(np.flatnonzero(bits), n)


# ---------------------------------------------------------------------------
# Membership utilities for recall (PC) evaluation without full materialization
# ---------------------------------------------------------------------------


def pair_covered(result: BlockingResult, pairs_a: np.ndarray, pairs_b: np.ndarray
                 ) -> np.ndarray:
    """For labeled pairs (a, b): does any accepted block contain both?

    Evaluated via a hash set of (key, rid) assignments — no pair
    materialization, so it works at any scale (used for PC on datasets
    whose full pair set exceeds the budget).
    """
    key64 = (result.key_hi.astype(np.uint64) << np.uint64(32)) | result.key_lo.astype(np.uint64)
    assign = np.stack([key64, result.rids.astype(np.uint64)], axis=1)
    # dictionary of key -> sorted rid ranges via lexsort
    order = np.lexsort((assign[:, 1], assign[:, 0]))
    k_sorted = assign[order, 0]
    r_sorted = assign[order, 1]
    covered = np.zeros(len(pairs_a), bool)
    # group keys of record a: need per-record key lists -> sort by rid
    order_r = np.lexsort((key64, result.rids))
    rid_sorted = result.rids[order_r]
    key_by_rid = key64[order_r]
    for idx, (a, b) in enumerate(zip(pairs_a, pairs_b)):
        lo = np.searchsorted(rid_sorted, a, "left")
        hi = np.searchsorted(rid_sorted, a, "right")
        for key in key_by_rid[lo:hi]:
            klo = np.searchsorted(k_sorted, key, "left")
            khi = np.searchsorted(k_sorted, key, "right")
            pos = np.searchsorted(r_sorted[klo:khi], np.uint64(b))
            if pos < khi - klo and r_sorted[klo + pos] == np.uint64(b):
                covered[idx] = True
                break
    return covered
