"""Sort-by-64-bit-key + segmented reductions.

The exact-counting stage of HDB (Algorithm 4) groups (record, key) entries
by blocking key and reduces each group to ``(count, XOR-of-rid-hashes)``.
On Spark that is a shuffle + reduceByKey; here it is a single
``lax.sort`` with the u64 key as a two-operand lexicographic sort key,
followed by O(n) segmented reductions — all dense, fixed-shape, TPU-friendly.

Invalid entries are padded with the u64 sentinel key so they sort to the
tail and fall out of every reduction naturally.
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from . import u64
from .u64 import U64


def sort_by_key(key: U64, payloads: Sequence[jnp.ndarray]) -> Tuple[U64, list]:
    """Sort flat arrays by u64 key (lexicographic on (hi, lo))."""
    operands = (key[0], key[1], *payloads)
    out = jax.lax.sort(operands, num_keys=2, is_stable=False)
    return (out[0], out[1]), list(out[2:])


def segment_starts(key: U64) -> jnp.ndarray:
    """Bool mask marking the first element of each equal-key run.

    Input must be sorted by key. Sentinel runs are still marked; callers
    mask with ``~u64.is_sentinel``.
    """
    prev = (jnp.roll(key[0], 1), jnp.roll(key[1], 1))
    first = jnp.arange(key[0].shape[0]) == 0
    return first | ~u64.eq(key, prev)


def segment_ids(starts: jnp.ndarray) -> jnp.ndarray:
    """Monotone segment id per element from a start mask."""
    return jnp.cumsum(starts.astype(jnp.int32)) - 1


def segment_counts(key: U64) -> jnp.ndarray:
    """Per-ELEMENT size of the segment it belongs to (sorted input).

    Computed via positions of starts: size = next_start_pos - my_start_pos.
    """
    n = key[0].shape[0]
    starts = segment_starts(key)
    idx = jnp.arange(n, dtype=jnp.int32)
    # position of my segment's start
    start_pos = jnp.where(starts, idx, 0)
    start_pos = jax.lax.associative_scan(jnp.maximum, start_pos)
    # position of my segment's end (exclusive): scan from the right
    end_pos = jnp.where(starts, idx, n)
    end_pos = jax.lax.associative_scan(jnp.minimum, end_pos, reverse=True)
    # end_pos currently holds the NEXT start among [i..); for elements of the
    # last run that's n via the init fill. But careful: scan-min from right of
    # start positions: for element i, min over j>=i of (starts[j] ? j : n)
    # gives my own start for the first element of a run. Shift to exclude self.
    nxt = jnp.concatenate([end_pos[1:], jnp.full((1,), n, jnp.int32)])
    seg_end = jnp.where(starts, nxt, end_pos)
    # For non-start elements, end_pos already excludes self's start (self is
    # not a start), i.e. it is the next run boundary.
    return seg_end - start_pos


def segment_xor(key: U64, value: U64) -> U64:
    """Per-ELEMENT XOR of `value` over the element's segment (sorted input).

    Uses the prefix-XOR trick: cumulative XOR c[i]; segment XOR over
    [s, e) = c[e-1] ^ c[s-1] (with c[-1] = 0).
    """
    n = key[0].shape[0]
    starts = segment_starts(key)
    idx = jnp.arange(n, dtype=jnp.int32)
    start_pos = jax.lax.associative_scan(jnp.maximum, jnp.where(starts, idx, 0))
    sizes = segment_counts(key)
    end_pos = start_pos + sizes - 1  # inclusive
    cum_hi = jax.lax.associative_scan(jnp.bitwise_xor, value[0])
    cum_lo = jax.lax.associative_scan(jnp.bitwise_xor, value[1])
    before = start_pos - 1
    pre_hi = jnp.where(before >= 0, cum_hi[jnp.maximum(before, 0)], 0).astype(jnp.uint32)
    pre_lo = jnp.where(before >= 0, cum_lo[jnp.maximum(before, 0)], 0).astype(jnp.uint32)
    return cum_hi[end_pos] ^ pre_hi, cum_lo[end_pos] ^ pre_lo


def unique_rows(key: U64, sizes: jnp.ndarray) -> jnp.ndarray:
    """Mask selecting one representative element per segment (the start)."""
    del sizes
    return segment_starts(key)


def compact(mask: jnp.ndarray, key: U64, payloads: Sequence[jnp.ndarray],
            fill_payload: int = 0) -> Tuple[U64, list, jnp.ndarray]:
    """Stable-compact masked entries to the array prefix.

    Entries where ``mask`` is False get sentinel keys / fill payloads and
    move to the tail. Returns (key, payloads, n_valid).
    """
    order = jnp.argsort(~mask, stable=True)
    khi = jnp.where(mask, key[0], jnp.uint32(0xFFFFFFFF))[order]
    klo = jnp.where(mask, key[1], jnp.uint32(0xFFFFFFFF))[order]
    outs = [jnp.where(mask, p, jnp.asarray(fill_payload, p.dtype))[order] for p in payloads]
    return (khi, klo), outs, jnp.sum(mask.astype(jnp.int32))


def searchsorted_u64(table: U64, query: U64) -> jnp.ndarray:
    """Vectorized lower-bound binary search of u64 queries in a sorted table.

    ``table`` is the paper's "broadcasted counts map": a sorted array of
    surviving over-sized keys all-gathered to every shard. Returns the
    insertion index; pair with an equality check at that index for lookups.
    """
    n = table[0].shape[0]
    # combine into sortable uint64-equivalent via float trick is lossy; do
    # manual binary search over (hi, lo).
    lo_idx = jnp.zeros(query[0].shape, jnp.int32)
    hi_idx = jnp.full(query[0].shape, n, jnp.int32)
    steps = max(1, math.ceil(math.log2(max(n, 2))) + 1)
    for _ in range(steps):
        mid = (lo_idx + hi_idx) // 2
        mid_c = jnp.clip(mid, 0, n - 1)
        mid_key = (table[0][mid_c], table[1][mid_c])
        go_right = u64.lt(mid_key, query) & (mid < hi_idx)
        lo_idx = jnp.where(go_right, mid + 1, lo_idx)
        hi_idx = jnp.where(go_right, hi_idx, jnp.minimum(hi_idx, mid))
    return lo_idx


def lookup_u64(table: U64, values: jnp.ndarray, query: U64,
               default) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sorted-table lookup: returns (found_mask, value_or_default)."""
    n = table[0].shape[0]
    idx = searchsorted_u64(table, query)
    idx_c = jnp.clip(idx, 0, n - 1)
    hit = (idx < n) & u64.eq((table[0][idx_c], table[1][idx_c]), query)
    val = jnp.where(hit, values[idx_c], jnp.asarray(default, values.dtype))
    return hit, val
