"""Blocking baselines from the paper's §5 evaluation.

- Threshold Blocking (THR): block on the same top-level keys, but *discard*
  any block larger than the threshold (paper: 500). One exact count, no
  iterations — the foil demonstrating what dynamic intersection buys.
- Naive blocking: keep every block regardless of size (only pair *counts*
  are ever reported — the paper's 120-quadrillion-pairs column).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from . import segments, u64
from .hdb import BlockingResult, IterationStats


def _exact_sizes(keys_packed: jnp.ndarray, valid: jnp.ndarray):
    """Exact per-entry block sizes via one global sort."""
    n, k = valid.shape
    flat = valid.reshape(-1)
    khi = jnp.where(flat, keys_packed[..., 0].reshape(-1), jnp.uint32(0xFFFFFFFF))
    klo = jnp.where(flat, keys_packed[..., 1].reshape(-1), jnp.uint32(0xFFFFFFFF))
    orig = jnp.arange(n * k, dtype=jnp.int32)
    (shi, slo), (sorig,) = segments.sort_by_key((khi, klo), [orig])
    live = ~u64.is_sentinel((shi, slo))
    sizes = segments.segment_counts((shi, slo))
    out = jnp.zeros((n * k,), jnp.int32).at[sorig].set(jnp.where(live, sizes, 0))
    return out.reshape(n, k)


@jax.jit
def _exact_sizes_jit(keys_packed, valid):
    return _exact_sizes(keys_packed, valid)


def threshold_blocking(keys_packed: jnp.ndarray, valid: jnp.ndarray,
                       max_block_size: int = 500) -> BlockingResult:
    """THR baseline: accept blocks with 2 <= size <= max_block_size."""
    sizes = _exact_sizes_jit(keys_packed, valid)
    accepted = np.asarray(valid & (sizes <= max_block_size) & (sizes >= 2))
    ridx, kidx = np.nonzero(accepted)
    keys_np = np.asarray(keys_packed)
    n_right = int(accepted.sum())
    stats = IterationStats(
        iteration=0, n_live_keys=int(np.asarray(valid).sum()), n_right_cms=0,
        n_right_exact=n_right, n_dropped_similarity=0, n_dropped_max_keys=0,
        n_duplicate_blocks=0, n_surviving_oversized=0, n_surviving_entries=0,
        rep_overflow=0)
    return BlockingResult(
        rids=ridx.astype(np.int64),
        key_hi=keys_np[ridx, kidx, 0],
        key_lo=keys_np[ridx, kidx, 1],
        stats=[stats],
        num_records=valid.shape[0],
    )


def naive_pair_count(keys_packed: jnp.ndarray, valid: jnp.ndarray) -> int:
    """Sum of C(n,2) over ALL top-level blocks (paper Table 3 "Naive")."""
    sizes = np.asarray(_exact_sizes_jit(keys_packed, valid))
    valid_np = np.asarray(valid)
    n, k = valid_np.shape
    khi = np.asarray(keys_packed[..., 0])[valid_np].astype(np.uint64)
    klo = np.asarray(keys_packed[..., 1])[valid_np].astype(np.uint64)
    key64 = (khi << np.uint64(32)) | klo
    uniq, first = np.unique(key64, return_index=True)
    bsz = sizes[valid_np][first].astype(np.int64)
    return int(np.sum(bsz * (bsz - 1) // 2))
