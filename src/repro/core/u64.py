"""64-bit unsigned integer arithmetic on uint32 limb pairs.

TPUs have no native 64-bit integer lanes; XLA emulates them slowly and
``jax_enable_x64`` is a global, trace-wide switch we do not want near the
bf16 model stack. Instead every 64-bit hash in this framework is a pair of
``uint32`` arrays ``(hi, lo)``. All ops below are elementwise, shape
polymorphic, and wrap mod 2**64 exactly like hardware u64.

A ``U64`` is simply a ``tuple[jnp.ndarray, jnp.ndarray]`` of equal-shape
uint32 arrays ``(hi, lo)``. Helper pack/unpack functions move between this
tuple form and a stacked ``(..., 2)`` array used for storage.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np
import jax.numpy as jnp

U64 = Tuple[jnp.ndarray, jnp.ndarray]

_U32 = jnp.uint32
# numpy scalars (not jnp arrays) so they inline as jaxpr literals — required
# for Pallas kernels, which reject closure-captured device constants.
_MASK16 = np.uint32(0xFFFF)


def u64(hi: int, lo: int) -> U64:
    """Construct a scalar U64 constant from python ints."""
    return np.uint32(hi & 0xFFFFFFFF), np.uint32(lo & 0xFFFFFFFF)


def from_int(value: int) -> U64:
    """Scalar U64 from a python int (mod 2**64)."""
    value &= (1 << 64) - 1
    return u64(value >> 32, value & 0xFFFFFFFF)


def to_int(x: U64) -> int:
    """Python int from a *concrete* scalar U64 (test helper)."""
    return (int(x[0]) << 32) | int(x[1])


def from_u32(x: jnp.ndarray) -> U64:
    """Zero-extend uint32 array to U64."""
    x = x.astype(_U32)
    return jnp.zeros_like(x), x


def full(shape, value: int) -> U64:
    hi, lo = from_int(value)
    return jnp.full(shape, hi, _U32), jnp.full(shape, lo, _U32)


def pack(x: U64) -> jnp.ndarray:
    """(hi, lo) tuple -> stacked (..., 2) uint32 array (storage form)."""
    return jnp.stack([x[0], x[1]], axis=-1)


def unpack(x: jnp.ndarray) -> U64:
    """Stacked (..., 2) uint32 array -> (hi, lo) tuple."""
    return x[..., 0], x[..., 1]


def xor(a: U64, b: U64) -> U64:
    return a[0] ^ b[0], a[1] ^ b[1]


def bitand(a: U64, b: U64) -> U64:
    return a[0] & b[0], a[1] & b[1]


def bitor(a: U64, b: U64) -> U64:
    return a[0] | b[0], a[1] | b[1]


def add(a: U64, b: U64) -> U64:
    """a + b mod 2**64."""
    lo = a[1] + b[1]
    carry = (lo < a[1]).astype(_U32)
    hi = a[0] + b[0] + carry
    return hi, lo


def mul32_wide(a: jnp.ndarray, b: jnp.ndarray) -> U64:
    """Full 32x32 -> 64 bit product of two uint32 arrays, via 16-bit limbs.

    Every partial product of 16-bit halves fits in uint32 with headroom for
    the carry chain below.
    """
    a = a.astype(_U32)
    b = b.astype(_U32)
    a0, a1 = a & _MASK16, a >> 16
    b0, b1 = b & _MASK16, b >> 16
    ll = a0 * b0
    lh = a0 * b1
    hl = a1 * b0
    hh = a1 * b1
    # mid <= (2^16-1) + 2*(2^16-1) => fits easily in uint32
    mid = (ll >> 16) + (lh & _MASK16) + (hl & _MASK16)
    lo = (ll & _MASK16) | ((mid & _MASK16) << 16)
    hi = hh + (lh >> 16) + (hl >> 16) + (mid >> 16)
    return hi, lo


def mul(a: U64, b: U64) -> U64:
    """a * b mod 2**64."""
    hi, lo = mul32_wide(a[1], b[1])
    hi = hi + a[1] * b[0] + a[0] * b[1]  # cross terms mod 2**32
    return hi, lo


def mul_const(a: U64, c: int) -> U64:
    """a * (python int constant) mod 2**64."""
    return mul(a, from_int(c))


def shr(a: U64, n: int) -> U64:
    """Logical right shift by a static amount 0 <= n < 64."""
    if n == 0:
        return a
    if n < 32:
        lo = (a[1] >> n) | (a[0] << (32 - n))
        hi = a[0] >> n
    else:
        lo = a[0] >> (n - 32) if n > 32 else a[0]
        hi = jnp.zeros_like(a[0])
    return hi, lo


def shl(a: U64, n: int) -> U64:
    """Left shift by a static amount 0 <= n < 64 (mod 2**64)."""
    if n == 0:
        return a
    if n < 32:
        hi = (a[0] << n) | (a[1] >> (32 - n))
        lo = a[1] << n
    else:
        hi = a[1] << (n - 32) if n > 32 else a[1]
        lo = jnp.zeros_like(a[1])
    return hi, lo


def rotl(a: U64, n: int) -> U64:
    n %= 64
    if n == 0:
        return a
    return bitor(shl(a, n), shr(a, 64 - n))


def eq(a: U64, b: U64) -> jnp.ndarray:
    return (a[0] == b[0]) & (a[1] == b[1])


def lt(a: U64, b: U64) -> jnp.ndarray:
    return (a[0] < b[0]) | ((a[0] == b[0]) & (a[1] < b[1]))


def le(a: U64, b: U64) -> jnp.ndarray:
    return (a[0] < b[0]) | ((a[0] == b[0]) & (a[1] <= b[1]))


def where(pred: jnp.ndarray, a: U64, b: U64) -> U64:
    return jnp.where(pred, a[0], b[0]), jnp.where(pred, a[1], b[1])


def minimum(a: U64, b: U64) -> U64:
    return where(lt(a, b), a, b)


# Sentinel = 0xFFFF... ; sorts after every real key, used as "no key" padding.
SENTINEL = (np.uint32(0xFFFFFFFF), np.uint32(0xFFFFFFFF))


def sentinel(shape) -> U64:
    return full(shape, (1 << 64) - 1)


def is_sentinel(a: U64) -> jnp.ndarray:
    return (a[0] == np.uint32(0xFFFFFFFF)) & (a[1] == np.uint32(0xFFFFFFFF))
