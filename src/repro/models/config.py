"""Unified model configuration covering all 10 assigned architectures."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
           "float16": jnp.float16}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_shared_experts: int = 0
    moe_d_ff: int = 0
    moe_layer_period: int = 1     # every k-th layer is MoE (1 = all)
    moe_first_dense: int = 0      # first k layers use a dense FFN
    capacity_factor: float = 1.25
    moe_impl: str = "psum"        # "psum" (partial-sum EP) | "a2a" (optimized)

    # --- MLA (DeepSeek) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False             # multi-token-prediction auxiliary head

    # --- hybrid (Jamba) ---
    attn_period: int = 0          # one attention layer per k layers (0 = all attn)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_chunk: int = 256

    # --- rwkv ---
    rwkv_head_dim: int = 64
    rwkv_impl: str = "scan"     # "scan" (step recurrence) | "chunked" (§Perf)
    rwkv_chunk: int = 64

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    decoder_layers: int = 0
    encoder_seq_ratio: int = 1    # encoder frames per decoder token (shape spec)

    # --- vlm ---
    num_patches: int = 0          # prepended stub patch embeddings

    # --- common ---
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # --- runtime / perf knobs (hillclimbed in §Perf) ---
    remat: str = "full"           # none | full | selective
    scan_layers: bool = True
    attn_impl: str = "auto"       # dense | chunked | auto (chunked >= this len)
    attn_chunk_threshold: int = 8192
    attn_chunk_size: int = 1024

    @property
    def pdtype(self):
        return _DTYPES[self.param_dtype]

    @property
    def cdtype(self):
        return _DTYPES[self.compute_dtype]

    @property
    def q_dim(self) -> int:
        if self.use_mla:
            return self.num_heads * (self.nope_head_dim + self.rope_head_dim)
        return self.num_heads * self.head_dim

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe_num_experts == 0:
            return False
        if layer_idx < self.moe_first_dense:
            return False
        return (layer_idx - self.moe_first_dense) % self.moe_layer_period == 0

    def is_attn_layer(self, layer_idx: int) -> bool:
        """Hybrid archs: attention every `attn_period` layers, else mamba."""
        if self.family != "hybrid":
            return True
        return layer_idx % self.attn_period == (self.attn_period - 1) // 2

    def active_params(self) -> int:
        """~Active parameter count (MoE counts top_k+shared experts)."""
        return _count_params(self, active_only=True)

    def total_params(self) -> int:
        return _count_params(self, active_only=False)


def _ffn_params(d_model: int, d_ff: int) -> int:
    return 3 * d_model * d_ff  # SwiGLU: gate, up, down


def _count_params(cfg: ModelConfig, active_only: bool) -> int:
    total = cfg.vocab_size * cfg.d_model  # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * cfg.d_model
    if cfg.family == "encdec":
        layers = [("attn", "ffn")] * cfg.encoder_layers
        layers += [("attn", "cross", "ffn")] * cfg.decoder_layers
        for parts in layers:
            for p in parts:
                if p in ("attn", "cross"):
                    total += cfg.d_model * (cfg.num_heads * cfg.head_dim) * 2
                    total += cfg.d_model * (cfg.num_kv_heads * cfg.head_dim) * 2
                else:
                    total += 2 * cfg.d_model * cfg.d_ff  # whisper MLP (gelu)
        return total
    for li in range(cfg.num_layers):
        if cfg.family == "ssm":
            d_att = cfg.d_model
            total += 6 * cfg.d_model * d_att + 2 * cfg.d_model  # rwkv blocks, approx
            total += _ffn_params(cfg.d_model, cfg.d_ff)
            continue
        if cfg.is_attn_layer(li):
            if cfg.use_mla:
                total += cfg.d_model * cfg.q_lora_rank
                total += cfg.q_lora_rank * cfg.q_dim
                total += cfg.d_model * (cfg.kv_lora_rank + cfg.rope_head_dim)
                total += cfg.kv_lora_rank * cfg.num_heads * (cfg.nope_head_dim + cfg.v_head_dim)
                total += cfg.num_heads * cfg.v_head_dim * cfg.d_model
            else:
                total += cfg.d_model * cfg.num_heads * cfg.head_dim * 2
                total += cfg.d_model * cfg.num_kv_heads * cfg.head_dim * 2
        else:  # mamba layer
            d_inner = cfg.mamba_expand * cfg.d_model
            total += 2 * cfg.d_model * d_inner + d_inner * cfg.mamba_d_state * 2
            total += d_inner * cfg.d_model
        if cfg.is_moe_layer(li):
            n_exp = (cfg.moe_top_k + cfg.moe_shared_experts if active_only
                     else cfg.moe_num_experts + cfg.moe_shared_experts)
            total += n_exp * _ffn_params(cfg.d_model, cfg.moe_d_ff)
            total += cfg.d_model * cfg.moe_num_experts  # router
        else:
            total += _ffn_params(cfg.d_model, cfg.d_ff)
    return total
