"""Model facade: build any assigned architecture from its ModelConfig.

    model = build_model(cfg)
    params = model.init(rng)
    logits, aux = model.apply(params, batch)          # training fwd
    loss, metrics = model.loss(params, batch)
    caches = model.init_caches(batch_size, max_len)   # serving
    logits, caches = model.decode_step(params, token, caches, extras)

Batch dict:  tokens (B,S) int32, targets (B,S) int32, and per modality:
  frames  (B, S_enc, d_model)  — whisper stub frontend
  patches (B, P, d_model)      — internvl stub ViT
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import encdec, layers, transformer


def cross_entropy(logits, targets, vocab: int):
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return (lse - gold).mean(), lse


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable
    apply: Callable          # (params, batch) -> (logits, aux_dict)
    loss: Callable           # (params, batch) -> (loss, metrics)
    init_caches: Callable    # (batch, max_len) -> caches
    prefill: Callable        # (params, batch, caches) -> (logits, caches)
    decode_step: Callable    # (params, token, caches, batch) -> (logits, caches)


def _decoder_only_model(cfg: ModelConfig) -> Model:
    stack = transformer.Stack.build(cfg)

    def init(rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        params = layers.embed_init(k1, cfg)
        params["layers"] = stack.init(k2)
        params["final_norm"] = jnp.zeros((cfg.d_model,), cfg.pdtype)
        if cfg.mtp:
            params["mtp"] = transformer._layer_init(
                k3, ("mla" if cfg.use_mla else "attn", "mlp"), cfg)
            params["mtp_proj"] = layers.dense_init(
                jax.random.fold_in(k3, 1), 2 * cfg.d_model, cfg.d_model,
                dtype=cfg.pdtype)
        return params

    def _backbone(params, tokens, extra_embed=None, caches=None, positions=None):
        x = layers.embed_apply(params, tokens, cfg)
        if extra_embed is not None:
            x = jnp.concatenate([extra_embed.astype(cfg.cdtype), x], axis=1)
        x, new_caches, aux, dropped = stack.apply(params["layers"], x,
                                                  positions=positions,
                                                  caches=caches)
        x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, new_caches, aux, dropped

    def apply(params, batch):
        extra = batch.get("patches")
        x, _, aux, dropped = _backbone(params, batch["tokens"], extra)
        if extra is not None:
            x = x[:, extra.shape[1]:]
        logits = layers.lm_head_apply(params, x, cfg)
        aux_d = {"moe_aux": aux, "moe_dropped": dropped}
        if cfg.mtp:
            # multi-token prediction: fuse h_t with emb(t+1) -> predict t+2
            emb_next = layers.embed_apply(params, batch["targets"], cfg)
            fused = jnp.concatenate([x, emb_next], axis=-1) @ \
                params["mtp_proj"].astype(cfg.cdtype)
            h_mtp, _, _, _ = transformer._layer_apply(
                params["mtp"], fused, ("mla" if cfg.use_mla else "attn", "mlp"), cfg)
            aux_d["mtp_logits"] = layers.lm_head_apply(params, h_mtp, cfg)
        return logits, aux_d

    def loss(params, batch):
        logits, aux = apply(params, batch)
        ce, lse = cross_entropy(logits, batch["targets"], cfg.vocab_size)
        total = ce + 1e-2 * aux["moe_aux"] + 1e-4 * jnp.mean(lse ** 2)
        metrics = {"ce": ce, "moe_aux": aux["moe_aux"],
                   "moe_dropped": aux["moe_dropped"]}
        if cfg.mtp:
            # targets for t+2 = targets shifted by one; mask the tail
            t2 = jnp.roll(batch["targets"], -1, axis=1)
            mtp_ce, _ = cross_entropy(aux["mtp_logits"][:, :-1], t2[:, :-1],
                                      cfg.vocab_size)
            total = total + 0.3 * mtp_ce
            metrics["mtp_ce"] = mtp_ce
        return total, metrics

    def init_caches(batch, max_len):
        return stack.init_caches(batch, max_len)

    def prefill(params, batch, caches):
        # teacher-forced prefill that fills caches token-block at once is
        # family-specific; for serving benchmarks we run apply() and then
        # decode from caches filled by a scan of decode steps when needed.
        tokens = batch["tokens"]
        x, new_caches, _, _ = _backbone(params, tokens, batch.get("patches"),
                                        caches=caches)
        logits = layers.lm_head_apply(params, x[:, -1:], cfg)
        return logits, new_caches

    def decode_step(params, token, caches, batch=None):
        positions = None
        x, new_caches, _, _ = _backbone(params, token, None, caches=caches,
                                        positions=positions)
        logits = layers.lm_head_apply(params, x, cfg)
        return logits, new_caches

    return Model(cfg, init, apply, loss, init_caches, prefill, decode_step)


def _encdec_model(cfg: ModelConfig) -> Model:
    def init(rng):
        return encdec.encdec_init(rng, cfg)

    def apply(params, batch):
        enc_out = encdec.encode(params, batch["frames"], cfg)
        logits = encdec.decode_train(params, batch["tokens"], enc_out, cfg)
        return logits, {"moe_aux": jnp.zeros(()), "moe_dropped": jnp.zeros((), jnp.int32)}

    def loss(params, batch):
        logits, _ = apply(params, batch)
        ce, _ = cross_entropy(logits, batch["targets"], cfg.vocab_size)
        return ce, {"ce": ce}

    def init_caches(batch, max_len):
        return encdec.init_dec_caches(cfg, batch, max_len)

    def prefill(params, batch, caches):
        enc_out = encdec.encode(params, batch["frames"], cfg)
        logits, caches = encdec.decode_step(params, batch["tokens"][:, -1:],
                                            enc_out, caches, cfg)
        return logits, caches

    def decode_step(params, token, caches, batch):
        enc_out = batch["enc_out"]
        return encdec.decode_step(params, token, enc_out, caches, cfg)

    return Model(cfg, init, apply, loss, init_caches, prefill, decode_step)


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        return _encdec_model(cfg)
    return _decoder_only_model(cfg)
