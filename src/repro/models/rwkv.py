"""RWKV-6 "Finch" block: token shift + data-dependent decay linear attention.

Per head of size D, the state S (D_k x D_v) evolves as
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with the decay w_t produced from the shifted input through a LoRA (the
"data-dependent decay" that distinguishes Finch from RWKV-5). Training
scans chunks (inner step is cheap; the state, not the sequence, is the
carry), decode is O(1) — hence this arch runs the long_500k shape.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, rms_norm
from ..distributed.sharding import lshard


def _heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.rwkv_head_dim


def rwkv_init(key, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> dict:
    d = cfg.d_model
    lora = max(32, d // 32)
    ks = jax.random.split(key, 10)
    return {"rwkv": {
        "mu": jnp.full((*stack, 5, d), 0.5, cfg.pdtype),  # shift mixes r,k,v,w,g
        "w_r": dense_init(ks[0], *stack, d, d, dtype=cfg.pdtype),
        "w_k": dense_init(ks[1], *stack, d, d, dtype=cfg.pdtype),
        "w_v": dense_init(ks[2], *stack, d, d, dtype=cfg.pdtype),
        "w_g": dense_init(ks[3], *stack, d, d, dtype=cfg.pdtype),
        "w_o": dense_init(ks[4], *stack, d, d, dtype=cfg.pdtype),
        "w_decay_lora_a": dense_init(ks[5], *stack, d, lora, dtype=cfg.pdtype),
        "w_decay_lora_b": dense_init(ks[6], *stack, lora, d, dtype=cfg.pdtype),
        "decay_base": jnp.full((*stack, d), -6.0, cfg.pdtype),
        "bonus": jnp.zeros((*stack, d), cfg.pdtype),
        "ln_x": jnp.ones((*stack, d), cfg.pdtype),
    }}


def _shift(x, last):
    """x_{t-1} stream: prepend `last` (zeros or cache) and drop the tail."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def _chunked_wkv(r, k, v, w, u, s0, chunk: int):
    """Chunked WKV (§Perf optimization — GLA-style parallel form).

    Per chunk of length C the recurrence splits into an inter-chunk term
    (carry state S, decayed by the running product of w), an intra-chunk
    strictly-causal attention with decay-ratio weights, and the current-
    token bonus. Log-space cumulative decays with per-chunk centering keep
    everything in f32 range; the C x C weight matrix is a plain matmul
    (MXU-friendly). State HBM traffic drops from T writes to T/C writes,
    which is the point (see EXPERIMENTS.md §Perf / rwkv row).

    Shapes: r/k/v (B,S,H,D) f32, w (B,S,H,D) decay in (0,1),
    u (H,D) bonus, s0 (B,H,D,D). Returns (state, y (B,S,H*D)).
    """
    b, s, h, dd = r.shape
    c = chunk
    n = s // c
    rc = r.reshape(b, n, c, h, dd)
    kc = k.reshape(b, n, c, h, dd)
    vc = v.reshape(b, n, c, h, dd)
    logw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-8, 1.0)
                   ).reshape(b, n, c, h, dd)

    causal = jnp.tril(jnp.ones((c, c), bool), k=-1)

    def chunk_step(state, inp):
        rr, kk, vv, lw = inp                     # (B,C,H,D)
        cum = jnp.cumsum(lw, axis=1)             # inclusive logW_t
        cum_prev = cum - lw                      # exclusive logW_{t-1}
        total = cum[:, -1:]                      # logW_end
        center = 0.5 * total
        r_t = rr * jnp.exp(cum_prev - center)    # bounded by exp(|range|/2)
        k_t = kk * jnp.exp(center - cum)
        a = jnp.einsum("bthd,bjhd->bhtj", r_t, k_t)
        a = jnp.where(causal[None, None], a, 0.0)
        y_intra = jnp.einsum("bhtj,bjhd->bthd", a, vv)
        # current-token bonus
        bonus = jnp.einsum("bthd,bthd->bth", rr, u[None, None] * kk)
        y_intra = y_intra + bonus[..., None] * vv
        # inter-chunk: y += (r ⊙ W_{t-1}) @ S
        r_in = rr * jnp.exp(cum_prev)
        y_inter = jnp.einsum("bthk,bhkv->bthv", r_in, state)
        # state update: S' = diag(W_end) S + Σ_j diag(W_end/W_j) k_j^T v_j
        k_dec = kk * jnp.exp(total - cum)
        state = (state * jnp.exp(total[:, 0])[..., None]
                 + jnp.einsum("bjhk,bjhv->bhkv", k_dec, vv))
        return state, y_intra + y_inter

    xs = tuple(t.transpose(1, 0, 2, 3, 4) for t in (rc, kc, vc, logw))
    state, ys = jax.lax.scan(chunk_step, s0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h * dd)
    return state, y


def _mix(x, x_prev, mu):
    return x + (x_prev - x) * mu


def rwkv_apply(p, x, cfg: ModelConfig, *, cache: Optional[Dict] = None):
    b, s, d = x.shape
    h = _heads(cfg)
    hd = cfg.rwkv_head_dim
    last = cache["x_prev"] if cache is not None else jnp.zeros((b, d), x.dtype)
    xp = _shift(x, last)
    mu = p["mu"].astype(cfg.cdtype)
    xr = _mix(x, xp, mu[0])
    xk = _mix(x, xp, mu[1])
    xv = _mix(x, xp, mu[2])
    xw = _mix(x, xp, mu[3])
    xg = _mix(x, xp, mu[4])

    r = (xr @ p["w_r"].astype(cfg.cdtype)).reshape(b, s, h, hd)
    k = (xk @ p["w_k"].astype(cfg.cdtype)).reshape(b, s, h, hd)
    v = (xv @ p["w_v"].astype(cfg.cdtype)).reshape(b, s, h, hd)
    g = xg @ p["w_g"].astype(cfg.cdtype)
    decay = (xw @ p["w_decay_lora_a"].astype(cfg.cdtype)
             ) @ p["w_decay_lora_b"].astype(cfg.cdtype)
    w = jnp.exp(-jnp.exp(decay.astype(jnp.float32)
                         + p["decay_base"].astype(jnp.float32)))
    w = w.reshape(b, s, h, hd)
    u = p["bonus"].astype(jnp.float32).reshape(h, hd)

    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))

    s0 = (cache["state"] if cache is not None
          else jnp.zeros((b, h, hd, hd), jnp.float32))
    if cache is None and cfg.rwkv_impl == "chunked" and s % cfg.rwkv_chunk == 0:
        state, ys = _chunked_wkv(r32, k32, v32, w, u, s0, cfg.rwkv_chunk)
        y = ys.reshape(b, s, d)
    else:
        def step(state, inp):
            rt, kt, vt, wt = inp                       # (B,H,hd) each
            kv = kt[..., :, None] * vt[..., None, :]   # (B,H,hd,hd)
            y = jnp.einsum("bhk,bhkv->bhv", rt, state + u[..., None] * kv)
            state = state * wt[..., None] + kv
            return state, y

        xs = (r32.transpose(1, 0, 2, 3), k32.transpose(1, 0, 2, 3),
              v32.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
        state, ys = jax.lax.scan(step, s0, xs)
        y = ys.transpose(1, 0, 2, 3).reshape(b, s, d)
    y = rms_norm(y.astype(cfg.cdtype), p["ln_x"], cfg.norm_eps)
    y = y * jax.nn.silu(g)
    out = y @ p["w_o"].astype(cfg.cdtype)
    new_cache = None
    if cache is not None:
        new_cache = {"state": state, "x_prev": x[:, -1, :]}
    return lshard(out, "batch", "seq", None), new_cache


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype=None):
    dtype = dtype or cfg.cdtype
    h, hd = _heads(cfg), cfg.rwkv_head_dim
    return {
        "state": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "x_prev": jnp.zeros((batch, cfg.d_model), dtype),
    }
