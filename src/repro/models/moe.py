"""Mixture-of-Experts with expert parallelism.

Dispatch is index-based (sort-free rank computation + scatter/gather):
no (T, E, C) one-hot tensors are ever materialized — the peak extra
activation is the dispatched (E_local, C, d) buffer itself. Two EP modes:

- "psum" (baseline): activations are replicated across the "model" axis
  (they already are, since TP shards only the weights' inner axes); each
  model shard gathers the tokens routed to ITS experts, computes them, and
  contributes a partial output; one psum over "model" combines. Collective
  cost: one all-reduce of (T_local, d) regardless of top_k.
- "a2a" (optimized, §Perf): tokens all_to_all to expert-owner shards and
  back — moves only routed tokens (top_k/E_shards of the psum bytes).

Router aux-loss follows the standard load-balancing form
``E * sum_e f_e * P_e``; dropped-token counts are surfaced, never silent.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import dense_init
from ..distributed.sharding import active_rules, lshard


def moe_init(key, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> dict:
    ks = jax.random.split(key, 8)
    d, e, ff = cfg.d_model, cfg.moe_num_experts, cfg.moe_d_ff
    p = {
        "router": dense_init(ks[0], *stack, d, e, dtype=jnp.float32, scale=0.02),
        "w_gate": dense_init(ks[1], *stack, e, d, ff, dtype=cfg.pdtype),
        "w_up": dense_init(ks[2], *stack, e, d, ff, dtype=cfg.pdtype),
        "w_down": dense_init(ks[3], *stack, e, ff, d, dtype=cfg.pdtype),
    }
    if cfg.moe_shared_experts:
        sff = cfg.moe_d_ff * cfg.moe_shared_experts
        p["shared_gate"] = dense_init(ks[4], *stack, d, sff, dtype=cfg.pdtype)
        p["shared_up"] = dense_init(ks[5], *stack, d, sff, dtype=cfg.pdtype)
        p["shared_down"] = dense_init(ks[6], *stack, sff, d, dtype=cfg.pdtype)
    return {"moe": p}


def _route(logits, cfg: ModelConfig):
    """top-k routing with normalized weights + aux load-balance loss."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_e = jax.lax.top_k(probs, cfg.moe_top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    e = cfg.moe_num_experts
    f = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0)
    f = f / jnp.maximum(f.sum(), 1.0)
    p_mean = probs.mean(axis=0)
    aux = e * jnp.sum(f * p_mean)
    return top_w, top_e, aux


def _expert_ranks(flat_e: jnp.ndarray, num_experts: int):
    """Rank of each assignment within its expert (scatter-free, via sort)."""
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    rank_sorted = jnp.arange(n, dtype=jnp.int32) - jnp.searchsorted(
        sorted_e, sorted_e, side="left").astype(jnp.int32)
    return jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)


def _expert_ffn(x_tok, expert_local, valid, w_gate, w_up, w_down,
                e_local: int, capacity: int):
    """Run local experts over routed tokens.

    x_tok (T, d) with per-token LOCAL expert id + validity; returns (T, d)
    outputs aligned with the inputs (invalid/over-capacity rows zero) and
    the dropped count.
    """
    t, d = x_tok.shape
    eid = jnp.where(valid, expert_local, e_local)
    rank = _expert_ranks(eid, e_local + 1)
    kept = valid & (rank < capacity)
    dropped = jnp.sum((valid & ~kept).astype(jnp.int32))
    slot = eid * capacity + rank
    x_e = jnp.zeros((e_local * capacity, d), x_tok.dtype)
    x_e = x_e.at[jnp.where(kept, slot, e_local * capacity)].set(
        x_tok, mode="drop").reshape(e_local, capacity, d)
    h = jnp.einsum("ecd,edf->ecf", x_e, w_gate)
    u = jnp.einsum("ecd,edf->ecf", x_e, w_up)
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, w_down)
    y_flat = y.reshape(e_local * capacity, d)
    out = jnp.where(kept[:, None],
                    y_flat[jnp.clip(slot, 0, e_local * capacity - 1)], 0)
    return out, dropped


def _moe_local(xf, router_w, w_gate, w_up, w_down, cfg: ModelConfig,
               e_offset, e_local: int, capacity: int):
    """Per-shard MoE: dispatch local tokens to local experts, partial out."""
    t, d = xf.shape
    logits = xf.astype(jnp.float32) @ router_w.astype(jnp.float32)
    top_w, top_e, aux = _route(logits, cfg)
    k = cfg.moe_top_k
    flat_e = top_e.reshape(t * k)
    flat_w = top_w.reshape(t * k).astype(xf.dtype)
    rank = _expert_ranks(flat_e, cfg.moe_num_experts)
    kept = rank < capacity
    dropped = jnp.sum((~kept).astype(jnp.int32))
    local = kept & (flat_e >= e_offset) & (flat_e < e_offset + e_local)
    slot = (flat_e - e_offset) * capacity + rank
    token_of = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    # dispatch: (E_loc*C, d)
    x_e = jnp.zeros((e_local * capacity, d), xf.dtype)
    x_e = x_e.at[jnp.where(local, slot, e_local * capacity)].set(
        xf[token_of], mode="drop")
    x_e = x_e.reshape(e_local, capacity, d)
    # expert FFNs (SwiGLU)
    h = jnp.einsum("ecd,edf->ecf", x_e, w_gate)
    u = jnp.einsum("ecd,edf->ecf", x_e, w_up)
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, w_down)
    # combine: per-assignment gather of this shard's partial expert outputs
    y_flat = y.reshape(e_local * capacity, d)
    contrib = y_flat[jnp.clip(slot, 0, e_local * capacity - 1)]
    contrib = jnp.where(local[:, None], contrib * flat_w[:, None], 0)
    out = jnp.zeros((t, d), xf.dtype).at[token_of].add(contrib)
    return out, aux, dropped


def moe_apply(p, x, cfg: ModelConfig):
    """MoE block: routed experts (+ optional shared experts)."""
    b, s, d = x.shape
    rules = active_rules()
    e = cfg.moe_num_experts
    router_w = p["router"]
    w_gate = p["w_gate"].astype(cfg.cdtype)
    w_up = p["w_up"].astype(cfg.cdtype)
    w_down = p["w_down"].astype(cfg.cdtype)

    ep_axis = rules.axis("experts") if rules is not None else None
    if ep_axis is None:
        xf = x.reshape(b * s, d)
        capacity = int(np.ceil(b * s * cfg.moe_top_k / e * cfg.capacity_factor))
        out, aux, dropped = _moe_local(xf, router_w, w_gate, w_up, w_down,
                                       cfg, 0, e, capacity)
        out = out.reshape(b, s, d)
    else:
        mesh = rules.mesh
        n_ep = mesh.shape[ep_axis]
        assert e % n_ep == 0, (e, n_ep)
        e_local = e // n_ep
        batch_axis = rules.axis("batch")
        dp = int(np.prod([mesh.shape[a] for a in (
            batch_axis if isinstance(batch_axis, tuple) else (batch_axis,))]))
        if b % dp:  # e.g. batch=1 long-context decode: replicate tokens
            batch_axis = None
            dp = 1
        t_local = b * s // dp
        capacity = int(np.ceil(t_local * cfg.moe_top_k / e * cfg.capacity_factor))
        # optional expert-internal FF sharding (weight-stationary serving):
        # logical axis "moe_ff" — inner ff dim sharded, down-proj partials
        # psum'd together with the EP combine.
        ff_axis = rules.axis("moe_ff")
        if ff_axis is not None:
            ff_axes = ff_axis if isinstance(ff_axis, tuple) else (ff_axis,)
            n_ff = int(np.prod([mesh.shape[a] for a in ff_axes]))
            if cfg.moe_d_ff % n_ff:
                ff_axis = None
        psum_axes = (ep_axis,) if ff_axis is None else \
            (ep_axis,) + (ff_axis if isinstance(ff_axis, tuple) else (ff_axis,))

        def body(x_l, router_l, wg_l, wu_l, wd_l):
            bl, sl, _ = x_l.shape
            e0 = jax.lax.axis_index(ep_axis) * e_local
            out, aux, dropped = _moe_local(
                x_l.reshape(bl * sl, d), router_l, wg_l, wu_l, wd_l, cfg,
                e0, e_local, capacity)
            # combine in the compute dtype: halves the EP wire bytes vs an
            # f32 psum (top-8 partials in bf16 are well within tolerance)
            out = jax.lax.psum(out.astype(cfg.cdtype), psum_axes)
            aux = jax.lax.pmean(aux, ep_axis)
            dropped = jax.lax.psum(dropped, ep_axis)
            return out.reshape(bl, sl, d), aux, dropped

        use_a2a = (cfg.moe_impl == "a2a" and ff_axis is None
                   and (b * s // dp) % n_ep == 0)

        def body_a2a(x_l, router_l, wg_l, wu_l, wd_l):
            """all_to_all EP: each shard routes ITS token slice to expert
            owners, computes, routes back, and all-gathers the combined
            slices — wire bytes ∝ top_k/n_ep instead of a dense psum."""
            bl, sl, _ = x_l.shape
            t_all = bl * sl
            t_chunk = t_all // n_ep
            me = jax.lax.axis_index(ep_axis)
            xf = jax.lax.dynamic_slice_in_dim(
                x_l.reshape(t_all, d), me * t_chunk, t_chunk, axis=0)
            logits = xf.astype(jnp.float32) @ router_l.astype(jnp.float32)
            top_w, top_e, aux = _route(logits, cfg)
            k = cfg.moe_top_k
            flat_e = top_e.reshape(t_chunk * k)
            flat_w = top_w.reshape(t_chunk * k).astype(xf.dtype)
            dest = flat_e // e_local
            # per-destination slotting
            rank = _expert_ranks(dest, n_ep)
            cap = int(np.ceil(t_chunk * k / n_ep * 2.0))
            kept = rank < cap
            n_drop_route = jnp.sum((~kept).astype(jnp.int32))
            slot = jnp.where(kept, dest * cap + rank, n_ep * cap)
            token_of = jnp.repeat(jnp.arange(t_chunk, dtype=jnp.int32), k)
            send_x = jnp.zeros((n_ep * cap, d), xf.dtype).at[slot].set(
                xf[token_of], mode="drop")
            send_e = jnp.full((n_ep * cap,), e, jnp.int32).at[slot].set(
                flat_e, mode="drop")
            recv_x = jax.lax.all_to_all(send_x.reshape(n_ep, cap, d),
                                        ep_axis, 0, 0, tiled=True)
            recv_e = jax.lax.all_to_all(send_e.reshape(n_ep, cap),
                                        ep_axis, 0, 0, tiled=True)
            recv_x = recv_x.reshape(n_ep * cap, d)
            recv_e = recv_e.reshape(n_ep * cap)
            e0 = me * e_local
            valid = (recv_e >= e0) & (recv_e < e0 + e_local)
            cap_e = int(np.ceil(n_ep * cap / e_local * 1.0)) + 8
            y, n_drop_cap = _expert_ffn(recv_x, recv_e - e0, valid,
                                        wg_l, wu_l, wd_l, e_local, cap_e)
            back = jax.lax.all_to_all(y.reshape(n_ep, cap, d),
                                      ep_axis, 0, 0, tiled=True)
            back = back.reshape(n_ep * cap, d)
            contrib = back[jnp.clip(slot, 0, n_ep * cap - 1)]
            contrib = jnp.where(kept[:, None], contrib * flat_w[:, None], 0)
            out_chunk = jnp.zeros((t_chunk, d), xf.dtype).at[token_of].add(contrib)
            out = jax.lax.all_gather(out_chunk, ep_axis, tiled=True)
            aux = jax.lax.pmean(aux, ep_axis)
            dropped = jax.lax.psum(n_drop_route + n_drop_cap, ep_axis)
            return out.reshape(bl, sl, d), aux, dropped

        out, aux, dropped = shard_map(
            body_a2a if use_a2a else body, mesh=mesh,
            in_specs=(P(batch_axis, None, None), P(),
                      P(ep_axis, None, ff_axis),
                      P(ep_axis, None, ff_axis),
                      P(ep_axis, ff_axis, None)),
            out_specs=(P(batch_axis, None, None), P(), P()),
            check_rep=False,
        )(x, router_w, w_gate, w_up, w_down)

    if cfg.moe_shared_experts:
        g = x @ p["shared_gate"].astype(cfg.cdtype)
        u = x @ p["shared_up"].astype(cfg.cdtype)
        shared = lshard(jax.nn.silu(g) * u, "batch", "seq", "ffn")
        out = out + shared @ p["shared_down"].astype(cfg.cdtype)
    return lshard(out, "batch", "seq", None), aux, dropped
