"""GQA attention: dense + chunked(flash-style) training paths, KV-cache
decode, and cross-attention (enc-dec).

Conventions: x (B,S,D); q (B,S,H,hd); k/v (B,S,KV,hd). GQA groups
G = H/KV query heads per KV head.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import apply_rope, dense_init
from ..distributed.sharding import lshard

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> dict:
    ks = jax.random.split(key, 4)
    h, kv, d, hd = cfg.num_heads, cfg.num_kv_heads, cfg.d_model, cfg.head_dim
    return {"attn": {
        "wq": dense_init(ks[0], *stack, d, h, hd, dtype=cfg.pdtype),
        "wk": dense_init(ks[1], *stack, d, kv, hd, dtype=cfg.pdtype),
        "wv": dense_init(ks[2], *stack, d, kv, hd, dtype=cfg.pdtype),
        "wo": dense_init(ks[3], *stack, h, hd, d, dtype=cfg.pdtype),
    }}


def _dense_attend(q, k, v, mask, scale):
    """q (B,Sq,H,D), k/v (B,Sk,H,D) (kv pre-repeated to H heads: Megatron-
    style GQA TP — scores stay head-sharded even when kv_heads < TP size).

    mask: broadcastable to (B,H,Sq,Sk) or None.
    """
    scores = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * scale
    scores = lshard(scores, "batch", "heads", None, None)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v)
    return lshard(out, "batch", "seq", "heads", None)


def _repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B,S,KV,D) -> (B,S,KV*G,D), sharded on the repeated head axis."""
    if groups == 1:
        return lshard(k, "batch", "kv_seq", "heads", None)
    b, s, kv, d = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, groups, d))
    return lshard(k.reshape(b, s, kv * groups, d),
                  "batch", "kv_seq", "heads", None)


def _chunked_attend(q, k, v, scale, q_offset, causal: bool, chunk: int):
    """Flash-style online-softmax attention, scanning KV chunks per Q chunk.

    q (B,Sq,H,D), k/v (B,Sk,H,D) pre-repeated. Never materializes
    (Sq, Sk); peak score block is (B,H,Cq,Ck).
    """
    b, sq, h, d = q.shape
    dv = v.shape[-1]  # may differ from d (MLA: qk 192, v 128)
    sk = k.shape[1]
    cq = min(chunk, sq)
    ck = min(chunk, sk)
    nq, nk = sq // cq, sk // ck
    assert sq % cq == 0 and sk % ck == 0

    def q_chunk_body(qi, q_blk):
        # q_blk: (b, h, cq, d)
        def kv_step(carry, ki):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * ck, ck, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * ck, ck, axis=1)
            s = jnp.einsum("bhqd,bshd->bhqs", q_blk, k_blk).astype(jnp.float32) * scale
            s = lshard(s, "batch", "heads", None, None)
            if causal:
                qpos = q_offset + qi * cq + jnp.arange(cq)
                kpos = ki * ck + jnp.arange(ck)
                s = jnp.where(qpos[:, None] >= kpos[None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqs,bshd->bhqd", p.astype(q.dtype), v_blk).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, cq), jnp.float32)
        a0 = jnp.zeros((b, h, cq, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(nk, dtype=jnp.int32))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)  # (b, h, cq, d)

    q_blocks = q.reshape(b, nq, cq, h, d).transpose(1, 0, 3, 2, 4)
    outs = jax.lax.map(lambda args: q_chunk_body(*args),
                       (jnp.arange(nq, dtype=jnp.int32), q_blocks))
    # outs: (nq, b, h, cq, dv) -> (b, nq*cq, h, dv)
    return outs.transpose(1, 0, 3, 2, 4).reshape(b, sq, h, dv)


def attn_apply(p, x, cfg: ModelConfig, *, positions=None,
               cache: Optional[Dict] = None, causal: bool = True,
               kv_x: Optional[jnp.ndarray] = None, use_rope: bool = True):
    """Self/cross attention. With `cache`, x is the new-token slice and the
    (pre-filled) cache supplies history (decode step).

    cache = {"k": (B,S,KV,D), "v": (B,S,KV,D), "pos": int32 ()} — `pos` is
    the number of valid history tokens.
    """
    b, sq, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kvh
    wq = p["wq"].astype(cfg.cdtype)
    wk = p["wk"].astype(cfg.cdtype)
    wv = p["wv"].astype(cfg.cdtype)
    wo = p["wo"].astype(cfg.cdtype)

    q = jnp.einsum("bsd,dhk->bshk", x, wq)
    src = kv_x if kv_x is not None else x
    k = jnp.einsum("bsd,dhk->bshk", src, wk)
    v = jnp.einsum("bsd,dhk->bshk", src, wv)
    q = lshard(q, "batch", "seq", "heads", None)
    k = lshard(k, "batch", "kv_seq", "kv_heads", None)
    v = lshard(v, "batch", "kv_seq", "kv_heads", None)

    if positions is None:
        positions = jnp.arange(sq)[None, :]
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        if kv_x is None:
            k = apply_rope(k, positions, cfg.rope_theta)

    scale = 1.0 / np.sqrt(hd)
    new_cache = None
    if cache is not None:
        pos = cache["pos"]
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=1)
        new_cache = {"k": ck, "v": cv, "pos": pos + sq}
        s_total = ck.shape[1]
        if sq >= cfg.attn_chunk_threshold:
            # PREFILL into a cache: chunked (flash-style) over the cache
            out = _chunked_attend(q, _repeat_kv(ck, g), _repeat_kv(cv, g),
                                  scale, pos, True, cfg.attn_chunk_size)
        else:
            # decode: attend over the full cache in the GROUPED layout
            # (reads each cached KV head once — the GQA win)
            kpos = jnp.arange(s_total)[None, None, None, None, :]
            qpos = (pos + jnp.arange(sq))[None, None, None, :, None]
            mask = kpos <= qpos
            qg = q.reshape(b, sq, kvh, g, hd)
            scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, ck).astype(jnp.float32) * scale
            scores = jnp.where(mask, scores, NEG_INF)
            probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
            out = jnp.einsum("bkgqs,bskd->bqkgd", probs, cv).reshape(b, sq, h, hd)
    else:
        use_chunked = (cfg.attn_impl == "chunked" or
                       (cfg.attn_impl == "auto" and sq >= cfg.attn_chunk_threshold))
        k_rep = _repeat_kv(k, g)
        v_rep = _repeat_kv(v, g)
        if use_chunked and kv_x is None:
            out = _chunked_attend(q, k_rep, v_rep, scale, 0, causal,
                                  cfg.attn_chunk_size)
        else:
            mask = None
            if causal and kv_x is None:
                sk = k.shape[1]
                mask = (jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :])[
                    None, None, :, :]
            out = _dense_attend(q, k_rep, v_rep, mask, scale)
    out = lshard(out, "batch", "seq", "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, wo)
    return lshard(y, "batch", "seq", None), new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.cdtype
    return {
        "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
