"""Shared layers: norms, RoPE, embeddings, SwiGLU MLP.

Models are pairs of pure functions (init -> params pytree, apply) — no
framework. Layer params are created *stacked over layers* where scanned.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from ..distributed.sharding import lshard


def _init_dense(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def dense_init(key, *shape, dtype, scale=None):
    return _init_dense(key, shape, dtype, scale)


def rms_norm(x, weight, eps: float):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layer_norm(x, weight, bias, eps: float):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight + bias).astype(dtype)


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (..., S)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_init(key, cfg: ModelConfig) -> dict:
    p = {"embed": {"table": dense_init(key, cfg.vocab_size, cfg.d_model,
                                       dtype=cfg.pdtype, scale=1.0)}}
    if not cfg.tie_embeddings:
        p["lm_head"] = {"w": dense_init(jax.random.fold_in(key, 1),
                                        cfg.d_model, cfg.vocab_size,
                                        dtype=cfg.pdtype)}
    return p


def embed_apply(params, tokens, cfg: ModelConfig):
    x = params["embed"]["table"].astype(cfg.cdtype)[tokens]
    return lshard(x, "batch", "seq", None)


def lm_head_apply(params, x, cfg: ModelConfig):
    if cfg.tie_embeddings:
        w = params["embed"]["table"].astype(cfg.cdtype).T
    else:
        w = params["lm_head"]["w"].astype(cfg.cdtype)
    logits = jnp.einsum("...d,dv->...v", x, w)
    return lshard(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# SwiGLU MLP (llama-family) and GELU MLP (whisper)
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None,
             stack: Tuple[int, ...] = ()) -> dict:
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {"mlp": {
        "w_gate": dense_init(ks[0], *stack, cfg.d_model, d_ff, dtype=cfg.pdtype),
        "w_up": dense_init(ks[1], *stack, cfg.d_model, d_ff, dtype=cfg.pdtype),
        "w_down": dense_init(ks[2], *stack, d_ff, cfg.d_model, dtype=cfg.pdtype),
    }}


def mlp_apply(p, x, cfg: ModelConfig):
    w_gate = p["w_gate"].astype(cfg.cdtype)
    w_up = p["w_up"].astype(cfg.cdtype)
    w_down = p["w_down"].astype(cfg.cdtype)
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    h = lshard(h, "batch", "seq", "ffn")
    return lshard(h @ w_down, "batch", "seq", None)


def gelu_mlp_init(key, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> dict:
    ks = jax.random.split(key, 2)
    return {"mlp": {
        "w_up": dense_init(ks[0], *stack, cfg.d_model, cfg.d_ff, dtype=cfg.pdtype),
        "w_down": dense_init(ks[1], *stack, cfg.d_ff, cfg.d_model, dtype=cfg.pdtype),
        "b_up": jnp.zeros((*stack, cfg.d_ff), cfg.pdtype),
        "b_down": jnp.zeros((*stack, cfg.d_model), cfg.pdtype),
    }}


def gelu_mlp_apply(p, x, cfg: ModelConfig):
    h = jax.nn.gelu(x @ p["w_up"].astype(cfg.cdtype) + p["b_up"].astype(cfg.cdtype))
    h = lshard(h, "batch", "seq", "ffn")
    return h @ p["w_down"].astype(cfg.cdtype) + p["b_down"].astype(cfg.cdtype)
