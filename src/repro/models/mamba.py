"""Mamba (S6) selective-SSM block — the "1" in Jamba's 1:7 attn:mamba mix.

Training uses a chunked scan: an outer lax.scan over sequence chunks
carries the (B, D_inner, N) state; within a chunk the linear recurrence
h_t = a_t * h_{t-1} + b_t is solved with an associative scan, so the
materialized working set is (B, chunk, D_inner, N) — sharded over batch
and (via TP on D_inner) the model axis. Decode is the O(1) single-step
recurrence (why the hybrid runs the long_500k shape).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init
from ..distributed.sharding import lshard


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, math.ceil(cfg.d_model / 16))


def mamba_init(key, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> dict:
    d = cfg.d_model
    din = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    r = _dt_rank(cfg)
    ks = jax.random.split(key, 8)
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32), (din, 1))
    a_log = jnp.log(a)
    if stack:
        a_log = jnp.broadcast_to(a_log, (*stack, din, n))
    return {"mamba": {
        "w_in": dense_init(ks[0], *stack, d, din, dtype=cfg.pdtype),
        "w_z": dense_init(ks[1], *stack, d, din, dtype=cfg.pdtype),
        "conv": dense_init(ks[2], *stack, cfg.mamba_d_conv, din, dtype=cfg.pdtype),
        "w_b": dense_init(ks[3], *stack, din, n, dtype=cfg.pdtype),
        "w_c": dense_init(ks[4], *stack, din, n, dtype=cfg.pdtype),
        "w_dt": dense_init(ks[5], *stack, din, r, dtype=cfg.pdtype),
        "w_dt_out": dense_init(ks[6], *stack, r, din, dtype=cfg.pdtype),
        "dt_bias": jnp.full((*stack, din), -4.6, cfg.pdtype),  # softplus^-1(0.01)
        "a_log": a_log.astype(cfg.pdtype),
        "d_skip": jnp.ones((*stack, din), cfg.pdtype),
        "w_out": dense_init(ks[7], *stack, din, d, dtype=cfg.pdtype),
    }}


def _causal_conv(u, conv_w, state=None):
    """Depthwise causal conv along seq. u (B,S,Din), conv_w (K,Din)."""
    k = conv_w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    else:
        pad = state
    u_ext = jnp.concatenate([pad, u], axis=1)
    out = sum(u_ext[:, i : i + u.shape[1], :] * conv_w[i] for i in range(k))
    new_state = u_ext[:, -(k - 1):, :] if k > 1 else None
    return out, new_state


def _ssm_params(p, u, cfg: ModelConfig):
    """Selective parameters from the (post-conv) inner activations."""
    bmat = u @ p["w_b"].astype(cfg.cdtype)                     # (B,S,N)
    cmat = u @ p["w_c"].astype(cfg.cdtype)                     # (B,S,N)
    dt = (u @ p["w_dt"].astype(cfg.cdtype)) @ p["w_dt_out"].astype(cfg.cdtype)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,S,Din)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))               # (Din,N)
    da = jnp.exp(dt[..., None] * a)                            # (B,S,Din,N)
    db = dt[..., None] * bmat[:, :, None, :]                   # (B,S,Din,N)
    return da, db, cmat


def mamba_apply(p, x, cfg: ModelConfig, *, cache: Optional[Dict] = None):
    b, s, d = x.shape
    u = x @ p["w_in"].astype(cfg.cdtype)
    z = x @ p["w_z"].astype(cfg.cdtype)
    u = lshard(u, "batch", "seq", "ffn")
    conv_w = p["conv"].astype(cfg.cdtype)

    if cache is not None:
        u, conv_state = _causal_conv(u, conv_w, cache["conv"])
        u = jax.nn.silu(u)
        da, db, cmat = _ssm_params(p, u, cfg)
        h = cache["h"] * da[:, 0] + db[:, 0] * u[:, 0, :, None].astype(jnp.float32)
        y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0].astype(jnp.float32))[:, None]
        new_cache = {"h": h, "conv": conv_state}
        y = y.astype(x.dtype) + u * p["d_skip"].astype(cfg.cdtype)
    else:
        u, _ = _causal_conv(u, conv_w)
        u = jax.nn.silu(u)
        chunk = min(cfg.mamba_chunk, s)
        assert s % chunk == 0
        nc = s // chunk

        def chunk_step(h0, inputs):
            uc, xc = inputs                       # (B,chunk,Din), (B,chunk,d)
            da, db, cmat = _ssm_params(p, uc, cfg)
            bx = db * uc[..., None].astype(jnp.float32)

            def combine(e1, e2):
                a1, b1 = e1
                a2, b2 = e2
                return a1 * a2, b1 * a2 + b2

            a_cum, b_scan = jax.lax.associative_scan(combine, (da, bx), axis=1)
            h = b_scan + a_cum * h0[:, None]      # fold in the carry
            yc = jnp.einsum("bsdn,bsn->bsd", h, cmat.astype(jnp.float32))
            return h[:, -1], yc.astype(x.dtype)

        u_c = u.reshape(b, nc, chunk, -1).transpose(1, 0, 2, 3)
        x_c = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
        h0 = jnp.zeros((b, u.shape[-1], cfg.mamba_d_state), jnp.float32)
        _, ys = jax.lax.scan(chunk_step, h0, (u_c, x_c))
        y = ys.transpose(1, 0, 2, 3).reshape(b, s, -1)
        y = y + u * p["d_skip"].astype(cfg.cdtype)
        new_cache = None

    y = y * jax.nn.silu(z)
    y = lshard(y, "batch", "seq", "ffn")
    out = y @ p["w_out"].astype(cfg.cdtype)
    return lshard(out, "batch", "seq", None), new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=None):
    dtype = dtype or cfg.cdtype
    din = cfg.mamba_expand * cfg.d_model
    return {
        "h": jnp.zeros((batch, din, cfg.mamba_d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, din), dtype),
    }
