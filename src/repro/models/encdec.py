"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, S_enc, d_model). Sinusoidal
positions on the encoder, learned positions on the decoder (extended past
whisper's 448 to cover the assigned shapes — documented deviation),
pre-LN blocks with GELU MLPs, no RoPE.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from . import attention, layers
from ..distributed.sharding import lshard


def _sinusoid(length: int, channels: int) -> jnp.ndarray:
    log_timescale = np.log(10_000) / (channels // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(channels // 2))
    t = np.arange(length)[:, None] * inv[None, :]
    return jnp.asarray(np.concatenate([np.sin(t), np.cos(t)], axis=1),
                       jnp.float32)


def _enc_layer_init(key, cfg: ModelConfig, stack=()):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((*stack, cfg.d_model), cfg.pdtype),
        "ln1_b": jnp.zeros((*stack, cfg.d_model), cfg.pdtype),
        **attention.attn_init(k1, cfg, stack=stack),
        "ln2": jnp.ones((*stack, cfg.d_model), cfg.pdtype),
        "ln2_b": jnp.zeros((*stack, cfg.d_model), cfg.pdtype),
        **layers.gelu_mlp_init(k2, cfg, stack=stack),
    }


def _dec_layer_init(key, cfg: ModelConfig, stack=()):
    k1, k2, k3 = jax.random.split(key, 3)
    p = _enc_layer_init(key, cfg, stack)
    cross = attention.attn_init(k3, cfg, stack=stack)
    p["cross"] = cross["attn"]
    p["ln_cross"] = jnp.ones((*stack, cfg.d_model), cfg.pdtype)
    p["ln_cross_b"] = jnp.zeros((*stack, cfg.d_model), cfg.pdtype)
    return p


def encdec_init(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    p = layers.embed_init(ks[0], cfg)
    p["dec_pos"] = layers.dense_init(ks[1], 1 << 16, cfg.d_model,
                                     dtype=cfg.pdtype, scale=0.01)
    p["enc"] = _enc_layer_init(ks[2], cfg, stack=(cfg.encoder_layers,))
    p["dec"] = _dec_layer_init(ks[3], cfg, stack=(cfg.decoder_layers,))
    p["enc_ln"] = jnp.ones((cfg.d_model,), cfg.pdtype)
    p["enc_ln_b"] = jnp.zeros((cfg.d_model,), cfg.pdtype)
    p["dec_ln"] = jnp.ones((cfg.d_model,), cfg.pdtype)
    p["dec_ln_b"] = jnp.zeros((cfg.d_model,), cfg.pdtype)
    return p


def _enc_layer_apply(p, x, cfg):
    h = layers.layer_norm(x, p["ln1"], p["ln1_b"], cfg.norm_eps)
    y, _ = attention.attn_apply(p["attn"], h, cfg, causal=False, use_rope=False)
    x = x + y
    h = layers.layer_norm(x, p["ln2"], p["ln2_b"], cfg.norm_eps)
    return x + layers.gelu_mlp_apply(p["mlp"], h, cfg)


def _dec_layer_apply(p, x, enc_out, cfg, cache=None, positions=None):
    h = layers.layer_norm(x, p["ln1"], p["ln1_b"], cfg.norm_eps)
    y, new_cache = attention.attn_apply(p["attn"], h, cfg, causal=True,
                                        use_rope=False, cache=cache,
                                        positions=positions)
    x = x + y
    h = layers.layer_norm(x, p["ln_cross"], p["ln_cross_b"], cfg.norm_eps)
    y, _ = attention.attn_apply(p["cross"], h, cfg, causal=False,
                                use_rope=False, kv_x=enc_out)
    x = x + y
    h = layers.layer_norm(x, p["ln2"], p["ln2_b"], cfg.norm_eps)
    return x + layers.gelu_mlp_apply(p["mlp"], h, cfg), new_cache


def encode(params, frames, cfg: ModelConfig):
    """frames: (B, S_enc, d_model) stub frontend embeddings."""
    x = frames.astype(cfg.cdtype) + _sinusoid(frames.shape[1], cfg.d_model
                                              ).astype(cfg.cdtype)[None]
    x = lshard(x, "batch", "seq", None)

    def step(x, layer_p):
        return _enc_layer_apply(layer_p, x, cfg), None

    body = step
    if cfg.remat != "none":
        body = jax.checkpoint(step)
    x, _ = jax.lax.scan(body, x, params["enc"])
    return layers.layer_norm(x, params["enc_ln"], params["enc_ln_b"], cfg.norm_eps)


def decode_train(params, tokens, enc_out, cfg: ModelConfig):
    b, s = tokens.shape
    x = layers.embed_apply(params, tokens, cfg)
    x = x + params["dec_pos"][:s].astype(cfg.cdtype)[None]

    def step(x, layer_p):
        y, _ = _dec_layer_apply(layer_p, x, enc_out, cfg)
        return y, None

    body = step
    if cfg.remat != "none":
        body = jax.checkpoint(step)
    x, _ = jax.lax.scan(body, x, params["dec"])
    x = layers.layer_norm(x, params["dec_ln"], params["dec_ln_b"], cfg.norm_eps)
    return layers.lm_head_apply(params, x, cfg)


def decode_step(params, token, enc_out, caches, cfg: ModelConfig):
    """One decode step. caches: stacked over decoder layers."""
    b, s = token.shape
    x = layers.embed_apply(params, token, cfg)
    pos = caches["pos"][0]  # all layers share the same write position
    x = x + jax.lax.dynamic_slice_in_dim(
        params["dec_pos"], pos, s, axis=0).astype(cfg.cdtype)[None]

    def step(x, scanned):
        layer_p, cache = scanned
        y, nc = _dec_layer_apply(layer_p, x, enc_out, cfg, cache=cache,
                                 positions=jnp.zeros((b, s), jnp.int32) + pos)
        return y, nc

    x, new_caches = jax.lax.scan(step, x, (params["dec"], caches))
    x = layers.layer_norm(x, params["dec_ln"], params["dec_ln_b"], cfg.norm_eps)
    return layers.lm_head_apply(params, x, cfg), new_caches


def init_dec_caches(cfg: ModelConfig, batch: int, max_len: int):
    one = attention.init_cache(cfg, batch, max_len)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.decoder_layers,) + a.shape), one)
