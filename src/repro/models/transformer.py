"""Decoder-only transformer assembly for dense / moe / hybrid / ssm archs.

Layers are grouped into (prefix, repeating unit): the prefix is unrolled,
the repeating unit is stacked and lax.scan-ned (small HLO even at 80
layers; remat applies per scanned unit). Layer kinds:

  mixer: "attn" | "mla" | "mamba" | "rwkv"
  ffn:   "mlp"  | "moe"

e.g. deepseek-v3 = prefix of 3 (mla+mlp) + 58x (mla+moe) scanned;
jamba = 9x scanned unit of 8 sublayers [7 mamba + 1 attn, alternating moe].
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import attention, layers, mamba, mla, moe, rwkv

LayerSpec = Tuple[str, str]  # (mixer_kind, ffn_kind)


def layer_specs(cfg: ModelConfig) -> List[LayerSpec]:
    specs = []
    for i in range(cfg.num_layers):
        if cfg.family == "ssm":
            mixer = "rwkv"
        elif cfg.family == "hybrid" and not cfg.is_attn_layer(i):
            mixer = "mamba"
        elif cfg.use_mla:
            mixer = "mla"
        else:
            mixer = "attn"
        ffn = "moe" if cfg.is_moe_layer(i) else "mlp"
        specs.append((mixer, ffn))
    return specs


def split_prefix_unit(specs: List[LayerSpec]) -> Tuple[List[LayerSpec], List[LayerSpec], int]:
    """Minimal (prefix, unit, n_repeat) with tail = unit * n_repeat."""
    n = len(specs)
    for prefix_len in range(0, min(8, n)):
        tail = specs[prefix_len:]
        for unit_len in (1, 2, 4, 8, 16):
            if len(tail) % unit_len:
                continue
            unit = tail[:unit_len]
            if all(tail[i] == unit[i % unit_len] for i in range(len(tail))):
                return specs[:prefix_len], unit, len(tail) // unit_len
    return specs, [], 0  # fully unrolled fallback


_MIXER_INIT = {"attn": attention.attn_init, "mla": mla.mla_init,
               "mamba": mamba.mamba_init, "rwkv": rwkv.rwkv_init}


def _ffn_init(kind):
    return moe.moe_init if kind == "moe" else layers.mlp_init


def _layer_init(key, spec: LayerSpec, cfg: ModelConfig, stack=()):
    mixer, ffn = spec
    k1, k2 = jax.random.split(key)
    p = {"pre_norm": jnp.zeros((*stack, cfg.d_model), cfg.pdtype)}
    p.update(_MIXER_INIT[mixer](k1, cfg, stack=stack))
    if mixer in ("attn", "mla"):
        p["post_norm"] = jnp.zeros((*stack, cfg.d_model), cfg.pdtype)
        if ffn == "moe":
            p.update(moe.moe_init(k2, cfg, stack=stack))
        else:
            p.update(layers.mlp_init(k2, cfg, stack=stack))
    else:
        # mamba/rwkv blocks in jamba/rwkv6 carry their own ffn sublayer
        p["post_norm"] = jnp.zeros((*stack, cfg.d_model), cfg.pdtype)
        if ffn == "moe":
            p.update(moe.moe_init(k2, cfg, stack=stack))
        else:
            p.update(layers.mlp_init(k2, cfg, stack=stack))
    return p


def _layer_apply(p, x, spec: LayerSpec, cfg: ModelConfig, *, positions=None,
                 cache=None):
    mixer, ffn = spec
    h = layers.rms_norm(x, p["pre_norm"], cfg.norm_eps)
    if mixer == "attn":
        y, new_cache = attention.attn_apply(p["attn"], h, cfg,
                                            positions=positions, cache=cache)
    elif mixer == "mla":
        y, new_cache = mla.mla_apply(p["attn"], h, cfg, positions=positions,
                                     cache=cache)
    elif mixer == "mamba":
        y, new_cache = mamba.mamba_apply(p["mamba"], h, cfg, cache=cache)
    else:
        y, new_cache = rwkv.rwkv_apply(p["rwkv"], h, cfg, cache=cache)
    x = x + y
    h = layers.rms_norm(x, p["post_norm"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    dropped = jnp.zeros((), jnp.int32)
    if ffn == "moe":
        y, aux, dropped = moe.moe_apply(p["moe"], h, cfg)
    else:
        y = layers.mlp_apply(p["mlp"], h, cfg)
    return x + y, new_cache, aux, dropped


@dataclasses.dataclass
class Stack:
    """Prefix/unit decomposition with init/apply for the layer stack."""

    cfg: ModelConfig
    prefix: List[LayerSpec]
    unit: List[LayerSpec]
    n_repeat: int

    @staticmethod
    def build(cfg: ModelConfig) -> "Stack":
        prefix, unit, n_repeat = split_prefix_unit(layer_specs(cfg))
        return Stack(cfg, prefix, unit, n_repeat)

    @property
    def num_layers(self):
        return len(self.prefix) + len(self.unit) * self.n_repeat

    def init(self, key) -> dict:
        cfg = self.cfg
        p = {"prefix": [], "unit": []}
        for i, spec in enumerate(self.prefix):
            p["prefix"].append(_layer_init(jax.random.fold_in(key, i), spec, cfg))
        for j, spec in enumerate(self.unit):
            if cfg.scan_layers:
                p["unit"].append(_layer_init(
                    jax.random.fold_in(key, 100 + j), spec, cfg,
                    stack=(self.n_repeat,)))
            else:
                p["unit"].append([
                    _layer_init(jax.random.fold_in(key, 100 + j * 1000 + r),
                                spec, cfg)
                    for r in range(self.n_repeat)])
        return p

    def apply(self, p, x, *, positions=None, caches=None):
        """caches: {"prefix": [cache...], "unit": [stacked cache...]} or None."""
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)
        dropped_total = jnp.zeros((), jnp.int32)
        new_caches = {"prefix": [], "unit": []} if caches is not None else None

        for i, spec in enumerate(self.prefix):
            c = caches["prefix"][i] if caches is not None else None
            x, nc, aux, dr = _layer_apply(p["prefix"][i], x, spec, cfg,
                                          positions=positions, cache=c)
            aux_total += aux
            dropped_total += dr
            if caches is not None:
                new_caches["prefix"].append(nc)

        if self.n_repeat == 0:
            return x, new_caches, aux_total, dropped_total

        def unit_body(x, unit_params, unit_caches):
            ncs = []
            aux_u = jnp.zeros((), jnp.float32)
            dr_u = jnp.zeros((), jnp.int32)
            for j, spec in enumerate(self.unit):
                c = unit_caches[j] if unit_caches is not None else None
                x, nc, aux, dr = _layer_apply(unit_params[j], x, spec, cfg,
                                              positions=positions, cache=c)
                aux_u += aux
                dr_u += dr
                ncs.append(nc)
            return x, ncs, aux_u, dr_u

        if cfg.remat == "full":
            unit_body = jax.checkpoint(unit_body,
                                       static_argnums=())  # type: ignore
        elif cfg.remat == "selective":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            unit_body = jax.checkpoint(unit_body, policy=policy)  # type: ignore

        if cfg.scan_layers:
            def scan_step(carry, scanned):
                x, aux_t, dr_t = carry
                u_params, u_caches = scanned
                x, ncs, aux_u, dr_u = unit_body(x, u_params, u_caches)
                return (x, aux_t + aux_u, dr_t + dr_u), ncs

            scanned_caches = caches["unit"] if caches is not None else [None] * len(self.unit)
            if caches is None:
                scanned_in = (p["unit"], [None] * len(self.unit))
                # lax.scan can't scan None leaves; use a dummy zero per slot
                scanned_in = (p["unit"],
                              [jnp.zeros((self.n_repeat,), jnp.int32)
                               for _ in self.unit])

                def scan_step_nc(carry, scanned):
                    x, aux_t, dr_t = carry
                    u_params, _ = scanned
                    x, _, aux_u, dr_u = unit_body(x, u_params, None)
                    return (x, aux_t + aux_u, dr_t + dr_u), jnp.zeros((), jnp.int32)

                (x, aux_total, dropped_total), _ = jax.lax.scan(
                    scan_step_nc, (x, aux_total, dropped_total), scanned_in)
            else:
                (x, aux_total, dropped_total), ncs = jax.lax.scan(
                    scan_step, (x, aux_total, dropped_total),
                    (p["unit"], scanned_caches))
                new_caches["unit"] = ncs
        else:
            for r in range(self.n_repeat):
                u_params = [p["unit"][j][r] for j in range(len(self.unit))]
                u_caches = ([caches["unit"][j][r] for j in range(len(self.unit))]
                            if caches is not None else None)
                x, ncs, aux_u, dr_u = unit_body(x, u_params, u_caches)
                aux_total += aux_u
                dropped_total += dr_u
                if caches is not None:
                    new_caches["unit"].append(ncs)
        return x, new_caches, aux_total, dropped_total

    def init_caches(self, batch: int, max_len: int):
        """Stacked caches matching apply()'s scan layout."""
        cfg = self.cfg

        def one(spec: LayerSpec):
            mixer, _ = spec
            if mixer == "attn":
                return attention.init_cache(cfg, batch, max_len)
            if mixer == "mla":
                return mla.init_mla_cache(cfg, batch, max_len)
            if mixer == "mamba":
                return mamba.init_mamba_cache(cfg, batch)
            return rwkv.init_rwkv_cache(cfg, batch)

        caches = {"prefix": [one(s) for s in self.prefix], "unit": []}
        if cfg.scan_layers:
            caches["unit"] = [
                jax.tree.map(lambda a: jnp.broadcast_to(a, (self.n_repeat,) + a.shape),
                             one(s))
                for s in self.unit]
        else:
            caches["unit"] = [[one(s) for _ in range(self.n_repeat)]
                              for s in self.unit]
        return caches
