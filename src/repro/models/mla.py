"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and KV are low-rank compressed; RoPE is decoupled into a small
per-head rope sub-dim (queries) plus one shared rope key channel. The
decode path caches only the compressed latent ``c_kv`` (+ shared rope key)
— the MLA memory win — and uses the absorbed-weight trick: scores and
values are computed in the latent space, so per-step decode FLOPs are
O(S * (kv_rank + rope_dim) * H) instead of O(S * H * head_dim * 2).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import apply_rope, dense_init, rms_norm
from ..distributed.sharding import lshard


def mla_init(key, cfg: ModelConfig, stack: Tuple[int, ...] = ()) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    h = cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    return {"attn": {
        "w_dq": dense_init(ks[0], *stack, d, qr, dtype=cfg.pdtype),
        "q_norm": jnp.zeros((*stack, qr), cfg.pdtype),
        "w_uq": dense_init(ks[1], *stack, qr, h, dn + dr, dtype=cfg.pdtype),
        "w_dkv": dense_init(ks[2], *stack, d, kvr, dtype=cfg.pdtype),
        "kv_norm": jnp.zeros((*stack, kvr), cfg.pdtype),
        "w_kr": dense_init(ks[3], *stack, d, dr, dtype=cfg.pdtype),
        "w_ukv": dense_init(ks[4], *stack, kvr, h, dn + dv, dtype=cfg.pdtype),
        "wo": dense_init(ks[5], *stack, h, dv, d, dtype=cfg.pdtype),
    }}


def _project_q(p, x, cfg: ModelConfig, positions):
    dn, dr = cfg.nope_head_dim, cfg.rope_head_dim
    cq = x @ p["w_dq"].astype(cfg.cdtype)
    cq = rms_norm(cq, p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"].astype(cfg.cdtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_apply(p, x, cfg: ModelConfig, *, positions=None,
              cache: Optional[Dict] = None):
    """Training/prefill path (expanded keys/values) or decode (absorbed)."""
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    if positions is None:
        positions = jnp.arange(s)[None, :]
    scale = 1.0 / np.sqrt(dn + dr)

    c_kv = x @ p["w_dkv"].astype(cfg.cdtype)                    # (B,S,kvr)
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = (x @ p["w_kr"].astype(cfg.cdtype))[:, :, None, :]  # (B,S,1,dr)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]

    if cache is None:
        q_nope, q_rope = _project_q(p, x, cfg, positions)
        kv = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_ukv"].astype(cfg.cdtype))
        k_nope, v = kv[..., :dn], kv[..., dn:]
        scores = (jnp.einsum("bqhd,bshd->bhqs", q_nope, k_nope)
                  + jnp.einsum("bqhd,bsd->bhqs", q_rope, k_rope))
        scores = scores.astype(jnp.float32) * scale
        mask = jnp.arange(s)[:, None] >= jnp.arange(s)[None, :]
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhqs,bshd->bqhd", probs, v)
        new_cache = None
    elif s >= cfg.attn_chunk_threshold:
        # PREFILL into the latent cache: expand k/v once, chunked attention
        from .attention import _chunked_attend
        pos = cache["pos"]
        cc = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, pos, axis=1)
        cr = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope, pos, axis=1)
        new_cache = {"c_kv": cc, "k_rope": cr, "pos": pos + s}
        q_nope, q_rope = _project_q(p, x, cfg, pos + positions)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        kv = jnp.einsum("bsr,rhk->bshk", cc, p["w_ukv"].astype(cfg.cdtype))
        k_nope, v = kv[..., :dn], kv[..., dn:]
        s_total = cc.shape[1]
        cr_b = jnp.broadcast_to(cr[:, :, None, :], (b, s_total, h, dr))
        k_full = jnp.concatenate([k_nope, cr_b], axis=-1)
        out = _chunked_attend(q_full, k_full, v, scale, pos, True,
                              cfg.attn_chunk_size)
    else:
        # absorbed decode: score/value in the latent space
        pos = cache["pos"]
        cc = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, pos, axis=1)
        cr = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope, pos, axis=1)
        new_cache = {"c_kv": cc, "k_rope": cr, "pos": pos + s}
        q_nope, q_rope = _project_q(p, x, cfg, pos + positions)
        w_uk = p["w_ukv"].astype(cfg.cdtype)[..., :dn]          # (kvr,h,dn)
        q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)      # (B,s,h,kvr)
        scores = (jnp.einsum("bqhr,bsr->bhqs", q_abs, cc)
                  + jnp.einsum("bqhd,bsd->bhqs", q_rope, cr))
        scores = scores.astype(jnp.float32) * scale
        s_total = cc.shape[1]
        mask = (pos + jnp.arange(s))[:, None] >= jnp.arange(s_total)[None, :]
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out_lat = jnp.einsum("bhqs,bsr->bqhr", probs, cc)       # latent values
        w_uv = p["w_ukv"].astype(cfg.cdtype)[..., dn:]          # (kvr,h,dv)
        out = jnp.einsum("bqhr,rhd->bqhd", out_lat, w_uv)

    out = lshard(out, "batch", "seq", "heads", None)
    y = jnp.einsum("bshd,hdk->bsk", out, p["wo"].astype(cfg.cdtype))
    return lshard(y, "batch", "seq", None), new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.cdtype
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
