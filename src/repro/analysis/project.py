"""Whole-program index: symbol table + call graph across modules.

PR 5's analyzer was explicitly per-file — "reachability does not cross
module boundaries" — which goes blind exactly where this codebase keeps
its hazards: a helper in ``core/hdb.py`` called from a jitted step in
``streaming/engine.py`` is jit-reachable at runtime but invisible to a
per-file closure. This module is the phase-1 *index* of the two-phase
run: parse every file, resolve imports into a project-wide symbol
table, build the call graph, and close jit reachability over it; the
phase-2 *check* then runs the per-module rule pack with each module's
``jit_reachable`` set extended by the cross-module closure.

What the index resolves (and what it deliberately does not):

- absolute imports (``import repro.core.hdb``, ``from repro.core.hdb
  import intersect_keys``) and relative imports at any level
  (``from . import routing``, ``from ..kernels import pairs``);
- package re-exports: ``from ..kernels import pairs as pk`` followed by
  ``pk.pack_sort_words(...)`` follows ``kernels/pairs/__init__.py``'s
  own ``from .ops import pack_sort_words`` chain (bounded depth), and
  ``import *`` falls back to searching the star-imported module;
- methods bound by class: ``self.m(...)`` resolves inside the enclosing
  ``ClassDef`` only (no inheritance walk, no duck typing);
- ``functools.partial(fn, ...)`` and decorator jit roots, including
  wrapper calls whose target lives in another module
  (``jax.jit(mod.fn)``, ``shard_map(imported_fn, ...)``).

Known imprecision (documented in docs/ANALYSIS.md): dynamic dispatch
(``getattr``, dict-of-functions), reflection, monkey-patching, and
``obj.method()`` on values of unknown type are not resolved — the graph
under-approximates there and rules stay quiet rather than guess.

The index also collects the project's *mesh-axis universe* for the R006
collective-contract rule: axis names (and literal sizes) declared by
``jax.make_mesh((2, 4), ("pod", "data"))`` / ``Mesh(..., axis_names=...)``
constructions plus literal ``axis_names=("data",)`` parameter defaults.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .engine import ModuleContext, dotted_name

# names whose literal defaults declare mesh axes (see module docstring)
_AXIS_PARAM_NAMES = {"axis_name", "axis_names", "axes"}
_RESOLVE_DEPTH = 8  # re-export chains are short; bound against cycles

Symbol = Tuple[str, str]  # (module name, bare function/method name)


def module_name_for(path: str) -> str:
    """Dotted module name from the file's package-root-relative path.

    Walks up through directories containing ``__init__.py`` (the package
    chain); files outside any package get their bare stem, so standalone
    scripts (benchmarks, tests) still index and cross-resolve by name.
    """
    path = os.path.abspath(path)
    d, base = os.path.split(path)
    stem = base[:-3] if base.endswith(".py") else base
    parts: List[str] = [] if stem == "__init__" else [stem]
    while os.path.isfile(os.path.join(d, "__init__.py")):
        d, pkg = os.path.split(d)
        parts.append(pkg)
    return ".".join(reversed(parts)) or stem


class ModuleInfo:
    """Import bindings + class table of one parsed module."""

    def __init__(self, ctx: ModuleContext, name: str):
        self.ctx = ctx
        self.path = ctx.path
        self.name = name
        self.is_package = ctx.path.endswith("__init__.py")
        # the package relative imports resolve against
        self.package = name if self.is_package else name.rpartition(".")[0]
        # local name -> ("mod", module) | ("sym", module, symbol)
        self.bindings: Dict[str, Tuple[str, ...]] = {}
        # full dotted module names bound by plain `import a.b.c`
        self.imported_modules: Set[str] = set()
        self.star_imports: List[str] = []
        # class name -> {method name -> def node}
        self.classes: Dict[str, Dict[str, ast.AST]] = {}
        # def bare name -> enclosing class name (methods only)
        self.method_class: Dict[str, str] = {}
        self._collect_imports()
        self._collect_classes()

    def _rel_base(self, level: int) -> Optional[str]:
        """Package that a level-``level`` relative import resolves in."""
        base = self.package
        for _ in range(level - 1):
            if not base:
                return None
            base = base.rpartition(".")[0]
        return base if base else None

    def _collect_imports(self) -> None:
        for node in ast.walk(self.ctx.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    self.imported_modules.add(alias.name)
                    if alias.asname:
                        self.bindings[alias.asname] = ("mod", alias.name)
            elif isinstance(node, ast.ImportFrom):
                if node.level > 0:
                    base = self._rel_base(node.level)
                    if base is None:
                        continue
                    mod = f"{base}.{node.module}" if node.module else base
                else:
                    mod = node.module or ""
                for alias in node.names:
                    if alias.name == "*":
                        self.star_imports.append(mod)
                        continue
                    bound = alias.asname or alias.name
                    self.bindings[bound] = ("sym", mod, alias.name)

    def _collect_classes(self) -> None:
        for node in ast.walk(self.ctx.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            methods = {
                ch.name: ch for ch in node.body
                if isinstance(ch, (ast.FunctionDef, ast.AsyncFunctionDef))
            }
            self.classes[node.name] = methods
            for m in methods:
                self.method_class.setdefault(m, node.name)


class Project:
    """Phase-1 index over a set of modules; closes jit reachability.

    Construction runs the whole index: per-module import/class tables,
    the global call graph, jit-root discovery, the cross-module
    reachability closure (injected into each ``ModuleContext`` via
    ``extend_jit_reachable``), and the R006 mesh-axis universe. Every
    ``ModuleContext`` gets ``ctx.project = self`` so rules can consult
    project-wide facts.
    """

    def __init__(self, contexts: Iterable[ModuleContext]):
        self.modules: Dict[str, ModuleInfo] = {}
        self.by_path: Dict[str, ModuleInfo] = {}
        for ctx in contexts:
            mi = ModuleInfo(ctx, module_name_for(ctx.path))
            self.modules[mi.name] = mi
            self.by_path[ctx.path] = mi
        # mesh-axis universe (R006)
        self.declared_axes: Set[str] = set()
        # axis name -> literal size, or None when declarations disagree
        self.axis_sizes: Dict[str, Optional[int]] = {}
        self._collect_axis_universe()
        # call graph + reachability
        self.edges: Dict[Symbol, Set[Symbol]] = {}
        self.jit_roots: Set[Symbol] = set()
        self.jit_reachable: Set[Symbol] = set()
        self._build_call_graph()
        self._close_reachability()
        for mi in self.modules.values():
            local = {s for (m, s) in self.jit_reachable if m == mi.name}
            mi.ctx.extend_jit_reachable(local)
            mi.ctx.project = self

    # -- symbol resolution ---------------------------------------------

    def _resolve_symbol(self, mod: str, sym: str,
                        depth: int = 0) -> Optional[Symbol]:
        """(module, symbol) of the def ``mod.sym`` names, following
        re-export chains through indexed modules."""
        if depth > _RESOLVE_DEPTH:
            return None
        mi = self.modules.get(mod)
        if mi is None:
            return None
        if sym in mi.ctx.functions:
            return (mod, sym)
        b = mi.bindings.get(sym)
        if b is not None:
            if b[0] == "sym":
                return self._resolve_symbol(b[1], b[2], depth + 1)
            return None  # a submodule, not a callable symbol
        for star in mi.star_imports:
            got = self._resolve_symbol(star, sym, depth + 1)
            if got is not None:
                return got
        return None

    def _resolve_dotted(self, mi: ModuleInfo, d: str) -> Optional[Symbol]:
        """Resolve a dotted reference ``a.b.c`` in module ``mi``."""
        head, _, rest = d.partition(".")
        if not rest:
            # bare name: local def wins, then from-imports, then stars
            if head in mi.ctx.functions:
                return (mi.name, head)
            b = mi.bindings.get(head)
            if b is not None and b[0] == "sym":
                return self._resolve_symbol(b[1], b[2])
            for star in mi.star_imports:
                got = self._resolve_symbol(star, head)
                if got is not None:
                    return got
            return None
        b = mi.bindings.get(head)
        base: Optional[str] = None
        if b is not None:
            if b[0] == "mod":
                base = b[1]
            elif b[0] == "sym":
                # `from ..kernels import pairs` binds the submodule
                cand = f"{b[1]}.{b[2]}"
                base = cand if cand in self.modules else None
        elif any(m == head or m.startswith(head + ".")
                 for m in mi.imported_modules):
            base = head
        if base is None:
            return None
        parts = rest.split(".")
        for i, part in enumerate(parts):
            if i == len(parts) - 1:
                return self._resolve_symbol(base, part)
            nxt = f"{base}.{part}"
            if nxt not in self.modules:
                # not an indexed submodule; try it as a re-exported one
                got = self.modules.get(base)
                if got is not None:
                    b2 = got.bindings.get(part)
                    if b2 is not None and b2[0] == "sym" \
                            and f"{b2[1]}.{b2[2]}" in self.modules:
                        nxt = f"{b2[1]}.{b2[2]}"
                    else:
                        return None
                else:
                    return None
            base = nxt
        return None

    def resolve_call(self, ctx: ModuleContext, node: ast.AST,
                     encl_class: Optional[str] = None) -> Optional[Symbol]:
        """Symbol a call/reference expression targets, or None."""
        mi = self.by_path.get(ctx.path)
        if mi is None:
            return None
        if isinstance(node, ast.Name):
            return self._resolve_dotted(mi, node.id)
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"):
            cls = encl_class
            if cls is not None and node.attr in mi.classes.get(cls, {}):
                return (mi.name, node.attr)
            return None
        d = dotted_name(node)
        if d is not None:
            return self._resolve_dotted(mi, d)
        return None

    # -- call graph ----------------------------------------------------

    def _enclosing_class(self, mi: ModuleInfo, fn_name: str) -> Optional[str]:
        return mi.method_class.get(fn_name)

    def _callees(self, mi: ModuleInfo, fn_name: str,
                 fn: ast.AST) -> Set[Symbol]:
        ctx = mi.ctx
        encl_class = self._enclosing_class(mi, fn_name)
        out: Set[Symbol] = set()
        for node in ast.walk(fn):
            target: Optional[ast.AST] = None
            if isinstance(node, ast.Call):
                target = node.func
                # functools.partial(fn, ...): the wrapped fn is "called"
                if ctx.is_partial_expr(node.func) and node.args:
                    got = self.resolve_call(ctx, node.args[0], encl_class)
                    if got is not None:
                        out.add(got)
            elif isinstance(node, (ast.Name, ast.Attribute)):
                # bare reference: fn passed as a value (lax.cond/scan,
                # wrapper builders); Attribute covers `mod.fn` references
                target = node
            if target is None:
                continue
            got = self.resolve_call(ctx, target, encl_class)
            if got is not None:
                out.add(got)
        return out

    def _build_call_graph(self) -> None:
        for mi in self.modules.values():
            ctx = mi.ctx
            for name, fn in ctx.functions.items():
                self.edges[(mi.name, name)] = self._callees(mi, name, fn)
            # local jit roots found by the per-file pass
            for name in ctx.jit_roots:
                self.jit_roots.add((mi.name, name))
            # wrapper calls whose target lives in another module:
            # jax.jit(mod.fn), shard_map(imported_fn, ...)
            for node in ast.walk(ctx.tree):
                if not isinstance(node, ast.Call):
                    continue
                if not (ctx.is_jit_expr(node.func)
                        or ctx.is_tracing_wrapper(node.func)):
                    continue
                cands = list(node.args[:1]) + [
                    kw.value for kw in node.keywords
                    if kw.arg in ("fun", "kernel", "f")
                ]
                for arg in cands:
                    if isinstance(arg, ast.Call) \
                            and ctx.is_partial_expr(arg.func) and arg.args:
                        arg = arg.args[0]
                    got = self.resolve_call(ctx, arg)
                    if got is not None:
                        self.jit_roots.add(got)

    def _close_reachability(self) -> None:
        reach = set(self.jit_roots)
        frontier = list(reach)
        while frontier:
            sym = frontier.pop()
            for callee in self.edges.get(sym, ()):
                if callee not in reach:
                    reach.add(callee)
                    frontier.append(callee)
        self.jit_reachable = reach

    # -- mesh-axis universe (R006) --------------------------------------

    @staticmethod
    def _literal_strs(node: ast.AST) -> Optional[List[str]]:
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            return [node.value]
        if isinstance(node, (ast.Tuple, ast.List)):
            out = []
            for el in node.elts:
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    out.append(el.value)
                else:
                    return None
            return out
        return None

    @staticmethod
    def _literal_ints(node: ast.AST) -> Optional[List[int]]:
        if isinstance(node, (ast.Tuple, ast.List)):
            out = []
            for el in node.elts:
                if isinstance(el, ast.Constant) and isinstance(el.value, int):
                    out.append(el.value)
                else:
                    return None
            return out
        return None

    def _declare_axes(self, names: Sequence[str],
                      sizes: Optional[Sequence[int]] = None) -> None:
        for i, name in enumerate(names):
            self.declared_axes.add(name)
            size = sizes[i] if sizes is not None and i < len(sizes) else None
            if size is None:
                self.axis_sizes.setdefault(name, None)
            elif name not in self.axis_sizes:
                self.axis_sizes[name] = size
            elif self.axis_sizes[name] != size:
                self.axis_sizes[name] = None  # ambiguous across decls

    @staticmethod
    def _axis_arg_variants(ctx: ModuleContext, use_site: ast.AST,
                           node: Optional[ast.AST],
                           depth: int = 0) -> List[ast.AST]:
        """Literal candidates a mesh-constructor argument can denote.

        Follows local names to their assignments and splits conditional
        expressions into both branches (``axes = (...) if multi else
        (...)``), in source order so names/sizes variants zip branchwise.
        """
        if node is None or depth > 4:
            return []
        if isinstance(node, ast.IfExp):
            return (Project._axis_arg_variants(ctx, use_site, node.body,
                                               depth + 1)
                    + Project._axis_arg_variants(ctx, use_site, node.orelse,
                                                 depth + 1))
        if isinstance(node, ast.Name):
            fn = ctx.enclosing_function(use_site)
            scopes = [fn] if fn is not None else []
            scopes.append(ctx.tree)
            for scope in scopes:
                for n in ast.walk(scope):
                    if isinstance(n, ast.Assign) and any(
                            isinstance(t, ast.Name) and t.id == node.id
                            for t in n.targets):
                        return Project._axis_arg_variants(
                            ctx, use_site, n.value, depth + 1)
            return []
        return [node]

    def _collect_axis_universe(self) -> None:
        for mi in self.modules.values():
            for node in ast.walk(mi.ctx.tree):
                if isinstance(node, ast.Call):
                    d = dotted_name(node.func) or ""
                    tail = d.rpartition(".")[2]
                    if tail not in ("Mesh", "make_mesh"):
                        continue
                    names_node: Optional[ast.AST] = None
                    sizes_node: Optional[ast.AST] = None
                    if len(node.args) >= 2:
                        names_node = node.args[1]
                    for kw in node.keywords:
                        if kw.arg == "axis_names":
                            names_node = kw.value
                        elif kw.arg == "axis_shapes":
                            sizes_node = kw.value
                    if tail == "make_mesh" and node.args:
                        sizes_node = node.args[0]
                    name_vars = [
                        got for v in self._axis_arg_variants(
                            mi.ctx, node, names_node)
                        if (got := self._literal_strs(v))
                    ]
                    size_vars = [
                        self._literal_ints(v)
                        for v in self._axis_arg_variants(
                            mi.ctx, node, sizes_node)
                    ]
                    branchwise = len(size_vars) == len(name_vars)
                    for i, names in enumerate(name_vars):
                        self._declare_axes(
                            names, size_vars[i] if branchwise else None)
                elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    # axis_names=("data",) parameter defaults declare the
                    # axes a library module is written against
                    args = node.args
                    pos = list(args.posonlyargs) + list(args.args)
                    defaults = list(args.defaults)
                    pairs = list(zip(pos[len(pos) - len(defaults):], defaults))
                    pairs += [(a, d) for a, d in
                              zip(args.kwonlyargs, args.kw_defaults)
                              if d is not None]
                    for a, dflt in pairs:
                        if a.arg in _AXIS_PARAM_NAMES:
                            names = self._literal_strs(dflt)
                            if names:
                                self._declare_axes(names)

    # -- cache support ---------------------------------------------------

    def reach_digest_parts(self, ctx: ModuleContext) -> List[str]:
        """Project-state inputs a module's findings depend on, for the
        on-disk cache key: the cross-module reachability injected into
        this module and the R006 axis universe."""
        mi = self.by_path.get(ctx.path)
        injected = sorted(
            s for (m, s) in self.jit_reachable
            if mi is not None and m == mi.name)
        axes = sorted(f"{a}={self.axis_sizes.get(a)}"
                      for a in self.declared_axes)
        return injected + ["|"] + axes
