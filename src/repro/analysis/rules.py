"""The R001-R005 rule pack over ``ModuleContext``.

Each rule is a registered ``ModuleContext -> [Finding]`` function.
Detection is a static approximation tuned to this codebase's idioms
(see docs/ANALYSIS.md for each rule's exact contract and how to
suppress with ``# repro: noqa[RULE]``). Jit reachability is no longer
per-file: the phase-1 index (``project.Project``) injects the
cross-module closure into each ``ModuleContext``, so R001/R003 flag a
helper here that is only jitted from another module:

- R001 host-transfer-in-jit: host calls (``np.*``, ``float``/``int``/
  ``bool``, ``.item()``/``.tolist()``, ``jax.device_get``) applied to
  *traced* values inside jit/pallas/shard_map-reachable functions.
- R002 dtype-contract drift: uint64 packed-word arithmetic with untyped
  int literals (NumPy 1.x value-based casting promotes through int64 to
  float64 — silent precision loss past 2**53, i.e. every 62-bit sort
  word), uint64 x int64 mixes (float64 even under NEP 50), narrowing
  casts straight off a uint64 word without an explicit mask/shift, and
  ``jnp.uint64``/``jnp.int64`` references (x64 is off: they are silently
  32-bit — core/u64.py exists precisely because of this).
- R003 tracer control flow: Python ``if``/``while``/``for``/``assert``
  branching on traced values inside jit-reachable functions.
- R004 unsynced benchmark timing: ``time.perf_counter()`` windows that
  call real work with no ``jax.block_until_ready`` before the clock
  stops (measures async dispatch, not execution).
- R005 jit-cache hazards: ``jax.jit`` constructed inside a loop or per
  call (uncached function body), and static_argnames/nums naming an
  array-annotated parameter (hashed by value per call, or unhashable).
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from .engine import (
    _STATIC_ATTRS,
    Finding,
    ModuleContext,
    dotted_name,
    register,
)

# -- shared expression helpers ---------------------------------------------

_U64_DTYPES = {"uint64"}
_I64_DTYPES = {"int64"}
_NARROW_DTYPES = {
    "int32", "uint32", "int16", "uint16", "int8", "uint8",
    "float32", "float16", "bfloat16",
}
_JNP_64BIT = {"uint64", "int64", "float64"}
_ARRAY_ANNOTATIONS = {"ndarray", "Array", "ArrayLike"}
# calls whose cost/semantics are irrelevant to a timing window
_TRIVIAL_CALLS = {
    "perf_counter", "time", "print", "len", "range", "min", "max", "int",
    "float", "str", "format", "append", "emit", "flush", "sum", "abs",
    "round", "enumerate", "zip", "dict", "list", "tuple", "set", "sorted",
    "isinstance", "getattr", "items", "keys", "values", "join", "split",
}


def _scope_nodes(scope: ast.AST, *, keep_lambdas: bool = False) -> List[ast.AST]:
    """All nodes in ``scope`` excluding nested function/class bodies."""
    out: List[ast.AST] = []
    stack = list(ast.iter_child_nodes(scope))
    while stack:
        n = stack.pop()
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            continue
        if isinstance(n, ast.Lambda) and not keep_lambdas:
            continue
        out.append(n)
        stack.extend(ast.iter_child_nodes(n))
    return sorted(out, key=lambda n: (getattr(n, "lineno", 0), getattr(n, "col_offset", 0)))


def _np_attr(ctx: ModuleContext, node: ast.AST, attrs: Set[str]) -> bool:
    """Is ``node`` the attribute ``np.<attr>`` for a numpy alias?"""
    d = dotted_name(node)
    if not d or "." not in d:
        return False
    root, _, attr = d.partition(".")
    return root in ctx.numpy_aliases and attr in attrs


def _jnp_attr(ctx: ModuleContext, node: ast.AST, attrs: Set[str]) -> bool:
    d = dotted_name(node)
    if not d or "." not in d:
        return False
    root, _, attr = d.partition(".")
    return root in ctx.jnp_aliases and attr in attrs


def _is_dtype_ref(ctx: ModuleContext, node: ast.AST, dtypes: Set[str]) -> bool:
    """np.uint64 / jnp.uint64 / "uint64" style dtype references."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value in dtypes
    return _np_attr(ctx, node, dtypes) or _jnp_attr(ctx, node, dtypes)


class _U64Scope:
    """Local-dataflow uint64 typing for one scope (module or function)."""

    def __init__(self, ctx: ModuleContext, scope: ast.AST,
                 inherited: Optional[Set[str]] = None):
        self.ctx = ctx
        self.names: Set[str] = set(inherited or ())
        assigns = [
            n for n in _scope_nodes(scope)
            if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign))
        ]
        for _ in range(2):  # 2 passes reach fixpoint on straight-line chains
            for a in assigns:
                value = a.value
                if value is None or not self.is_u64(value):
                    continue
                targets = a.targets if isinstance(a, ast.Assign) else [a.target]
                for t in targets:
                    for el in (t.elts if isinstance(t, ast.Tuple) else [t]):
                        if isinstance(el, ast.Name):
                            self.names.add(el.id)

    def is_u64(self, node: ast.AST) -> bool:
        ctx = self.ctx
        if isinstance(node, ast.Name):
            return node.id in self.names
        if isinstance(node, ast.Subscript):
            return self.is_u64(node.value)
        if isinstance(node, (ast.UnaryOp,)):
            return self.is_u64(node.operand)
        if isinstance(node, ast.BinOp):
            return self.is_u64(node.left) or self.is_u64(node.right)
        if isinstance(node, ast.IfExp):
            return self.is_u64(node.body) or self.is_u64(node.orelse)
        if isinstance(node, ast.Call):
            # np.uint64(x) constructor
            if _np_attr(ctx, node.func, _U64_DTYPES):
                return True
            # x.astype(np.uint64)
            if (
                isinstance(node.func, ast.Attribute)
                and node.func.attr in ("astype", "view")
                and node.args
                and _is_dtype_ref(ctx, node.args[0], _U64_DTYPES)
            ):
                return True
            # u64-preserving numpy transforms: np.sort(w), np.concatenate(...)
            if _np_attr(ctx, node.func, {
                "sort", "concatenate", "unique", "where", "pad", "minimum",
                "maximum", "copy", "ascontiguousarray", "flip", "roll",
            }):
                for arg in node.args:
                    elts = arg.elts if isinstance(arg, (ast.List, ast.Tuple)) else [arg]
                    if any(self.is_u64(e) for e in elts):
                        return True
        return False


def _uses_traced(node: ast.AST, traced: Set[str]) -> bool:
    """Does this expression read a traced value?

    Skips subtrees whose result is host-static: ``x.shape``-style
    attribute reads and ``len(x)``.
    """
    stack = [node]
    while stack:
        n = stack.pop()
        if isinstance(n, ast.Attribute) and n.attr in _STATIC_ATTRS:
            continue
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Name) and n.func.id == "len":
            continue
        if isinstance(n, ast.Name) and n.id in traced:
            return True
        stack.extend(ast.iter_child_nodes(n))
    return False


def _traced_names(ctx: ModuleContext, fn) -> Set[str]:
    """Parameters traced under jit, plus names derived from them."""
    args = fn.args
    params = [
        a.arg
        for a in list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs)
    ]
    static = ctx.static_params.get(fn.name, set())
    traced = {p for p in params if p not in static}
    assigns = [
        n for n in _scope_nodes(fn, keep_lambdas=True)
        if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign))
    ]
    for _ in range(2):
        for a in assigns:
            if a.value is None or not _uses_traced(a.value, traced):
                continue
            targets = a.targets if isinstance(a, ast.Assign) else [a.target]
            for t in targets:
                for el in (t.elts if isinstance(t, ast.Tuple) else [t]):
                    if isinstance(el, ast.Name):
                        traced.add(el.id)
    return traced


# -- R001: host transfer inside jit-traced code ----------------------------


@register(
    "R001",
    "host-transfer-in-jit",
    "host calls (np.*, float/int/bool, .item()/.tolist(), jax.device_get) "
    "on traced values inside jit/pallas/shard_map-reachable functions",
)
def check_host_transfer(ctx: ModuleContext) -> List[Finding]:
    findings: List[Finding] = []
    for name in sorted(ctx.jit_reachable):
        fn = ctx.functions.get(name)
        if fn is None:
            continue
        traced = _traced_names(ctx, fn)
        for node in _scope_nodes(fn, keep_lambdas=True):
            if not isinstance(node, ast.Call):
                continue
            argish = list(node.args) + [kw.value for kw in node.keywords]
            touches = any(_uses_traced(a, traced) for a in argish)
            # np.anything(traced) — trace-time host compute / forced transfer
            d = dotted_name(node.func)
            if d and d.partition(".")[0] in ctx.numpy_aliases and touches:
                findings.append(ctx.finding(
                    "R001", node,
                    f"host numpy call `{d}` on a traced value inside "
                    f"jit-reachable `{name}` (forces a device->host "
                    "transfer or silently computes at trace time)"))
                continue
            # float(x) / int(x) / bool(x) on traced values
            if (
                isinstance(node.func, ast.Name)
                and node.func.id in ("float", "int", "bool", "complex")
                and touches
            ):
                findings.append(ctx.finding(
                    "R001", node,
                    f"`{node.func.id}()` on a traced value inside "
                    f"jit-reachable `{name}` (implicit device->host "
                    "transfer; fails under jax.transfer_guard)"))
                continue
            # x.item() / x.tolist() where x is traced
            if (
                isinstance(node.func, ast.Attribute)
                and node.func.attr in ("item", "tolist", "to_py")
                and _uses_traced(node.func.value, traced)
            ):
                findings.append(ctx.finding(
                    "R001", node,
                    f"`.{node.func.attr}()` on a traced value inside "
                    f"jit-reachable `{name}` (device->host transfer)"))
                continue
            # jax.device_get(traced)
            if d and any(d == f"{a}.device_get" for a in ctx.jax_aliases) and touches:
                findings.append(ctx.finding(
                    "R001", node,
                    f"`jax.device_get` inside jit-reachable `{name}` "
                    "(host transfer mid-trace)"))
    return findings


# -- R002: dtype-contract drift --------------------------------------------


@register(
    "R002",
    "dtype-contract-drift",
    "uint64 packed-word arithmetic with untyped int literals or int64 "
    "values, narrowing casts straight off a uint64 word, and 64-bit jnp "
    "dtype references while x64 is disabled",
)
def check_dtype_contracts(ctx: ModuleContext) -> List[Finding]:
    findings: List[Finding] = []

    # (d) jnp.uint64 / jnp.int64 / jnp.float64 anywhere: x64 is off
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Attribute) and _jnp_attr(ctx, node, _JNP_64BIT):
            findings.append(ctx.finding(
                "R002", node,
                f"`{dotted_name(node)}` with x64 disabled is silently "
                "32-bit — use core/u64.py limb pairs for 64-bit values"))

    module_scope = _U64Scope(ctx, ctx.tree)
    scopes = [(ctx.tree, module_scope)]
    for fn in ctx.functions.values():
        scopes.append((fn, _U64Scope(ctx, fn, inherited=module_scope.names)))

    seen: Set[int] = set()
    for scope, u64 in scopes:
        for node in _scope_nodes(scope, keep_lambdas=True):
            if id(node) in seen:
                continue
            # (a)/(b) uint64 mixed with untyped literal or int64 value
            if isinstance(node, ast.BinOp) and not isinstance(node.op, (ast.MatMult,)):
                left_u64, right_u64 = u64.is_u64(node.left), u64.is_u64(node.right)
                if left_u64 ^ right_u64:
                    other = node.right if left_u64 else node.left
                    if isinstance(other, ast.Constant) and isinstance(other.value, int) \
                            and not isinstance(other.value, bool):
                        seen.add(id(node))
                        findings.append(ctx.finding(
                            "R002", node,
                            "uint64 arithmetic with an untyped int literal "
                            "(NumPy 1.x value-based casting promotes through "
                            "int64 to float64 — precision loss past 2**53); "
                            "wrap the literal in np.uint64(...)"))
                    elif isinstance(other, ast.Call) and (
                        _np_attr(ctx, other.func, _I64_DTYPES)
                        or (
                            isinstance(other.func, ast.Attribute)
                            and other.func.attr == "astype"
                            and other.args
                            and _is_dtype_ref(ctx, other.args[0], _I64_DTYPES)
                        )
                    ):
                        seen.add(id(node))
                        findings.append(ctx.finding(
                            "R002", node,
                            "uint64 x int64 arithmetic promotes to float64 "
                            "(even under NEP 50) — cast one side explicitly"))
            # (c) narrowing cast straight off a uint64 word
            if isinstance(node, ast.Call):
                cast_to_narrow = (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr == "astype"
                    and node.args
                    and _is_dtype_ref(ctx, node.args[0], _NARROW_DTYPES)
                )
                if cast_to_narrow:
                    src_node = node.func.value
                elif (
                    (_np_attr(ctx, node.func, _NARROW_DTYPES)
                     or _jnp_attr(ctx, node.func, _NARROW_DTYPES))
                    and len(node.args) == 1
                ):
                    src_node = node.args[0]
                else:
                    continue
                # masked/shifted words ((w >> k), (w & m)) narrow on purpose
                if isinstance(src_node, (ast.Name, ast.Subscript)) and u64.is_u64(src_node):
                    seen.add(id(node))
                    findings.append(ctx.finding(
                        "R002", node,
                        "narrowing cast directly off a uint64 packed word "
                        "drops high bits (62-bit word / 23-bit rid contract) "
                        "— mask or shift the field out explicitly first"))
    return findings


# -- R003: Python control flow on traced values ----------------------------


@register(
    "R003",
    "tracer-control-flow",
    "Python if/while/for/assert branching on traced values inside "
    "jit-reachable functions (TracerBoolConversionError at trace time, or "
    "silent per-value recompilation)",
)
def check_tracer_control_flow(ctx: ModuleContext) -> List[Finding]:
    findings: List[Finding] = []
    for name in sorted(ctx.jit_reachable):
        fn = ctx.functions.get(name)
        if fn is None:
            continue
        traced = _traced_names(ctx, fn)
        for node in _scope_nodes(fn, keep_lambdas=True):
            test: Optional[ast.AST] = None
            kind = ""
            if isinstance(node, (ast.If, ast.While)):
                test, kind = node.test, type(node).__name__.lower()
            elif isinstance(node, ast.IfExp):
                test, kind = node.test, "conditional expression"
            elif isinstance(node, ast.Assert):
                test, kind = node.test, "assert"
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                test, kind = node.iter, "for-loop iteration"
            if test is not None and _uses_traced(test, traced):
                findings.append(ctx.finding(
                    "R003", node,
                    f"Python {kind} on a traced value inside jit-reachable "
                    f"`{name}` — use jax.lax.cond/while_loop/fori_loop or "
                    "jnp.where (Python control flow branches at trace time)"))
    return findings


# -- R004: unsynced benchmark timing ---------------------------------------


def _is_perf_counter_call(ctx: ModuleContext, node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    if isinstance(node.func, ast.Name) and node.func.id in ctx.perf_counter_names:
        return True
    d = dotted_name(node.func)
    return bool(d) and any(d == f"{t}.perf_counter" for t in ctx.time_aliases)


def _is_sync_call(ctx: ModuleContext, node: ast.Call) -> bool:
    # jax.block_until_ready / x.block_until_ready(), plus the benchmarks'
    # `sync(...)` helper (benchmarks/common.py), which wraps it
    if isinstance(node.func, ast.Attribute) and node.func.attr in (
        "block_until_ready", "sync",
    ):
        return True
    if isinstance(node.func, ast.Name) and node.func.id == "sync":
        return True
    d = dotted_name(node.func)
    return bool(d) and any(d == f"{a}.block_until_ready" for a in ctx.jax_aliases)


def _is_trivial_call(ctx: ModuleContext, node: ast.Call) -> bool:
    if _is_perf_counter_call(ctx, node):
        return True
    if isinstance(node.func, ast.Name):
        return node.func.id in _TRIVIAL_CALLS
    if isinstance(node.func, ast.Attribute):
        return node.func.attr in _TRIVIAL_CALLS
    return False


@register(
    "R004",
    "unsynced-benchmark-timing",
    "time.perf_counter() windows that run real work with no "
    "jax.block_until_ready before the clock stops (JAX dispatch is async: "
    "the window measures enqueue time, not execution)",
)
def check_unsynced_timing(ctx: ModuleContext) -> List[Finding]:
    if not ctx.imports_jaxlike:
        return []
    findings: List[Finding] = []
    scopes: List[ast.AST] = [ctx.tree] + list(ctx.functions.values())
    for scope in scopes:
        nodes = _scope_nodes(scope, keep_lambdas=True)
        starts = [
            (n.targets[0].id, n.lineno)
            for n in nodes
            if isinstance(n, ast.Assign)
            and len(n.targets) == 1
            and isinstance(n.targets[0], ast.Name)
            and _is_perf_counter_call(ctx, n.value)
        ]
        stops = [
            (n.right.id, n.lineno, n)
            for n in nodes
            if isinstance(n, ast.BinOp)
            and isinstance(n.op, ast.Sub)
            and _is_perf_counter_call(ctx, n.left)
            and isinstance(n.right, ast.Name)
        ]
        for var, start_line in starts:
            matching = [s for s in stops if s[0] == var and s[1] >= start_line]
            if not matching:
                continue
            _, stop_line, stop_node = min(matching, key=lambda s: s[1])
            window = [
                c for c in nodes
                if isinstance(c, ast.Call) and start_line < c.lineno <= stop_line
            ]
            if any(_is_sync_call(ctx, c) for c in window):
                continue
            if any(not _is_trivial_call(ctx, c) and not _is_sync_call(ctx, c)
                   for c in window):
                findings.append(ctx.finding(
                    "R004", stop_node,
                    f"timing window `{var}` (opened line {start_line}) stops "
                    "the clock without jax.block_until_ready on the timed "
                    "outputs — measures async dispatch, not execution"))
    return findings


# -- R005: jit-cache hazards -----------------------------------------------


def _has_cache_decorator(ctx: ModuleContext, fn) -> bool:
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        if isinstance(target, ast.Name) and target.id in ctx.cache_deco_names:
            return True
        d = dotted_name(target)
        if d and any(d in (f"{a}.lru_cache", f"{a}.cache")
                     for a in ctx.functools_aliases):
            return True
    return False


def _is_self_attr_assign(ctx: ModuleContext, call: ast.Call) -> bool:
    """``self._step = jax.jit(...)``: per-instance cache, a legit idiom."""
    parent = ctx.parents.get(call)
    if isinstance(parent, ast.Assign) and parent.value is call:
        return any(
            isinstance(t, ast.Attribute)
            and isinstance(t.value, ast.Name)
            and t.value.id == "self"
            for t in parent.targets
        )
    return False


def _is_array_annotation(node: Optional[ast.AST]) -> bool:
    if node is None:
        return False
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        tail = node.value.split("[")[0].split(".")[-1]
        return tail in _ARRAY_ANNOTATIONS
    if isinstance(node, ast.Subscript):  # e.g. jax.Array-ish generics
        return _is_array_annotation(node.value)
    d = dotted_name(node)
    if d:
        return d.split(".")[-1] in _ARRAY_ANNOTATIONS
    return False


def _array_static_findings(ctx: ModuleContext, call_or_dec: ast.Call, fn,
                           findings: List[Finding]) -> None:
    static = ctx._static_argnames_from_call(call_or_dec, fn)
    args = fn.args
    ann = {
        a.arg: a.annotation
        for a in list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs)
    }
    for p in sorted(static):
        if _is_array_annotation(ann.get(p)):
            findings.append(ctx.finding(
                "R005", call_or_dec,
                f"static arg `{p}` of `{fn.name}` is array-annotated — "
                "arrays are unhashable (TypeError) or retrace per value; "
                "pass it traced or hash a scalar summary instead"))


@register(
    "R005",
    "jit-cache-hazard",
    "jax.jit constructed inside a loop or per call (uncached function "
    "body), and static_argnames/static_argnums naming an array-annotated "
    "parameter",
)
def check_jit_cache(ctx: ModuleContext) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) and ctx.is_jit_expr(node.func):
            encl = ctx.enclosing_function(node)
            if ctx.inside_loop(node, stop_at=encl):
                findings.append(ctx.finding(
                    "R005", node,
                    "jax.jit constructed inside a loop — every iteration "
                    "builds a new callable with a fresh compilation cache; "
                    "hoist the jit out of the loop"))
            elif encl is not None and not _has_cache_decorator(ctx, encl) \
                    and not _is_self_attr_assign(ctx, node):
                findings.append(ctx.finding(
                    "R005", node,
                    f"jax.jit constructed inside `{encl.name}` without "
                    "functools.lru_cache — each call recompiles; hoist to "
                    "module scope or lru_cache the builder"))
            # array-valued static args on the wrapped local function
            for name in ctx._named_targets(node):
                if name in ctx.functions:
                    _array_static_findings(ctx, node, ctx.functions[name], findings)
    # decorator form: @partial(jax.jit, static_argnames=...) naming arrays
    for fn in ctx.functions.values():
        for dec in fn.decorator_list:
            if isinstance(dec, ast.Call) and (
                ctx.is_jit_expr(dec.func)
                or (ctx.is_partial_expr(dec.func) and dec.args
                    and ctx.is_jit_expr(dec.args[0]))
            ):
                _array_static_findings(ctx, dec, fn, findings)
    return findings
