"""repro.analysis: JAX/Pallas static-analysis pass for this codebase.

``python -m repro.analysis src benchmarks`` runs the R001-R005 rule pack
(transfer sanitizer + dtype-contract lint) and exits nonzero on any
unsuppressed finding. See docs/ANALYSIS.md.
"""
from .engine import (  # noqa: F401
    Finding,
    ModuleContext,
    all_rules,
    analyze_file,
    analyze_paths,
    analyze_source,
    run_cli,
)
