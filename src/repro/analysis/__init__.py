"""repro.analysis: whole-program JAX/Pallas static analysis.

``python -m repro.analysis src benchmarks`` runs the R001-R009 rule pack
(transfer sanitizer, dtype/collective/padding/concurrency/kernel
contract lint) as a two-phase whole-program pass — phase 1 indexes the
cross-module call graph, phase 2 checks each module with an on-disk
findings cache — and exits nonzero on any unsuppressed finding. See
docs/ANALYSIS.md.
"""
from .engine import (  # noqa: F401
    AnalysisCache,
    Finding,
    ModuleContext,
    all_rules,
    analyze_file,
    analyze_paths,
    analyze_source,
    format_github,
    run_cli,
)
from .project import Project, module_name_for  # noqa: F401
