"""The R006-R009 contract rule families over ``ModuleContext``.

These rules guard the cross-shard and kernel contracts the R001-R005
pack cannot see — the ones the paper's headline scale rests on (exact
``all_to_all`` exchanges, padded fixed-capacity buffers with sentinel
lanes, the serving admission lanes, Pallas block shapes). Like the base
pack they are per-idiom static approximations (see docs/ANALYSIS.md for
the exact contracts and known imprecision):

- R006 collective-contract: literal mesh-axis names used by
  ``lax.psum``/``all_to_all``/``axis_index``/... (or ``mesh.shape[...]``)
  must exist in the project's declared mesh-axis universe; ``all_to_all``
  split extents must divide the shard count when both are static.
- R007 padding/sentinel-contract: values built by ``np.pad``/``jnp.pad``
  or ``pad_*`` helpers carry dead lanes and must be masked, sliced, or
  ``where``-guarded before reductions/compactions; sentinel-filled word
  buffers must be filtered before ``unpack_*`` calls.
- R008 serving-concurrency: no blocking call while holding a lock, and
  no attribute mutated both under a lock and bare (outside ``__init__``)
  in the same class — the admission-lane state contract.
- R009 pallas-kernel-shape: ``pallas_call`` grids computed with floor
  division need a divisibility guard (assert/raise on the remainder, or
  padding first), and static ref indices inside kernels must stay inside
  the ref's ``BlockSpec`` block shape.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from .engine import ModuleContext, Finding, dotted_name, register
from .rules import _scope_nodes

# -- shared expression helpers ---------------------------------------------

_COLLECTIVE_AXIS_ARG = {
    "psum": 1, "pmax": 1, "pmin": 1, "pmean": 1, "psum_scatter": 1,
    "all_gather": 1, "all_to_all": 1, "ppermute": 1,
    "axis_index": 0, "axis_size": 0,
}
_REDUCERS = {
    "sum", "mean", "prod", "min", "max", "amin", "amax", "all", "any",
    "median", "average", "argmin", "argmax", "count_nonzero", "nonzero",
    "flatnonzero", "unique", "bincount", "cumsum", "cumprod",
}
_MASKISH = ("valid", "mask", "live", "keep", "real")
_SENTINEL_INTS = {0xFFFFFFFF, 0xFFFFFFFFFFFFFFFF}
_BLOCKING_ATTRS = {"sleep", "join", "wait", "acquire", "block_until_ready"}
_QUEUEISH = ("q", "queue")
_MUTATORS = {
    "append", "extend", "insert", "add", "remove", "discard", "pop",
    "popleft", "appendleft", "clear", "update", "inc", "dec", "record",
    "put", "push", "setdefault",
}


def _lax_op(ctx: ModuleContext, func: ast.AST) -> Optional[str]:
    """``lax.psum`` / ``jax.lax.psum`` -> "psum", else None."""
    d = dotted_name(func)
    if not d or "." not in d:
        return None
    root, _, rest = d.partition(".")
    if root in ctx.lax_aliases and "." not in rest:
        return rest
    if root in ctx.jax_aliases and rest.startswith("lax.") \
            and rest.count(".") == 1:
        return rest.partition(".")[2]
    return None


def _enclosing_scopes(ctx: ModuleContext, node: ast.AST) -> List[ast.AST]:
    """Function scopes containing ``node``, innermost first, then module."""
    out: List[ast.AST] = []
    cur = ctx.parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.append(cur)
        cur = ctx.parents.get(cur)
    out.append(ctx.tree)
    return out


def _literal_str_list(node: ast.AST) -> Optional[List[str]]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, str):
                out.append(el.value)
            else:
                return None
        return out
    return None


def _param_default(fn, name: str) -> Optional[ast.AST]:
    args = fn.args
    pos = list(args.posonlyargs) + list(args.args)
    defaults = list(args.defaults)
    for a, d in zip(pos[len(pos) - len(defaults):], defaults):
        if a.arg == name:
            return d
    for a, d in zip(args.kwonlyargs, args.kw_defaults):
        if a.arg == name and d is not None:
            return d
    return None


def _resolve_axis_literals(ctx: ModuleContext, node: ast.AST,
                           use_site: ast.AST,
                           depth: int = 0) -> Optional[List[str]]:
    """Literal axis names an axis argument denotes, or None (dynamic)."""
    if depth > 4:
        return None
    got = _literal_str_list(node)
    if got is not None:
        return got
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("tuple", "list") and len(node.args) == 1:
        return _resolve_axis_literals(ctx, node.args[0], use_site, depth + 1)
    if isinstance(node, ast.Name):
        for scope in _enclosing_scopes(ctx, use_site):
            if isinstance(scope, (ast.FunctionDef, ast.AsyncFunctionDef)):
                dflt = _param_default(scope, node.id)
                if dflt is not None:
                    return _resolve_axis_literals(ctx, dflt, use_site,
                                                  depth + 1)
            for n in _scope_nodes(scope):
                if isinstance(n, ast.Assign) and any(
                        isinstance(t, ast.Name) and t.id == node.id
                        for t in n.targets):
                    return _resolve_axis_literals(ctx, n.value, use_site,
                                                  depth + 1)
    return None


def _find_local_assign(ctx: ModuleContext, use_site: ast.AST,
                       name: str) -> Optional[ast.AST]:
    """RHS of a ``name = ...`` assignment visible at ``use_site``."""
    for scope in _enclosing_scopes(ctx, use_site):
        for n in _scope_nodes(scope):
            if isinstance(n, ast.Assign) and any(
                    isinstance(t, ast.Name) and t.id == name
                    for t in n.targets):
                return n.value
    return None


# -- R006: collective contracts --------------------------------------------


def _axis_arg(call: ast.Call, op: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg in ("axis_name", "axis_names"):
            return kw.value
    pos = _COLLECTIVE_AXIS_ARG[op]
    if len(call.args) > pos:
        return call.args[pos]
    return None


def _split_extent(ctx: ModuleContext, call: ast.Call) -> Optional[int]:
    """Static extent of the all_to_all operand's split dimension."""
    if not call.args:
        return None
    split_axis = 0
    for kw in call.keywords:
        if kw.arg == "split_axis" and isinstance(kw.value, ast.Constant) \
                and isinstance(kw.value.value, int):
            split_axis = kw.value.value
    if len(call.args) > 2 and isinstance(call.args[2], ast.Constant) \
            and isinstance(call.args[2].value, int):
        split_axis = call.args[2].value
    x = call.args[0]
    if isinstance(x, ast.Name):
        x = _find_local_assign(ctx, call, x.id) or x
    if isinstance(x, ast.Call) and isinstance(x.func, ast.Attribute) \
            and x.func.attr == "reshape" and len(x.args) > split_axis:
        dim = x.args[split_axis]
        if isinstance(dim, ast.Constant) and isinstance(dim.value, int):
            return dim.value
    return None


@register(
    "R006",
    "collective-contract",
    "mesh-axis names used by lax collectives (psum/all_to_all/axis_index/"
    "mesh.shape[...]) must exist in a mesh declaration, and static "
    "all_to_all split extents must divide the shard count",
)
def check_collective_contract(ctx: ModuleContext) -> List[Finding]:
    project = ctx.project
    if project is None or not project.declared_axes:
        return []  # no mesh declaration in scope: no universe to check
    findings: List[Finding] = []
    declared = project.declared_axes
    for node in ast.walk(ctx.tree):
        # mesh.shape["axis"] subscripts
        if isinstance(node, ast.Subscript):
            d = dotted_name(node.value)
            sl = node.slice
            if d and d.endswith(".shape") and isinstance(sl, ast.Constant) \
                    and isinstance(sl.value, str) and sl.value not in declared:
                findings.append(ctx.finding(
                    "R006", node,
                    f"mesh axis `{sl.value}` in `{d}[...]` is not declared "
                    f"by any mesh in the project (known: "
                    f"{sorted(declared)})"))
            continue
        if not isinstance(node, ast.Call):
            continue
        op = _lax_op(ctx, node.func)
        if op not in _COLLECTIVE_AXIS_ARG:
            continue
        axis_expr = _axis_arg(node, op)
        if axis_expr is None:
            continue
        axes = _resolve_axis_literals(ctx, axis_expr, node)
        if axes is None:
            continue  # dynamic axis argument: out of static reach
        unknown = [a for a in axes if a not in declared]
        for a in unknown:
            findings.append(ctx.finding(
                "R006", node,
                f"`lax.{op}` over axis `{a}` which no mesh declares "
                f"(known axes: {sorted(declared)}) — an unbound axis "
                "name fails at trace time inside shard_map"))
        if op == "all_to_all" and not unknown:
            sizes = [project.axis_sizes.get(a) for a in axes]
            if sizes and all(isinstance(s, int) for s in sizes):
                n_shards = 1
                for s in sizes:
                    n_shards *= s
                extent = _split_extent(ctx, node)
                if extent is not None and n_shards and extent % n_shards:
                    findings.append(ctx.finding(
                        "R006", node,
                        f"`all_to_all` split extent {extent} is not "
                        f"divisible by the {n_shards}-shard axis "
                        f"{tuple(axes)} — the exchange needs equal "
                        "per-shard tiles"))
    return findings


# -- R007: padding / sentinel contracts ------------------------------------


def _is_pad_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    tail = None
    if isinstance(node.func, ast.Name):
        tail = node.func.id
    elif isinstance(node.func, ast.Attribute):
        tail = node.func.attr
    return bool(tail) and "pad" in tail.lower()


def _is_sentinel_const(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value in _SENTINEL_INTS
    if isinstance(node, ast.Name) and "sent" in node.id.lower():
        return True
    if isinstance(node, ast.Attribute) and "sent" in node.attr.lower():
        return True
    if isinstance(node, ast.Call) and node.args:
        # np.uint32(0xFFFFFFFF)-style wrappers
        return _is_sentinel_const(node.args[0])
    return False


def _is_sentinel_fill(node: ast.AST) -> bool:
    """np.full(shape, SENT) / jnp.pad(x, ..., constant_values=SENT)."""
    if not isinstance(node, ast.Call):
        return False
    tail = node.func.attr if isinstance(node.func, ast.Attribute) else (
        node.func.id if isinstance(node.func, ast.Name) else None)
    if tail == "full" and len(node.args) >= 2:
        return _is_sentinel_const(node.args[1])
    if tail == "pad":
        for kw in node.keywords:
            if kw.arg == "constant_values":
                return _is_sentinel_const(kw.value)
    return False


def _has_guard(node: ast.AST) -> bool:
    """Mask/slice/where evidence inside an expression: the dead lanes
    are being filtered, so the padded/sentinel value is used safely."""
    for n in ast.walk(node):
        if isinstance(n, ast.Subscript):
            return True
        if isinstance(n, ast.Call):
            tail = n.func.attr if isinstance(n.func, ast.Attribute) else (
                n.func.id if isinstance(n.func, ast.Name) else None)
            if tail in ("where", "compress", "is_sentinel", "take"):
                return True
        if isinstance(n, ast.Name) and any(m in n.id.lower()
                                           for m in _MASKISH):
            return True
        if isinstance(n, ast.Attribute) and any(m in n.attr.lower()
                                                for m in _MASKISH):
            return True
        if isinstance(n, ast.Compare):
            return True
    return False


_PRESERVING_METHODS = {
    "reshape", "astype", "ravel", "flatten", "copy", "view", "squeeze",
    "transpose",
}


def _taint_flows(node: ast.AST, names: Set[str]) -> bool:
    """Does taint in ``names`` flow through this value expression?

    Deliberately narrow: taint crosses arithmetic, tuples, subscripts,
    pad calls, and shape-preserving methods (``x.reshape(...)``), but
    NOT arbitrary function calls — a callee may consume the padding
    internally (e.g. a kernel launch whose outputs are per-lane ranks),
    and propagating through it drowns the rule in false positives.
    """
    stack = [node]
    while stack:
        n = stack.pop()
        if isinstance(n, ast.Name):
            if n.id in names:
                return True
            continue
        if isinstance(n, ast.Call):
            if _is_pad_call(n):
                stack.extend(n.args)
            elif isinstance(n.func, ast.Attribute) \
                    and n.func.attr in _PRESERVING_METHODS:
                stack.append(n.func.value)
            continue
        stack.extend(ast.iter_child_nodes(n))
    return False


class _TaintScope:
    """Local-dataflow tracking of padded / sentinel-filled names."""

    def __init__(self, ctx: ModuleContext, scope: ast.AST):
        self.ctx = ctx
        self.padded: Set[str] = set()
        self.sentinel: Set[str] = set()
        assigns = [
            n for n in _scope_nodes(scope, keep_lambdas=True)
            if isinstance(n, (ast.Assign, ast.AnnAssign, ast.AugAssign))
        ]
        for _ in range(2):  # fixpoint on straight-line chains
            for a in assigns:
                value = a.value
                if value is None:
                    continue
                targets = a.targets if isinstance(a, ast.Assign) else [a.target]
                names = [el.id for t in targets
                         for el in (t.elts if isinstance(t, ast.Tuple) else [t])
                         if isinstance(el, ast.Name)]
                if not names:
                    continue
                guarded = _has_guard(value)
                pad_src = (_is_pad_call(value)
                           or _taint_flows(value, self.padded))
                sent_src = (_is_sentinel_fill(value)
                            or _taint_flows(value, self.sentinel))
                for name in names:
                    if pad_src and not guarded:
                        self.padded.add(name)
                    else:
                        self.padded.discard(name)
                    if sent_src and not guarded:
                        self.sentinel.add(name)
                    else:
                        self.sentinel.discard(name)


@register(
    "R007",
    "padding-sentinel-contract",
    "padded arrays (np.pad/jnp.pad/pad_* helpers, the n_real batching "
    "contract) must be masked/sliced before reductions or compactions, "
    "and sentinel-filled word buffers must be filtered before unpack_*",
)
def check_padding_sentinel(ctx: ModuleContext) -> List[Finding]:
    findings: List[Finding] = []
    scopes: List[ast.AST] = [ctx.tree] + list(ctx.functions.values())
    for scope in scopes:
        taint = _TaintScope(ctx, scope)
        if not taint.padded and not taint.sentinel:
            continue
        for node in _scope_nodes(scope, keep_lambdas=True):
            if not isinstance(node, ast.Call):
                continue
            # (a) reduction over a padded value with no mask/slice/where
            data = None
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _REDUCERS:
                mod = node.func.value
                root = dotted_name(mod)
                if root in ctx.numpy_aliases or root in ctx.jnp_aliases:
                    data = node.args[0] if node.args else None
                else:
                    data = mod  # x.sum() method form
            if data is not None and _taint_flows(data, taint.padded) \
                    and not _has_guard(data):
                findings.append(ctx.finding(
                    "R007", node,
                    f"reduction `{node.func.attr}` over a padded array — "
                    "dead pad lanes count into the result; slice by the "
                    "real-row count (x[:n_real]) or mask first"))
                continue
            # (b) unpack of sentinel-filled words with no filter
            tail = node.func.attr if isinstance(node.func, ast.Attribute) \
                else (node.func.id if isinstance(node.func, ast.Name) else None)
            if tail and tail.startswith("unpack"):
                for arg in node.args:
                    if _taint_flows(arg, taint.sentinel) \
                            and not _has_guard(arg):
                        findings.append(ctx.finding(
                            "R007", node,
                            f"`{tail}` on a sentinel-filled word buffer — "
                            "all-ones sentinel lanes decode as garbage "
                            "pairs; filter (words != SENTINEL / winner "
                            "mask) before unpacking"))
                        break
    return findings


# -- R008: serving concurrency ---------------------------------------------


def _lock_item_name(item: ast.withitem) -> Optional[str]:
    d = dotted_name(item.context_expr)
    if d and "lock" in d.rpartition(".")[2].lower():
        return d
    return None


def _with_lock_names(node: ast.With) -> List[str]:
    return [n for n in (_lock_item_name(i) for i in node.items) if n]


def _is_blocking_call(node: ast.Call) -> bool:
    if isinstance(node.func, ast.Name):
        return node.func.id == "sleep"
    if not isinstance(node.func, ast.Attribute):
        return False
    attr = node.func.attr
    if attr in _BLOCKING_ATTRS:
        return True
    if attr in ("get", "put"):
        recv = dotted_name(node.func.value)
        tail = (recv or "").rpartition(".")[2].lower()
        return any(tail == q or tail.endswith("_" + q) or tail.endswith(q)
                   for q in _QUEUEISH)
    return False


def _under_lock(ctx: ModuleContext, node: ast.AST,
                stop_at: Optional[ast.AST] = None) -> bool:
    cur = ctx.parents.get(node)
    while cur is not None and cur is not stop_at:
        if isinstance(cur, ast.With) and _with_lock_names(cur):
            return True
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return False
        cur = ctx.parents.get(cur)
    return False


def _self_attr_writes(ctx: ModuleContext, fn) -> List[Tuple[str, ast.AST]]:
    """(attr, node) per mutation of ``self.<attr>`` in the method."""
    out: List[Tuple[str, ast.AST]] = []

    def self_attr(node: ast.AST) -> Optional[str]:
        # self.X, self.X[i], self.X.anything -> "X"
        while isinstance(node, ast.Subscript):
            node = node.value
        if isinstance(node, ast.Attribute) \
                and isinstance(node.value, ast.Name) and node.value.id == "self":
            return node.attr
        return None

    for node in _scope_nodes(fn, keep_lambdas=True):
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                for el in (t.elts if isinstance(t, ast.Tuple) else [t]):
                    attr = self_attr(el)
                    if attr is not None:
                        out.append((attr, node))
        elif isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in _MUTATORS:
            attr = self_attr(node.func.value)
            if attr is not None:
                out.append((attr, node))
    return out


@register(
    "R008",
    "serving-concurrency",
    "blocking calls (sleep/join/wait/acquire/queue get-put/"
    "block_until_ready) while holding a lock, and attributes mutated "
    "both under a lock and bare outside __init__ in the same class",
)
def check_serving_concurrency(ctx: ModuleContext) -> List[Finding]:
    findings: List[Finding] = []
    # (a) blocking call while a lock is held
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.With) and _with_lock_names(node)):
            continue
        locks = ", ".join(_with_lock_names(node))
        for inner in _scope_nodes(node, keep_lambdas=True):
            if isinstance(inner, ast.Call) and _is_blocking_call(inner):
                findings.append(ctx.finding(
                    "R008", inner,
                    f"blocking call while holding `{locks}` — every other "
                    "lane stalls behind this request; move the wait "
                    "outside the critical section"))
    # (b) inconsistently-guarded attribute mutations per class
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        locked: Dict[str, List[ast.AST]] = {}
        bare: Dict[str, List[Tuple[str, ast.AST]]] = {}
        for fn in node.body:
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for attr, site in _self_attr_writes(ctx, fn):
                if _under_lock(ctx, site, stop_at=fn):
                    locked.setdefault(attr, []).append(site)
                elif fn.name != "__init__":
                    bare.setdefault(attr, []).append((fn.name, site))
        for attr, sites in sorted(bare.items()):
            if attr not in locked:
                continue
            for fn_name, site in sites:
                findings.append(ctx.finding(
                    "R008", site,
                    f"`self.{attr}` is mutated under a lock elsewhere in "
                    f"`{node.name}` but bare in `{fn_name}` — a concurrent "
                    "lane can observe torn state; hold the same lock (or "
                    "confine the attribute to one lane)"))
    return findings


# -- R009: pallas kernel shapes --------------------------------------------


def _is_pallas_call_expr(ctx: ModuleContext, node: ast.AST) -> bool:
    if isinstance(node, ast.Name) and node.id in ctx.pallas_call_names:
        return True
    d = dotted_name(node)
    return bool(d) and any(d == f"{a}.pallas_call"
                           for a in ctx.pallas_aliases)


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _blockspec_dims(ctx: ModuleContext, spec: ast.AST,
                    use_site: ast.AST) -> Optional[List[Optional[int]]]:
    """Literal dims of a BlockSpec expression (None per unknown dim)."""
    if isinstance(spec, ast.Name):
        spec = _find_local_assign(ctx, use_site, spec.id) or spec
    if not (isinstance(spec, ast.Call)
            and (dotted_name(spec.func) or "").rpartition(".")[2]
            == "BlockSpec"):
        return None
    shape = spec.args[0] if spec.args else _kw(spec, "block_shape")
    if not isinstance(shape, ast.Tuple):
        return None
    dims: List[Optional[int]] = []
    for el in shape.elts:
        dims.append(el.value if isinstance(el, ast.Constant)
                    and isinstance(el.value, int) else None)
    return dims


def _spec_list(node: Optional[ast.AST]) -> Optional[List[ast.AST]]:
    if node is None:
        return None
    if isinstance(node, (ast.Tuple, ast.List)):
        return list(node.elts)
    return [node]


def _kernel_fn_name(ctx: ModuleContext, call: ast.Call) -> Optional[str]:
    if not call.args:
        return None
    k = call.args[0]
    if isinstance(k, ast.Call) and ctx.is_partial_expr(k.func) and k.args:
        if len(k.args) > 1:
            return None  # positional partial binding shifts params: skip
        k = k.args[0]
    if isinstance(k, ast.Name):
        return k.id
    d = dotted_name(k)
    return d.rpartition(".")[2] if d else None


def _grid_has_unguarded_floordiv(ctx: ModuleContext,
                                 call: ast.Call) -> bool:
    grid = _kw(call, "grid")
    if grid is None:
        return False
    if isinstance(grid, ast.Name):
        grid = _find_local_assign(ctx, call, grid.id) or grid
    has_div = any(isinstance(n, ast.BinOp) and isinstance(n.op, ast.FloorDiv)
                  for n in ast.walk(grid))
    if not has_div:
        return False
    encl = ctx.enclosing_function(call)
    scope = encl if encl is not None else ctx.tree
    for n in _scope_nodes(scope):
        if isinstance(n, (ast.Assert, ast.If)):
            test = n.test
            if any(isinstance(m, ast.BinOp) and isinstance(m.op, ast.Mod)
                   for m in ast.walk(test)):
                return False  # a remainder guard exists in this scope
        if _is_pad_call(n):
            return False  # operands are padded up before the launch
    return True


@register(
    "R009",
    "pallas-kernel-shape",
    "pallas_call grids computed with floor division need a divisibility "
    "guard, and constant ref indices inside the kernel must stay inside "
    "the ref's BlockSpec block shape",
)
def check_pallas_kernel_shape(ctx: ModuleContext) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and _is_pallas_call_expr(ctx, node.func)):
            continue
        if _grid_has_unguarded_floordiv(ctx, node):
            findings.append(ctx.finding(
                "R009", node,
                "pallas_call grid uses floor division with no "
                "divisibility guard in scope — a non-dividing shape "
                "silently drops the remainder tile; assert "
                "`dim % block == 0` or pad first"))
        # map kernel params to BlockSpec dims: in_specs then out_specs
        name = _kernel_fn_name(ctx, node)
        kernel = ctx.functions.get(name) if name else None
        if kernel is None:
            continue
        specs = (_spec_list(_kw(node, "in_specs")) or []) + \
                (_spec_list(_kw(node, "out_specs")) or [])
        params = [a.arg for a in list(kernel.args.posonlyargs)
                  + list(kernel.args.args)]
        if len(params) < len(specs):
            continue  # *args or mismatched launch: skip
        dims_of: Dict[str, List[Optional[int]]] = {}
        for param, spec in zip(params, specs):
            dims = _blockspec_dims(ctx, spec, node)
            if dims is not None:
                dims_of[param] = dims
        if not dims_of:
            continue
        for sub in ast.walk(kernel):
            if not (isinstance(sub, ast.Subscript)
                    and isinstance(sub.value, ast.Name)
                    and sub.value.id in dims_of):
                continue
            dims = dims_of[sub.value.id]
            idx = sub.slice
            elts = list(idx.elts) if isinstance(idx, ast.Tuple) else [idx]
            for i, el in enumerate(elts):
                if i >= len(dims) or dims[i] is None:
                    continue
                if isinstance(el, ast.Constant) and isinstance(el.value, int):
                    if el.value >= dims[i] or el.value < -dims[i]:
                        findings.append(ctx.finding(
                            "R009", sub,
                            f"static index {el.value} on ref "
                            f"`{sub.value.id}` exceeds its BlockSpec "
                            f"block extent {dims[i]} along dim {i} — "
                            "out-of-bounds ref access inside the kernel"))
    return findings
