"""CLI entry point: ``python -m repro.analysis PATH...``."""
import sys

from .engine import run_cli

if __name__ == "__main__":
    sys.exit(run_cli())
