"""AST lint engine for the repo's JAX/Pallas correctness contracts.

The blocking engine's correctness rests on exact bit-level contracts
(packed 62-bit sort words, splitmix64 owner routing, XOR fingerprints)
and its speed on hot paths that never silently fall off-device. Both are
enforced dynamically by parity tests and the ``--transfer-guard`` pytest
mode; this module enforces them *statically*, before the code runs:

- ``ModuleContext`` parses one file and resolves the import aliases,
  function table, jit/pallas/shard_map roots and the jit-reachable call
  closure that every rule keys off.
- ``analyze_paths`` runs in two phases: phase 1 parses every file and
  builds the whole-program index (``project.Project``: cross-module
  symbol table, call graph, jit reachability closure, mesh-axis
  universe); phase 2 runs the rule pack per module, so R001/R003
  reachability follows calls across module boundaries.
- Rules live in ``rules.py`` / ``rules_contracts.py`` and register
  themselves via ``register``; each is a pure function
  ``ModuleContext -> list[Finding]``.
- ``# repro: noqa[R001]`` (or bare ``# repro: noqa``) on the finding's
  line — or on the FIRST line of the multi-line statement containing
  it — suppresses it; suppressed findings are counted, not fatal.
- Phase-2 results are cached on disk keyed by (mtime, size) of the file
  plus a digest of the engine version, the rule selection, and the
  cross-module facts the file's findings depend on (``AnalysisCache``),
  so repeated CI/lint runs only re-check what changed.
- ``python -m repro.analysis PATH...`` walks files/trees and exits
  nonzero on any unsuppressed finding (the CI lint gate);
  ``--format github`` emits workflow annotations and ``--warn-only``
  reports without failing (the tests/ advisory lane).

Type inference remains a local-dataflow heuristic and call resolution
skips dynamic dispatch. Rules therefore aim to be *precise on this
codebase's idioms* and suppressible where intent is explicit, not sound
in general — see docs/ANALYSIS.md for each rule's exact contract.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import json
import os
import re
import sys
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set

NOQA_RE = re.compile(r"#\s*repro:\s*noqa(?:\[(?P<rules>[A-Za-z0-9_,\s]*)\])?")

# bump when rules/engine change enough to invalidate cached findings
ANALYSIS_VERSION = "2"

# annotations the codebase uses for host-static (non-traced) parameters
_STATIC_ANNOTATIONS = {"int", "bool", "str", "float"}
# host objects passed into traced functions by convention (mesh handles
# are compile-time metadata: .shape/.axis_names reads are static)
_STATIC_OBJECT_TAILS = {"Mesh"}
# container annotations that are static when their elements are
_STATIC_CONTAINERS = {"Sequence", "Tuple", "List", "tuple", "list",
                      "Iterable", "FrozenSet", "frozenset"}
# attribute reads on traced arrays that yield host-static values
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding"}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str
    suppressed: bool = False

    def format(self) -> str:
        mark = " (suppressed)" if self.suppressed else ""
        return f"{self.path}:{self.line}:{self.col + 1}: {self.rule} {self.message}{mark}"


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    name: str
    description: str
    check: Callable[["ModuleContext"], List[Finding]]


_REGISTRY: Dict[str, Rule] = {}


def register(rule_id: str, name: str, description: str):
    """Decorator: add a ``ModuleContext -> [Finding]`` function to the registry."""

    def deco(fn):
        _REGISTRY[rule_id] = Rule(rule_id, name, description, fn)
        return fn

    return deco


def all_rules() -> Dict[str, Rule]:
    # import for side effect: rules register on first use
    from . import rules, rules_contracts  # noqa: F401

    return dict(sorted(_REGISTRY.items()))


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` attribute chain -> "a.b.c", else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class ModuleContext:
    """Everything the rules need to know about one parsed source file."""

    def __init__(self, path: str, src: str, tree: ast.Module):
        self.path = path
        self.src = src
        self.lines = src.splitlines()
        self.tree = tree

        # import alias tables (alias name -> stands for module X)
        self.numpy_aliases: Set[str] = set()
        self.jnp_aliases: Set[str] = set()
        self.jax_aliases: Set[str] = set()
        self.lax_aliases: Set[str] = set()
        self.pallas_aliases: Set[str] = set()
        self.time_aliases: Set[str] = set()
        self.functools_aliases: Set[str] = set()
        # names bound by from-imports
        self.jit_names: Set[str] = set()         # from jax import jit
        self.partial_names: Set[str] = set()     # from functools import partial
        self.cache_deco_names: Set[str] = set()  # lru_cache / cache
        self.perf_counter_names: Set[str] = set()
        self.shard_map_names: Set[str] = set()   # from jax.experimental.shard_map import shard_map
        self.pallas_call_names: Set[str] = set()
        self.imports_jaxlike = False             # jax / jnp / repro imported

        # function table: name -> def node (module functions + methods;
        # later definitions win, matching runtime rebinding)
        self.functions: Dict[str, ast.AST] = {}
        # per-function host-static parameter names
        self.static_params: Dict[str, Set[str]] = {}
        self.jit_roots: Set[str] = set()
        self.jit_reachable: Set[str] = set()
        # set by project.Project after the phase-1 index is built; rules
        # may consult it for project-wide facts (None in single-file use)
        self.project = None
        # parent links for ancestry queries (loops, enclosing defs)
        self.parents: Dict[ast.AST, ast.AST] = {}

        self._collect_imports()
        self._collect_functions()
        self._collect_parents()
        self._collect_jit_roots()
        self._close_reachability()

    # -- construction --------------------------------------------------

    def _collect_imports(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    bound = alias.asname or alias.name.split(".")[0]
                    if alias.name == "numpy":
                        self.numpy_aliases.add(bound)
                    elif alias.name == "jax.numpy" and alias.asname:
                        self.jnp_aliases.add(alias.asname)
                        self.imports_jaxlike = True
                    elif alias.name == "jax.lax" and alias.asname:
                        self.lax_aliases.add(alias.asname)
                        self.imports_jaxlike = True
                    elif alias.name.split(".")[0] == "jax":
                        self.jax_aliases.add(bound)
                        self.imports_jaxlike = True
                    elif alias.name == "time":
                        self.time_aliases.add(bound)
                    elif alias.name == "functools":
                        self.functools_aliases.add(bound)
                    elif alias.name.split(".")[0] == "repro":
                        self.imports_jaxlike = True
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if node.level > 0 or mod.split(".")[0] == "repro":
                    self.imports_jaxlike = True
                for alias in node.names:
                    bound = alias.asname or alias.name
                    if mod == "jax" and alias.name == "jit":
                        self.jit_names.add(bound)
                        self.imports_jaxlike = True
                    elif mod == "jax" and alias.name == "numpy":
                        self.jnp_aliases.add(bound)
                        self.imports_jaxlike = True
                    elif mod == "jax" and alias.name == "lax":
                        self.lax_aliases.add(bound)
                        self.imports_jaxlike = True
                    elif mod.split(".")[0] == "jax":
                        self.imports_jaxlike = True
                        if alias.name == "pallas":
                            self.pallas_aliases.add(bound)
                        elif alias.name == "pallas_call":
                            self.pallas_call_names.add(bound)
                        elif alias.name == "shard_map":
                            self.shard_map_names.add(bound)
                    elif mod == "functools":
                        if alias.name == "partial":
                            self.partial_names.add(bound)
                        elif alias.name in ("lru_cache", "cache"):
                            self.cache_deco_names.add(bound)
                    elif mod == "time" and alias.name == "perf_counter":
                        self.perf_counter_names.add(bound)

    def _collect_functions(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions[node.name] = node
                self.static_params[node.name] = self._annotation_static_params(node)

    def _collect_parents(self) -> None:
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node

    def _annotation_static_params(self, fn) -> Set[str]:
        static = set()
        args = list(fn.args.posonlyargs) + list(fn.args.args) + list(fn.args.kwonlyargs)
        for a in args:
            if self._is_static_annotation(a.annotation):
                static.add(a.arg)
        return static

    def _is_static_annotation(self, ann: Optional[ast.AST]) -> bool:
        """Does this annotation denote a host-static (untraced) value?

        int/bool/str annotations, the repo's frozen *Config dataclasses,
        mesh handles (compile-time metadata), and containers of static
        elements (``Sequence[str]``, ``Tuple[int, ...]``) are hashable
        static args by convention.
        """
        if ann is None:
            return False
        if isinstance(ann, ast.Subscript):
            base = dotted_name(ann.value)
            if base is None or base.split(".")[-1] not in _STATIC_CONTAINERS:
                return False
            sl = ann.slice
            elts = sl.elts if isinstance(sl, ast.Tuple) else [sl]
            return all(
                (isinstance(e, ast.Constant) and e.value is Ellipsis)
                or self._is_static_annotation(e)
                for e in elts
            )
        name = None
        if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
            name = ann.value.split("[")[0]
        else:
            name = dotted_name(ann)
        if name is None:
            return False
        tail = name.split(".")[-1]
        return (tail in _STATIC_ANNOTATIONS or tail.endswith("Config")
                or tail in _STATIC_OBJECT_TAILS)

    # -- jit root discovery --------------------------------------------

    def is_jit_expr(self, node: ast.AST) -> bool:
        """Does this expression denote ``jax.jit`` (or a bare ``jit``)?"""
        if isinstance(node, ast.Name) and node.id in self.jit_names:
            return True
        d = dotted_name(node)
        return bool(d) and any(d == f"{a}.jit" for a in self.jax_aliases)

    def is_partial_expr(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name) and node.id in self.partial_names:
            return True
        d = dotted_name(node)
        return bool(d) and any(d == f"{a}.partial" for a in self.functools_aliases)

    def is_tracing_wrapper(self, node: ast.AST) -> bool:
        """shard_map / pallas_call / vmap: wraps a traced function."""
        if isinstance(node, ast.Name) and (
            node.id in self.shard_map_names or node.id in self.pallas_call_names
        ):
            return True
        d = dotted_name(node)
        if not d:
            return False
        if any(d == f"{a}.pallas_call" for a in self.pallas_aliases):
            return True
        return any(
            d in (f"{a}.vmap", f"{a}.experimental.shard_map.shard_map")
            for a in self.jax_aliases
        )

    def _named_targets(self, call: ast.Call) -> Iterable[str]:
        """Local function names a jit/shard_map/pallas_call call wraps."""
        cands = list(call.args[:1]) + [
            kw.value for kw in call.keywords if kw.arg in ("fun", "kernel", "f")
        ]
        for arg in cands:
            # unwrap functools.partial(fn, ...) one level
            if isinstance(arg, ast.Call) and self.is_partial_expr(arg.func) and arg.args:
                arg = arg.args[0]
            if isinstance(arg, ast.Name):
                yield arg.id
            elif isinstance(arg, ast.Lambda):
                # lambdas trace inline: their body is scanned by rules via
                # the enclosing jit-reachable function, nothing to name
                continue

    def _static_argnames_from_call(self, call: ast.Call, fn) -> Set[str]:
        static: Set[str] = set()
        params = [a.arg for a in list(fn.args.posonlyargs) + list(fn.args.args)] if fn else []
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                vals = kw.value.elts if isinstance(kw.value, (ast.Tuple, ast.List)) else [kw.value]
                for v in vals:
                    if isinstance(v, ast.Constant) and isinstance(v.value, str):
                        static.add(v.value)
            elif kw.arg == "static_argnums":
                vals = kw.value.elts if isinstance(kw.value, (ast.Tuple, ast.List)) else [kw.value]
                for v in vals:
                    if isinstance(v, ast.Constant) and isinstance(v.value, int):
                        if 0 <= v.value < len(params):
                            static.add(params[v.value])
        return static

    def _collect_jit_roots(self) -> None:
        # decorator forms
        for name, fn in self.functions.items():
            for dec in fn.decorator_list:
                if self.is_jit_expr(dec):
                    self.jit_roots.add(name)
                elif isinstance(dec, ast.Call):
                    if self.is_jit_expr(dec.func):
                        self.jit_roots.add(name)
                        self.static_params[name] |= self._static_argnames_from_call(dec, fn)
                    elif (self.is_partial_expr(dec.func) and dec.args
                          and self.is_jit_expr(dec.args[0])):
                        self.jit_roots.add(name)
                        self.static_params[name] |= self._static_argnames_from_call(dec, fn)
        # call forms: jax.jit(f), shard_map(f, ...), pl.pallas_call(kernel, ...)
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            if self.is_jit_expr(node.func) or self.is_tracing_wrapper(node.func):
                for name in self._named_targets(node):
                    if name in self.functions:
                        self.jit_roots.add(name)
                        if self.is_jit_expr(node.func):
                            self.static_params[name] |= self._static_argnames_from_call(
                                node, self.functions[name]
                            )

    def _called_local_names(self, fn) -> Set[str]:
        called: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                if isinstance(node.func, ast.Name):
                    called.add(node.func.id)
                elif (
                    isinstance(node.func, ast.Attribute)
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == "self"
                ):
                    called.add(node.func.attr)
            # bare references (fn passed as value, e.g. into lax.cond/scan)
            elif isinstance(node, ast.Name) and node.id in self.functions:
                called.add(node.id)
        return called

    def _close_reachability(self) -> None:
        reach = set(self.jit_roots)
        frontier = list(reach)
        while frontier:
            fn_name = frontier.pop()
            fn = self.functions.get(fn_name)
            if fn is None:
                continue
            for callee in self._called_local_names(fn):
                if callee in self.functions and callee not in reach:
                    reach.add(callee)
                    frontier.append(callee)
        self.jit_reachable = reach

    def extend_jit_reachable(self, names: Iterable[str]) -> None:
        """Inject cross-module reachability facts (phase-1 index).

        ``names`` are bare local def names proven jit-reachable through
        the project call graph (e.g. a helper here called from a jitted
        step in another module); R001/R003 pick them up exactly like
        locally-discovered reachability.
        """
        self.jit_reachable |= {n for n in names if n in self.functions}

    # -- helpers for rules ---------------------------------------------

    def enclosing_function(self, node: ast.AST):
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self.parents.get(cur)
        return None

    def inside_loop(self, node: ast.AST, stop_at=None) -> bool:
        cur = self.parents.get(node)
        while cur is not None and cur is not stop_at:
            if isinstance(cur, (ast.For, ast.While, ast.AsyncFor)):
                return True
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return False
            cur = self.parents.get(cur)
        return False

    def finding(self, rule_id: str, node: ast.AST, message: str) -> Finding:
        return Finding(rule_id, self.path, node.lineno, node.col_offset, message)


def _noqa_rules_on(ctx: ModuleContext, lineno: int) -> Optional[Set[str]]:
    """Rule ids a noqa comment on ``lineno`` names (empty set = all)."""
    line = ctx.lines[lineno - 1] if 0 < lineno <= len(ctx.lines) else ""
    m = NOQA_RE.search(line)
    if m is None:
        return None
    rules = m.group("rules")
    if rules is None:
        return set()
    return {r.strip() for r in rules.split(",") if r.strip()}


def _statement_spans(ctx: ModuleContext) -> List[tuple]:
    """(first_line, last_line) spans a first-line noqa covers.

    A simple statement (a multi-line call, assignment, return, ...)
    covers its full ``lineno..end_lineno`` span. A compound statement
    (if/for/while/with/def/try) covers only its HEADER — up to the line
    before its first body statement — so a noqa on ``if (...):`` cannot
    blanket-suppress the whole block under it.
    """
    spans = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.stmt):
            continue
        end = getattr(node, "end_lineno", None) or node.lineno
        body = getattr(node, "body", None)
        if isinstance(body, list) and body and isinstance(body[0], ast.stmt):
            end = max(node.lineno, body[0].lineno - 1)
        if end > node.lineno:
            spans.append((node.lineno, end))
    return spans


def _apply_suppressions(ctx: ModuleContext, findings: List[Finding]) -> List[Finding]:
    # suppression spans: the finding's own line always; a noqa on the
    # first line of a multi-line statement covers every line of that
    # statement (findings anchor to inner expression nodes, which can
    # start lines below the comment)
    span_rules: Dict[int, Set[str]] = {}  # finding line -> noqa'd rules
    for start, end in _statement_spans(ctx):
        rules = _noqa_rules_on(ctx, start)
        if rules is None:
            continue
        for line in range(start, end + 1):
            got = span_rules.get(line)
            if got is None:
                span_rules[line] = set(rules)
            elif rules and got:
                got |= rules
            else:
                span_rules[line] = set()  # bare noqa wins: all rules
    out = []
    for f in findings:
        suppressed = False
        for rules in (_noqa_rules_on(ctx, f.line), span_rules.get(f.line)):
            if rules is not None and (not rules or f.rule in rules):
                suppressed = True
        if suppressed:
            f = dataclasses.replace(f, suppressed=True)
        out.append(f)
    return out


def _run_rules(ctx: ModuleContext,
               select: Optional[Sequence[str]] = None) -> List[Finding]:
    """Phase 2 for one module: run the (selected) rule pack."""
    rules = all_rules()
    wanted = list(rules) if select is None else [r for r in rules if r in set(select)]
    findings: List[Finding] = []
    for rule_id in wanted:
        findings.extend(rules[rule_id].check(ctx))
    findings.sort(key=lambda f: (f.line, f.col, f.rule))
    return _apply_suppressions(ctx, findings)


def _parse_context(src: str, path: str):
    """(ModuleContext, None) or (None, [E999 finding])."""
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return None, [Finding("E999", path, e.lineno or 1,
                              (e.offset or 1) - 1, f"syntax error: {e.msg}")]
    return ModuleContext(path, src, tree), None


def analyze_source(
    src: str, path: str = "<string>", select: Optional[Sequence[str]] = None
) -> List[Finding]:
    """Run the (selected) rule pack over one source string.

    Single-module entry point: the whole-program index degenerates to a
    one-module project (no cross-module edges, but rules that consult
    ``ctx.project`` still see a consistent view).
    """
    from .project import Project

    ctx, errors = _parse_context(src, path)
    if ctx is None:
        return errors
    Project([ctx])
    return _run_rules(ctx, select)


def analyze_file(path: str, select: Optional[Sequence[str]] = None) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as f:
        src = f.read()
    return analyze_source(src, path, select)


def iter_python_files(paths: Sequence[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs if d != "__pycache__")
                for name in sorted(files):
                    if name.endswith(".py"):
                        yield os.path.join(root, name)
        elif p.endswith(".py"):
            yield p


class AnalysisCache:
    """On-disk findings cache for the phase-2 check.

    One JSON file; per analyzed file an entry keyed by the file's
    ``(mtime, size)`` plus a digest of everything else its findings
    depend on: the engine version, the rule selection, and the
    cross-module facts the phase-1 index injected (reachability, axis
    universe). Phase 1 always re-parses — the index must be exact — so
    the cache only skips phase-2 rule execution, which is where the
    time goes. A dependency edit that changes a module's injected
    reachability changes the digest and re-checks the module even
    though its own mtime did not move.
    """

    def __init__(self, path: str):
        self.path = path
        self.dirty = False
        self.data: Dict[str, dict] = {}
        try:
            with open(path, "r", encoding="utf-8") as f:
                raw = json.load(f)
            if raw.get("version") == ANALYSIS_VERSION:
                self.data = raw.get("files", {})
        except (OSError, ValueError):
            pass

    @staticmethod
    def _stat_key(path: str):
        st = os.stat(path)
        return st.st_mtime, st.st_size

    def lookup(self, path: str, digest: str) -> Optional[List[Finding]]:
        ent = self.data.get(os.path.abspath(path))
        if ent is None or ent.get("digest") != digest:
            return None
        try:
            mtime, size = self._stat_key(path)
        except OSError:
            return None
        if ent.get("mtime") != mtime or ent.get("size") != size:
            return None
        return [Finding(**f) for f in ent.get("findings", [])]

    def store(self, path: str, digest: str, findings: List[Finding]) -> None:
        try:
            mtime, size = self._stat_key(path)
        except OSError:
            return
        self.data[os.path.abspath(path)] = {
            "mtime": mtime, "size": size, "digest": digest,
            "findings": [dataclasses.asdict(f) for f in findings],
        }
        self.dirty = True

    def save(self) -> None:
        if not self.dirty:
            return
        tmp = f"{self.path}.tmp.{os.getpid()}"
        try:
            with open(tmp, "w", encoding="utf-8") as f:
                json.dump({"version": ANALYSIS_VERSION, "files": self.data}, f)
            os.replace(tmp, self.path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass


def _module_digest(project, ctx: ModuleContext,
                   select: Optional[Sequence[str]]) -> str:
    parts = [ANALYSIS_VERSION,
             ",".join(sorted(select)) if select else "*"]
    parts += project.reach_digest_parts(ctx)
    return hashlib.sha1("\x00".join(parts).encode()).hexdigest()


def analyze_paths(
    paths: Sequence[str], select: Optional[Sequence[str]] = None,
    cache_path: Optional[str] = None,
) -> List[Finding]:
    """Two-phase whole-program run over files/trees.

    Phase 1 parses every file and builds the cross-module index
    (``project.Project``); phase 2 runs the rule pack per module,
    consulting the on-disk cache when ``cache_path`` is given.
    """
    from .project import Project

    findings: List[Finding] = []
    contexts: List[ModuleContext] = []
    for path in iter_python_files(paths):
        with open(path, "r", encoding="utf-8") as f:
            src = f.read()
        ctx, errors = _parse_context(src, path)
        if ctx is None:
            findings.extend(errors)
        else:
            contexts.append(ctx)
    project = Project(contexts)
    cache = AnalysisCache(cache_path) if cache_path else None
    for ctx in contexts:
        if cache is not None:
            digest = _module_digest(project, ctx, select)
            got = cache.lookup(ctx.path, digest)
            if got is None:
                got = _run_rules(ctx, select)
                cache.store(ctx.path, digest, got)
            findings.extend(got)
        else:
            findings.extend(_run_rules(ctx, select))
    if cache is not None:
        cache.save()
    return findings


def _github_escape(s: str) -> str:
    return s.replace("%", "%25").replace("\r", "%0D").replace("\n", "%0A")


def format_github(f: Finding, warn_only: bool = False) -> str:
    """One GitHub Actions workflow-command annotation per finding."""
    level = "notice" if f.suppressed else ("warning" if warn_only else "error")
    rule = all_rules().get(f.rule)
    title = f"{f.rule} {rule.name}" if rule else f.rule
    msg = f.message + (" (suppressed)" if f.suppressed else "")
    return (f"::{level} file={f.path},line={f.line},col={f.col + 1},"
            f"title={_github_escape(title)}::{_github_escape(msg)}")


def run_cli(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Whole-program JAX/Pallas static-analysis pass: "
        "transfer sanitizer, dtype/collective/padding/concurrency/kernel "
        "contract lint. Exits 1 on unsuppressed findings.",
    )
    ap.add_argument("paths", nargs="*", help="files or directories to analyze")
    ap.add_argument("--select", default=None, help="comma-separated rule ids (default: all)")
    ap.add_argument("--format", choices=("text", "json", "github"),
                    default="text",
                    help="github emits workflow-command annotations")
    ap.add_argument("--warn-only", action="store_true",
                    help="report findings but exit 0 (advisory lanes)")
    ap.add_argument("--cache", default=".repro-analysis.cache.json",
                    metavar="FILE",
                    help="on-disk findings cache (default: %(default)s)")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the findings cache")
    ap.add_argument("--report", default=None, metavar="FILE",
                    help="also write the full JSON findings report to FILE")
    ap.add_argument("--list-rules", action="store_true", help="print the rule pack and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in all_rules().values():
            print(f"{rule.id}  {rule.name}\n    {rule.description}")
        return 0
    if not args.paths:
        ap.error("no paths given (or use --list-rules)")
    select = [s.strip() for s in args.select.split(",")] if args.select else None
    cache_path = None if args.no_cache else args.cache
    findings = analyze_paths(args.paths, select, cache_path=cache_path)
    live = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]
    if args.report:
        with open(args.report, "w", encoding="utf-8") as fh:
            json.dump([dataclasses.asdict(f) for f in findings], fh, indent=2)
    if args.format == "json":
        print(json.dumps([dataclasses.asdict(f) for f in findings], indent=2))
    else:
        for f in findings:
            print(format_github(f, args.warn_only) if args.format == "github"
                  else f.format())
        by_rule: Dict[str, int] = {}
        for f in live:
            by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
        stats = ", ".join(f"{k}={v}" for k, v in sorted(by_rule.items())) or "none"
        print(
            f"repro.analysis: {len(live)} finding(s) ({stats}), "
            f"{len(suppressed)} suppressed",
            file=sys.stderr,
        )
    return 0 if args.warn_only else (1 if live else 0)
