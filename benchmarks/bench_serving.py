"""Serving bench: probe QPS and p50/p99 latency vs client batch size.

The workload: a hot single-tenant store serves a stream of probe requests
through the ``DedupeService`` front-end. Clients submit micro-batches of
``--batch-sizes`` rows; the service collates them up to ``probe_slots``
and pads to the power-of-two bucket ladder, so every batch size rides the
same few compiled walk shapes. The acceptance gate (``--check``) asserts
the recompile trajectory: after a one-round warmup, running every batch
size adds ZERO compiled variants to the shared jitted probe steps
(measured via ``probe_jit_cache_sizes``, i.e. real jit cache sizes, not a
proxy) — the bucket ladder is what makes mixed batch sizes servable.

Latency percentiles come from the service's own metrics histograms — the
same numbers a dashboard would scrape — and QPS from wall clock over
served rows.

    PYTHONPATH=src python -m benchmarks.bench_serving [--check] \
        [--records N] [--probes N] [--json [PATH]]
"""
from __future__ import annotations

import time

import numpy as np

from .bench_streaming import _make_stream_keys
from .common import emit, sync

from repro.core import hdb
from repro.serving import DedupeService, ServiceConfig
from repro.streaming.delta import probe_jit_cache_sizes


def run(n_records: int = 50_000, n_probes: int = 2_048,
        batch_sizes=(1, 8, 64), check: bool = False, seed: int = 0):
    cfg = hdb.HDBConfig(max_block_size=64, max_iterations=6,
                        cms_width=1 << 16)
    rng = np.random.default_rng(seed)
    keys, valid = _make_stream_keys(rng, n_records + n_probes)
    svc = DedupeService(cfg, ServiceConfig(
        probe_slots=64, ingest_slots=1 << 20,
        max_read_queue=1 << 20, max_write_queue=64))
    svc.add_tenant("t")

    t0 = time.perf_counter()
    svc.submit_ingest("t", keys[:n_records], valid[:n_records])
    sync(svc.run())
    t_build = time.perf_counter() - t0
    store = svc.tenant("t").store
    print(f"# store: {n_records} records, {len(store.led_pack)} candidate "
          f"pairs, built in {t_build:.2f}s")

    probe_k, probe_v = keys[n_records:], valid[n_records:]

    # warmup: one drained round per batch size compiles that size's bucket
    # rung (and the walk's descent shapes); measured rounds then replay the
    # exact same shapes
    for b in batch_sizes:
        svc.submit_probe("t", probe_k[:b], probe_v[:b])
        sync(svc.run())
    cache_warm = probe_jit_cache_sizes()
    compiles_warm = svc.snapshot()["counters"]["bucket_compiles_total"]
    print(f"# warmup: {compiles_warm} bucket shapes compiled, "
          f"jit cache {cache_warm}")

    for b in batch_sizes:
        svc.metrics.reset()
        svc.probe_responses.clear()
        t0 = time.perf_counter()
        for off in range(0, n_probes, b):
            svc.submit_probe("t", probe_k[off:off + b], probe_v[off:off + b])
        sync(svc.run())
        dt = time.perf_counter() - t0
        snap = svc.snapshot()
        rows = snap["counters"]["probe_rows_total"]
        lat = snap["histograms"]["probe_latency_s"]
        occ = snap["histograms"]["batch_occupancy"]
        qps = rows / dt
        emit(f"serving/probe_b{b}", dt / rows * 1e6,
             f"qps={qps:.4g};p50_ms={lat['p50'] * 1e3:.4g};"
             f"p99_ms={lat['p99'] * 1e3:.4g};occupancy={occ['mean']:.3f};"
             f"batches={snap['counters']['probe_batches_total']}")
        print(f"serving,b={b},{qps:.4g} probes/s,"
              f"p50={lat['p50'] * 1e3:.3g}ms,p99={lat['p99'] * 1e3:.3g}ms,"
              f"occupancy={occ['mean']:.2f}")
        if check:
            assert rows == n_probes, f"served {rows} of {n_probes} probes"
            assert all(r.status == "ok" for r in svc.probe_responses)

    cache_end = probe_jit_cache_sizes()
    recompiles = sum(cache_end.values()) - sum(cache_warm.values())
    emit("serving/recompiles_after_warmup", float(recompiles),
         f"jit_cache={cache_end};bucket_shapes={compiles_warm}")
    print(f"# recompiles after warmup across {len(batch_sizes)} batch "
          f"sizes: {recompiles} (jit cache {cache_end})")
    if check:
        assert recompiles == 0, (
            f"bucket ladder leaked {recompiles} recompiles across batch "
            f"sizes {tuple(batch_sizes)}: {cache_warm} -> {cache_end}")
        print("# acceptance OK: recompile count constant after warmup")


if __name__ == "__main__":  # PYTHONPATH=src python -m benchmarks.bench_serving
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="assert full service + zero recompiles after warmup")
    ap.add_argument("--records", type=int, default=50_000)
    ap.add_argument("--probes", type=int, default=2_048)
    ap.add_argument("--batch-sizes", type=int, nargs="+", default=[1, 8, 64])
    ap.add_argument("--json", nargs="?", const="BENCH_serving.json",
                    default=None, metavar="PATH",
                    help="write the BENCH_serving.json perf record")
    args = ap.parse_args()
    run(n_records=args.records, n_probes=args.probes,
        batch_sizes=tuple(args.batch_sizes), check=args.check)
    if args.json:
        from .common import write_json
        write_json(args.json, "serving", records=args.records,
                   probes=args.probes, batch_sizes=list(args.batch_sizes))
