"""Kernel-layer microbenches (paper §3 hot spots).

Wall times here are the CPU jnp reference paths (the production path on
this container); the Pallas kernels target TPU and are validated in
interpret mode (tests/test_kernels.py) — interpret-mode timings are not
meaningful and are reported only as parity checks.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit

from repro.core import hashing, minhash, sketches


def _time(fn, *args, iters=5):
    fn(*args)  # warm/compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run():
    rng = np.random.default_rng(0)
    # minhash over 32k records x 32 tokens, 24 hashes
    tokens = jnp.asarray(rng.integers(0, 1 << 31, (32768, 32)), jnp.uint32)
    mask = jnp.ones(tokens.shape, bool)
    # once-per-run microbench jits throughout:
    f = jax.jit(lambda t, m: minhash.minhash_tokens(t, m, 24))  # repro: noqa[R005]
    t = _time(f, tokens, mask)
    emit("kernel/minhash_ref_32kx32x24", t * 1e6,
         f"mh_per_s={32768 * 24 / t:.3g}")

    # bulk mix64 over 4M hashes
    vals = jnp.asarray(rng.integers(0, 1 << 62, 1 << 22)
                       .astype(np.uint64).view(np.uint32).reshape(-1, 2))
    f = jax.jit(lambda h, lo: hashing.mix64((h, lo)))  # repro: noqa[R005]
    t = _time(f, vals[:, 0], vals[:, 1])
    emit("kernel/mix64_ref_4M", t * 1e6, f"hashes_per_s={(1 << 22) / t:.3g}")

    # CMS build over 1M keys
    cfg = sketches.CMSConfig(depth=4, width=1 << 18)
    key = (vals[: 1 << 20, 0], vals[: 1 << 20, 1])
    m = jnp.ones(1 << 20, bool)
    f = jax.jit(lambda h, lo, m: sketches.cms_build(cfg, (h, lo), m))  # repro: noqa[R005]
    t = _time(f, key[0], key[1], m)
    emit("kernel/cms_build_ref_1M", t * 1e6, f"keys_per_s={(1 << 20) / t:.3g}")

    # bloom build+query 1M
    bcfg = sketches.BloomConfig.for_capacity(1 << 20, 1e-8)
    # once-per-run microbench jit:
    f = jax.jit(lambda h, lo, m: sketches.bloom_build(bcfg, (h, lo), m))  # repro: noqa[R005]
    t = _time(f, key[0], key[1], m)
    emit("kernel/bloom_build_ref_1M", t * 1e6,
         f"slots={bcfg.num_slots};k={bcfg.num_hashes}")


if __name__ == "__main__":
    run()
