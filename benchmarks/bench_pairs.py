"""Pair materialization throughput: numpy vs JAX vs Pallas backends.

Measures end-to-end ``dedupe_pairs`` (enumerate + largest-block-wins
dedupe) in pairs/sec across block-size distributions — the numpy shift
method degrades on many-small-block layouts (one pass per diagonal
offset), while the device engine's cost is distribution-independent
(O(1) integer decode per slot + one sort). The acceptance workload is
~1M pair slots, where the JAX backend must report >=5x the numpy path.

Pallas timings here are interpret-mode (CPU container) and are parity
checks, not perf numbers — see bench_kernels.py for the same caveat.
"""
from __future__ import annotations

import time

import numpy as np

from .common import emit

from repro.core import pairs


def _make_blocks(dist: str, target_slots: int, seed: int = 0) -> pairs.Blocks:
    """Synthesize a CSR block layout with ~target_slots pair slots."""
    rng = np.random.default_rng(seed)
    if dist == "small":        # many tiny blocks (shift method's worst case)
        size_draw = lambda: rng.integers(2, 9)
    elif dist == "medium":
        size_draw = lambda: rng.integers(16, 65)
    elif dist == "large":      # few big blocks (meshgrid path)
        size_draw = lambda: rng.integers(300, 501)
    else:                      # zipf-ish mix
        size_draw = lambda: min(500, 2 + int(rng.zipf(1.5)))
    sizes = []
    slots = 0
    while slots < target_slots:
        n = int(size_draw())
        sizes.append(n)
        slots += n * (n - 1) // 2
    sizes = np.asarray(sizes, np.int64)
    start = np.concatenate([[0], np.cumsum(sizes)])[:-1]
    # overlapping membership so the dedupe actually removes pairs
    universe = int(sizes.sum())
    members = np.concatenate(
        [np.sort(rng.choice(universe, n, replace=False)) for n in sizes]
    ).astype(np.int64)
    zu = np.zeros(len(sizes), np.uint32)
    return pairs.Blocks(zu, zu, start, sizes, members)


def _time_backend(blk: pairs.Blocks, backend: str, iters: int = 3) -> float:
    pairs.dedupe_pairs(blk, backend=backend)  # warm / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = pairs.dedupe_pairs(blk, backend=backend)
    dt = (time.perf_counter() - t0) / iters
    assert out.exact
    return dt


def run(distributions=("small", "medium", "large", "zipf"),
        target_slots: int = 1_000_000, check_speedup: bool = False):
    print("# pairs: distribution,backend,seconds,pairs_per_sec,speedup_vs_numpy")
    accept_ratio = None
    for dist in distributions:
        blk = _make_blocks(dist, target_slots)
        total = blk.num_pair_slots
        t_np = _time_backend(blk, "numpy")
        for backend in ("numpy", "jax", "pallas"):
            t = t_np if backend == "numpy" else _time_backend(blk, backend)
            rate = total / t
            speedup = t_np / t
            emit(f"pairs/{dist}_{backend}", t * 1e6,
                 f"pairs_per_s={rate:.3g};speedup={speedup:.2f}x;slots={total}")
            print(f"pairs,{dist},{backend},{t:.4f},{rate:.3g},{speedup:.2f}")
            if dist == "small" and backend == "jax":
                accept_ratio = speedup
    if check_speedup and accept_ratio is not None:
        assert accept_ratio >= 5.0, (
            f"JAX backend only {accept_ratio:.2f}x over numpy on the "
            "1M-slot small-block workload (acceptance: >=5x)")
        print(f"# acceptance OK: jax {accept_ratio:.2f}x >= 5x")


if __name__ == "__main__":  # PYTHONPATH=src python -m benchmarks.bench_pairs [--check]
    import sys
    run(check_speedup="--check" in sys.argv)
