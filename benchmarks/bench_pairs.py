"""Pair materialization throughput: numpy vs JAX vs Pallas backends.

Measures end-to-end ``dedupe_pairs`` (enumerate + largest-block-wins
dedupe) in pairs/sec across block-size distributions — the numpy shift
method degrades on many-small-block layouts (one pass per diagonal
offset), while the device engine's cost is distribution-independent
(O(1) integer decode per slot + one sort). The acceptance workload is
~1M pair slots, where the JAX backend must report >=5x the numpy path.

Pallas timings here are interpret-mode (CPU container) and are parity
checks, not perf numbers — see bench_kernels.py for the same caveat.
"""
from __future__ import annotations

import time

import numpy as np

from .common import emit, sync

from repro.core import pairs


def _make_blocks(dist: str, target_slots: int, seed: int = 0) -> pairs.Blocks:
    """Synthesize a CSR block layout with ~target_slots pair slots."""
    rng = np.random.default_rng(seed)
    if dist == "small":        # many tiny blocks (shift method's worst case)
        size_draw = lambda: rng.integers(2, 9)
    elif dist == "medium":
        size_draw = lambda: rng.integers(16, 65)
    elif dist == "large":      # few big blocks (meshgrid path)
        size_draw = lambda: rng.integers(300, 501)
    else:                      # zipf-ish mix
        size_draw = lambda: min(500, 2 + int(rng.zipf(1.5)))
    sizes = []
    slots = 0
    while slots < target_slots:
        n = int(size_draw())
        sizes.append(n)
        slots += n * (n - 1) // 2
    sizes = np.asarray(sizes, np.int64)
    start = np.concatenate([[0], np.cumsum(sizes)])[:-1]
    # overlapping membership so the dedupe actually removes pairs
    universe = int(sizes.sum())
    members = np.concatenate(
        [np.sort(rng.choice(universe, n, replace=False)) for n in sizes]
    ).astype(np.int64)
    zu = np.zeros(len(sizes), np.uint32)
    return pairs.Blocks(zu, zu, start, sizes, members)


def _time_backend(blk: pairs.Blocks, backend: str, iters: int = 3,
                  sort_backend: str = "auto") -> float:
    pairs.dedupe_pairs(blk, backend=backend,
                       sort_backend=sort_backend)  # warm / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = sync(pairs.dedupe_pairs(blk, backend=backend,
                                      sort_backend=sort_backend))
    dt = (time.perf_counter() - t0) / iters
    assert out.exact
    return dt


def run(distributions=("small", "medium", "large", "zipf"),
        target_slots: int = 1_000_000, check_speedup: bool = False,
        sort_backend: str = "auto"):
    """Backend axis (numpy/jax/pallas) x dedupe-sort axis.

    ``sort_backend`` measures the dedupe-sort knob the same way the
    numpy-vs-JAX axis is measured: "auto" keeps the per-platform default
    (the legacy rows); "comparator"/"radix" force that device sort in
    the jax backend and ALSO emit the comparator baseline, so the
    comparator-vs-radix crossover lands in the same record.
    """
    sort_axes = (["auto"] if sort_backend == "auto"
                 else sorted({"comparator", sort_backend}))
    print("# pairs: distribution,backend,sort,seconds,pairs_per_sec,"
          "speedup_vs_numpy")
    accept_ratio = None
    for dist in distributions:
        blk = _make_blocks(dist, target_slots)
        total = blk.num_pair_slots
        t_np = _time_backend(blk, "numpy")
        rows = [("numpy", "auto", t_np)]
        for sb in sort_axes:
            rows.append(("jax", sb, _time_backend(blk, "jax",
                                                  sort_backend=sb)))
        # the pallas row stays on the default sort: its interpret-mode
        # timing is a parity check, not a perf number (see module doc)
        rows.append(("pallas", "auto", _time_backend(blk, "pallas")))
        for backend, sb, t in rows:
            rate = total / t
            speedup = t_np / t
            tag = "" if sb == "auto" else f"_sort-{sb}"
            emit(f"pairs/{dist}_{backend}{tag}", t * 1e6,
                 f"pairs_per_s={rate:.3g};speedup={speedup:.2f}x;"
                 f"slots={total};sort={sb}")
            print(f"pairs,{dist},{backend},{sb},{t:.4f},{rate:.3g},"
                  f"{speedup:.2f}")
            if dist == "small" and backend == "jax" and accept_ratio is None:
                accept_ratio = speedup
    if check_speedup and sort_backend != "auto":
        # the >=5x gate is defined for the per-platform default sort; a
        # forced device sort measures a different axis — say so loudly
        # instead of exiting green as if the gate had held
        print("# acceptance check SKIPPED: --check gates the auto sort "
              f"backend, not sort_backend={sort_backend!r}")
    elif check_speedup and accept_ratio is not None:
        assert accept_ratio >= 5.0, (
            f"JAX backend only {accept_ratio:.2f}x over numpy on the "
            "1M-slot small-block workload (acceptance: >=5x)")
        print(f"# acceptance OK: jax {accept_ratio:.2f}x >= 5x")


def run_mesh(target_slots: int = 1_200_000,
             distributions=("small", "zipf"),
             chunk_per_shard: int = 1 << 16,
             check_speedup: bool = False,
             sort_backend: str = "auto"):
    """Routed vs global-sort distributed dedupe on an emulated host mesh.

    Requires >= 2 devices (run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; ``--mesh``
    re-execs with that set). Measures ``materialize_pairs_distributed``
    end-to-end in both dedupe modes: "global" gathers every shard's
    decoded pairs into ONE device sort (the pre-routing bottleneck),
    "routed" fingerprint-routes packed sort words with an all_to_all per
    round and dedupes shard-locally, so the per-shard peak buffer stays
    at ~total/n_shards * route_slack words instead of total.
    """
    import math

    import jax

    from repro.core.distributed import materialize_pairs_distributed

    n_shards = jax.device_count()
    assert n_shards >= 2, "mesh bench needs emulated devices (use --mesh)"
    mesh = jax.make_mesh((n_shards,), ("data",))
    route_slack = 2.0
    print("# pairs-mesh: distribution,mode,seconds,pairs_per_sec,speedup_vs_global")
    accept = None
    for dist in distributions:
        blk = _make_blocks(dist, target_slots)
        total = blk.num_pair_slots
        results = {}
        times = {}
        for mode in ("global", "routed"):
            kw = dict(axis_names=("data",), chunk_per_shard=chunk_per_shard,
                      dedupe=mode, route_slack=route_slack,
                      sort_backend=sort_backend)
            results[mode] = materialize_pairs_distributed(blk, mesh, **kw)
            # best-of-3: min de-noises shared-runner scheduler contention
            # (this timing gates the CI slow lane)
            best = float("inf")
            for _ in range(3):
                t0 = time.perf_counter()
                results[mode] = sync(
                    materialize_pairs_distributed(blk, mesh, **kw))
                best = min(best, time.perf_counter() - t0)
            times[mode] = best
        # bit-identical contract between the two dedupe modes
        np.testing.assert_array_equal(results["routed"].a, results["global"].a)
        np.testing.assert_array_equal(results["routed"].b, results["global"].b)
        np.testing.assert_array_equal(results["routed"].src_size,
                                      results["global"].src_size)
        # per-shard peak pair-buffer of the routed path (words), vs the
        # full pair set the global path funnels through one device
        cap = math.ceil(chunk_per_shard / n_shards * route_slack)
        rounds = math.ceil(total / (n_shards * chunk_per_shard))
        per_shard = rounds * n_shards * cap
        assert per_shard < total, (per_shard, total)
        for mode in ("global", "routed"):
            speedup = times["global"] / times[mode]
            emit(f"pairs_mesh/{dist}_{mode}", times[mode] * 1e6,
                 f"pairs_per_s={total/times[mode]:.3g};speedup={speedup:.2f}x;"
                 f"slots={total};shards={n_shards}")
            print(f"pairs-mesh,{dist},{mode},{times[mode]:.4f},"
                  f"{total/times[mode]:.3g},{speedup:.2f}")
        print(f"#   per-shard peak buffer {per_shard} words "
              f"({per_shard/total:.2f}x of {total} total slots)")
        if dist == distributions[0]:
            accept = times["global"] / times["routed"]
    if check_speedup and accept is not None:
        assert accept > 1.0, (
            f"routed dedupe only {accept:.2f}x vs the global sort on "
            f"{n_shards} emulated hosts (acceptance: >1x at >=1M slots)")
        print(f"# acceptance OK: routed {accept:.2f}x > 1x vs global sort")


if __name__ == "__main__":  # PYTHONPATH=src python -m benchmarks.bench_pairs
    import argparse
    import os
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="assert the acceptance speedups")
    ap.add_argument("--mesh", action="store_true",
                    help="routed-vs-global bench on 8 emulated hosts")
    ap.add_argument("--slots", type=int, default=None,
                    help="target pair slots per layout")
    ap.add_argument("--sort-backend", default="auto",
                    choices=("auto", "comparator", "radix"),
                    help="dedupe-sort knob; non-auto adds the "
                         "comparator-vs-radix axis to the jax rows")
    ap.add_argument("--json", nargs="?", const="BENCH_pairs.json",
                    default=None, metavar="PATH",
                    help="write the BENCH_pairs.json perf record")
    args = ap.parse_args()
    if args.mesh:
        if "--xla_force_host_platform_device_count" not in os.environ.get(
                "XLA_FLAGS", ""):
            env = dict(os.environ)
            env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                                + " --xla_force_host_platform_device_count=8").strip()
            env.pop("JAX_PLATFORMS", None)
            os.execve(sys.executable,
                      [sys.executable, "-m", "benchmarks.bench_pairs"]
                      + sys.argv[1:], env)
        run_mesh(check_speedup=args.check, sort_backend=args.sort_backend,
                 **({"target_slots": args.slots} if args.slots else {}))
    else:
        run(check_speedup=args.check, sort_backend=args.sort_backend,
            **({"target_slots": args.slots} if args.slots else {}))
    if args.json:
        from .common import write_json
        write_json(args.json, "pairs", mesh=args.mesh,
                   sort_backend=args.sort_backend)
