"""End-to-end dedup pipeline: host vs fused match->filter->cluster.

Times every stage of ``dedup_corpus`` (blocking / matching / partition,
all ``block_until_ready``-synced inside the pipeline) for the host
baseline and the fused device backends, and accounts the per-call
host<->device transit each back-half incurs:

- **host**: the full per-pair score vector and matched mask cross to the
  host, the matched pair list is gathered in numpy and re-uploaded for
  connected components — transit scales with the CANDIDATE pair count.
- **jnp / pallas** (kernels/match): score+threshold+compaction and the
  CC rounds stay on device; only final labels, survivors, and three
  scalars cross — transit scales with the RECORD count.

Both paths must produce bit-identical survivors/labels (asserted every
run; ``--check`` makes a failure fatal for CI). Pallas rows off-TPU are
interpret-mode parity checks, not perf numbers (the bench_pairs caveat).
"""
from __future__ import annotations

import argparse

import numpy as np

from .common import emit, get_corpus, write_json

from repro.core import hdb
from repro.data import pipeline

# stage seconds -> derived transit bytes: see module docstring
_F32 = 4
_I32 = 4
_I64 = 8


def _transit_bytes(rep: pipeline.DedupReport, backend: str) -> int:
    p = rep.num_candidate_pairs
    m = rep.num_matched_pairs
    n = rep.num_records
    s = rep.num_survivors
    down = n * _I32 + s * _I32 + 3 * _I32        # labels + survivors + scalars
    if backend == "host":
        # scores down, matched mask down, matched pairs back up for CC
        return p * _F32 + p * 1 + 2 * m * _I64 + down
    return down


def run(dataset: str = "SYN30K", backends=("host", "jnp"),
        max_block_size: int = 100, check: bool = False) -> bool:
    corpus = get_corpus(dataset)
    cfg = hdb.HDBConfig(max_block_size=max_block_size)
    print("# match: backend,stage,seconds + derived counters")
    reports = {}
    for backend in backends:
        pipeline.dedup_corpus(corpus, cfg, match_backend=backend)  # warm
        rep = pipeline.dedup_corpus(corpus, cfg, match_backend=backend)
        reports[backend] = rep
        total = (rep.blocking_seconds + rep.matching_seconds
                 + rep.partition_seconds)
        emit(f"match/block/{backend}", rep.blocking_seconds * 1e6,
             f"pairs={rep.num_candidate_pairs}")
        emit(f"match/match/{backend}", rep.matching_seconds * 1e6,
             f"matched={rep.num_matched_pairs}")
        emit(f"match/cluster/{backend}", rep.partition_seconds * 1e6,
             f"components={rep.num_components}")
        emit(f"match/e2e/{backend}", total * 1e6,
             f"records={rep.num_records} transit_bytes="
             f"{_transit_bytes(rep, backend)}")
    ok = True
    base = reports.get("host")
    if base is not None:
        for backend, rep in reports.items():
            same = (np.array_equal(rep.survivors, base.survivors)
                    and np.array_equal(rep.component_of, base.component_of)
                    and rep.num_matched_pairs == base.num_matched_pairs)
            ok = ok and same
            emit(f"match/parity/{backend}", 0.0,
                 f"bit_identical={'yes' if same else 'NO'}")
    if check and not ok:
        raise SystemExit("fused path is NOT bit-identical to host baseline")
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="SYN30K")
    ap.add_argument("--backends", default="host,jnp",
                    help="comma list from host,jnp,pallas")
    ap.add_argument("--max-block-size", type=int, default=100)
    ap.add_argument("--check", action="store_true",
                    help="fail the process if bit-identity breaks")
    ap.add_argument("--json", metavar="PATH",
                    help="write a BENCH_match.json perf record")
    args = ap.parse_args()
    backends = tuple(b for b in args.backends.split(",") if b)
    ok = run(dataset=args.dataset, backends=backends,
             max_block_size=args.max_block_size, check=args.check)
    if args.json:
        write_json(args.json, "match", dataset=args.dataset,
                   backends=list(backends), bit_identical=ok)


if __name__ == "__main__":
    main()
