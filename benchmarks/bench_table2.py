"""Paper Table 2: PQ / PC / elapsed time for THR vs PMB vs HDB."""
from __future__ import annotations

from .common import emit, get_corpus, get_keys, timed

from repro.core import baselines, hdb, metablocking
from repro.data import metrics


def run(datasets=("SYN10K", "VOTERSYN", "SYN100K"), max_block_size=200):
    print("# table2: dataset,method,pq,pc,pairs,seconds")
    rows = []
    for ds in datasets:
        corpus = get_corpus(ds)
        keys, valid = get_keys(ds)
        labeled = corpus.labeled_pairs()

        thr, t_thr = timed(baselines.threshold_blocking, keys, valid,
                           max_block_size)
        m_thr = metrics.evaluate(thr, corpus, labeled)

        cfg = hdb.HDBConfig(max_block_size=max_block_size)
        res, t_hdb = timed(hdb.hashed_dynamic_blocking, keys, valid, cfg)
        m_hdb = metrics.evaluate(res, corpus, labeled)

        try:
            pmb, t_pmb = timed(metablocking.meta_blocking_result, keys, valid)
            m_pmb = metrics.evaluate(pmb, corpus, labeled)
            pmb_row = (m_pmb.pq, m_pmb.pc, m_pmb.distinct_pairs // 2, t_pmb)
        except metablocking.MetaBlockingBudgetError as e:
            pmb_row = (float("nan"), float("nan"), 0, float("nan"))
            print(f"# PMB failed on {ds}: {e} (mirrors paper §5.3)")

        for method, (pq, pc, pairs, t) in [
            ("THR", (m_thr.pq, m_thr.pc, m_thr.distinct_pairs, t_thr)),
            ("PMB", pmb_row),
            ("HDB", (m_hdb.pq, m_hdb.pc, m_hdb.distinct_pairs, t_hdb)),
        ]:
            print(f"table2,{ds},{method},{pq:.4g},{pc:.4g},{pairs},{t:.2f}")
            rows.append((ds, method, pq, pc, pairs, t))
        emit(f"table2/{ds}/hdb", t_hdb * 1e6,
             f"pq={m_hdb.pq:.4g};pc={m_hdb.pc:.4g}")
    return rows


if __name__ == "__main__":
    run()
