"""Paper Fig 1b / Fig 3: PQ vs PC across LSH(b, w) settings (+ token
blocking reference point)."""
from __future__ import annotations



from .common import emit, get_corpus, timed

from repro.core import blocks, hdb
from repro.core.blocks import ColumnBlocking
from repro.data import metrics


def run(dataset="SYN10K", settings=((3, 8), (6, 4), (8, 4), (14, 4), (16, 3),
                                    (1, 1)),
        max_block_size=200, include_token_blocking=True):
    corpus = get_corpus(dataset)
    labeled = corpus.labeled_pairs()
    print("# fig1b: dataset,blocking,pq,pc,pairs")
    rows = []

    def eval_blocking(tag, blocking):
        keys, valid = blocks.build_keys(corpus.columns, blocking)
        res, t = timed(hdb.hashed_dynamic_blocking, keys, valid,
                       hdb.HDBConfig(max_block_size=max_block_size))
        m = metrics.evaluate(res, corpus, labeled)
        print(f"fig1b,{dataset},{tag},{m.pq:.4g},{m.pc:.4g},{m.distinct_pairs}")
        rows.append((tag, m.pq, m.pc, m.distinct_pairs))
        return m

    for b, w in settings:
        blocking = dict(corpus.blocking)
        for col in ("name", "description"):
            blocking[col] = ColumnBlocking.lsh(b, w)
        eval_blocking(f"LSH({b},{w})", blocking)

    if include_token_blocking:
        blocking = {c: ColumnBlocking.token() for c in corpus.columns}
        eval_blocking("TOKEN", blocking)

    emit(f"fig1b/{dataset}", 0.0, f"settings={len(rows)}")
    return rows


if __name__ == "__main__":
    run()
