"""Paper §1/abstract claim: HDB scales ~linearly in record count.

Measures wall time across SYN sizes and fits time = a*N^p; the paper
demonstrates p ~= 1 between 1M and 530M rows on a Spark cluster; here the
same algorithm (one CPU core, jit'd fixed-shape iterations) should show
p ~= 1 over 10k -> 1M-record synthetic corpora.
"""
from __future__ import annotations

import numpy as np

from .common import emit, get_corpus, get_keys, timed

from repro.core import hdb


def run(datasets=("SYN10K", "SYN30K", "SYN100K", "SYN300K"),
        max_block_size=200, include_1m=False):
    if include_1m:
        datasets = tuple(datasets) + ("SYN1M",)
    print("# scaling: dataset,records,seconds,pairs")
    ns, ts = [], []
    cfg = hdb.HDBConfig(max_block_size=max_block_size)
    for ds in datasets:
        corpus = get_corpus(ds)
        keys, valid = get_keys(ds)
        # warm the jit caches on the first dataset shape, then measure
        res, t = timed(hdb.hashed_dynamic_blocking, keys, valid, cfg)
        res, t = timed(hdb.hashed_dynamic_blocking, keys, valid, cfg)
        print(f"scaling,{ds},{corpus.num_records},{t:.2f},{len(res.rids)}")
        ns.append(corpus.num_records)
        ts.append(t)
    p, log_a = np.polyfit(np.log(ns), np.log(ts), 1)
    print(f"scaling,fit,exponent,{p:.3f},")
    emit("scaling/fit", 0.0, f"exponent={p:.3f}")
    return p


if __name__ == "__main__":
    run()
