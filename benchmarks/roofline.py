"""Aggregate results/dryrun/*.json into the §Roofline table.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
Emits a markdown table (also pasted into EXPERIMENTS.md) with the three
terms, the dominant bound, MODEL_FLOPS/HLO_FLOPs usefulness ratio, and
memory-fit per chip.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.configs.shapes import SHAPES

HBM_PER_CHIP = 16e9  # v5e-class


def model_flops(arch: str, shape_name: str) -> float:
    """6*N_active*D for train (fwd+bwd); 2*N_active*D for inference.

    enc-dec: the encoder runs over seq_len frames while the decoder sees
    seq_len/ratio tokens — count the two halves separately.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_params()
    mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[shape.kind]
    if cfg.family == "encdec":
        n_enc = n * cfg.encoder_layers / (cfg.encoder_layers + cfg.decoder_layers)
        n_dec = n - n_enc
        dec_tokens = (shape.seq_len // cfg.encoder_seq_ratio
                      if shape.kind != "decode" else 1)
        enc_tokens = shape.seq_len if shape.kind != "decode" else 0
        return mult * (n_enc * enc_tokens + n_dec * dec_tokens) * shape.global_batch
    tokens = shape.seq_len if shape.kind != "decode" else 1
    return mult * n * tokens * shape.global_batch


def load(dir_: str, tag: str = "baseline", mesh: str = "single"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dir_, f"*__{mesh}__{tag}.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def table(rows, mesh="single"):
    hdr = ("| arch | shape | compute s | memory s | collective s | bound | "
           "MODEL/HLO flops | HBM GB/chip | note |")
    lines = [hdr, "|" + "---|" * 9]
    for r in rows:
        if not r.get("ok"):
            err = r.get("error", "")[:40]
            lines.append(
                f"| {r['arch']} | {r['shape']} | - | - | - | FAIL | - | - | {err} |")
            continue
        roof = r["roofline"]
        mf = model_flops(r["arch"], r["shape"])
        hlo_global = roof["flops_per_device"] * roof["chips"]
        ratio = mf / hlo_global if hlo_global else 0.0
        mem = r["memory"]
        # argument_size is already per-device on SPMD CPU? record raw temp
        hbm_gb = ((mem.get("temp_size_in_bytes") or 0)
                  + (mem.get("argument_size_in_bytes") or 0)) / 1e9
        fits = "fits" if hbm_gb < HBM_PER_CHIP / 1e9 else "OVER"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {roof['compute_seconds']:.3g} "
            f"| {roof['memory_seconds']:.3g} | {roof['collective_seconds']:.3g} "
            f"| {roof['dominant']} | {ratio:.2f} | {hbm_gb:.1f} ({fits}) | |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rows = load(args.dir, args.tag, args.mesh)
    print(table(rows, args.mesh))
    ok = sum(1 for r in rows if r.get("ok"))
    print(f"\n{ok}/{len(rows)} cells OK ({args.mesh} mesh, tag={args.tag})")


if __name__ == "__main__":
    main()
