"""Shared benchmark plumbing: datasets, timing, CSV contract."""
from __future__ import annotations

import sys
import time
from typing import Callable, Dict

sys.path.insert(0, "src")


from repro.core import blocks
from repro.data import synthetic


def sync(out):
    """Block until every jax array reachable in ``out`` is computed.

    JAX dispatch is async: stopping a clock without this measures enqueue
    time, not execution (repro.analysis rule R004). Accepts any pytree
    and unwraps one level of dataclass (PairSet, IngestReport, ...) so
    device-resident fields like ``PairSet.device_a`` are awaited too.
    Host numpy leaves pass through untouched.
    """
    import dataclasses

    import jax

    tree = out
    if dataclasses.is_dataclass(out) and not isinstance(out, type):
        tree = [getattr(out, f.name) for f in dataclasses.fields(out)
                if not dataclasses.is_dataclass(getattr(out, f.name))]
    jax.block_until_ready(tree)
    return out


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = sync(fn(*args, **kw))
    return out, time.perf_counter() - t0


_DATASETS: Dict[str, synthetic.SyntheticSpec] = {
    # name -> spec; sizes chosen for a single CPU core (paper: 1M-530M on a
    # 100-node Spark cluster — scaling bench extrapolates the complexity)
    "SYN10K": synthetic.SyntheticSpec(num_entities=4_000, seed=1),
    "SYN30K": synthetic.SyntheticSpec(num_entities=12_000, seed=2),
    "SYN100K": synthetic.SyntheticSpec(num_entities=40_000, seed=3),
    "SYN300K": synthetic.SyntheticSpec(num_entities=120_000, seed=4),
    "SYN1M": synthetic.SyntheticSpec(num_entities=400_000, seed=5),
    # VOTER-analog: more columns, scalar-heavy, complete ground truth
    "VOTERSYN": synthetic.SyntheticSpec(
        num_entities=20_000, dup_rate=0.15, max_dups=2, name_len=(2, 4),
        desc_len=(4, 8), brand_card=50_000, category_card=2_000,
        model_no_present=0.9, tok_dropout=0.08, tok_substitute=0.05, seed=6),
}

_cache: Dict[str, object] = {}


def get_corpus(name: str) -> synthetic.Corpus:
    if name not in _cache:
        _cache[name] = synthetic.generate(_DATASETS[name])
    return _cache[name]


def get_keys(name: str):
    key = name + "/keys"
    if key not in _cache:
        c = get_corpus(name)
        _cache[key] = blocks.build_keys(c.columns, c.blocking)
    return _cache[key]


# rows collected by emit() for the machine-readable perf record
_RECORDS: list = []


def emit(name: str, us_per_call: float, derived: str = ""):
    """Benchmark output contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
    _RECORDS.append({"name": name, "us_per_call": round(us_per_call, 1),
                     "derived": derived})


def write_json(path: str, bench: str, **meta):
    """Write every emitted row so far as a BENCH_<bench>.json perf record.

    The record is the CI perf-trajectory artifact: one JSON object with
    the bench name, environment provenance, optional caller metadata,
    and the ``emit`` rows verbatim.
    """
    import json
    import platform

    import jax

    record = {
        "bench": bench,
        "unix_time": int(time.time()),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "jax_backend": jax.default_backend(),
        "device_count": jax.device_count(),
        **meta,
        "results": list(_RECORDS),
    }
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    print(f"# wrote {len(_RECORDS)} records to {path}", flush=True)
