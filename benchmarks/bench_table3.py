"""Paper Table 3: pairs produced by Naive / THR / PMB / HDB."""
from __future__ import annotations

from .common import emit, get_corpus, get_keys

from repro.core import baselines, hdb, metablocking, pairs as pairs_mod


def run(datasets=("SYN10K", "VOTERSYN", "SYN100K"), max_block_size=200):
    print("# table3: dataset,naive,thr,pmb,hdb (distinct pairs)")
    out = []
    for ds in datasets:
        keys, valid = get_keys(ds)
        naive = baselines.naive_pair_count(keys, valid)
        thr = baselines.threshold_blocking(keys, valid, max_block_size)
        thr_pairs = pairs_mod.dedupe_pairs(pairs_mod.build_blocks(thr))
        res = hdb.hashed_dynamic_blocking(
            keys, valid, hdb.HDBConfig(max_block_size=max_block_size))
        hdb_pairs = pairs_mod.dedupe_pairs(pairs_mod.build_blocks(res))
        try:
            a, b = metablocking.meta_blocking(keys, valid)
            pmb_n = len(a)
        except metablocking.MetaBlockingBudgetError:
            pmb_n = -1
        print(f"table3,{ds},{naive},{len(thr_pairs.a)},{pmb_n},{len(hdb_pairs.a)}")
        emit(f"table3/{ds}", 0.0,
             f"naive={naive};thr={len(thr_pairs.a)};pmb={pmb_n};hdb={len(hdb_pairs.a)}")
        out.append((ds, naive, len(thr_pairs.a), pmb_n, len(hdb_pairs.a)))
    return out


if __name__ == "__main__":
    run()
