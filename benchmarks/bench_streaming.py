"""Streaming ingest: delta cost vs full re-blocking, and throughput.

The acceptance workload: a >=100k-record BlockStore absorbs a 1% record
delta; the ingest (incremental HDB + delta pair materialization, i.e.
everything needed to keep the candidate-pair ledger exact) must be >=5x
faster than re-running batch ``hashed_dynamic_blocking`` + ``build_blocks``
+ ``dedupe_pairs`` on the union — the work a batch system would redo per
arrival wave. Both paths are compile-warmed first; the comparison is
steady-state wall clock on the same backend.

    PYTHONPATH=src python -m benchmarks.bench_streaming [--check] [--records N]
"""
from __future__ import annotations

import time

import numpy as np

from .common import emit, sync

from repro.core import blocks as blocks_mod
from repro.core import hdb, pairs
from repro.streaming import BlockStore, DeltaBlocker

import jax.numpy as jnp


def _make_stream_keys(rng, n, k_small=8, card_ratio=0.25, k_hot=2,
                      hot_card=24):
    """Key layout shaped like production blocking: mostly discriminative
    keys (small blocks) plus a few hot keys (over-sized -> intersections)."""
    small = rng.integers(0, max(int(n * card_ratio), 4), (n, k_small))
    hot = rng.integers(0, hot_card, (n, k_hot)) + (1 << 40)
    ids = np.concatenate([small, hot], axis=1).astype(np.uint64)
    k64 = ids * np.uint64(0x9E3779B97F4A7C15)
    keys = np.stack([(k64 >> np.uint64(32)).astype(np.uint32),
                     (k64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)], -1)
    valid = np.ones(ids.shape, bool)
    h, l, v = blocks_mod.dedupe_row_keys(
        jnp.asarray(keys[..., 0]), jnp.asarray(keys[..., 1]),
        jnp.asarray(valid))
    return np.stack([np.asarray(h), np.asarray(l)], -1), np.asarray(v)


def _full_reblock(keys, valid, cfg):
    res = hdb.hashed_dynamic_blocking(jnp.asarray(keys), jnp.asarray(valid),
                                      cfg)
    blk = pairs.build_blocks(res)
    return sync(pairs.dedupe_pairs(blk, budget=max(blk.num_pair_slots, 1) + 1))


def bench_delta_vs_full(n_records: int = 100_000, delta_frac: float = 0.01,
                        check_speedup: bool = False, seed: int = 0):
    cfg = hdb.HDBConfig(max_block_size=64, max_iterations=6,
                        cms_width=1 << 18)
    rng = np.random.default_rng(seed)
    n_delta = max(int(n_records * delta_frac), 1)
    # two deltas: the first warms the delta-sized jit shapes (one-time
    # compiles), the second measures the steady-state serving cost
    keys, valid = _make_stream_keys(rng, n_records + 2 * n_delta)
    base_k, base_v = keys[:n_records], valid[:n_records]

    # --- streaming: build the base store ---
    store = BlockStore(cfg)
    blocker = DeltaBlocker(store)
    t0 = time.perf_counter()
    sync(blocker.ingest_keys(base_k, base_v))
    t_base = time.perf_counter() - t0
    print(f"# base store: {n_records} records, "
          f"{len(store.led_pack)} candidate pairs, built in {t_base:.2f}s")
    blocker.ingest_keys(keys[n_records:n_records + n_delta],
                        valid[n_records:n_records + n_delta])  # warm

    # --- batch: warm the compile cache, then time the union re-block ---
    _full_reblock(base_k[:4096], base_v[:4096], cfg)
    t0 = time.perf_counter()
    full = sync(_full_reblock(keys, valid, cfg))
    t_full = time.perf_counter() - t0

    # --- streaming: time the steady-state 1% delta ingest ---
    t0 = time.perf_counter()
    report = sync(blocker.ingest_keys(keys[n_records + n_delta:],
                                      valid[n_records + n_delta:]))
    t_delta = time.perf_counter() - t0

    want_pack = ((full.a.astype(np.uint64) << np.uint64(32))
                 | full.b.astype(np.uint64))
    assert np.array_equal(store.led_pack, want_pack), (
        "streaming ledger diverged from batch union "
        f"({len(store.led_pack)} vs {len(full.a)} pairs)")
    speedup = t_full / t_delta
    emit("streaming/delta_ingest", t_delta * 1e6,
         f"records={n_delta};pairs_added={report.num_pairs_added}")
    emit("streaming/full_reblock", t_full * 1e6, f"records={n_records + n_delta}")
    print(f"streaming,delta_ingest,{t_delta:.4f}s,{n_delta} records,"
          f"{report.num_pairs_added} new pairs")
    print(f"streaming,full_reblock,{t_full:.4f}s,{n_records + n_delta} records")
    print(f"streaming,speedup,{speedup:.2f}x (delta vs full re-block)")
    if check_speedup:
        assert speedup >= 5.0, (
            f"delta ingest only {speedup:.2f}x faster than full re-block "
            "(acceptance: >=5x)")
        print(f"# acceptance OK: {speedup:.2f}x >= 5x")
    return speedup


def bench_ingest_throughput(n_records: int = 20_000, seed: int = 1):
    cfg = hdb.HDBConfig(max_block_size=64, max_iterations=6,
                        cms_width=1 << 16)
    rng = np.random.default_rng(seed)
    keys, valid = _make_stream_keys(rng, n_records)
    print("# streaming: micro_batch,records_per_sec")
    for mb in (256, 1024, 4096):
        store = BlockStore(cfg)
        blocker = DeltaBlocker(store)
        # warm with the first batch, time the rest
        blocker.ingest_keys(keys[:mb], valid[:mb])
        t0 = time.perf_counter()
        for off in range(mb, n_records, mb):
            sync(blocker.ingest_keys(keys[off:off + mb], valid[off:off + mb]))
        dt = time.perf_counter() - t0
        rate = (n_records - mb) / dt
        emit(f"streaming/ingest_mb{mb}", dt * 1e6 / max(n_records - mb, 1),
             f"records_per_s={rate:.3g}")
        print(f"streaming,ingest,mb={mb},{rate:.3g} records/s")


def bench_sharded_ingest(n_shards: int, n_records: int = 20_000,
                         mb: int = 1024, seed: int = 2):
    """Sharded-store ingest (host-routing mirror) vs single-host, with
    ledger parity asserted and the per-shard occupancy/skew gauges from
    ``memory_stats`` emitted into the JSON record."""
    from repro.streaming import ShardedBlockStore

    cfg = hdb.HDBConfig(max_block_size=64, max_iterations=6,
                        cms_width=1 << 16)
    rng = np.random.default_rng(seed)
    keys, valid = _make_stream_keys(rng, n_records)
    flat = BlockStore(cfg)
    fb = DeltaBlocker(flat)
    st = ShardedBlockStore(cfg, n_shards=n_shards)
    sb = DeltaBlocker(st)
    fb.ingest_keys(keys[:mb], valid[:mb])   # warm
    sb.ingest_keys(keys[:mb], valid[:mb])
    times = {}
    for name, blocker in (("flat", fb), (f"shards{n_shards}", sb)):
        t0 = time.perf_counter()
        for off in range(mb, n_records, mb):
            sync(blocker.ingest_keys(keys[off:off + mb],
                                     valid[off:off + mb]))
        times[name] = time.perf_counter() - t0
    assert np.array_equal(flat.led_pack, st.led_pack), (
        f"sharded (n={n_shards}) ledger diverged from single-host")
    ms = st.memory_stats()
    n_done = n_records - mb
    emit(f"streaming/sharded_ingest_n{n_shards}",
         times[f"shards{n_shards}"] * 1e6 / max(n_done, 1),
         f"records_per_s={n_done / times[f'shards{n_shards}']:.3g};"
         f"shard_skew={ms['shard_skew']:.3f};"
         f"keytab_bytes={ms['keytab_bytes']};"
         f"csr_bytes={ms['csr_bytes']};ledger_bytes={ms['ledger_bytes']}")
    print(f"streaming,sharded_ingest,n_shards={n_shards},"
          f"{n_done / times[f'shards{n_shards}']:.3g} records/s,"
          f"skew={ms['shard_skew']:.3f} "
          f"(single-host {n_done / times['flat']:.3g} records/s)")
    for s in range(n_shards):
        print(f"streaming,shard{s},keytab={ms[f'shard{s}_keytab_bytes']},"
              f"csr={ms[f'shard{s}_csr_bytes']},"
              f"ledger={ms[f'shard{s}_ledger_bytes']}")


def run(check_speedup: bool = False, n_records: int = 100_000,
        n_shards: int = 0):
    bench_ingest_throughput()
    if n_shards > 0:
        bench_sharded_ingest(n_shards)
    bench_delta_vs_full(n_records=n_records, check_speedup=check_speedup)


if __name__ == "__main__":  # PYTHONPATH=src python -m benchmarks.bench_streaming
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="assert the >=5x delta-vs-full acceptance")
    ap.add_argument("--records", type=int, default=100_000)
    ap.add_argument("--json", nargs="?", const="BENCH_streaming.json",
                    default=None, metavar="PATH",
                    help="write the BENCH_streaming.json perf record")
    ap.add_argument("--shards", type=int, default=0, metavar="N",
                    help="also bench an N-shard ShardedBlockStore ingest "
                    "(parity-checked; per-shard bytes + skew in the JSON)")
    args = ap.parse_args()
    run(check_speedup=args.check, n_records=args.records,
        n_shards=args.shards)
    if args.json:
        from .common import write_json
        write_json(args.json, "streaming", records=args.records,
                   shards=args.shards)
