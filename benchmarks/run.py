"""Benchmark entrypoint: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--large]

Prints ``name,us_per_call,derived`` CSV lines (plus per-table detail rows
prefixed with the table id). --large adds the 1M-record scaling point.
"""
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "src")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small datasets only (CI)")
    ap.add_argument("--large", action="store_true",
                    help="add the 1M-record scaling point")
    args = ap.parse_args()

    from . import (bench_kernels, bench_lsh_curve, bench_lsh_sweep,
                   bench_pairs, bench_scaling, bench_table2, bench_table3)

    t0 = time.time()
    print("name,us_per_call,derived")
    bench_kernels.run()
    bench_lsh_curve.run()
    if args.fast:
        bench_pairs.run(distributions=("small",), target_slots=100_000)
        bench_table2.run(datasets=("SYN10K",))
        bench_table3.run(datasets=("SYN10K",))
        bench_lsh_sweep.run(settings=((6, 4), (1, 1)))
        bench_scaling.run(datasets=("SYN10K", "SYN30K"))
    else:
        bench_pairs.run()
        bench_table2.run()
        bench_table3.run()
        bench_lsh_sweep.run()
        bench_scaling.run(include_1m=args.large)
    print(f"# total benchmark time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
