"""Paper Fig 1a: P[share a band key] vs Jaccard for LSH(b, w) — analytic
curve validated against empirical band collisions."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .common import emit

from repro.core import minhash
from repro.data import synthetic


def run(settings=((6, 4), (14, 4), (3, 8), (1, 1)),
        jaccards=(0.2, 0.4, 0.6, 0.8)):
    print("# fig1a: b,w,jaccard,analytic,empirical")
    rows = []
    for b, w in settings:
        for j in jaccards:
            a, bb, true_j = synthetic.jaccard_pair_corpus(400, j, set_size=60,
                                                          seed=17)
            m = jnp.ones(a.shape, bool)
            ka, _ = minhash.lsh_keys(jnp.asarray(a), m, b, w)
            kb, _ = minhash.lsh_keys(jnp.asarray(bb), m, b, w)
            share = ((np.asarray(ka[0]) == np.asarray(kb[0]))
                     & (np.asarray(ka[1]) == np.asarray(kb[1]))).any(axis=1)
            analytic = float(minhash.lsh_probability(b, w, true_j))
            print(f"fig1a,{b},{w},{true_j:.3f},{analytic:.4f},{share.mean():.4f}")
            rows.append((b, w, true_j, analytic, float(share.mean())))
    worst = max(abs(r[3] - r[4]) for r in rows)
    emit("fig1a/lsh_curve", 0.0, f"max_abs_err={worst:.4f}")
    return rows


if __name__ == "__main__":
    run()
