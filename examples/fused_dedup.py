"""Fused on-device dedup demo: records -> blocks -> pairs -> clusters.

Runs the full 4-stage pipeline twice over the same synthetic corpus —
once on the host match/cluster baseline and once on the fused device
path (``match_backend="auto"``: score+threshold+compaction in
kernels/match, bounded-round connected components + survivor extraction
on device) — prints per-stage timings and cluster quality, and asserts
the two back halves are bit-identical (same matched pairs, labels, and
survivors; the docs/PIPELINE.md contract).

    PYTHONPATH=src python examples/fused_dedup.py [--entities 2000]
    PYTHONPATH=src python examples/fused_dedup.py --smoke   # CI-sized
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import hdb
from repro.data import pipeline, synthetic
from repro.data.pipeline import dedup_quality


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--entities", type=int, default=2_000)
    ap.add_argument("--max-block-size", type=int, default=50)
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "jnp", "pallas"))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny corpus + parity assert (CI smoke step)")
    args = ap.parse_args()
    if args.smoke:
        args.entities = 150

    corpus = synthetic.generate(synthetic.SyntheticSpec(
        num_entities=args.entities, seed=7))
    cfg = hdb.HDBConfig(max_block_size=args.max_block_size, max_iterations=6,
                        cms_width=1 << (12 if args.smoke else 16))
    print(f"corpus: {corpus.num_records} records, "
          f"{args.entities} true entities")

    def show(name, rep):
        print(f"  {name:>6}: block {rep.blocking_seconds:6.3f}s | "
              f"match {rep.matching_seconds:6.3f}s | "
              f"cluster {rep.partition_seconds:6.3f}s | "
              f"{rep.num_candidate_pairs} pairs -> "
              f"{rep.num_matched_pairs} matched -> "
              f"{rep.num_components} clusters")

    host = pipeline.dedup_corpus(corpus, cfg, match_backend="host")
    show("host", host)
    fused = pipeline.dedup_corpus(corpus, cfg, match_backend=args.backend)
    show(args.backend, fused)

    # the fused-path contract: bit-identical, not merely close
    assert fused.num_matched_pairs == host.num_matched_pairs
    np.testing.assert_array_equal(fused.component_of, host.component_of)
    np.testing.assert_array_equal(fused.survivors, host.survivors)
    print("fused back half is bit-identical to the host baseline")

    q = dedup_quality(fused, corpus)
    print(f"quality: pair_recall={q['pair_recall']:.3f} "
          f"pair_precision={q['pair_precision']:.3f} "
          f"dedup_ratio={q['dedup_ratio']:.3f}")
    print("OK")


if __name__ == "__main__":
    main()
