"""End-to-end driver: dedup a corpus with HDB, then train an LM on the
deduplicated token stream — the paper's technique feeding the model zoo.

    PYTHONPATH=src python examples/train_lm.py --steps 200           # ~100M model
    PYTHONPATH=src python examples/train_lm.py --preset ci --steps 20 # CPU-quick

Any assigned architecture works via --arch (reduced config); the default
"midi" preset is a ~100M-param tinyllama-family model. Features exercised:
HDB dedup -> loader -> AdamW + grad accum -> checkpoint/resume ->
straggler monitor -> preemption handler.
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core import hdb
from repro.data import loader, pipeline, synthetic
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.training import checkpoint
from repro.training.optimizer import OptimizerConfig
from repro.training.stragglers import PreemptionHandler, StragglerMonitor
from repro.training.train_loop import TrainConfig, init_train_state, make_train_step


def midi_config() -> ModelConfig:
    """~100M-param llama-family model (the assignment's e2e target)."""
    return ModelConfig(
        name="midi-100m", family="dense", num_layers=8, d_model=768,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=16_384, param_dtype="float32", compute_dtype="float32",
        remat="none")


def ci_config() -> ModelConfig:
    return dataclasses.replace(midi_config(), num_layers=2, d_model=128,
                               d_ff=256, vocab_size=2_048, name="ci-2m")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="assigned arch id (reduced)")
    ap.add_argument("--preset", default="midi", choices=["midi", "ci"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--entities", type=int, default=4000)
    ap.add_argument("--no-dedup", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.arch:
        cfg = reduced_config(args.arch)
    else:
        cfg = midi_config() if args.preset == "midi" else ci_config()
    n_params = cfg.total_params()
    print(f"model: {cfg.name} (~{n_params/1e6:.0f}M params)")

    # ---- stage 0: data pipeline with the paper's blocking ----
    corpus = synthetic.generate(synthetic.SyntheticSpec(
        num_entities=args.entities, dup_rate=0.5, seed=11))
    survivors = None
    if not args.no_dedup:
        rep = pipeline.dedup_corpus(corpus, hdb.HDBConfig(max_block_size=100))
        survivors = rep.survivors
        print(f"dedup: {corpus.num_records} -> {rep.num_survivors} records "
              f"(blocking {rep.blocking_seconds:.1f}s)")
    ld = loader.TokenStreamLoader(
        corpus, loader.LoaderConfig(batch_size=args.batch, seq_len=args.seq,
                                    vocab_size=cfg.vocab_size),
        survivors=survivors)
    print(f"token stream: {len(ld.stream)} tokens")

    # ---- training with fault-tolerance plumbing ----
    model = build_model(cfg)
    tcfg = TrainConfig(opt=OptimizerConfig(
        lr=3e-4, warmup_steps=20, total_steps=args.steps))
    state = init_train_state(model, jax.random.PRNGKey(0), tcfg)
    start = 0
    if args.resume and checkpoint.latest_step(args.ckpt_dir) is not None:
        start = checkpoint.latest_step(args.ckpt_dir)
        state = checkpoint.restore(args.ckpt_dir, jax.eval_shape(lambda: state))
        print(f"resumed from step {start}")
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=0)
    monitor = StragglerMonitor()
    preempt = PreemptionHandler().install()

    t0 = time.time()
    for step in range(start, args.steps):
        monitor.start_step()
        inputs, targets = ld.batch(step)
        batch = {"tokens": inputs, "targets": targets}
        if cfg.family == "vlm":
            batch["patches"] = np.zeros(
                (args.batch, cfg.num_patches, cfg.d_model), np.float32)
        if cfg.family == "encdec":
            batch["frames"] = np.zeros(
                (args.batch, args.seq, cfg.d_model), np.float32)
        state, metrics = step_fn(state, batch)
        slow = monitor.end_step(step)
        if step % 10 == 0 or step == args.steps - 1:
            toks = (step + 1 - start) * args.batch * args.seq
            print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({toks / max(time.time() - t0, 1e-9):.0f} tok/s)"
                  + (" [straggler-flag]" if slow else ""))
        if step % 50 == 49 or preempt.requested:
            checkpoint.save(args.ckpt_dir, step + 1, state)
            if preempt.requested:
                print("preemption requested: emergency checkpoint written")
                break
    preempt.uninstall()
    final = float(metrics["loss"])
    print(f"done: final loss {final:.4f}")


if __name__ == "__main__":
    main()
