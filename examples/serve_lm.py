"""Serve a small LM with batched requests through the slot-based engine.

    PYTHONPATH=src python examples/serve_lm.py --requests 6
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import reduced_config
from repro.models.model import build_model
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, batch_slots=args.slots, max_len=256)

    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size, rng.integers(2, 8)).astype(np.int32)
        engine.submit(Request(uid=uid, prompt=prompt,
                              max_new_tokens=args.max_new, eos_id=-1))
    t0 = time.time()
    results = engine.run()
    dt = time.time() - t0
    total = sum(len(r.tokens) for r in results)
    print(f"served {len(results)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on 1 CPU core)")
    for r in sorted(results, key=lambda r: r.uid):
        print(f"  req {r.uid}: {r.tokens[:8]}{'...' if len(r.tokens) > 8 else ''}")


if __name__ == "__main__":
    main()
