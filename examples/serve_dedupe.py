"""Gazetteer-mode dedupe serving demo: canonical store + streamed probes.

The dedupe-examples gazetteer workload: a canonical reference table is
ingested once (write lane); messy duplicate records then stream in as
probe queries (read lane, ``include_probe=True``) and are matched against
the canonical store WITHOUT joining it. The demo builds a synthetic
corpus with ground-truth entity ids, ingests the first record of each
entity as the canonical table, streams every remaining duplicate through
the ``DedupeService`` in waves, and reports blocking recall (how often
the true entity's canonical record appears among a probe's candidates)
plus the service's own metrics snapshot.

    PYTHONPATH=src python examples/serve_dedupe.py [--entities 1500]
    PYTHONPATH=src python examples/serve_dedupe.py --smoke   # CI-sized
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import blocks as blocks_mod
from repro.core import hdb
from repro.data import synthetic
from repro.serving import DedupeService, ServiceConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--entities", type=int, default=1_500)
    ap.add_argument("--wave", type=int, default=48,
                    help="probe records per submitted request")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny corpus + recall assert (CI smoke step)")
    args = ap.parse_args()
    if args.smoke:
        args.entities = 150

    corpus = synthetic.generate(synthetic.SyntheticSpec(
        num_entities=args.entities, dup_rate=0.5, seed=13))
    keys, valid = blocks_mod.build_keys(corpus.columns, corpus.blocking)
    keys, valid = np.asarray(keys), np.asarray(valid)
    ent = corpus.entity_id

    # canonical table = first record of each entity; probes = the duplicates
    _, first_idx = np.unique(ent, return_index=True)
    is_canon = np.zeros(len(ent), bool)
    is_canon[first_idx] = True
    canon = np.flatnonzero(is_canon)
    probes = np.flatnonzero(~is_canon)
    print(f"gazetteer: {len(canon)} canonical records, "
          f"{len(probes)} streamed probes")

    cfg = hdb.HDBConfig(max_block_size=50, max_iterations=6,
                        cms_width=1 << (12 if args.smoke else 16))
    svc = DedupeService(cfg, ServiceConfig(
        probe_slots=64, ingest_slots=1 << 16, max_read_queue=1 << 16))
    svc.add_tenant("gazetteer")
    svc.submit_ingest("gazetteer", keys[canon], valid[canon])
    svc.run()
    # store rids 0..len(canon)-1 were assigned in canon order
    canon_ent = ent[canon]

    uid_rows = {}
    for off in range(0, len(probes), args.wave):
        idx = probes[off:off + args.wave]
        uid = svc.submit_probe("gazetteer", keys[idx], valid[idx],
                               include_probe=True)
        uid_rows[uid] = idx
    svc.run()

    hit = total = 0
    for resp in svc.probe_responses:
        assert resp.status == "ok"
        for row, qr in zip(uid_rows[resp.uid], resp.results):
            total += 1
            if len(qr.candidates):
                hit += ent[row] in canon_ent[qr.candidates]
    recall = hit / max(total, 1)
    print(f"blocking recall vs canonical store: {hit}/{total} "
          f"({recall:.1%})")

    snap = svc.snapshot()
    counters, hists = snap["counters"], snap["histograms"]
    lat = hists["probe_latency_s"]
    print(f"metrics: {counters['probe_rows_total']} probe rows in "
          f"{counters['probe_batches_total']} padded batches "
          f"({counters['bucket_compiles_total']} bucket shapes), "
          f"p50={lat['p50'] * 1e3:.2f}ms p99={lat['p99'] * 1e3:.2f}ms, "
          f"occupancy={hists['batch_occupancy']['mean']:.2f}")
    if args.smoke and recall < 0.6:
        raise SystemExit(f"smoke recall {recall:.1%} < 60%")


if __name__ == "__main__":
    main()
