"""End-to-end 4-stage dedup pipeline (paper §1): blocking -> pairwise
matching -> graph partitioning -> canonical records, with a blocking-stage
comparison (HDB vs threshold baseline).

    PYTHONPATH=src python examples/dedup_corpus.py [--entities 5000]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core import hdb
from repro.data import pipeline, synthetic


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--entities", type=int, default=5_000)
    ap.add_argument("--max-block-size", type=int, default=100)
    args = ap.parse_args()

    corpus = synthetic.generate(synthetic.SyntheticSpec(
        num_entities=args.entities, seed=7))
    print(f"corpus: {corpus.num_records} records")

    for blocker in ("threshold", "hdb"):
        rep = pipeline.dedup_corpus(
            corpus, hdb.HDBConfig(max_block_size=args.max_block_size),
            blocker=blocker)
        q = pipeline.dedup_quality(rep, corpus)
        print(f"\n[{blocker}] candidates={rep.num_candidate_pairs} "
              f"matched={rep.num_matched_pairs} "
              f"components={rep.num_components}")
        print(f"[{blocker}] block={rep.blocking_seconds:.2f}s "
              f"match={rep.matching_seconds:.2f}s "
              f"partition={rep.partition_seconds:.2f}s")
        print(f"[{blocker}] pair_recall={q['pair_recall']:.4f} "
              f"pair_precision={q['pair_precision']:.4f} "
              f"dedup_ratio={q['dedup_ratio']:.3f}")


if __name__ == "__main__":
    main()
