"""Streaming dedup service demo: micro-batch ingest + candidate queries.

Feeds a synthetic corpus through the StreamingEngine in arrival order,
printing what each micro-batch changed (new candidate pairs, retracted
pairs, dirty rows per HDB level), then issues serving-style probe queries,
and finally verifies the incrementally-maintained candidate-pair ledger
against one batch HDB run on the union.

    PYTHONPATH=src python examples/streaming_dedup.py [--entities 2000]
    PYTHONPATH=src python examples/streaming_dedup.py --smoke   # CI-sized
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import blocks as blocks_mod
from repro.core import hdb, pairs
from repro.data import matcher, synthetic
from repro.streaming import RecordBatch, StreamingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--entities", type=int, default=2_000)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--max-block-size", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny corpus + parity assert (CI smoke step)")
    args = ap.parse_args()
    if args.smoke:
        args.entities, args.batches = 120, 4

    corpus = synthetic.generate(synthetic.SyntheticSpec(
        num_entities=args.entities, seed=7))
    n = corpus.num_records
    cfg = hdb.HDBConfig(max_block_size=args.max_block_size, max_iterations=6,
                        cms_width=1 << (12 if args.smoke else 16))
    print(f"corpus: {n} records arriving in {args.batches} micro-batches")

    eng = StreamingEngine(corpus.blocking, cfg, ingest_slots=4096,
                          matcher_cfg=matcher.MatcherConfig())
    for part in np.array_split(np.arange(n), args.batches):
        eng.submit_ingest(RecordBatch.from_corpus(corpus, part))
        eng.step()
        r = eng.ingest_results[-1]
        rep = r.report
        dirty = ",".join(str(lv.n_dirty_rows) for lv in rep.levels)
        n_match = (int((r.match_scores >= 0.65).sum())
                   if r.match_scores is not None else 0)
        print(f"  ingest +{rep.num_records:5d} records: "
              f"+{len(rep.pairs_added[0]):6d}/-{len(rep.pairs_retracted[0]):4d} "
              f"pairs ({n_match} matched) dirty_rows/level=[{dirty}] "
              f"{rep.seconds:.2f}s")

    # serving-style probes: re-present the first few records as queries
    probe_ids = np.arange(min(4, n))
    eng.submit_query(RecordBatch.from_corpus(corpus, probe_ids))
    eng.run()
    for pid, pr in zip(probe_ids, eng.probe_results):
        r = pr.result
        print(f"  query record {pid}: {len(r.candidates)} candidates from "
              f"{r.n_blocks_hit} blocks ({r.levels_walked} levels walked)")

    got = eng.store.candidate_pairs()
    stats = eng.store.memory_stats()
    print(f"store: {stats['accepted_blocks']} blocks, "
          f"{stats['accepted_assignments']} assignments, "
          f"{stats['ledger_pairs']} candidate pairs")

    # verify against one batch run on the union
    keys, valid = blocks_mod.build_keys(corpus.columns, corpus.blocking)
    res = hdb.hashed_dynamic_blocking(keys, valid, cfg)
    blk = pairs.build_blocks(res)
    want = pairs.dedupe_pairs(blk, budget=blk.num_pair_slots + 1)
    same = (np.array_equal(got.a, want.a) and np.array_equal(got.b, want.b)
            and np.array_equal(got.src_size, want.src_size))
    print(f"batch-parity: {'EXACT' if same else 'MISMATCH'} "
          f"({len(got.a)} vs {len(want.a)} pairs)")
    if not same:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
