"""Quickstart: block a small synthetic product catalog with Hashed Dynamic
Blocking and inspect the quality metrics.

    PYTHONPATH=src python examples/quickstart.py
"""
import logging
import sys

sys.path.insert(0, "src")

# per-iteration [hdb] stats flow through logging (verbose=True -> INFO)
logging.basicConfig(level=logging.INFO, format="%(message)s")

from repro.core import blocks, hdb, pairs
from repro.data import metrics, synthetic


def main():
    # 1) a corpus with planted duplicates + complete ground truth
    corpus = synthetic.generate(synthetic.SyntheticSpec(num_entities=3_000,
                                                        seed=42))
    print(f"corpus: {corpus.num_records} records "
          f"({corpus.num_records - 3_000} duplicates planted)")

    # 2) top-level blocking keys: LSH(6,4) on text columns, identity on
    #    scalar columns (paper §2)
    keys, valid = blocks.build_keys(corpus.columns, corpus.blocking)
    print(f"top-level keys: {keys.shape[1]} per record")

    # 3) Hashed Dynamic Blocking (paper §3, Algorithms 1-4)
    cfg = hdb.HDBConfig(max_block_size=100)
    result = hdb.hashed_dynamic_blocking(keys, valid, cfg, verbose=True)

    # 4) blocks -> deduplicated candidate pairs
    blk = pairs.build_blocks(result)
    pset = pairs.dedupe_pairs(blk)
    print(f"\nblocks: {blk.num_blocks}, largest {int(blk.size.max())}, "
          f"distinct pairs: {len(pset.a)}")

    # 5) quality vs ground truth
    m = metrics.evaluate(result, corpus)
    print(f"PQ (precision) = {m.pq:.4f}   PC (recall) = {m.pc:.4f}")
    assert m.pc > 0.8, "quickstart expects healthy recall"


if __name__ == "__main__":
    main()
