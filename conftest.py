import os
import sys

# Make `import repro` work regardless of how pytest is invoked, and make
# test-local helpers (tests/_propcheck.py) importable from any rootdir.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "tests"))
