import os
import sys

import pytest

# Make `import repro` work regardless of how pytest is invoked, and make
# test-local helpers (tests/_propcheck.py) importable from any rootdir.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "tests"))

# Hot-path test modules that must hold the no-implicit-transfer contract
# (the dynamic counterpart of repro.analysis rule R001): under
# ``--transfer-guard`` these run inside jax.transfer_guard("disallow"),
# which rejects implicit host<->device transfers — numpy arrays passed
# straight into jitted functions, float()/.item()/bool() on device
# arrays — while still allowing the explicit jnp.asarray/np.asarray/
# device_get conversions the drivers are built around.
TRANSFER_GUARDED_MODULES = {
    "test_match_cluster",
    "test_pairs_engine",
    "test_serving",
    "test_sort_radix",
    "test_streaming",
    "test_streaming_sharded",
}


def pytest_addoption(parser):
    parser.addoption(
        "--transfer-guard",
        action="store_true",
        default=False,
        help="run the hot-path test modules (pairs/sort/streaming) under "
        "jax.transfer_guard('disallow') so implicit host transfers fail",
    )


@pytest.fixture(autouse=True)
def _transfer_guard(request):
    module = getattr(request, "module", None)
    if (
        not request.config.getoption("--transfer-guard")
        or module is None
        or module.__name__.split(".")[-1] not in TRANSFER_GUARDED_MODULES
    ):
        yield
        return
    import jax

    with jax.transfer_guard("disallow"):
        yield
